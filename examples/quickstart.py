"""Quickstart: parallel ABC inference of the COVID-19 model in ~1 CPU-minute.

    PYTHONPATH=src python examples/quickstart.py

Samples 100 posterior draws for a synthetic outbreak with known generating
parameters and prints recovery quality — the paper's core loop end to end.
"""

import numpy as np

from repro.core.abc import ABCConfig, run_abc
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset
from repro.epi.model import PARAM_NAMES


def main():
    ds = get_dataset("synthetic_small", num_days=20)
    print(f"dataset: {ds.name}, P={ds.population:.0f}, T={ds.num_days} days")
    print(f"generating theta: {dict(zip(PARAM_NAMES, ds.true_theta))}")

    cfg = ABCConfig(
        batch_size=8192,          # vectorized simulations per run (paper: 100k/IPU)
        tolerance=1.2e4,
        target_accepted=100,
        strategy="outfeed",       # the paper's IPU chunked-outfeed strategy
        chunk_size=1024,
        num_days=20,
        backend="xla_fused",      # fused simulate+distance (no [B,3,T] tensor)
    )
    post = run_abc(ds, cfg, key=0, verbose=True)
    print()
    print(post.summary_table())

    true = np.asarray(ds.true_theta)
    highs = np.asarray(paper_prior().highs)
    err = np.abs(post.theta.mean(0) - true) / highs
    print("\nnormalized |posterior mean - truth| per parameter:")
    for name, e in zip(PARAM_NAMES, err):
        print(f"  {name:>8}: {e:.3f}")
    print(f"  (prior-mean baseline averages ~{np.abs(highs/2 - true).mean()/highs.mean():.2f})")


if __name__ == "__main__":
    main()
