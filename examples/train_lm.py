"""Train a small LM end to end with the production train loop: ZeRO-1 AdamW,
async checkpointing + restart, deterministic data addressing.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the gemma-2b architecture family at reduced width (~M-scale params for
CPU) — same code path the pod configs lower through. Demonstrates the loss
actually decreasing on the learnable synthetic stream, then kills and
resumes from the checkpoint to show the restart contract.
"""

import argparse
import shutil
import tempfile

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    ckdir = tempfile.mkdtemp(prefix="repro_ck_")
    try:
        print("== phase 1: train from scratch, checkpoint every 40 steps ==")
        train_mod.main([
            "--arch", "gemma-2b", "--smoke",
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--lr", "1e-3",
            "--ckpt-dir", ckdir, "--ckpt-every", "40", "--log-every", "20",
        ])
        print("\n== phase 2: simulate preemption -> resume from checkpoint ==")
        final = train_mod.main([
            "--arch", "gemma-2b", "--smoke",
            "--steps", str(args.steps + 40), "--batch", str(args.batch),
            "--seq", str(args.seq), "--lr", "1e-3",
            "--ckpt-dir", ckdir, "--ckpt-every", "40", "--resume",
            "--log-every", "20",
        ])
        print(f"\nresumed training continued to step {args.steps + 40}, "
              f"final loss {final:.4f}")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
