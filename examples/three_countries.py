"""Paper §5 workflow: fit the epidemiology model to three countries, then
simulate forward trajectories from the posterior (Figs 7-9 + Table 8).

    PYTHONPATH=src python examples/three_countries.py [--days 25] [--particles 64]

Produces per-country posterior summaries and 90% predictive bands for the
A/R/D channels over a forward horizon (printed as text sparklines — no
plotting deps in the container).
"""

import argparse

import jax
import numpy as np

from repro.core.smc import SMCConfig, run_smc_abc
from repro.epi import model as em
from repro.epi.data import get_dataset
from repro.epi.model import PARAM_NAMES


def _band(vals, width=40):
    lo, hi = float(np.min(vals)), float(np.max(vals))
    blocks = " .:-=+*#%@"
    out = []
    for v in vals:
        t = 0.0 if hi == lo else (float(v) - lo) / (hi - lo)
        out.append(blocks[min(int(t * (len(blocks) - 1)), len(blocks) - 1)])
    return "".join(out), lo, hi


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=25)
    ap.add_argument("--horizon", type=int, default=60)
    ap.add_argument("--particles", type=int, default=48)
    args = ap.parse_args()

    for country in ("italy", "new_zealand", "usa"):
        ds = get_dataset(country, num_days=args.days)
        print(f"\n=== {country} (P={ds.population:.3g}, fit on {args.days} days) ===")
        post = run_smc_abc(
            ds,
            SMCConfig(n_particles=args.particles, batch_size=4096, n_rounds=3,
                      num_days=args.days),
            key=2,
        )
        mu = post.mean()
        print("posterior means: "
              + "  ".join(f"{p}={mu[p]:.3f}" for p in PARAM_NAMES))
        print(f"final tolerance {post.tolerance:.3g}, "
              f"{post.simulations} simulations, {post.wall_time_s:.1f}s")

        # forward simulation from posterior samples (paper Fig 7)
        cfg = ds.model_config(args.horizon)
        theta = post.theta[: min(len(post), 64)]
        traj = em.simulate_observed(theta, jax.random.PRNGKey(9), cfg)  # [N,3,H]
        for ci, ch in enumerate(("Active", "Recovered", "Deaths")):
            med = np.median(np.asarray(traj[:, ci]), axis=0)
            q05 = np.quantile(np.asarray(traj[:, ci]), 0.05, axis=0)
            q95 = np.quantile(np.asarray(traj[:, ci]), 0.95, axis=0)
            spark, lo, hi = _band(med)
            print(f"  {ch:>9} median [{lo:9.0f}..{hi:9.0f}] {spark}")
            print(f"  {'90% band':>9} day{args.horizon}: "
                  f"[{q05[-1]:.0f}, {q95[-1]:.0f}]")


if __name__ == "__main__":
    main()
