"""Serve a small LM with batched requests through the continuous-batching
decode loop (fixed-shape slots — the serving analogue of the paper's
fixed-shape outfeed).

    PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch import serve as serve_mod


def main():
    for arch in ("gemma-2b", "mamba2-130m"):
        print(f"\n== serving {arch} (reduced config) ==")
        serve_mod.main([
            "--arch", arch, "--smoke",
            "--requests", "8", "--prompt-len", "12", "--gen", "6", "--slots", "4",
        ])


if __name__ == "__main__":
    main()
