"""Amortized inference quickstart: train a tiny NPE, query it, check it
against the ABC oracle — in ~1 CPU-minute.

    PYTHONPATH=src python examples/npe_quickstart.py

Trains a neural posterior estimator (`backend="npe"`, repro.core.npe) on
the `sir` model: ~1e5 tau-leap simulations spent ONCE, after which every
posterior query is a single forward pass (zero simulations). Then runs the
classic ABC fit on the same synthetic outbreak and prints the per-parameter
credible-interval agreement — the accuracy-oracle validation the recovery
tests gate on (tests/test_posterior_recovery.py).
"""

import time

import numpy as np

from repro.core import npe
from repro.core.abc import ABCConfig, run_abc
from repro.epi.data import synthetic_dataset
from repro.epi.models import get_model

TRUTH = (0.5, 0.2, 1.0)  # (beta, gamma, kappa)
DAYS = 15


def interval(theta: np.ndarray, j: int, level: float = 0.90):
    lo = (1.0 - level) / 2.0
    return np.quantile(theta[:, j], [lo, 1.0 - lo])


def main():
    ds = synthetic_dataset(theta=TRUTH, population=1e6, num_days=DAYS,
                           a0=100.0, seed=11, name="npe_quickstart",
                           model="sir")
    print(f"dataset: {ds.name}, P={ds.population:.0f}, T={ds.num_days} days")
    print(f"generating theta: {dict(zip(get_model('sir').param_names, TRUTH))}")

    # -- train once (the amortized phase) ---------------------------------
    cfg = ABCConfig(
        model="sir", num_days=DAYS, backend="npe", target_accepted=256,
        npe=npe.NPEConfig(train_steps=300, train_batch=256, n_pilot=256),
    )
    est = npe.train_npe(ds, cfg, key=0, verbose=True)
    print(f"\ntrained in {est.train_wall_s:.1f}s "
          f"({est.train_sims} simulations, spent once)")

    # -- query many (each one is a forward pass) --------------------------
    t0 = time.perf_counter()
    npe_post = est.sample_posterior(ds.observed, 256, key=1)
    print(f"posterior query: {time.perf_counter() - t0:.3f}s, "
          f"0 simulations\n")
    print(npe_post.summary_table())

    # -- the ABC oracle ---------------------------------------------------
    from repro.core.abc import calibrate_tolerance

    pilot = ABCConfig(batch_size=4096, tolerance=1.0, num_days=DAYS,
                      strategy="topk", top_k=1, chunk_size=4096,
                      backend="xla_fused", model="sir")
    eps = calibrate_tolerance(ds, pilot, key=0, quantile=5e-3)
    abc_cfg = ABCConfig(batch_size=4096, tolerance=eps, target_accepted=100,
                        chunk_size=4096, max_runs=60, num_days=DAYS,
                        backend="xla_fused", model="sir")
    abc_post = run_abc(ds, abc_cfg, key=0)

    print("\nNPE vs ABC-oracle 90% credible intervals:")
    spec = get_model("sir")
    width = np.asarray(spec.prior().highs) - np.asarray(spec.prior().lows)
    for j, name in enumerate(npe_post.param_names):
        n_lo, n_hi = interval(npe_post.theta, j)
        a_lo, a_hi = interval(abc_post.theta, j)
        overlap = min(n_hi, a_hi) - max(n_lo, a_lo)
        drift = abs(npe_post.theta[:, j].mean()
                    - abc_post.theta[:, j].mean()) / width[j]
        tick = "OK " if overlap > 0 and drift < 0.25 else "?? "
        print(f"  {tick}{name:>6}: npe [{n_lo:.3f}, {n_hi:.3f}]  "
              f"abc [{a_lo:.3f}, {a_hi:.3f}]  "
              f"mean drift {drift:.3f} of prior width")
    print(f"\nABC spent {abc_post.simulations} simulations for THIS "
          f"observation; the estimator answers any same-shape observation "
          f"without new ones.")


if __name__ == "__main__":
    main()
