"""Cross-model sweep: ABC posterior recovery for EVERY registered model.

    PYTHONPATH=src python examples/model_zoo.py [--backend xla_fused]

For each registry entry (siard — the paper model —, sir, seir, seiard) this
generates a synthetic outbreak from the model's `default_theta`, calibrates a
tolerance from a pilot wave, runs parallel ABC rejection to 50 accepted
samples, and reports normalized recovery error — the model-comparison
workflow the stoichiometry-driven engine exists to serve.
"""

import argparse
import dataclasses

import numpy as np

from repro.core.abc import ABCConfig, calibrate_tolerance, run_abc
from repro.epi.data import get_dataset
from repro.epi.models import get_model, list_models

DAYS = 15


def run_one(name: str, backend: str):
    spec = get_model(name)
    ds = get_dataset("synthetic_small", num_days=DAYS, model=name)
    cfg = ABCConfig(
        batch_size=4096,
        tolerance=1.0,  # replaced by the calibrated epsilon below
        target_accepted=50,
        strategy="outfeed",
        chunk_size=512,
        max_runs=60,
        num_days=DAYS,
        backend=backend,
        model=name,
    )
    eps = calibrate_tolerance(ds, cfg, key=1, quantile=2e-2, n_pilot=4096)
    post = run_abc(ds, dataclasses.replace(cfg, tolerance=eps), key=0)
    true = np.asarray(ds.true_theta)
    highs = np.asarray(spec.prior().highs)
    err = float(np.mean(np.abs(post.theta.mean(0) - true) / highs))
    prior_err = float(np.mean(np.abs(highs / 2 - true) / highs))
    return post, eps, err, prior_err


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla_fused",
                    choices=["xla", "xla_fused", "pallas"])
    args = ap.parse_args(argv)

    print(f"{'model':>8} | {'p':>2} | {'eps':>10} | {'N':>4} | "
          f"{'sims':>7} | {'err':>6} | {'prior err':>9}")
    print("-" * 64)
    for name in list_models():
        post, eps, err, prior_err = run_one(name, args.backend)
        spec = get_model(name)
        print(f"{name:>8} | {spec.n_params:>2} | {eps:>10.4g} | {len(post):>4} | "
              f"{post.simulations:>7} | {err:>6.3f} | {prior_err:>9.3f}")
    print("\nerr = mean normalized |posterior mean - truth|; "
          "smaller than 'prior err' means the posterior concentrated.")


if __name__ == "__main__":
    main()
