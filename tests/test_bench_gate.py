"""The benchmark-regression gate must trip on synthetic regressions and pass
on the committed baselines (tests for tests/check_bench_regression.py)."""

import copy
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent))
from check_bench_regression import (  # noqa: E402
    BASELINE_DIR,
    FRESH_DIR,
    SCHEMA,
    compare_artifacts,
    evaluate_dirs,
    main,
)


def _artifact(wall=1.0, sims=100, eff=0.5):
    return {
        "benchmark": "demo",
        "schema": SCHEMA,
        "meta": {},
        "cells": {
            "siard/xla_fused/n1": {"wall_s": wall, "sims_per_s": sims / wall,
                                   "roofline_efficiency": eff},
            "siard/xla_fused/n2": {"wall_s": wall * 2},
        },
        "parity": {"siard/xla_fused/n1": {"simulations": sims, "devices": 1}},
    }


def _dirs(tmp_path, baseline, fresh):
    bdir, fdir = tmp_path / "baselines", tmp_path / "fresh"
    bdir.mkdir(), fdir.mkdir()
    (bdir / "demo.json").write_text(json.dumps(baseline))
    if fresh is not None:
        (fdir / "demo.json").write_text(json.dumps(fresh))
    return bdir, fdir


def test_identical_artifacts_pass(tmp_path):
    base = _artifact()
    bdir, fdir = _dirs(tmp_path, base, copy.deepcopy(base))
    problems, _ = evaluate_dirs(bdir, fdir)
    assert problems == []


def test_synthetic_2x_slowdown_trips(tmp_path):
    """The acceptance criterion: a synthetically slowed JSON must fail."""
    bdir, fdir = _dirs(tmp_path, _artifact(wall=1.0), _artifact(wall=2.0))
    problems, _ = evaluate_dirs(bdir, fdir)
    assert len(problems) == 2  # both cells doubled
    assert all("wall-clock regression" in p for p in problems)
    # and through the CLI entry point
    assert main(["--baseline-dir", str(bdir), "--fresh-dir", str(fdir)]) == 1


def test_slowdown_below_threshold_passes(tmp_path):
    bdir, fdir = _dirs(tmp_path, _artifact(wall=1.0), _artifact(wall=1.2))
    problems, _ = evaluate_dirs(bdir, fdir)
    assert problems == []
    # a tighter threshold flips it
    problems, _ = evaluate_dirs(bdir, fdir, threshold=0.1)
    assert problems and "wall-clock regression" in problems[0]


def test_synthetic_efficiency_only_regression_trips(tmp_path):
    """The ISSUE 6 acceptance criterion: a cell whose wall clock is FINE but
    whose roofline_efficiency collapsed (same time, much less useful work —
    e.g. the cell silently simulates fewer days) must fail the gate."""
    fresh = _artifact(wall=1.0, eff=0.1)  # wall unchanged, eff 0.5 -> 0.1
    bdir, fdir = _dirs(tmp_path, _artifact(wall=1.0, eff=0.5), fresh)
    problems, _ = evaluate_dirs(bdir, fdir)
    assert len(problems) == 1
    assert "roofline-efficiency regression" in problems[0]
    # and through the CLI entry point
    assert main(["--baseline-dir", str(bdir), "--fresh-dir", str(fdir)]) == 1


def test_efficiency_drop_below_threshold_passes(tmp_path):
    bdir, fdir = _dirs(tmp_path, _artifact(eff=0.5), _artifact(eff=0.45))
    problems, _ = evaluate_dirs(bdir, fdir)
    assert problems == []
    # a tighter efficiency threshold flips it; efficiency GAINS never trip
    problems, _ = evaluate_dirs(bdir, fdir, eff_threshold=0.05)
    assert problems and "roofline-efficiency regression" in problems[0]
    up = tmp_path / "up"
    up.mkdir()
    problems, _ = evaluate_dirs(*_dirs(up, _artifact(eff=0.5),
                                       _artifact(eff=0.9)))
    assert problems == []


def test_lost_efficiency_instrumentation_trips(tmp_path):
    """A baselined cell that stops reporting roofline_efficiency is a gate
    failure even with --allow-missing: losing the instrumentation would
    silently un-gate the efficiency dimension."""
    fresh = _artifact()
    del fresh["cells"]["siard/xla_fused/n1"]["roofline_efficiency"]
    bdir, fdir = _dirs(tmp_path, _artifact(), fresh)
    problems, _ = evaluate_dirs(bdir, fdir)
    assert len(problems) == 1
    assert "lost its roofline_efficiency" in problems[0]
    problems, _ = evaluate_dirs(bdir, fdir, allow_missing=True)
    assert len(problems) == 1 and "lost its roofline_efficiency" in problems[0]


def test_parity_drift_trips(tmp_path):
    fresh = _artifact()
    fresh["parity"]["siard/xla_fused/n1"]["simulations"] = 101
    bdir, fdir = _dirs(tmp_path, _artifact(), fresh)
    problems, _ = evaluate_dirs(bdir, fdir)
    assert len(problems) == 1 and "parity drift" in problems[0]


def test_speedup_and_new_cells_pass(tmp_path):
    fresh = _artifact(wall=0.5)  # faster is never a regression
    fresh["cells"]["new/cell"] = {"wall_s": 9.9}
    fresh["parity"]["new/cell"] = 1
    bdir, fdir = _dirs(tmp_path, _artifact(), fresh)
    problems, notes = evaluate_dirs(bdir, fdir)
    assert problems == []
    assert any("new cell" in n for n in notes)


def test_missing_fresh_artifact_trips_unless_allowed(tmp_path):
    bdir, fdir = _dirs(tmp_path, _artifact(), None)
    problems, _ = evaluate_dirs(bdir, fdir)
    assert problems and "no fresh artifact" in problems[0]
    problems, notes = evaluate_dirs(bdir, fdir, allow_missing=True)
    # nothing was gated, so the gate refuses to claim success silently
    assert problems == ["no bench-artifact/v1 baseline/fresh artifact "
                        "pairs were gated"]
    assert any("no fresh artifact" in n for n in notes)


def test_vanished_cell_trips_unless_allowed(tmp_path):
    fresh = _artifact()
    del fresh["cells"]["siard/xla_fused/n2"]
    bdir, fdir = _dirs(tmp_path, _artifact(), fresh)
    problems, _ = evaluate_dirs(bdir, fdir)
    assert len(problems) == 1 and "missing from the fresh run" in problems[0]
    problems, notes = evaluate_dirs(bdir, fdir, allow_missing=True)
    assert problems == []
    assert any("missing from the fresh run" in n for n in notes)


def test_legacy_baseline_is_skipped_not_gated(tmp_path):
    legacy = {"some": "old", "payload": True}
    bdir, fdir = _dirs(tmp_path, legacy, legacy)
    problems, notes = evaluate_dirs(bdir, fdir)
    # a dir holding ONLY ungateable artifacts must not silently pass
    assert problems == ["no bench-artifact/v1 baseline/fresh artifact "
                        "pairs were gated"]
    assert any("skipped" in n for n in notes)


def test_fresh_artifact_lost_envelope_trips():
    base = _artifact()
    problems, _ = compare_artifacts("demo.json", base, {"schema": None})
    assert problems and "not bench-artifact/v1" in problems[0]


@pytest.mark.skipif(not BASELINE_DIR.exists(),
                    reason="no committed baselines in this checkout")
def test_committed_baselines_pass_against_themselves():
    """The committed baseline set must pass the gate against the committed
    fresh artifacts (the nightly's state right after a baseline refresh)."""
    problems, _ = evaluate_dirs(BASELINE_DIR, FRESH_DIR)
    assert problems == [], problems
