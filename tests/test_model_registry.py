"""Per-model invariants for the stoichiometry-driven engine + registry.

Every registered `CompartmentalModel` must satisfy the tau-leap contract
(mass conservation, non-negativity, determinism) through the generic engine,
agree between its XLA / fused / Pallas formulations, and run end-to-end
through `run_abc`. The SIARD entry is additionally pinned bit-for-bit to a
standalone copy of the legacy hand-unrolled implementation so the refactor
can never silently change the paper reproduction.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abc import ABCConfig, ABCState, run_abc
from repro.epi import engine
from repro.epi.models import get_model, list_models
from repro.epi.spec import CompartmentalModel, EpiModelConfig

CFG = EpiModelConfig(population=1e6, num_days=12, a0=100.0, r0=5.0, d0=1.0)

ALL_MODELS = list_models()


def _theta(model, batch=16, seed=0):
    return get_model(model).prior().sample(jax.random.PRNGKey(seed), (batch,))


# ------------------------------------------------------------ registry basics
def test_registry_contains_paper_model_and_three_more():
    assert "siard" in ALL_MODELS
    assert {"sir", "seir", "seiard"} <= set(ALL_MODELS)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_spec_is_consistent(name):
    m = get_model(name)
    assert m.n_params == len(m.param_names) == len(m.prior_highs)
    assert m.n_state == len(m.compartments)
    assert m.n_transitions == len(m.stoichiometry) == len(m.transition_sources)
    assert all(0 <= j < m.n_state for j in m.observed_idx)
    assert m.prior().dim == m.n_params
    assert len(m.default_theta) == m.n_params
    # every stoichiometry row conserves mass by construction
    assert all(sum(row) == 0 for row in m.stoichiometry)
    assert m.describe().startswith(f"model {name}")


def test_spec_validation_rejects_bad_rows():
    with pytest.raises(ValueError, match="conserve"):
        CompartmentalModel(
            name="bad",
            compartments=("S", "I"),
            param_names=("beta",),
            prior_highs=(1.0,),
            stoichiometry=((-1, 0),),  # loses mass
            observed=("I",),
            hazard_rows=lambda sc, pc, p: (pc[0] * sc[0],),
            initial_rows=lambda pc, p, a0, r0, d0: (p - a0, a0 + 0 * pc[0]),
            default_theta=(0.5,),
        )


# ---------------------------------------------------- per-model invariants
@pytest.mark.parametrize("name", ALL_MODELS)
def test_mass_conservation_and_nonnegativity(name):
    m = get_model(name)
    th = _theta(name, batch=64, seed=3)
    traj = engine.simulate(m, th, jax.random.PRNGKey(2), CFG)
    # region-major flattened channel axis (== n_state for R=1 models)
    assert traj.shape == (64, CFG.num_days, m.total_state)
    assert bool(jnp.all(jnp.isfinite(traj)))
    assert float(jnp.min(traj)) >= 0.0
    total = jnp.sum(traj, axis=-1)
    init_total = jnp.sum(engine.initial_state(m, th, CFG), axis=-1)
    expected = np.broadcast_to(np.asarray(init_total)[:, None], total.shape)
    np.testing.assert_allclose(np.asarray(total), expected, rtol=1e-6)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_deterministic_under_fixed_key(name):
    m = get_model(name)
    th = _theta(name, batch=8)
    a = engine.simulate(m, th, jax.random.PRNGKey(42), CFG)
    b = engine.simulate(m, th, jax.random.PRNGKey(42), CFG)
    assert bool(jnp.all(a == b))
    c = engine.simulate(m, th, jax.random.PRNGKey(43), CFG)
    assert not bool(jnp.all(a == c))


@pytest.mark.parametrize("name", ALL_MODELS)
def test_fused_distance_matches_full_trajectory(name):
    m = get_model(name)
    th = _theta(name, batch=16, seed=7)
    key = jax.random.PRNGKey(11)
    obs_ref = engine.simulate_observed(m, th, key, CFG)  # [B, n_obs, T]
    observed = obs_ref[0]
    d_full = jnp.sqrt(
        jnp.sum((obs_ref - observed[None]) ** 2, axis=(-2, -1))
    )
    d_fused, state_f = engine.simulate_observed_lowmem(m, th, key, CFG, observed)
    np.testing.assert_allclose(np.asarray(d_full), np.asarray(d_fused), rtol=1e-5)
    assert float(d_fused[0]) == 0.0  # self-distance exactly zero
    assert state_f.shape == (16, m.total_state)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_pallas_kernel_matches_oracle(name):
    from repro.kernels import ops, ref

    m = get_model(name)
    obs = engine.simulate_observed(
        m, jnp.asarray([m.default_theta], jnp.float32), jax.random.PRNGKey(0), CFG
    )[0]
    th = _theta(name, batch=256, seed=5)
    kw = dict(population=CFG.population, a0=CFG.a0, r0=CFG.r0, d0=CFG.d0, model=m)
    d_k = ops.abc_sim_distance(th, jnp.uint32(7), obs, tile=128, interpret=True, **kw)
    d_r = ref.abc_sim_distance_ref(th, jnp.uint32(7), obs, **kw)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-6, atol=1e-3)


# ------------------------------------------------- legacy SIARD equivalence
def _legacy_siard_simulate(theta, key, cfg):
    """Standalone copy of the pre-refactor hand-unrolled SIARD step, kept
    here verbatim so the generic engine stays pinned to it bit-for-bit."""
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    kappa = theta[..., 7]
    a0 = jnp.asarray(cfg.a0, jnp.float32)
    r0 = jnp.asarray(cfg.r0, jnp.float32)
    d0 = jnp.asarray(cfg.d0, jnp.float32)
    i0 = kappa * a0
    s0 = cfg.population - (a0 + r0 + d0 + i0)
    zeros = jnp.zeros_like(kappa)
    state0 = jnp.stack(
        [s0, i0, zeros + a0, zeros + r0, zeros + d0, zeros], axis=-1
    ).astype(jnp.float32)

    def hazards(state, theta):
        s, i, a = state[..., 0], state[..., 1], state[..., 2]
        ard = state[..., 2] + state[..., 3] + state[..., 4]
        alpha0, alpha, n = theta[..., 0], theta[..., 1], theta[..., 2]
        g = alpha0 + alpha / (1.0 + jnp.power(jnp.maximum(ard, 0.0), n))
        beta, gamma, delta, eta = (
            theta[..., 3], theta[..., 4], theta[..., 5], theta[..., 6],
        )
        h = jnp.stack(
            [g * s * i / cfg.population, gamma * i, beta * a, delta * a,
             beta * eta * i],
            axis=-1,
        )
        return jnp.maximum(h, 0.0)

    def step(state, day):
        z = jax.random.normal(
            jax.random.fold_in(key, day), batch_shape + (5,), jnp.float32
        )
        h = hazards(state, theta)
        n_raw = jnp.floor(h + jnp.sqrt(h) * z)
        s, i, a, r, d, ru = (state[..., k] for k in range(6))
        n1 = jnp.clip(n_raw[..., 0], 0.0, s)
        n2 = jnp.clip(n_raw[..., 1], 0.0, i)
        n5 = jnp.clip(n_raw[..., 4], 0.0, i - n2)
        n3 = jnp.clip(n_raw[..., 2], 0.0, a)
        n4 = jnp.clip(n_raw[..., 3], 0.0, a - n3)
        nxt = jnp.stack(
            [s - n1, i + n1 - n2 - n5, a + n2 - n3 - n4, r + n3, d + n4,
             ru + n5],
            axis=-1,
        )
        return nxt, nxt

    _, traj = jax.lax.scan(step, state0, jnp.arange(cfg.num_days))
    return jnp.moveaxis(traj, 0, -2)


def test_generic_engine_pins_legacy_siard_bit_for_bit():
    m = get_model("siard")
    th = _theta("siard", batch=32, seed=9)
    key = jax.random.PRNGKey(17)
    new = engine.simulate(m, th, key, CFG)
    old = _legacy_siard_simulate(th, key, CFG)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


# ------------------------------------------------------ end-to-end inference
@pytest.mark.parametrize("name", ALL_MODELS)
@pytest.mark.parametrize("backend", ["xla", "xla_fused"])
def test_run_abc_end_to_end(name, backend):
    from repro.core.abc import calibrate_tolerance
    from repro.epi.data import get_dataset

    ds = get_dataset("synthetic_small", num_days=10, model=name)
    cfg = ABCConfig(
        batch_size=512, tolerance=1.0, target_accepted=5, chunk_size=128,
        max_runs=10, num_days=10, backend=backend, model=name,
    )
    eps = calibrate_tolerance(ds, cfg, key=1, quantile=0.05, n_pilot=512)
    post = run_abc(ds, dataclasses.replace(cfg, tolerance=eps), key=0)
    assert len(post) >= 5
    assert post.theta.shape[1] == get_model(name).n_params
    assert post.param_names == get_model(name).param_names


def test_abc_state_empty_arrays_derive_param_dim():
    """Regression: to_arrays used to return a hardcoded np.zeros((0, 8))."""
    for name in ("sir", "seiard"):
        m = get_model(name)
        st = ABCState(n_params=m.n_params)
        th, d = st.to_arrays()
        assert th.shape == (0, m.n_params)
        assert d.shape == (0,)


def test_abc_state_roundtrip_preserves_param_dim(tmp_path):
    st = ABCState(n_params=3)
    path = str(tmp_path / "state.npz")
    st.save(path)
    loaded = ABCState.load(path)
    assert loaded.n_params == 3
    assert loaded.to_arrays()[0].shape == (0, 3)


def test_dataset_model_mismatch_rejected():
    from repro.core.abc import make_simulator
    from repro.epi.data import get_dataset

    ds = get_dataset("synthetic_small", num_days=10, model="sir")
    cfg = ABCConfig(batch_size=256, num_days=10, model="siard", chunk_size=256)
    with pytest.raises(ValueError, match="observes different channels"):
        make_simulator(ds, cfg)
