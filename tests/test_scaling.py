"""Scaling-study executor tests: the pinned contract is that sharding NEVER
changes the statistics — an N-device shard_map wave loop produces per-shard
accepted sets BIT-IDENTICAL to the same-seed 1-device lockstep run of the
same N-shard program (`scaling.make_reference_wave_runner`). Wall clock is
the only thing a device count may change."""

import hashlib
import json

import jax
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro.core.abc import ABCConfig, ABCState
from repro.core.scaling import (
    ScalingConfig,
    device_mesh,
    format_report,
    make_reference_wave_runner,
    run_scaling_study,
)
from repro.epi.data import get_dataset
from repro.epi.models import get_model

DAYS = 12
N_SHARDS = 8

# one config, shared VERBATIM by the parent-process reference run and the
# subprocess shard_map run — any drift would void the bit-identity pin
_CFG_KW = dict(
    batch_size=2048, tolerance=3.4e3, target_accepted=60, chunk_size=2048,
    max_runs=6, num_days=DAYS, backend="xla_fused", wave_loop="device",
)


def _digest(out) -> str:
    h = hashlib.sha256()
    for a in (out.theta_buf, out.dist_buf, out.fill_counts):
        h.update(np.asarray(a).tobytes())
    h.update(np.int64(int(out.n_accepted)).tobytes())
    h.update(np.int64(int(out.waves_done)).tobytes())
    return h.hexdigest()


def _reference_digest() -> str:
    from repro.core.abc import make_simulator

    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = ABCConfig(**_CFG_KW)
    prior = get_model(cfg.model).prior()
    ref = make_reference_wave_runner(
        prior, make_simulator(ds, cfg), cfg, n_shards=N_SHARDS
    )
    out = ref(jax.random.PRNGKey(0), 0, ref.init(ABCState(n_params=prior.dim)),
              cfg.max_runs)
    return _digest(out)


def test_n_device_accepted_sets_bit_identical_to_one_device_run():
    """THE acceptance criterion: the same-seed accepted sets of the 8-device
    shard_map wave loop (simulated host devices, own subprocess) and the
    1-device run of the same 8-shard program (this process) are bit-identical
    per shard — buffers, fills, totals and wave counts all hash equal."""
    code = f"""
import hashlib, jax, numpy as np
from repro.core.abc import ABCConfig, ABCState, make_simulator
from repro.core import distributed
from repro.core.scaling import device_mesh, make_reference_wave_runner
from repro.epi.data import get_dataset
from repro.epi.models import get_model

assert len(jax.devices()) == {N_SHARDS}
ds = get_dataset("synthetic_small", num_days={DAYS})
cfg = ABCConfig(**{_CFG_KW!r})
prior = get_model(cfg.model).prior()

wr = distributed.make_wave_runner(device_mesh({N_SHARDS}), ds, cfg,
                                  style="shard_map")
out = wr(jax.random.PRNGKey(0), 0, wr.init(ABCState(n_params=prior.dim)),
         cfg.max_runs)

# in-subprocess cross-check against the lockstep reference on one device
ref = make_reference_wave_runner(prior, make_simulator(ds, cfg), cfg,
                                 n_shards={N_SHARDS})
ref_out = ref(jax.random.PRNGKey(0), 0,
              ref.init(ABCState(n_params=prior.dim)), cfg.max_runs)
np.testing.assert_array_equal(np.asarray(out.fill_counts),
                              np.asarray(ref_out.fill_counts))
np.testing.assert_array_equal(np.asarray(out.theta_buf),
                              np.asarray(ref_out.theta_buf))
np.testing.assert_array_equal(np.asarray(out.dist_buf),
                              np.asarray(ref_out.dist_buf))
assert int(out.n_accepted) == int(ref_out.n_accepted) > 0
assert int(out.waves_done) == int(ref_out.waves_done)

h = hashlib.sha256()
for a in (out.theta_buf, out.dist_buf, out.fill_counts):
    h.update(np.asarray(a).tobytes())
h.update(np.int64(int(out.n_accepted)).tobytes())
h.update(np.int64(int(out.waves_done)).tobytes())
print("DIGEST", h.hexdigest())
"""
    stdout = run_in_subprocess(code, n_devices=N_SHARDS)
    sharded_digest = stdout.split("DIGEST")[1].strip()
    assert sharded_digest == _reference_digest()


def test_reference_runner_multi_shard_on_this_process():
    """The lockstep reference is usable wherever run_abc is: multi-shard
    buffers harvest into a posterior with every shard's accepts."""
    from repro.core.abc import make_simulator, run_abc

    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = ABCConfig(**_CFG_KW)
    prior = get_model(cfg.model).prior()
    ref = make_reference_wave_runner(
        prior, make_simulator(ds, cfg), cfg, n_shards=4
    )
    post = run_abc(ds, cfg, key=0, wave_runner=ref)
    assert len(post) >= cfg.target_accepted
    assert np.isfinite(post.distances).all()


def test_reference_runner_rejects_uneven_shards():
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = ABCConfig(**{**_CFG_KW, "batch_size": 2047, "chunk_size": 2047})
    prior = get_model(cfg.model).prior()
    from repro.core.abc import make_simulator

    with pytest.raises(ValueError, match="not divisible"):
        make_reference_wave_runner(prior, make_simulator(ds, cfg), cfg,
                                   n_shards=4)


def test_device_mesh_prefix_subsets_and_overflow():
    mesh = device_mesh(1)
    assert mesh.devices.shape == (1,)
    assert mesh.axis_names == ("data",)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        device_mesh(len(jax.devices()) + 1)


def test_scaling_config_validation():
    with pytest.raises(ValueError, match="non-empty"):
        ScalingConfig(device_counts=())
    with pytest.raises(ValueError, match="style"):
        ScalingConfig(style="magic")


def test_scaling_study_single_count_metrics():
    """The smallest device count is the efficiency reference: its cell must
    read efficiency 1 / overhead 0, and every cell carries the headline
    metrics with the fixed simulation budget."""
    scfg = ScalingConfig(
        device_counts=(1,), models=("sir",), batch_per_device=512,
        waves=2, num_days=DAYS, reps=1,
    )
    rep = run_scaling_study(scfg)
    key = "sir/xla_fused/b512/n1"
    cell = rep["cells"][key]
    assert cell["parallel_efficiency"] == 1.0
    assert cell["scaling_overhead_pct"] == 0.0
    assert cell["simulations"] == 2 * 512  # waves x global batch, pinned
    assert cell["sims_per_s"] > 0
    table = format_report(rep)
    assert "overhead_%" in table and "sir" in table
    json.dumps(rep)  # the report must be JSON-serializable as-is


def test_scaling_study_multi_count_in_subprocess():
    """Device counts 1..4 on simulated host devices: weak-scaling budgets
    (simulations scale with n) and well-formed efficiency metrics."""
    out = run_in_subprocess(
        f"""
import jax
from repro.core.scaling import ScalingConfig, run_scaling_study
scfg = ScalingConfig(device_counts=(1, 2, 4), models=("sir",),
                     batch_per_device=256, waves=2, num_days={DAYS}, reps=1)
rep = run_scaling_study(scfg)
for n in (1, 2, 4):
    cell = rep["cells"][f"sir/xla_fused/b256/n{{n}}"]
    assert cell["simulations"] == 2 * 256 * n, cell
    assert 0 < cell["parallel_efficiency"] <= 1.5  # noise tolerance at n=1
    assert cell["waves"] == 2
print("OK", rep["cells"]["sir/xla_fused/b256/n4"]["scaling_overhead_pct"])
""",
        n_devices=4,
    )
    assert "OK" in out


def test_sharded_smc_full_population_and_determinism():
    """SMC rounds under the scaling study's sharding: full particle refresh,
    finite distances, deterministic in (key, mesh shape)."""
    out = run_in_subprocess(
        f"""
import jax, numpy as np
from jax.sharding import Mesh
from repro.core.smc import SMCConfig, run_smc_abc
from repro.epi.data import get_dataset
ds = get_dataset("synthetic_small", num_days={DAYS})
mesh = Mesh(np.asarray(jax.devices()), ("data",))
cfg = SMCConfig(n_particles=48, batch_size=1024, n_rounds=2,
                num_days={DAYS}, wave_loop="device")
a = run_smc_abc(ds, cfg, key=0, mesh=mesh)
b = run_smc_abc(ds, cfg, key=0, mesh=mesh)
assert len(a) == 48 and np.isfinite(a.distances).all()
np.testing.assert_array_equal(a.theta, b.theta)
# the sharded rounds must actually tighten the tolerance like the others
single = run_smc_abc(ds, cfg, key=0)
assert a.tolerance <= 1.5 * single.tolerance
try:
    run_smc_abc(ds, SMCConfig(wave_loop="host"), key=0, mesh=mesh)
except ValueError as e:
    assert "wave_loop" in str(e)
else:
    raise AssertionError("host loop + mesh should be rejected")
print("OK", a.tolerance)
""",
        n_devices=4,
    )
    assert "OK" in out


def test_campaign_disjoint_device_groups():
    """devices_per_scenario=2 on 4 devices: two scenarios advance
    concurrently on DISJOINT 2-device groups, complete, and resume."""
    out = run_in_subprocess(
        f"""
import tempfile
from repro.core.campaign import CampaignConfig, run_campaign
with tempfile.TemporaryDirectory() as td:
    cfg = CampaignConfig(
        datasets=("italy", "usa"), models=("siard",), batch_size=1024,
        num_days={DAYS}, target_accepted=20, max_runs=300,
        auto_quantile=2e-3, out_dir=td, checkpoint_every=4,
        devices_per_scenario=2,
    )
    rep = run_campaign(cfg)
    statuses = [r.status for r in rep.scenarios]
    assert statuses == ["ok", "ok"], statuses
    groups = [r.device for r in rep.scenarios]
    assert groups == ["0+1", "2+3"], groups  # disjoint round-robin groups
    assert all(r.n_accepted >= 20 for r in rep.scenarios)
    rep2 = run_campaign(cfg)
    assert [r.status for r in rep2.scenarios] == ["resumed_complete"] * 2
print("OK")
""",
        n_devices=4,
    )
    assert "OK" in out
