"""ABCState checkpoint durability: atomic save, loud rejection of corruption.

A campaign interrupted mid-save must never leave a truncated checkpoint at
the target path (satellite of the campaign subsystem: resume reads these
files unattended, so a silent partial read would poison a whole scenario).
"""

import os

import numpy as np
import pytest

from repro.core.abc import ABCState


def _state(n=7, p=4):
    st = ABCState(run_idx=3, simulations=3000, n_params=p)
    rng = np.random.default_rng(0)
    st.accepted_theta = [rng.normal(size=(n, p)).astype(np.float32)]
    st.accepted_dist = [rng.random(n).astype(np.float32)]
    return st


def test_save_load_roundtrip(tmp_path):
    path = str(tmp_path / "state.npz")
    st = _state()
    st.save(path)
    back = ABCState.load(path)
    assert back.run_idx == 3 and back.simulations == 3000
    np.testing.assert_array_equal(back.to_arrays()[0], st.to_arrays()[0])
    np.testing.assert_array_equal(back.to_arrays()[1], st.to_arrays()[1])


def test_save_leaves_no_temp_files(tmp_path):
    path = str(tmp_path / "state.npz")
    _state().save(path)
    _state(n=9).save(path)  # overwrite goes through rename too
    assert sorted(os.listdir(tmp_path)) == ["state.npz"]


def test_truncated_checkpoint_rejected_with_clear_error(tmp_path):
    path = str(tmp_path / "state.npz")
    _state().save(path)
    raw = open(path, "rb").read()
    with open(path, "wb") as f:  # simulate a non-atomic partial write
        f.write(raw[: len(raw) // 2])
    with pytest.raises(ValueError, match="corrupt or incomplete"):
        ABCState.load(path)


def test_garbage_checkpoint_rejected(tmp_path):
    path = str(tmp_path / "state.npz")
    with open(path, "wb") as f:
        f.write(b"this is not an npz file")
    with pytest.raises(ValueError, match="corrupt or incomplete"):
        ABCState.load(path)


def test_missing_arrays_rejected(tmp_path):
    path = str(tmp_path / "state.npz")
    np.savez(open(path, "wb"), run_idx=1)  # valid zip, wrong contents
    with pytest.raises(ValueError, match="corrupt or incomplete"):
        ABCState.load(path)


def test_crash_during_save_preserves_previous_checkpoint(tmp_path, monkeypatch):
    """If serialization dies mid-way, the previous complete file survives and
    the temp file is cleaned up."""
    path = str(tmp_path / "state.npz")
    _state(n=5).save(path)
    good = open(path, "rb").read()

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError):
        _state(n=9).save(path)
    assert open(path, "rb").read() == good
    assert sorted(os.listdir(tmp_path)) == ["state.npz"]
