"""Spatial metapopulation acceptance surface (the region-axis refactor).

Four layers of protection:

  * R=1 BIT-IDENTITY — every pre-metapop registered model must produce
    byte-for-byte the distances frozen in tests/data/r1_pins.npz (captured
    against the pre-refactor tree) on all four compute paths. The region
    axis is a refactor, not a fork: single-region users get the exact same
    streams.
  * mobility validation — malformed matrices fail loudly at spec
    construction, never silently renormalize.
  * coupling correctness — identity mobility factorizes into R independent
    single-region runs (same noise slices, exact equality), and the R=4
    metapop_seir kernel matches its hash-RNG oracle / the XLA paths.
  * end-to-end — ABC posterior recovery on metapop_seir, and a 100-region
    campaign smoke driving the shape cache with spec-object scenarios.
"""

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abc import ABCConfig, make_simulator, resolved_mobility, run_abc
from repro.core.summaries import (
    get_summary,
    lower_summary,
    summary_distance,
)
from repro.epi import engine
from repro.epi.data import get_dataset, synthetic_dataset
from repro.epi.models import get_model
from repro.epi.spec import (
    EpiModelConfig,
    identity_mobility,
    make_mobility,
    regionalize,
    validate_mobility,
)
from repro.kernels import abc_sim, ops, ref

PINS = Path(__file__).parent / "data" / "r1_pins.npz"

# the capture-time constants of tests/data/capture_r1_pins.py — changing
# them here would recompute different quantities than the frozen pins
PIN_BATCH, PIN_DAYS, PIN_SEED, PIN_KEY = 16, 14, 123, 5


# ---------------------------------------------------------------------------
# R=1 bit-identity: the refactor must not move a single bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["seiard", "seir", "siard", "sir"])
def test_r1_bit_identity_pins(model):
    """All four compute paths reproduce the pre-metapop golden distances
    EXACTLY (np.testing.assert_array_equal, not allclose)."""
    pins = np.load(PINS)
    spec = get_model(model)
    ds = get_dataset("synthetic_small", num_days=PIN_DAYS, model=spec)
    cfg = ds.model_config()
    theta = spec.prior().sample(jax.random.PRNGKey(0), (PIN_BATCH,))
    obs = jnp.asarray(ds.observed, jnp.float32)
    # inputs first: if these drift the distance comparison is meaningless
    np.testing.assert_array_equal(np.asarray(theta), pins[f"{model}/theta"])
    np.testing.assert_array_equal(np.asarray(obs), pins[f"{model}/observed"])

    common = dict(population=cfg.population, a0=cfg.a0, r0=cfg.r0, d0=cfg.d0)
    key = jax.random.PRNGKey(PIN_KEY)
    got = {
        "pallas": ops.abc_sim_distance(
            theta, np.uint32(PIN_SEED), obs, model=spec, **common
        ),
        "oracle": ref.abc_sim_distance_ref(
            theta, np.uint32(PIN_SEED), obs, model=spec, **common
        ),
        "xla_fused": engine.simulate_observed_lowmem(
            spec, theta, key, cfg, obs
        )[0],
    }
    sim = engine.simulate_observed(spec, theta, key, cfg)
    lowered = lower_summary(get_summary(None), "euclidean", obs)
    got["xla"] = summary_distance("euclidean", lowered, sim)
    for backend, val in got.items():
        np.testing.assert_array_equal(
            np.asarray(val), pins[f"{model}/{backend}"],
            err_msg=(
                f"{model}/{backend} drifted from its pre-metapop pin — the "
                "region-axis refactor changed an R=1 stream"
            ),
        )


def test_r1_rng_slots_unchanged():
    """Counter widening keeps slots=8 for every R=1 model (the hash-RNG
    stream layout the pins freeze) and widens only past 8 transitions."""
    for name in ("sir", "seir", "siard", "seiard"):
        assert get_model(name).ctr_slots == 8, name
    mp = get_model("metapop_seir")  # 4 regions x 3 transitions = 12 -> 16
    assert mp.ctr_slots == 16
    r100 = regionalize(mp, 100, "ring:0.1")  # 300 -> 304
    assert r100.ctr_slots == 304


# ---------------------------------------------------------------------------
# mobility validation: loud failures, sound grammar
# ---------------------------------------------------------------------------

def test_validate_mobility_rejects_wrong_shape():
    with pytest.raises(ValueError, match=r"\[3\]\[3\] matrix"):
        validate_mobility(((1.0, 0.0), (0.0, 1.0)), 3)
    with pytest.raises(ValueError, match=r"\[2\]\[2\] matrix"):
        validate_mobility(((1.0, 0.0, 0.0), (0.0, 1.0, 0.0)), 2)


def test_validate_mobility_rejects_negative_entries():
    with pytest.raises(ValueError, match="negative"):
        validate_mobility(((1.5, -0.5), (0.0, 1.0)), 2)


def test_validate_mobility_rejects_non_row_stochastic():
    with pytest.raises(ValueError, match="row-stochastic"):
        validate_mobility(((0.5, 0.4), (0.0, 1.0)), 2)


def test_regionalize_rejects_bad_matrix_at_spec_construction():
    bad = ((1.0, 0.0, 0.0), (0.0, 1.0, 0.0), (0.0, 0.0, 0.5))
    with pytest.raises(ValueError, match="row-stochastic"):
        regionalize(get_model("seir"), 3, bad)


def test_make_mobility_grammar():
    assert make_mobility("identity", 3) == identity_mobility(3)
    for spec_str in ("uniform:0.2", "ring:0.1"):
        m = validate_mobility(make_mobility(spec_str, 5), 5)
        assert all(abs(sum(row) - 1.0) < 1e-9 for row in m)
        assert all(abs(m[r][r] - (1.0 - float(spec_str.split(":")[1]))) < 1e-9
                   for r in range(5))
    # ring sends eps/2 to each lattice neighbour (wraparound)
    ring = make_mobility("ring:0.2", 4)
    assert ring[0][1] == pytest.approx(0.1) and ring[0][3] == pytest.approx(0.1)
    assert ring[0][2] == 0.0
    for bad in ("gravity:0.1", "uniform", "ring:1.5", "identity:0.1"):
        with pytest.raises(ValueError):
            make_mobility(bad, 4)


def test_abc_config_mobility_validation():
    with pytest.raises(ValueError, match="row-stochastic"):
        ABCConfig(mobility=((0.5, 0.4), (0.0, 1.0)))
    cfg = ABCConfig(model="seir", mobility=identity_mobility(2))
    with pytest.raises(ValueError, match="no region axis"):
        resolved_mobility(cfg, get_model("seir"))


# ---------------------------------------------------------------------------
# coupling correctness
# ---------------------------------------------------------------------------

def test_identity_mobility_equals_independent_regions():
    """With identity mobility, the R-region trajectory factorizes into R
    independent single-region runs fed the matching region-major noise
    slices — exact equality, whole trajectory."""
    R = 3
    metapop = regionalize(get_model("metapop_seir"), R, "identity")
    r1 = regionalize(get_model("metapop_seir"), 1, "identity",
                     name="metapop_r1_ref")
    cfg = EpiModelConfig(
        population=3e6, num_days=12, a0=90.0, r0=4.0, d0=2.0
    )
    theta = metapop.prior().sample(jax.random.PRNGKey(2), (8,))
    key = jax.random.PRNGKey(9)
    traj = np.asarray(engine.simulate(metapop, theta, key, cfg))

    T = metapop.n_transitions
    states = []
    for r in range(R):
        seed = r == metapop.seed_region
        sub = EpiModelConfig(
            population=cfg.population / R, num_days=cfg.num_days,
            a0=cfg.a0 if seed else 0.0, r0=cfg.r0 if seed else 0.0,
            d0=cfg.d0 if seed else 0.0,
        )
        states.append(engine.initial_state(r1, theta, sub))
    for day in range(cfg.num_days):
        # the exact per-day stream the regional scan draws, sliced per region
        z = jax.random.normal(
            jax.random.fold_in(key, day),
            theta.shape[:-1] + (metapop.total_transitions,), jnp.float32,
        )
        for r in range(R):
            states[r] = engine.tau_leap_step(
                r1, states[r], theta, z[..., r * T:(r + 1) * T],
                cfg.population / R,
            )
        ref_day = np.concatenate([np.asarray(s) for s in states], axis=-1)
        np.testing.assert_array_equal(
            traj[:, day, :], ref_day,
            err_msg=f"day {day}: identity-mobility run is not independent",
        )


def test_metapop_seir_coupling_spreads_infection():
    """Ring mobility must actually move mass: with identity mobility the
    non-seed regions stay fully susceptible forever; with ring coupling
    they develop infections."""
    cfg = EpiModelConfig(population=4e6, num_days=20, a0=500.0)
    spec = get_model("metapop_seir")  # R=4, ring:0.1
    theta = jnp.asarray([spec.default_theta], jnp.float32)
    key = jax.random.PRNGKey(0)
    obs = engine.simulate_observed(spec, theta, key, cfg)  # [1, 8, T]
    per_region = np.asarray(engine.regional_view(obs, spec))[0]  # [R, 2, T]
    infected_final = per_region[:, 0, -1] + per_region[:, 1, -1]  # I+R at T
    assert infected_final[spec.seed_region] > 0
    assert (infected_final > 0).all(), (
        f"ring mobility failed to spread infection: {infected_final}"
    )
    uncoupled = regionalize(spec, spec.n_regions, "identity")
    obs_u = engine.simulate_observed(uncoupled, theta, key, cfg)
    per_u = np.asarray(engine.regional_view(obs_u, uncoupled))[0]
    final_u = per_u[:, 0, -1] + per_u[:, 1, -1]
    off_seed = [r for r in range(spec.n_regions) if r != spec.seed_region]
    assert (final_u[off_seed] == 0).all()


@pytest.mark.parametrize("summary,distance", [
    (None, "euclidean"),
    ("region_pooled", "euclidean"),
    ("log_weekly", "mae"),
])
def test_metapop_r4_kernel_matches_oracle(summary, distance):
    """The fused Pallas kernel (mobility on const lanes, unrolled coupled
    rows) matches the hash-RNG XLA oracle on the registered R=4 model."""
    spec = get_model("metapop_seir")
    ds = get_dataset("synthetic_small", num_days=12, model=spec)
    cfg = ds.model_config()
    theta = spec.prior().sample(jax.random.PRNGKey(0), (16,))
    obs = jnp.asarray(ds.observed, jnp.float32)
    common = dict(
        population=cfg.population, a0=cfg.a0, r0=cfg.r0, d0=cfg.d0,
        model=spec, summary=summary, distance=distance,
    )
    d_kernel = ops.abc_sim_distance(theta, np.uint32(3), obs, **common)
    d_oracle = ref.abc_sim_distance_ref(theta, np.uint32(3), obs, **common)
    assert np.isfinite(np.asarray(d_kernel)).all()
    np.testing.assert_allclose(
        np.asarray(d_kernel), np.asarray(d_oracle), rtol=2e-5, atol=1e-2
    )


@pytest.mark.parametrize("summary", [None, "region_pooled"])
def test_metapop_r4_xla_matches_fused(summary):
    """Post-hoc xla and the fused running-distance scan share the threefry
    stream — their distances must agree on the regional path too."""
    spec = get_model("metapop_seir")
    ds = get_dataset("synthetic_small", num_days=12, model=spec)
    theta = spec.prior().sample(jax.random.PRNGKey(1), (64,))
    key = jax.random.PRNGKey(3)
    dists = {}
    for backend in ("xla", "xla_fused"):
        cfg = ABCConfig(batch_size=64, chunk_size=64, num_days=12,
                        backend=backend, model=spec, summary=summary)
        sim = jax.jit(make_simulator(ds, cfg))
        dists[backend] = np.asarray(sim(theta, key))
    assert np.isfinite(dists["xla"]).all()
    np.testing.assert_allclose(dists["xla"], dists["xla_fused"], rtol=2e-5)


def test_region_pooled_is_identity_at_r1():
    """The registered region_pooled summary is a no-op for single-region
    models: pooling factor 1, identical distances to the identity summary."""
    spec = get_model("seir")
    ds = get_dataset("synthetic_small", num_days=10, model=spec)
    theta = spec.prior().sample(jax.random.PRNGKey(4), (32,))
    key = jax.random.PRNGKey(7)
    out = {}
    for summary in (None, "region_pooled"):
        cfg = ABCConfig(batch_size=32, chunk_size=32, num_days=10,
                        backend="xla_fused", model=spec, summary=summary)
        out[summary] = np.asarray(jax.jit(make_simulator(ds, cfg))(theta, key))
    np.testing.assert_array_equal(out[None], out["region_pooled"])


def test_mobility_override_is_a_runtime_value():
    """cfg.mobility overrides the spec's static matrix: identity override
    of the ring-coupled model equals the identity-regionalized spec."""
    spec = get_model("metapop_seir")
    ds = get_dataset("synthetic_small", num_days=10, model=spec)
    theta = spec.prior().sample(jax.random.PRNGKey(8), (32,))
    key = jax.random.PRNGKey(2)
    cfg_override = ABCConfig(
        batch_size=32, chunk_size=32, num_days=10, backend="xla_fused",
        model=spec, mobility=identity_mobility(spec.n_regions),
    )
    d_override = np.asarray(jax.jit(make_simulator(ds, cfg_override))(theta, key))
    ident = regionalize(spec, spec.n_regions, "identity")
    cfg_ident = ABCConfig(batch_size=32, chunk_size=32, num_days=10,
                          backend="xla_fused", model=ident)
    d_ident = np.asarray(jax.jit(make_simulator(ds, cfg_ident))(theta, key))
    np.testing.assert_array_equal(d_override, d_ident)
    # ...and it actually changes the result vs the spec's ring matrix
    cfg_ring = ABCConfig(batch_size=32, chunk_size=32, num_days=10,
                         backend="xla_fused", model=spec)
    d_ring = np.asarray(jax.jit(make_simulator(ds, cfg_ring))(theta, key))
    assert not np.array_equal(d_ring, d_ident)


# ---------------------------------------------------------------------------
# kernel lane budget: loud refusal past R=10, fine at the boundary
# ---------------------------------------------------------------------------

def test_kernel_lane_budget_boundary():
    mp = get_model("metapop_seir")
    r10 = regionalize(mp, 10, "ring:0.1")  # 8 + 20 + 100 = 128 lanes: fits
    assert abc_sim.kernel_lane_budget_ok(r10, pool=1)
    r11 = regionalize(mp, 11, "ring:0.1")
    assert not abc_sim.kernel_lane_budget_ok(r11, pool=1)
    # pooling frees summary lanes but mobility still needs R^2
    assert abc_sim.kernel_lane_budget_ok(r10, pool=10)
    assert not abc_sim.kernel_lane_budget_ok(
        regionalize(mp, 100, "ring:0.1"), pool=100
    )


def test_kernel_refuses_oversized_metapop_loudly():
    spec = regionalize(get_model("metapop_seir"), 100, "ring:0.1")
    theta = spec.prior().sample(jax.random.PRNGKey(0), (128,))
    obs = jnp.zeros((spec.total_observed, 8), jnp.float32)
    with pytest.raises(ValueError, match="const-lane budget"):
        ops.abc_sim_distance(
            theta, np.uint32(0), obs, model=spec,
            population=1e6, a0=100.0,
        )


# ---------------------------------------------------------------------------
# end to end: posterior recovery + 100-region campaign smoke
# ---------------------------------------------------------------------------

def test_run_abc_recovers_truth_metapop():
    """C2 for the spatial model: the ABC posterior concentrates around the
    generating parameters of a 4-region coupled SEIR ground truth."""
    spec = get_model("metapop_seir")
    truth = spec.default_theta
    ds = synthetic_dataset(
        theta=truth, population=1e6, num_days=15, a0=100.0, seed=11,
        name="recovery_metapop", model=spec,
    )
    pilot = ABCConfig(batch_size=4096, num_days=15, chunk_size=4096,
                      backend="xla_fused", model=spec)
    sim = jax.jit(make_simulator(ds, pilot))
    th = spec.prior().sample(jax.random.PRNGKey(5), (4096,))
    d = np.asarray(sim(th, jax.random.PRNGKey(6)))
    eps = float(np.quantile(d[np.isfinite(d)], 5e-3))
    cfg = ABCConfig(
        batch_size=4096, tolerance=eps, target_accepted=60, chunk_size=4096,
        max_runs=60, num_days=15, backend="xla_fused", model=spec,
    )
    post = run_abc(ds, cfg, key=0)
    assert len(post) >= 60
    prior = spec.prior()
    width = np.asarray(prior.highs, np.float32) - np.asarray(
        prior.lows, np.float32
    )
    err = np.abs(post.theta.mean(axis=0) - np.asarray(truth)) / width
    assert (err <= 0.30).all(), (
        f"metapop posterior-mean error {err} exceeds 0.30 of prior width"
    )


def test_campaign_100_region_smoke(tmp_path):
    """The 100-region example: two spec-object scenarios through the
    campaign runner, sharing ONE compiled wave loop (the shape cache keys
    on the resolved spec, so unregistered regionalized specs behave like
    registry names)."""
    from repro.core.campaign import CampaignConfig, run_campaign

    spec = regionalize(get_model("metapop_seir"), 100, "ring:0.1")
    assert spec.total_state == 400 and spec.total_observed == 200
    ds = get_dataset("synthetic_small", num_days=8, model=spec)
    assert ds.observed.shape == (200, 8)
    assert ds.observed_channels[:3] == ("I@r0", "R@r0", "I@r1")

    cfg = CampaignConfig(
        datasets=("synthetic_small",),
        models=(spec,),
        backends=("xla_fused",),
        seeds=(0, 1),
        batch_size=256,
        num_days=8,
        target_accepted=4,
        auto_quantile=0.05,
        pilot_size=256,
        max_runs=12,
        out_dir=str(tmp_path / "camp100"),
        checkpoint_every=8,
    )
    report = run_campaign(cfg)
    assert len(report.scenarios) == 2
    for r in report.scenarios:
        assert r.status == "ok", (r.name, r.status, r.detail)
        assert r.model == spec.name  # serialized by tag, not by object
        assert r.n_accepted >= cfg.target_accepted
    assert report.compiled_shapes == 1  # both seeds share one wave loop
