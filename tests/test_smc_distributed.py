"""SMC-ABC quality tests + multi-device shard_map driver tests (subprocess)."""

import numpy as np
import pytest

from conftest import run_in_subprocess


def test_smc_abc_tightens_posterior():
    """SMC-ABC must reach a lower tolerance than a single prior wave and keep
    a full particle population."""
    import jax
    from repro.core.smc import SMCConfig, run_smc_abc
    from repro.epi.data import get_dataset

    ds = get_dataset("synthetic_small", num_days=15)
    cfg = SMCConfig(
        n_particles=64, batch_size=2048, n_rounds=3, quantile=0.5, num_days=15
    )
    post = run_smc_abc(ds, cfg, key=0)
    assert len(post) == 64
    # tolerance after 3 halvings of the population quantile must be far below
    # the prior-predictive median distance
    from repro.core.abc import ABCConfig, make_simulator
    from repro.core.priors import paper_prior

    sim = jax.jit(make_simulator(ds, ABCConfig(num_days=15, backend="xla_fused")))
    th = paper_prior().sample(jax.random.PRNGKey(1), (2048,))
    d_prior = np.asarray(sim(th, jax.random.PRNGKey(2)))
    d_prior = d_prior[np.isfinite(d_prior)]
    assert post.tolerance < np.quantile(d_prior, 0.08)
    assert np.isfinite(post.distances).all()
    # posterior mean closer to truth than prior mean (normalized)
    true = np.asarray(ds.true_theta)
    highs = np.asarray(paper_prior().highs)
    err_post = np.abs(post.theta.mean(axis=0) - true) / highs
    err_prior = np.abs(highs / 2 - true) / highs
    assert err_post.mean() < err_prior.mean()


@pytest.mark.slow
def test_shardmap_runner_multi_device():
    """Explicit per-device ABC replica on 8 host devices: global accept count
    must equal the host-side filter count, and the sample stream must be
    deterministic in (key, device)."""
    out = run_in_subprocess(
        """
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.abc import ABCConfig, make_simulator
from repro.core.distributed import make_shardmap_runner, make_pjit_runner
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset
from repro.launch.mesh import make_compat_mesh

assert len(jax.devices()) == 8
mesh = make_compat_mesh((8,), ("data",))
ds = get_dataset("synthetic_small", num_days=15)
cfg = ABCConfig(batch_size=8 * 512, tolerance=1.6e4, target_accepted=10**9,
                chunk_size=128, strategy="outfeed", num_days=15,
                backend="xla_fused", max_runs=1)
sim = make_simulator(ds, cfg)
runner = make_shardmap_runner(mesh, paper_prior(), sim, cfg)
key = jax.random.PRNGKey(0)
out = runner(key)
d = np.asarray(out.dist)          # [global_chunks, chunk]
flags = np.asarray(out.chunk_flags)
count = int(out.accept_count)
assert d.shape == (8 * 512 // 128, 128), d.shape
host_count = int((d <= cfg.tolerance).sum())
assert count == host_count, (count, host_count)
np.testing.assert_array_equal(flags, (d <= cfg.tolerance).any(axis=1))
# determinism
out2 = runner(key)
np.testing.assert_array_equal(np.asarray(out2.dist), d)
# pjit runner gives a valid stream too
runner_p = make_pjit_runner(mesh, paper_prior(), sim, cfg)
outp = runner_p(key)
dp = np.asarray(outp.dist)
assert int(outp.accept_count) == int((dp <= cfg.tolerance).sum())
print("OK", count)
""",
        n_devices=8,
    )
    assert "OK" in out


@pytest.mark.slow
def test_scaling_device_counts_same_statistics():
    """Paper claim C5 scaffold: accept-rate is device-count independent."""
    rates = {}
    for n in (1, 4):
        out = run_in_subprocess(
            f"""
import jax, numpy as np
from repro.core.abc import ABCConfig, make_simulator
from repro.core.distributed import make_shardmap_runner
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset
from repro.launch.mesh import make_compat_mesh
mesh = make_compat_mesh(({n},), ("data",))
ds = get_dataset("synthetic_small", num_days=15)
cfg = ABCConfig(batch_size={n} * 2048, tolerance=1.8e4, target_accepted=10**9,
                chunk_size=256, num_days=15, backend="xla_fused", max_runs=1)
runner = make_shardmap_runner(mesh, paper_prior(), make_simulator(ds, cfg), cfg)
total = 0
for r in range(4):
    out = runner(jax.random.fold_in(jax.random.PRNGKey(1), r))
    total += int(out.accept_count)
print("RATE", total / (4 * cfg.batch_size))
""",
            n_devices=n,
        )
        rates[n] = float(out.split("RATE")[1].strip())
    assert rates[1] > 0
    assert abs(rates[1] - rates[4]) / rates[1] < 0.8, rates
