"""Flash-attention Pallas kernel vs the dense oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.models import common as cm


def _qkv(b, s, h, kh, d, t=None, seed=0, dtype=jnp.float32):
    t = t or s
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kh, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kh,d", [
    (1, 64, 2, 2, 16),   # MHA
    (2, 64, 4, 2, 16),   # GQA
    (1, 128, 4, 1, 32),  # MQA
])
def test_flash_matches_dense_causal(b, s, h, kh, d):
    q, k, v = _qkv(b, s, h, kh, d, seed=s + h)
    out = ops.flash_attention(q, k, v, causal=True, q_block=32, kv_block=32,
                              interpret=True)
    ref = cm.dense_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-5)


def test_flash_window_and_softcap():
    q, k, v = _qkv(1, 64, 2, 2, 16, seed=3)
    out = ops.flash_attention(q, k, v, causal=True, window=16, softcap=30.0,
                              q_block=16, kv_block=16, interpret=True)
    ref = cm.dense_attention(q, k, v, causal=True, window=16, attn_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-5)


def test_flash_non_causal_cross_length():
    """Encoder-style: no causal mask, kv length != q length (+padding path)."""
    q, k, v = _qkv(1, 24, 2, 2, 16, t=40, seed=5)
    out = ops.flash_attention(q, k, v, causal=False, q_block=16, kv_block=16,
                              interpret=True)
    ref = cm.dense_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-4, atol=3e-5)


def test_flash_block_size_invariance():
    q, k, v = _qkv(1, 64, 2, 2, 16, seed=7)
    a = ops.flash_attention(q, k, v, q_block=16, kv_block=16, interpret=True)
    bb = ops.flash_attention(q, k, v, q_block=64, kv_block=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), rtol=1e-4, atol=1e-5)


def test_flash_bf16_inputs():
    q, k, v = _qkv(1, 64, 4, 2, 16, seed=9, dtype=jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, q_block=32, kv_block=32,
                              interpret=True)
    ref = cm.dense_attention(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=0.05, atol=0.05
    )
