"""Unit tests for the HLO cost analyzer (the dry-run 'profiler')."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import analysis as A


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_loop_flops_counted_with_trip_multiplier():
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        out, _ = jax.lax.scan(body, x, w)
        return out.sum()

    costs = A.analyze_hlo(_compile(f, x, w).as_text())
    expected = 5 * 2 * 8 * 64 * 64
    assert abs(costs.flops - expected) / expected < 0.01
    assert 5 in costs.while_trips


def test_single_dot_flops_exact():
    a = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 8), jnp.float32)
    costs = A.analyze_hlo(_compile(lambda a, b: a @ b, a, b).as_text())
    assert costs.flops == 2 * 16 * 32 * 8


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 2, 16, 16), jnp.float32)

    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, ()
            c, _ = jax.lax.scan(inner, c, wo)
            return c, ()
        out, _ = jax.lax.scan(outer, x, w)
        return out.sum()

    costs = A.analyze_hlo(_compile(f, x, w).as_text())
    expected = 6 * 2 * 4 * 16 * 16  # 3 x 2 dots
    assert abs(costs.flops - expected) / expected < 0.02


def test_shape_bytes_parsing():
    assert A._shape_bytes("f32[4,8]") == 128
    assert A._shape_bytes("bf16[2,3]{1,0}") == 12
    assert A._shape_bytes("(s32[], f32[10])") == 44
    assert A._shape_bytes("pred[7]") == 7
    assert A._shape_bytes("token[]") == 0


def test_collective_wire_math():
    # synthetic HLO lines via the public entry
    txt = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16] parameter(0)
  %ag = f32[16]{0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[16]{0} all-reduce(%ag), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    costs = A.analyze_hlo(txt)
    # all-gather result 64B, group 4: wire = 64 * 3/4 = 48
    assert costs.collective_wire["all-gather"] == pytest.approx(48.0)
    # all-reduce 64B: wire = 2 * 64 * 3/4 = 96
    assert costs.collective_wire["all-reduce"] == pytest.approx(96.0)
    assert costs.collective_operand["all-gather"] == pytest.approx(16.0)


def test_dynamic_update_slice_charged_as_slice():
    buf = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 256), jnp.float32)

    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (5, 0))

    # donated buffer -> true in-place update; must NOT charge ~2 x 1MB
    compiled = jax.jit(f, donate_argnums=(0,)).lower(buf, upd).compile()
    costs = A.analyze_hlo(compiled.as_text())
    assert costs.bytes_accessed < 300_000, costs.bytes_accessed


def test_roofline_terms_and_bottleneck():
    r = A.Roofline(
        flops=197e12, bytes_accessed=819e9 * 2, collective_wire=50e9 * 0.5,
        collective_operand=0, collective_detail={}, n_devices=4,
        model_flops=4 * 197e12, raw_cost_analysis={},
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_flop_ratio == pytest.approx(1.0)
    assert r.mfu_bound == pytest.approx(0.5)
