"""Posterior persistence: atomic save, exact-path round-trip, loud
corruption errors.

Posteriors back the serving cache (repro.core.serving.PosteriorStore), so
a crash mid-save must never leave a truncated file where a complete one
was, and a corrupt file must fail loudly with a remediation hint instead
of a bare zipfile traceback deep inside a serving loop.
"""

import os

import numpy as np
import pytest

from repro.core.posterior import Posterior


def _posterior(n=17, p=3, weights=True):
    rng = np.random.default_rng(0)
    return Posterior(
        theta=rng.normal(size=(n, p)).astype(np.float32),
        distances=rng.uniform(1, 2, size=n).astype(np.float32),
        tolerance=1.5,
        param_names=[f"p{j}" for j in range(p)],
        runs=4,
        simulations=1234,
        wall_time_s=0.5,
        weights=rng.uniform(size=n).astype(np.float32) if weights else None,
    )


def test_round_trip_exact_path(tmp_path):
    """load(path) must round-trip save(path) — including a suffix-less
    path, where bare np.savez would silently write `path + '.npz'`."""
    post = _posterior()
    for fname in ("post.npz", "post"):  # with and without the suffix
        path = str(tmp_path / fname)
        post.save(path)
        assert os.path.exists(path), fname
        back = Posterior.load(path)
        np.testing.assert_array_equal(back.theta, post.theta)
        np.testing.assert_array_equal(back.distances, post.distances)
        np.testing.assert_array_equal(back.weights, post.weights)
        assert back.tolerance == post.tolerance
        assert list(back.param_names) == list(post.param_names)
        assert (back.runs, back.simulations) == (post.runs, post.simulations)
        assert back.wall_time_s == post.wall_time_s


def test_round_trip_without_weights(tmp_path):
    """Rejection-ABC posteriors have no weights; None survives the trip."""
    post = _posterior(weights=False)
    path = str(tmp_path / "post.npz")
    post.save(path)
    assert Posterior.load(path).weights is None


def test_missing_file_is_not_corruption(tmp_path):
    with pytest.raises(FileNotFoundError):
        Posterior.load(str(tmp_path / "nope.npz"))


def test_corrupt_file_raises_loudly(tmp_path):
    path = str(tmp_path / "post.npz")
    _posterior().save(path)
    with open(path, "rb") as f:
        data = f.read()
    with open(path, "wb") as f:
        f.write(data[: len(data) // 2])  # truncated mid-write
    with pytest.raises(ValueError, match="corrupt"):
        Posterior.load(path)
    with open(path, "w") as f:
        f.write("not a zip at all")
    with pytest.raises(ValueError, match="corrupt"):
        Posterior.load(path)


def test_missing_arrays_raise_loudly(tmp_path):
    path = str(tmp_path / "post.npz")
    with open(path, "wb") as f:
        np.savez(f, theta=np.zeros((2, 2), np.float32))
    with pytest.raises(ValueError, match="corrupt"):
        Posterior.load(path)


def test_crash_mid_save_preserves_previous_file(tmp_path, monkeypatch):
    """The atomic-write contract: a failure before the rename leaves the
    previously saved posterior intact (and no temp litter behind)."""
    path = str(tmp_path / "post.npz")
    first = _posterior(n=5)
    first.save(path)

    def boom(*a, **k):
        raise RuntimeError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError, match="disk full"):
        _posterior(n=9).save(path)
    monkeypatch.undo()
    back = Posterior.load(path)
    np.testing.assert_array_equal(back.theta, first.theta)
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []


def test_top_subsets_weights():
    post = _posterior(n=10)
    top = post.top(4)
    assert len(top) == 4
    order = np.argsort(post.distances)[:4]
    np.testing.assert_array_equal(top.weights, post.weights[order])
    assert _posterior(weights=False).top(4).weights is None
