"""Summary statistics + weighted distances across all three backends.

Acceptance contract of the subsystem (ISSUE 4):
  * every registered (summary, distance) pair runs on "xla", "xla_fused" and
    "pallas", with kernel-vs-oracle parity per pair;
  * the default (identity, euclidean) spec is BIT-identical to the pre-
    summary behaviour on every backend;
  * a (summary, distance) sweep reuses one compiled Pallas kernel (weights
    and selectors ride scalar lanes like the intervention breakpoints).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abc import ABCConfig, make_simulator, run_abc
from repro.core.distances import DISTANCES
from repro.core.priors import paper_prior
from repro.core.summaries import (
    SUMMARIES,
    SummarySpec,
    apply_summary,
    flush_mask,
    get_summary,
    lower_summary,
    num_bins,
    summary_pairs,
)
from repro.epi import engine
from repro.epi.data import get_dataset
from repro.epi.models import get_model
from repro.epi.spec import EpiModelConfig
from repro.kernels import ops, ref

DAYS = 15
POP = 1e6
KW = dict(population=POP, a0=100.0, r0=5.0, d0=1.0)


@pytest.fixture(scope="module")
def ds():
    return get_dataset("synthetic_small", num_days=DAYS)


@pytest.fixture(scope="module")
def obs(ds):
    return jnp.asarray(ds.observed[:, :DAYS], jnp.float32)


@pytest.fixture(scope="module")
def theta():
    return paper_prior().sample(jax.random.PRNGKey(3), (256,))


# ---------------------------------------------------------------- spec layer

def test_registry_resolution():
    assert get_summary(None).is_identity
    assert get_summary("identity").is_identity
    assert get_summary("weekly").bin_days == 7
    spec = SummarySpec(cumulative=True, bin_days=3)
    assert get_summary(spec) is spec
    with pytest.raises(ValueError):
        get_summary("no_such_summary")
    with pytest.raises(TypeError):
        get_summary(42)


def test_spec_validation():
    with pytest.raises(ValueError):
        SummarySpec(bin_days=0)
    with pytest.raises(ValueError):
        SummarySpec(channel_weights=(1.0, -1.0, 1.0))
    # weights length is checked against the observed channels at lower time
    with pytest.raises(ValueError):
        lower_summary(
            SummarySpec(channel_weights=(1.0, 2.0)), "euclidean",
            jnp.zeros((3, DAYS)),
        )


def test_tags_are_distinct_and_filesystem_safe():
    tags = {get_summary(n).tag() for n in SUMMARIES}
    tags.add(SummarySpec(cumulative=True, bin_days=3, log1p=True).tag())
    tags.add(SummarySpec(channel_weights=(1.0, 0.5, 2.0)).tag())
    assert len(tags) == len(SUMMARIES) + 2
    for t in tags:
        assert t and "/" not in t and " " not in t


def test_tag_never_trusts_a_reused_registry_name():
    """A custom spec wearing a registry name must NOT collide with the
    registry entry's tag (scenario names double as checkpoint dirs)."""
    imposter = SummarySpec("weekly", bin_days=14)
    assert imposter.tag() != SUMMARIES["weekly"].tag()
    assert imposter.tag() == "bin14"
    # the real registry instances keep their short names
    assert SUMMARIES["weekly"].tag() == "weekly"
    assert SummarySpec().tag() == "identity"


def test_config_validation():
    with pytest.raises(ValueError):
        ABCConfig(distance="chebyshev")
    with pytest.raises(ValueError):
        ABCConfig(summary="no_such_summary")
    assert ABCConfig(summary="weekly").summary_spec.bin_days == 7


# ------------------------------------------------------- observed-side math

def test_apply_summary_against_numpy_reference():
    rng = np.random.default_rng(0)
    x = rng.gamma(2.0, 50.0, size=(3, DAYS)).astype(np.float32)

    # weekly binning: value at day t is the running sum within t's bin
    got = np.asarray(apply_summary(SummarySpec(bin_days=7), x))
    for t in range(DAYS):
        start = (t // 7) * 7
        np.testing.assert_allclose(
            got[:, t], x[:, start : t + 1].sum(axis=1), rtol=1e-5
        )

    # cumulative then log1p
    got = np.asarray(apply_summary(SummarySpec(cumulative=True, log1p=True), x))
    np.testing.assert_allclose(
        got, np.log1p(np.cumsum(x, axis=1)), rtol=1e-5
    )

    # identity is literally the input (bit-exact)
    np.testing.assert_array_equal(np.asarray(apply_summary(SummarySpec(), x)), x)

    # cumulative x weekly: the bin value is the END-OF-BIN cumulative level
    # (not a sum of levels, which would scale with bin length and
    # down-weight a partial final bin)
    got = np.asarray(apply_summary(SummarySpec(cumulative=True, bin_days=7), x))
    np.testing.assert_allclose(got, np.cumsum(x, axis=1), rtol=1e-5)


def test_cumulative_weekly_parity_across_lowerings(ds, obs, theta):
    """The cumulative x binned combination must agree across all three
    lowerings too (it is not in the registry, so the pair sweep misses it)."""
    spec = SummarySpec(cumulative=True, bin_days=7)
    key = jax.random.PRNGKey(13)
    d = {}
    for backend in ("xla", "xla_fused"):
        cfg = ABCConfig(batch_size=256, num_days=DAYS, chunk_size=256,
                        backend=backend, summary=spec, distance="euclidean")
        d[backend] = np.asarray(make_simulator(ds, cfg)(theta, key))
    np.testing.assert_allclose(d["xla"], d["xla_fused"], rtol=2e-5, atol=1e-3)
    d_k = ops.abc_sim_distance(theta, jnp.uint32(7), obs, tile=128,
                               interpret=True, summary=spec,
                               distance="euclidean", **KW)
    d_r = ref.abc_sim_distance_ref(theta, jnp.uint32(7), obs, summary=spec,
                                   distance="euclidean", **KW)
    assert bool(jnp.all(jnp.isfinite(d_k)))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-5,
                               atol=1e-3)


def test_flush_mask_and_num_bins_edges():
    # T=15, weekly: bins close at days 6, 13 and the partial final day 14
    m = np.asarray(flush_mask(15, 7))
    assert list(np.nonzero(m)[0]) == [6, 13, 14]
    assert num_bins(15, 7) == 3
    # bin longer than the horizon: single partial bin, flush on the last day
    m = np.asarray(flush_mask(5, 30))
    assert list(np.nonzero(m)[0]) == [4]
    assert num_bins(5, 30) == 1
    # daily: every day flushes
    assert np.asarray(flush_mask(5, 1)).sum() == 5


def test_normalized_weights_match_legacy_scale(obs):
    """For the identity summary, the normalized kind's weights must equal the
    legacy normalized_euclidean channel scaling 1/(rms + 1)^2."""
    low = lower_summary(SummarySpec(), "normalized_euclidean", obs)
    scale = np.sqrt(np.mean(np.asarray(obs) ** 2, axis=-1)) + 1.0
    np.testing.assert_allclose(
        np.asarray(low.weights), 1.0 / scale**2, rtol=1e-6
    )


# ------------------------------------------- default path stays bit-identical

def _legacy_lowmem(model, theta, key, cfg, observed):
    """The pre-summary fused accumulation, verbatim."""
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    obs_idx = model.observed_idx
    state0 = engine.initial_state(model, theta, cfg)
    acc0 = state0[..., 0] * 0.0
    obs_by_day = jnp.swapaxes(jnp.asarray(observed, jnp.float32), 0, 1)

    def step(carry, inp):
        state, acc = carry
        day, obs_t = inp
        z = jax.random.normal(
            jax.random.fold_in(key, day),
            batch_shape + (model.n_transitions,), jnp.float32,
        )
        nxt = engine.tau_leap_step(model, state, theta, z, cfg.population)
        diff = nxt[..., obs_idx] - obs_t
        return (nxt, acc + jnp.sum(diff * diff, axis=-1)), None

    days = jnp.arange(cfg.num_days)
    (_, acc), _ = jax.lax.scan(step, (state0, acc0), (days, obs_by_day))
    return jnp.sqrt(acc)


def test_fused_default_bit_identical_to_legacy(obs, theta):
    m = get_model("siard")
    cfg = EpiModelConfig(population=POP, num_days=DAYS, **{
        k: v for k, v in KW.items() if k != "population"})
    key = jax.random.PRNGKey(0)
    d_legacy = _legacy_lowmem(m, theta, key, cfg, obs)
    d_none, _ = engine.simulate_observed_lowmem(m, theta, key, cfg, obs)
    d_spec, _ = engine.simulate_observed_lowmem(
        m, theta, key, cfg, obs, summary=SummarySpec(), distance="euclidean"
    )
    np.testing.assert_array_equal(np.asarray(d_none), np.asarray(d_legacy))
    np.testing.assert_array_equal(np.asarray(d_spec), np.asarray(d_legacy))


def test_kernel_default_bit_identical_across_summary_forms(obs, theta):
    """summary=None and an explicit identity SummarySpec must be the SAME
    computation in the kernel (selector lanes flip, math is bit-exact)."""
    a = ops.abc_sim_distance(theta, jnp.uint32(7), obs, tile=128,
                             interpret=True, **KW)
    b = ops.abc_sim_distance(theta, jnp.uint32(7), obs, tile=128,
                             interpret=True, summary=SummarySpec(),
                             distance="euclidean", **KW)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_xla_backend_default_matches_legacy_distances(ds, theta):
    """backend='xla' with an identity summary routes through the legacy
    DISTANCES registry (bit-compat for all three distance names)."""
    for name in sorted(DISTANCES):
        cfg = ABCConfig(batch_size=256, num_days=DAYS, chunk_size=256,
                        backend="xla", distance=name)
        sim = make_simulator(ds, cfg)
        key = jax.random.PRNGKey(5)
        d_bk = sim(theta, key)
        mcfg = ds.model_config(DAYS)
        traj = engine.simulate_observed(get_model("siard"), theta, key, mcfg)
        d_ref = DISTANCES[name](traj, jnp.asarray(ds.observed[:, :DAYS]))
        np.testing.assert_array_equal(np.asarray(d_bk), np.asarray(d_ref))


# ----------------------------------------------- cross-backend / oracle parity

@pytest.mark.parametrize("summary,distance", summary_pairs())
def test_xla_vs_fused_parity_per_pair(ds, theta, summary, distance):
    """Same threefry stream, two lowerings: post-hoc transform (xla) vs the
    running accumulator (xla_fused)."""
    key = jax.random.PRNGKey(11)
    dists = {}
    for backend in ("xla", "xla_fused"):
        cfg = ABCConfig(batch_size=256, num_days=DAYS, chunk_size=256,
                        backend=backend, summary=summary, distance=distance)
        dists[backend] = np.asarray(make_simulator(ds, cfg)(theta, key))
    assert np.all(np.isfinite(dists["xla"]))
    np.testing.assert_allclose(
        dists["xla"], dists["xla_fused"], rtol=2e-5, atol=1e-4
    )


@pytest.mark.parametrize("summary,distance", summary_pairs())
def test_kernel_vs_oracle_parity_per_pair(obs, theta, summary, distance):
    d_k = ops.abc_sim_distance(theta, jnp.uint32(7), obs, tile=128,
                               interpret=True, summary=summary,
                               distance=distance, **KW)
    d_r = ref.abc_sim_distance_ref(theta, jnp.uint32(7), obs, summary=summary,
                                   distance=distance, **KW)
    assert bool(jnp.all(jnp.isfinite(d_k)))
    np.testing.assert_allclose(
        np.asarray(d_k), np.asarray(d_r), rtol=2e-5, atol=1e-3
    )


def test_pallas_backend_accepts_every_pair(ds, theta):
    """`make_simulator` must no longer raise for non-euclidean pallas runs."""
    for summary, distance in (("weekly", "mae"),
                              ("cumulative", "normalized_euclidean")):
        cfg = ABCConfig(batch_size=256, num_days=DAYS, chunk_size=256,
                        backend="pallas", interpret=True, summary=summary,
                        distance=distance)
        d = make_simulator(ds, cfg)(theta, jax.random.PRNGKey(2))
        assert d.shape == (256,) and bool(jnp.all(jnp.isfinite(d)))


def test_summary_sweep_shares_one_compiled_kernel(obs, theta):
    """Sweeping (summary, distance) must not grow the kernel's jit cache:
    weights and selectors are runtime lanes, like intervention breakpoints."""
    ops.abc_sim_distance(theta, jnp.uint32(1), obs, tile=128, interpret=True,
                         **KW)
    base = ops._abc_sim_distance_jit._cache_size()
    for summary, distance in summary_pairs():
        ops.abc_sim_distance(theta, jnp.uint32(1), obs, tile=128,
                             interpret=True, summary=summary,
                             distance=distance, **KW)
    assert ops._abc_sim_distance_jit._cache_size() == base


# --------------------------------------------------------------- end to end

def _tolerance_for(ds, cfg, q=0.05):
    sim = jax.jit(make_simulator(ds, cfg))
    th = get_model(cfg.model).prior().sample(jax.random.PRNGKey(99), (1024,))
    d = np.asarray(sim(th, jax.random.PRNGKey(98)))
    return float(np.quantile(d[np.isfinite(d)], q))


@pytest.mark.parametrize("backend", ["xla", "xla_fused", "pallas"])
def test_run_abc_with_summary_all_backends(ds, backend):
    cfg = ABCConfig(batch_size=1024, num_days=DAYS, chunk_size=1024,
                    backend=backend, interpret=True, summary="weekly",
                    distance="normalized_euclidean", target_accepted=10,
                    max_runs=10, tolerance=1.0)
    cfg = dataclasses.replace(cfg, tolerance=_tolerance_for(ds, cfg))
    post = run_abc(ds, cfg, key=0)
    assert len(post) >= 10
    assert np.all(post.distances <= cfg.tolerance)


def test_device_wave_loop_matches_host_with_summary(ds):
    """The device-resident wave loop must reproduce the host loop exactly
    for a non-default (summary, distance) pair too."""
    base = ABCConfig(batch_size=1024, num_days=DAYS, chunk_size=128,
                     backend="xla_fused", summary="log_weekly", distance="mae",
                     target_accepted=15, max_runs=10, tolerance=1.0)
    base = dataclasses.replace(base, tolerance=_tolerance_for(ds, base))
    p_host = run_abc(ds, dataclasses.replace(base, wave_loop="host"), key=0)
    p_dev = run_abc(ds, dataclasses.replace(base, wave_loop="device"), key=0)
    assert len(p_dev) == len(p_host) > 0
    np.testing.assert_array_equal(p_host.theta, p_dev.theta)
    np.testing.assert_array_equal(p_host.distances, p_dev.distances)


def test_smc_with_summary(ds):
    from repro.core.smc import SMCConfig, run_smc_abc

    cfg = SMCConfig(n_particles=32, batch_size=512, n_rounds=2, num_days=DAYS,
                    summary="weekly", distance="mae")
    post = run_smc_abc(ds, cfg, key=0)
    assert post.theta.shape[0] == 32
    assert np.all(np.isfinite(post.distances))


def test_campaign_summary_axis(tmp_path):
    from repro.core.campaign import CampaignConfig, run_campaign

    cfg = CampaignConfig(
        datasets=("synthetic_small",),
        models=("siard",),
        backends=("xla_fused",),
        summaries=(None, "weekly"),
        distance="normalized_euclidean",
        batch_size=1024,
        num_days=DAYS,
        target_accepted=10,
        max_runs=10,
        auto_quantile=0.02,
        pilot_size=1024,
        out_dir=str(tmp_path),
        checkpoint_every=0,
    )
    report = run_campaign(cfg)
    assert len(report.scenarios) == 2
    names = {r.name for r in report.scenarios}
    assert len(names) == 2  # the summary tag distinguishes the cells
    assert any("bin7" in n or "weekly" in n for n in names)
    for r in report.scenarios:
        assert r.status in ("ok", "budget_exhausted")
        assert r.n_accepted > 0


def test_calibrate_tolerance_with_summary(ds):
    from repro.core.abc import calibrate_tolerance

    cfg = ABCConfig(batch_size=1024, num_days=DAYS, chunk_size=1024,
                    backend="xla_fused", summary="log_weekly", distance="mae")
    eps = calibrate_tolerance(ds, cfg, key=0, quantile=0.1, n_pilot=1024)
    assert np.isfinite(eps) and eps > 0
