"""The known-failures CI gate: new failures fail, baseline failures pass,
and STALE baseline entries (fixed bugs still allowlisted) fail on an
unfiltered run so they can't silently rot in tests/known_failures.txt."""

from check_new_failures import evaluate, narrows_collection

K = {"tests/test_a.py::test_old_bug", "tests/test_b.py::test_other_bug"}


def test_all_green_empty_baseline_passes():
    assert evaluate(set(), 0, set(), filtered=False) == 0


def test_baseline_failures_pass():
    assert evaluate(K, 1, set(K), filtered=False) == 0


def test_new_failure_fails():
    assert evaluate(K, 1, set(K) | {"tests/test_c.py::test_new"},
                    filtered=False) == 1


def test_stale_entry_fails_unfiltered():
    # one baseline entry now passes: the gate must demand its deletion
    assert evaluate(K, 1, {"tests/test_a.py::test_old_bug"},
                    filtered=False) == 1


def test_stale_requires_confirmed_pass():
    # "did not fail" is not "passes": an env-gated skip or a deleted test
    # must keep its baseline line (warn, exit 0) — only a candidate the
    # confirmation re-run proves green may hard-fail the gate
    failed = {"tests/test_a.py::test_old_bug"}
    assert evaluate(K, 1, failed, filtered=False,
                    confirm_stale=lambda s: set()) == 0  # skipped, not stale
    assert evaluate(K, 1, failed, filtered=False,
                    confirm_stale=lambda s: s) == 1  # verifiably passing
    # whole-baseline-stale (exit 0) goes through the same confirmation
    assert evaluate(K, 0, set(), filtered=False,
                    confirm_stale=lambda s: set()) == 0


def test_whole_baseline_stale_fails_unfiltered():
    assert evaluate(K, 0, set(), filtered=False) == 1


def test_stale_only_warns_when_filtered():
    # a -m/-k/path run may simply not collect the baseline entry
    assert evaluate(K, 1, {"tests/test_a.py::test_old_bug"},
                    filtered=True) == 0
    assert evaluate(K, 0, set(), filtered=True) == 0


def test_hard_pytest_error_propagates():
    assert evaluate(K, 2, set(), filtered=False) == 2


def test_exit1_with_nothing_parsed_fails():
    # pytest says red but no FAILED/ERROR lines were parsed (suppressed
    # summary): the gate must refuse to pass, whatever the baseline holds
    assert evaluate(set(), 1, set(), filtered=False) == 1
    assert evaluate(K, 1, set(), filtered=True) == 1


def test_new_failure_beats_stale_reporting():
    got = evaluate(K, 1, {"tests/test_c.py::test_new"}, filtered=False)
    assert got == 1


def test_narrows_collection_detects_real_filters():
    assert narrows_collection(["-m", "slow"])
    assert narrows_collection(["-mslow"])
    assert narrows_collection(["-k", "wave_loop"])
    assert narrows_collection(["tests/test_abc.py"])
    assert narrows_collection(["--ignore=tests/test_moe.py"])
    assert narrows_collection(["--deselect", "tests/test_a.py::t"])
    assert narrows_collection(["--lf"])
    # run truncators: an early-stopped run proves nothing about later
    # baseline entries, so stale may only warn
    assert narrows_collection(["-x"])
    assert narrows_collection(["-xq"])  # combined short-flag cluster
    assert narrows_collection(["-qx"])
    assert narrows_collection(["--maxfail", "1"])
    assert narrows_collection(["--maxfail=1"])
    assert narrows_collection(["--stepwise"])


def test_narrows_collection_ignores_benign_flags():
    # benign forwarded flags must not downgrade the stale gate to a warning
    assert not narrows_collection([])
    assert not narrows_collection(["-q"])
    assert not narrows_collection(["-p", "no:cacheprovider"])
    assert not narrows_collection(["--tb", "short", "-q"])
    assert not narrows_collection(["--color=yes", "-W", "ignore"])
    # space-separated values of common valued flags are NOT positional paths
    assert not narrows_collection(["--junitxml", "report.xml"])
    assert not narrows_collection(["--cov", "src", "-r", "a"])
    # "-rx" is -r's value chars (report xfailed), not -r plus -x
    assert not narrows_collection(["-rx"])
    assert not narrows_collection(["-rfE"])
