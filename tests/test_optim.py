"""AdamW, schedule, clipping, and int8 error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

# degrades to skip-markers when hypothesis is absent (tier-1 container)
from _hypothesis_compat import given, settings, st

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_gradients,
    cosine_schedule,
    decompress_gradients,
)


def _params():
    return {"a": jnp.ones((4, 4), jnp.bfloat16), "nested": (jnp.ones(3),)}


def test_adamw_decreases_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100,
                      min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 5e-2


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 60, 110)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6  # linear warmup
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[2] > lrs[3] > lrs[4]
    assert abs(lrs[4] - 0.1) < 1e-3  # floor


def test_grad_clipping_caps_update_norm():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(params, g, opt, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_tuple_containing_trees_supported():
    """Regression: decoder params contain tuples as internal nodes."""
    params = _params()
    opt = adamw_init(params)
    g = jax.tree.map(jnp.ones_like, params)
    p2, o2, _ = adamw_update(params, g, opt, AdamWConfig())
    assert jax.tree.structure(p2) == jax.tree.structure(params)


def test_compression_roundtrip_error_bound():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    comp, err = compress_gradients(g)
    deq = decompress_gradients(comp)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.5 + 1e-6
    # error feedback holds the exact residual
    np.testing.assert_allclose(
        np.asarray(err["w"]), np.asarray(g["w"] - deq["w"]), rtol=1e-5, atol=1e-7
    )


def test_error_feedback_unbiased_over_steps():
    """With error feedback, the SUM of dequantized grads converges to the sum
    of true grads (compression bias does not accumulate)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros((32,), np.float32)
    deq_sum = np.zeros((32,), np.float32)
    err = None
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
        comp, err = compress_gradients(g, err)
        deq = decompress_gradients(comp)
        true_sum += np.asarray(g["w"])
        deq_sum += np.asarray(deq["w"])
    resid = np.abs(true_sum - deq_sum).max()
    scale_bound = np.abs(true_sum).max() * 0.05 + 0.2
    assert resid < scale_bound, resid


@settings(max_examples=20, deadline=None)
@given(
    vals=st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=64)
)
def test_property_compression_max_error(vals):
    g = {"w": jnp.asarray(np.array(vals, np.float32))}
    comp, _ = compress_gradients(g)
    deq = decompress_gradients(comp)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.5 + 1e-5
