"""Checkpoint/restore: atomicity, async overlap, keep-k GC, elastic reshard."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, load_checkpoint, save_checkpoint


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": (
            {"w": jax.random.normal(k, (4, 8)), "b": jnp.zeros(8)},
        ),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t, metadata={"note": "x"})
    restored, meta, step = load_checkpoint(tmp_path, t)
    assert step == 3 and meta["note"] == "x"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        t,
        restored,
    )


def test_latest_selected_and_keep_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.steps() == [3, 4]
    _, _, step = ck.restore(_tree())
    assert step == 4


def test_async_save_overlaps_and_commits(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    t = _tree(1)
    ck.save_async(5, t, metadata={"rng": 123})
    ck.wait()
    restored, meta, step = ck.restore(_tree())
    assert step == 5 and meta["rng"] == 123
    np.testing.assert_array_equal(
        np.asarray(restored["layers"][0]["w"]), np.asarray(t["layers"][0]["w"])
    )


def test_crash_mid_write_never_corrupts(tmp_path):
    """A leftover .tmp dir (simulated crash) must be invisible to restore."""
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate a partial write of step 2
    bad = tmp_path / "step_0000000002.tmp"
    bad.mkdir()
    (bad / "leaf_00000.npy").write_bytes(b"garbage")
    restored, _, step = load_checkpoint(tmp_path, t)
    assert step == 1  # tmp dir ignored


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, {"w": jnp.zeros((2, 2))})


def test_train_state_resume_equivalence(tmp_path):
    """Training N steps == training k, checkpoint, restore, train N-k
    (the restart contract)."""
    from repro.models.registry import get_model
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    model = get_model("gemma-2b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)

    from repro.data import SyntheticTokenDataset

    ds = SyntheticTokenDataset(vocab=model.cfg.vocab, seq_len=16, seed=1)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        p2, o2, _ = adamw_update(params, grads, opt, cfg)
        return p2, o2, loss

    def run(params, opt, start, n):
        for s in range(start, n):
            b = ds.batch(s, 4)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, _ = step_fn(params, opt, batch)
        return params, opt

    pa, oa = run(params, opt, 0, 4)

    pb, ob = run(params, opt, 0, 2)
    save_checkpoint(tmp_path, 2, {"params": pb, "opt": ob})
    restored, _, _ = load_checkpoint(tmp_path, {"params": pb, "opt": ob})
    pc, oc = run(restored["params"], restored["opt"], 2, 4)

    for a, c in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(c, np.float32), rtol=1e-5, atol=1e-6
        )
