"""Planted violations must trip their named analysis rules, and the clean
committed tree must pass (tests for src/repro/analysis + check_analysis.py,
mirroring test_bench_gate.py's synthetic-trip style).

Note: repro.analysis (this subsystem) is distinct from repro.launch.analysis
(the HLO cost analyzer, covered by tests/test_analysis.py).
"""

import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.lint import Linter, run_lint
from repro.analysis.report import (
    SCHEMA,
    Finding,
    evaluate,
    load_baseline,
    make_report,
)
from repro.analysis.trace_audit import (
    audit_donation,
    audit_jaxpr,
    audit_shape_cache,
)

REPO = Path(__file__).resolve().parents[1]

sys.path.insert(0, str(Path(__file__).resolve().parent))
import check_analysis  # noqa: E402


def lint_source(code: str):
    """Lint a synthetic module; return the list of tripped rule names."""
    src = textwrap.dedent(code)
    findings = Linter(REPO / "src/repro/_planted.py", REPO, source=src).run()
    return findings


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# lint: non-atomic-artifact-write
# ---------------------------------------------------------------------------

def test_planted_bare_savez_trips():
    findings = lint_source("""
        import numpy as np

        def save(path, arrays):
            np.savez(path, **arrays)
    """)
    assert rules_of(findings) == ["non-atomic-artifact-write"]
    assert findings[0].context == "save"


def test_planted_bare_open_w_and_json_dump_trip():
    findings = lint_source("""
        import json

        def save(path, payload):
            with open(path, "w") as f:
                json.dump(payload, f)
    """)
    # both the open(..., "w") and the json.dump into its bare handle trip
    assert rules_of(findings) == ["non-atomic-artifact-write"]
    assert len(findings) == 2


def test_planted_write_text_trips():
    findings = lint_source("""
        def save(path, text):
            path.write_text(text)
    """)
    assert rules_of(findings) == ["non-atomic-artifact-write"]


def test_atomic_write_handle_is_clean():
    findings = lint_source("""
        import json
        import numpy as np
        from repro.ioutils import atomic_write

        def save(path, payload, arrays):
            with atomic_write(path, "w") as f:
                json.dump(payload, f)
            with atomic_write(path, "wb") as g:
                np.savez(g, **arrays)
    """)
    assert findings == []


def test_read_mode_open_is_clean():
    findings = lint_source("""
        import json

        def load(path):
            with open(path) as f:
                return json.load(f)
    """)
    assert findings == []


# ---------------------------------------------------------------------------
# lint: traced-context rules
# ---------------------------------------------------------------------------

def test_planted_host_sync_item_under_trace_trips():
    findings = lint_source("""
        import jax

        @jax.jit
        def step(x):
            return x + x.sum().item()
    """)
    assert rules_of(findings) == ["host-sync-under-trace"]


def test_planted_float_of_traced_param_trips():
    findings = lint_source("""
        import jax

        def run(x0):
            def body(x):
                return x * float(x0)

            def cond(x):
                return x.sum() > 0

            return jax.lax.while_loop(cond, body, x0)
    """)
    # body/cond are traced via while_loop; float(x0)... x0 is run's param,
    # not body's — only flagged when the converted name is a TRACED param
    # of the flagged function itself, so this is clean...
    # ...but the same conversion of body's own parameter must trip:
    findings2 = lint_source("""
        import jax

        def run(x0):
            def body(x):
                return x * float(x)

            def cond(x):
                return x.sum() > 0

            return jax.lax.while_loop(cond, body, x0)
    """)
    assert "host-sync-under-trace" not in rules_of(findings)
    assert rules_of(findings2) == ["host-sync-under-trace"]


def test_host_sync_outside_trace_is_clean():
    findings = lint_source("""
        def harvest(out):
            return float(out.sum()), out.n_accepted.item()
    """)
    assert findings == []


def test_static_argname_param_is_clean():
    findings = lint_source("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("tile",))
        def kernel(x, *, tile):
            pad = int(tile) * 2
            return x[:pad]
    """)
    assert findings == []


def test_planted_numpy_rng_under_trace_trips():
    findings = lint_source("""
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return x + np.random.normal()
    """)
    assert rules_of(findings) == ["python-rng-under-trace"]


def test_planted_time_under_trace_trips():
    findings = lint_source("""
        import time
        import jax

        @jax.jit
        def step(x):
            t0 = time.time()
            return x * t0
    """)
    assert rules_of(findings) == ["time-under-trace"]


def test_time_on_host_is_clean():
    findings = lint_source("""
        import time

        def bench(fn, x):
            t0 = time.perf_counter()
            fn(x)
            return time.perf_counter() - t0
    """)
    assert findings == []


def test_planted_scalar_closure_capture_trips():
    """The silent in-jit tile clamp bug class: a factory bakes
    float(parameter) into a jitted closure as a compile constant."""
    findings = lint_source("""
        import jax

        def make_step(scale_arg):
            scale = float(scale_arg)

            def step(x):
                return x * scale

            return jax.jit(step)
    """)
    assert rules_of(findings) == ["scalar-closure-capture"]
    assert findings[0].context == "step"


def test_literal_closure_constant_is_clean():
    """Deliberate literal statics stay allowed — only param-derived
    conversions trip."""
    findings = lint_source("""
        import jax

        def make_step():
            scale = 3.0

            def step(x):
                return x * scale

            return jax.jit(step)
    """)
    assert findings == []


def test_transitive_same_module_callee_is_traced():
    findings = lint_source("""
        import time
        import jax

        def helper(x):
            return x * time.time()

        @jax.jit
        def step(x):
            return helper(x)
    """)
    assert rules_of(findings) == ["time-under-trace"]


# ---------------------------------------------------------------------------
# lint: suppression machinery
# ---------------------------------------------------------------------------

def test_suppression_with_reason_suppresses():
    findings = lint_source("""
        import numpy as np

        def save(tmp, arr):
            # analysis: allow(non-atomic-artifact-write) — staged into an
            # uncommitted tmp dir; the directory rename is the atomic commit
            np.savez(tmp, arr=arr)
    """)
    assert findings == []


def test_suppression_without_reason_trips_its_own_rule():
    findings = lint_source("""
        import numpy as np

        def save(tmp, arr):
            # analysis: allow(non-atomic-artifact-write)
            np.savez(tmp, arr=arr)
    """)
    assert rules_of(findings) == ["suppression-missing-reason"]


def test_suppression_for_other_rule_does_not_suppress():
    findings = lint_source("""
        import numpy as np

        def save(path, arr):
            # analysis: allow(time-under-trace) — wrong rule on purpose
            np.savez(path, arr=arr)
    """)
    assert rules_of(findings) == ["non-atomic-artifact-write"]


# ---------------------------------------------------------------------------
# trace audit: planted jaxpr violations
# ---------------------------------------------------------------------------

def test_planted_f64_promotion_trips():
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2
        )(jnp.zeros(4, jnp.float32))
    findings = audit_jaxpr(jaxpr, "planted/f64")
    assert "f64-promotion" in rules_of(findings)


def test_planted_f64_inside_scan_trips():
    """The walker must recurse into control-flow sub-jaxprs."""
    with jax.experimental.enable_x64():
        def body(c, x):
            return c, x.astype(jnp.float64).sum()

        jaxpr = jax.make_jaxpr(
            lambda xs: jax.lax.scan(body, 0.0, xs)
        )(jnp.zeros((3, 2), jnp.float32))
    findings = audit_jaxpr(jaxpr, "planted/f64-scan")
    assert "f64-promotion" in rules_of(findings)


def test_planted_weak_type_leak_trips():
    jaxpr = jax.make_jaxpr(lambda x: (x, jnp.sin(2.0)))(
        jnp.zeros(3, jnp.float32)
    )
    findings = audit_jaxpr(jaxpr, "planted/weak")
    assert "weak-type-leak" in rules_of(findings)


def test_planted_host_callback_trips():
    def fn(x):
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )
        return y * 2

    jaxpr = jax.make_jaxpr(fn)(jnp.zeros(3, jnp.float32))
    findings = audit_jaxpr(jaxpr, "planted/callback")
    assert "host-transfer-under-jit" in rules_of(findings)


def test_clean_f32_jaxpr_passes():
    jaxpr = jax.make_jaxpr(
        lambda x: jnp.sin(x) + jnp.float32(1.0)
    )(jnp.zeros(4, jnp.float32))
    assert audit_jaxpr(jaxpr, "clean") == []


def test_planted_shape_cache_recompile_trips():
    a = {"obs": jnp.zeros((3, 21)), "pop": jnp.float32(1e6)}
    b = {"obs": jnp.zeros((3, 28)), "pop": jnp.float32(5e6)}  # shape drift
    findings = audit_shape_cache([a, b], "planted/retrace")
    assert rules_of(findings) == ["shape-cache-retrace"]


def test_same_shape_variants_share_one_compile():
    a = {"obs": jnp.zeros((3, 21)), "pop": jnp.float32(1e6)}
    b = {"obs": jnp.ones((3, 21)), "pop": jnp.float32(5e6)}  # values only
    assert audit_shape_cache([a, b], "clean/retrace") == []


def test_planted_non_donated_buffer_trips():
    def loop(buf, x):
        return buf + x

    buf = jnp.zeros((256, 4), jnp.float32)
    x = jnp.ones((256, 4), jnp.float32)
    text_plain = jax.jit(loop).lower(buf, x).as_text()
    findings = audit_donation(
        text_plain, "planted/donation", expected_donated=(0,)
    )
    assert rules_of(findings) == ["non-donated-buffer"]

    text_donated = jax.jit(loop, donate_argnums=(0,)).lower(buf, x).as_text()
    assert audit_donation(
        text_donated, "clean/donation", expected_donated=(0,)
    ) == []


# ---------------------------------------------------------------------------
# the gate decision (pure) + report schema
# ---------------------------------------------------------------------------

def _finding(rule="non-atomic-artifact-write", ctx="save"):
    return Finding(rule=rule, path="src/repro/x.py", line=3, context=ctx,
                   message="planted")


def test_gate_fails_on_unbaselined_finding(capsys):
    assert evaluate(set(), [_finding()]) == 1


def test_gate_passes_on_baselined_finding():
    f = _finding()
    assert evaluate({f.key}, [f]) == 0


def test_gate_fails_on_stale_baseline_entry():
    assert evaluate({"time-under-trace:src/repro/gone.py:fn"}, []) == 1


def test_gate_passes_clean():
    assert evaluate(set(), []) == 0


def test_report_schema_and_keys(tmp_path):
    f = _finding()
    report = make_report([f], ["lint"])
    assert report["schema"] == SCHEMA == "analysis-report/v1"
    assert report["counts"] == {
        "total": 1, "by_rule": {"non-atomic-artifact-write": 1}
    }
    assert report["findings"][0]["key"] == f.key
    # baseline round-trip: a key written to the baseline file matches
    b = tmp_path / "baseline.txt"
    b.write_text(f"# comment\n{f.key}\n")
    assert load_baseline(b) == {f.key}
    assert load_baseline(tmp_path / "missing.txt") == set()


# ---------------------------------------------------------------------------
# the committed tree is clean
# ---------------------------------------------------------------------------

def test_committed_tree_lints_clean():
    """The acceptance criterion for the lint half: zero unbaselined findings
    on the real repo (suppressions with reasons are already applied)."""
    findings = run_lint(REPO)
    known = load_baseline(REPO / "tests" / "analysis_baseline.txt")
    new = [f for f in findings if f.key not in known]
    assert new == [], "\n".join(str(f) for f in new)


@pytest.mark.slow
def test_committed_tree_audits_clean_quick():
    """Axis-coverage trace audit of the real wave loops stays clean (the
    full cross product runs in the repro-lint CI job / nightly)."""
    from repro.analysis.trace_audit import run_audit

    findings = run_audit(quick=True)
    known = load_baseline(REPO / "tests" / "analysis_baseline.txt")
    new = [f for f in findings if f.key not in known]
    assert new == [], "\n".join(str(f) for f in new)


def test_check_analysis_cli_lint_pass_on_committed_tree():
    """The gate entry point itself returns 0 for the lint pass."""
    assert check_analysis.main(["--pass", "lint"]) == 0
