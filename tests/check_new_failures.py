#!/usr/bin/env python
"""Tier-1 gate that fails only on NEW test failures.

Runs pytest over the tier-1 suite, collects the set of failed test ids, and
compares it against the checked-in baseline `tests/known_failures.txt` (the
pre-existing seed failures). The job:

  * FAILS (exit 1) if any test outside the baseline fails — a regression is
    caught at PR time instead of silently joining the pile;
  * PASSES if the only failures are baseline entries;
  * WARNS about baseline entries that now pass — delete them from the
    baseline so they can never regress silently again;
  * propagates pytest's own hard errors (collection error, internal error,
    usage error) verbatim.

Usage (what CI runs):

    PYTHONPATH=src python tests/check_new_failures.py [extra pytest args]

Extra args are forwarded to pytest (e.g. `-m "not slow"` or a subset path).
"""

from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent
BASELINE = HERE / "known_failures.txt"

# pytest summary lines look like:  FAILED tests/test_x.py::test_y[p] - Msg
_FAILED_RE = re.compile(r"^(?:FAILED|ERROR) +(\S+)")


def load_baseline() -> set:
    known = set()
    for line in BASELINE.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            known.add(line)
    return known


def run_pytest(extra_args) -> tuple:
    cmd = [
        sys.executable, "-m", "pytest", "-q", "--tb=no", "-rfE",
        "--continue-on-collection-errors", *extra_args,
    ]
    print("[check_new_failures] $", " ".join(cmd), flush=True)
    proc = subprocess.run(
        cmd, cwd=HERE.parent, capture_output=True, text=True
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    failed = set()
    for line in proc.stdout.splitlines():
        m = _FAILED_RE.match(line.strip())
        if m:
            failed.add(m.group(1))
    return proc.returncode, failed


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    known = load_baseline()
    code, failed = run_pytest(argv)
    if code == 0:
        stale = known  # everything passed; the whole baseline is stale
        new = set()
    elif code == 1:
        new = failed - known
        stale = known - failed
    else:
        print(f"[check_new_failures] pytest exited {code} (hard error; "
              "collection problem or internal error) — failing outright")
        return code
    if stale and not argv:
        # only meaningful on an unfiltered run: with -m/-k/path filters a
        # baseline entry may simply not have been collected
        print("[check_new_failures] WARNING: baseline entries now pass — "
              "delete them from tests/known_failures.txt:")
        for t in sorted(stale):
            print(f"  {t}")
    if new:
        print(f"[check_new_failures] {len(new)} NEW failure(s) beyond the "
              "known baseline:")
        for t in sorted(new):
            print(f"  {t}")
        return 1
    print(f"[check_new_failures] OK: {len(failed)} failure(s), all in the "
          f"known baseline ({len(known)} entries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
