#!/usr/bin/env python
"""Tier-1 gate that fails only on NEW test failures.

Runs pytest over the tier-1 suite, collects the set of failed test ids, and
compares it against the checked-in baseline `tests/known_failures.txt` (the
pre-existing seed failures). The job:

  * FAILS (exit 1) if any test outside the baseline fails — a regression is
    caught at PR time instead of silently joining the pile;
  * PASSES if the only failures are baseline entries;
  * FAILS (exit 1) on an UNFILTERED run if a baseline entry now passes — a
    stale entry is a fixed bug whose line was never deleted, i.e. a test
    that could regress without tripping the gate. Delete the line. (With
    -m/-k/path filters stale entries only warn, because a filtered run may
    simply not have collected them.)
  * propagates pytest's own hard errors (collection error, internal error,
    usage error) verbatim.

Usage (what CI runs):

    PYTHONPATH=src python tests/check_new_failures.py [extra pytest args]

Extra args are forwarded to pytest (e.g. `-m "not slow"` or a subset path).

`--baseline PATH` (consumed here, never forwarded) selects a different
known-failures file — the CI jax version matrix keeps one baseline per leg
(`known_failures.txt` for the 0.4.x pin, `known_failures_jax_latest.txt`
for latest-release jax), because upstream drift breaks different tests on
different versions.
"""

from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent
BASELINE = HERE / "known_failures.txt"

# pytest summary lines look like:  FAILED tests/test_x.py::test_y[p] - Msg
_FAILED_RE = re.compile(r"^(?:FAILED|ERROR) +(\S+)")


def load_baseline(path: Path = BASELINE) -> set:
    known = set()
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            known.add(line)
    return known


def run_pytest(extra_args) -> tuple:
    cmd = [
        sys.executable, "-m", "pytest", "-q", "--tb=no", "-rfE",
        "--continue-on-collection-errors", *extra_args,
    ]
    print("[check_new_failures] $", " ".join(cmd), flush=True)
    proc = subprocess.run(
        cmd, cwd=HERE.parent, capture_output=True, text=True
    )
    sys.stdout.write(proc.stdout)
    sys.stderr.write(proc.stderr)
    failed = set()
    for line in proc.stdout.splitlines():
        m = _FAILED_RE.match(line.strip())
        if m:
            failed.add(m.group(1))
    return proc.returncode, failed


_OUTCOME_RE = re.compile(r"^(\S+)\s+(PASSED|XPASS)\b")


def confirm_stale_by_rerun(stale: set) -> set:
    """Re-run the stale candidates alone; return only those that PASS.

    "Did not fail" is not "now passes": an env-gated skipif (e.g. the
    shard_map guards) or a deleted/uncollected test also never appears in
    the failure set. Such entries are NOT provably stale and must keep
    their baseline lines, so only an entry that verifiably runs green here
    may hard-fail the gate.
    """
    print(f"[check_new_failures] confirming {len(stale)} stale candidate(s) "
          "with targeted re-runs", flush=True)
    confirmed = set()
    # one candidate per invocation: a single unknown nodeid (deleted test)
    # in a combined run makes pytest run NOTHING, masking the others
    for t in sorted(stale):
        cmd = [sys.executable, "-m", "pytest", "-v", "--no-header",
               "--tb=no", t]
        proc = subprocess.run(cmd, cwd=HERE.parent, capture_output=True,
                              text=True)
        for line in proc.stdout.splitlines():
            m = _OUTCOME_RE.match(line.strip())
            if m and m.group(1) == t:
                confirmed.add(t)
                break
    return confirmed


def evaluate(known: set, code: int, failed: set, filtered: bool,
             confirm_stale=None) -> int:
    """Pure gate decision: pytest outcome + baseline -> exit code.

    `filtered` means extra pytest args narrowed collection (-m/-k/path), so
    a baseline entry that did not fail may simply not have run.

    `confirm_stale`, when given, maps the stale candidate set to the subset
    proven to actually pass (see confirm_stale_by_rerun); candidates it
    rejects (skipped / uncollected) only warn instead of hard-failing.
    """
    if code == 0:
        stale = known  # everything passed; the whole baseline is stale
        new = set()
    elif code == 1:
        if not failed:
            # exit-code/parse mismatch: pytest reported failures but none
            # were parsed from the -rfE summary (e.g. a flag or plugin
            # suppressed it) — never let a red run pass the gate
            print("[check_new_failures] pytest exited 1 but no FAILED/ERROR "
                  "summary lines were parsed — refusing to pass")
            return 1
        new = failed - known
        stale = known - failed
    else:
        print(f"[check_new_failures] pytest exited {code} (hard error; "
              "collection problem or internal error) — failing outright")
        return code
    if new:
        # report new failures FIRST and skip the stale confirmation below:
        # its per-candidate re-runs could not change this exit code
        print(f"[check_new_failures] {len(new)} NEW failure(s) beyond the "
              "known baseline:")
        for t in sorted(new):
            print(f"  {t}")
        return 1
    rc = 0
    if stale and not filtered and confirm_stale is not None:
        proven = set(confirm_stale(stale))
        unproven = stale - proven
        stale = proven
        if unproven:
            print("[check_new_failures] note: baseline entries did not fail "
                  "but also did not verifiably pass (skipped/uncollected) — "
                  "keeping their lines:")
            for t in sorted(unproven):
                print(f"  {t}")
    if stale:
        if filtered:
            # a filtered run (-m/-k/path) may simply not have collected the
            # baseline entry — stale-ness is only provable unfiltered
            print("[check_new_failures] WARNING: baseline entries did not "
                  "fail under this filtered run (not necessarily stale):")
        else:
            # a baseline entry that PASSES is a fixed bug still allowlisted:
            # it could regress without tripping the gate. Fail until the
            # line is deleted so fixes can never rot in the baseline.
            print("[check_new_failures] STALE: baseline entries now pass — "
                  "delete them from tests/known_failures.txt:")
            rc = 1
        for t in sorted(stale):
            print(f"  {t}")
    if rc:
        return rc
    print(f"[check_new_failures] OK: {len(failed)} failure(s), all in the "
          f"known baseline ({len(known)} entries)")
    return 0


#: long pytest flags under which "baseline entry did not fail" proves
#: nothing: collection filters AND run truncators (--maxfail/--stepwise
#: stop before later baseline entries get a chance to fail)
_FILTER_LONG = ("--ignore", "--ignore-glob", "--deselect", "--last-failed",
                "--lf", "--failed-first", "--ff", "--exitfirst", "--maxfail",
                "--stepwise", "--sw")
#: non-filter long flags that consume the NEXT argv entry as their value (so
#: the value is not mistaken for a positional path); prefer --flag=value
#: form for anything not listed here
_VALUED_LONG = ("--tb", "--durations", "--timeout", "--color", "--junitxml",
                "--junit-xml", "--cov", "--cov-report", "--basetemp",
                "--rootdir", "--html", "--result-log")
#: short options whose value is the remainder of the cluster (or, when the
#: cluster ends there, the next argv entry) — e.g. "-rx" is -r's value "x",
#: NOT -r plus -x
_VALUED_SHORT = "poWcnr"


def _short_cluster(a: str):
    """Classify a combined short-option cluster like "-xq" or "-rfE".

    Returns (narrows, consumes_next): `narrows` if the cluster contains a
    collection filter (-m/-k) or the -x run truncator; `consumes_next` if
    its final option takes a value that must come from the next argv entry.
    """
    i = 1
    while i < len(a):
        ch = a[i]
        if ch in "mk":
            return True, False  # filter; value is the remainder or next arg
        if ch == "x":
            return True, False  # early stop: later entries never ran
        if ch in _VALUED_SHORT:
            return False, i + 1 == len(a)  # remainder is this option's value
        i += 1
    return False, False


def narrows_collection(argv) -> bool:
    """True only for args under which stale-ness is unprovable: anything
    that can shrink the collected test set OR truncate the run early.

    A benign forwarded flag (e.g. `-p no:cacheprovider`, `-q`, `-rfE`) must
    NOT disable the stale-baseline hard failure — only -m/-k/--ignore/
    --deselect-style filters, early-stop flags (-x, --maxfail, --stepwise,
    including combined forms like "-xq") and positional paths/nodeids do.
    """
    skip_next = False
    for a in argv:
        if skip_next:
            skip_next = False
            continue
        if a.startswith("--"):
            if any(a == f or a.startswith(f + "=") for f in _FILTER_LONG):
                return True
            if a in _VALUED_LONG:
                skip_next = True
            continue  # some other long flag (boolean or --flag=value form)
        if a.startswith("-") and len(a) > 1:
            narrows, consumes = _short_cluster(a)
            if narrows:
                return True
            skip_next = consumes
            continue
        return True  # positional path / test id
    return False


def split_baseline_arg(argv):
    """Extract our own --baseline option; everything else goes to pytest."""
    baseline, rest = BASELINE, []
    it = iter(argv)
    for a in it:
        if a == "--baseline":
            try:
                baseline = Path(next(it))
            except StopIteration:
                raise SystemExit("--baseline requires a path argument")
        elif a.startswith("--baseline="):
            baseline = Path(a.split("=", 1)[1])
        else:
            rest.append(a)
    return baseline, rest


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    baseline, argv = split_baseline_arg(argv)
    known = load_baseline(baseline)
    code, failed = run_pytest(argv)
    return evaluate(known, code, failed, filtered=narrows_collection(argv),
                    confirm_stale=confirm_stale_by_rerun)


if __name__ == "__main__":
    sys.exit(main())
