"""Time-varying intervention schedules through the whole stack.

Covers the contract pinned in ISSUE 3:
  * kernel-vs-oracle parity for multiple schedules on sir AND siard,
  * the no-window path is bit-identical to the constant-theta path
    (engine trajectories and the full run_abc accepted set),
  * an intervention-enabled fit recovers a mid-horizon contact-rate drop,
  * a campaign sweeps lockdown-day x scale scenarios with ONE compiled
    wave loop,
  * the forecast entry point emits strict-JSON credible bands,
  * interpret dispatch is backend-aware and plumbed through ABCConfig.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abc import ABCConfig, run_abc
from repro.core.priors import schedule_prior
from repro.epi import engine
from repro.epi.data import get_dataset, synthetic_dataset
from repro.epi.models import get_model
from repro.epi.spec import EMPTY_SCHEDULE, EpiModelConfig, InterventionSchedule
from repro.kernels import abc_sim, ops, ref

POP = 1e6
KW = dict(population=POP, a0=100.0, r0=5.0, d0=1.0)


def _observed(model, days, seed=0):
    cfg = EpiModelConfig(population=POP, num_days=days, a0=100.0, r0=5.0, d0=1.0)
    th = jnp.asarray([model.default_theta], jnp.float32)
    return engine.simulate_observed(model, th, jax.random.PRNGKey(seed), cfg)[0]


# --------------------------------------------------------------- spec layer

def test_schedule_validation():
    with pytest.raises(ValueError, match="strictly increasing"):
        InterventionSchedule.inferred(("alpha",), (10, 10))
    with pytest.raises(ValueError, match="positive"):
        InterventionSchedule.inferred(("alpha",), (0,))
    with pytest.raises(ValueError, match="no tv_params"):
        InterventionSchedule((), (5,), ((0.5,),), ((0.5,),))
    with pytest.raises(ValueError, match="not a parameter"):
        InterventionSchedule.inferred(("nope",), (5,)).shape(get_model("sir"))
    s = InterventionSchedule.fixed(("alpha",), (10, 20), (0.3, 0.8))
    assert s.n_windows == 2 and s.n_tv == 1 and s.n_scales == 2
    assert s.fixed_scales() == ((0.3,), (0.8,))
    assert s.scale_param_names() == ("alpha_w1", "alpha_w2")
    m = get_model("siard")
    assert s.param_width(m) == m.n_params + 2
    assert s.shape(m).tv_indices == (m.param_names.index("alpha"),)


def test_schedule_prior_widens_and_pins():
    m = get_model("siard")
    s = InterventionSchedule(
        ("alpha",), (10, 20), ((0.4,), (0.2,)), ((0.4,), (1.0,))
    )
    p = schedule_prior(m, s)
    assert p.dim == m.n_params + 2
    assert p.lows[-2:] == (0.4, 0.2) and p.highs[-2:] == (0.4, 1.0)
    assert p.free_dims()[-2:] == (False, True)
    th = p.sample(jax.random.PRNGKey(0), (64,))
    # pinned dim samples exactly its value; log_pdf stays finite there
    assert np.all(np.asarray(th[:, -2]) == np.float32(0.4))
    assert np.all(np.isfinite(np.asarray(p.log_pdf(th))))
    assert schedule_prior(m, None).dim == m.n_params
    assert schedule_prior(m, EMPTY_SCHEDULE).dim == m.n_params


# ------------------------------------------------------------- engine layer

def test_engine_empty_schedule_bit_identical():
    m = get_model("siard")
    cfg = EpiModelConfig(population=POP, num_days=15, a0=100.0)
    th = m.prior().sample(jax.random.PRNGKey(1), (16,))
    key = jax.random.PRNGKey(2)
    base = np.asarray(engine.simulate(m, th, key, cfg))
    for sched in (None, EMPTY_SCHEDULE):
        out = np.asarray(engine.simulate(m, th, key, cfg, sched))
        np.testing.assert_array_equal(base, out)
    obs = _observed(m, 15)
    d0, _ = engine.simulate_observed_lowmem(m, th, key, cfg, obs)
    d1, _ = engine.simulate_observed_lowmem(m, th, key, cfg, obs, EMPTY_SCHEDULE)
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(d1))


def test_engine_unit_scales_bit_identical():
    """A schedule whose scales are pinned at 1.0 must not change a bit."""
    m = get_model("siard")
    cfg = EpiModelConfig(population=POP, num_days=15, a0=100.0)
    th = m.prior().sample(jax.random.PRNGKey(1), (16,))
    key = jax.random.PRNGKey(2)
    sched = InterventionSchedule.fixed(("alpha", "gamma"), (5, 10), ((1.0, 1.0), (1.0, 1.0)))
    thw = jnp.concatenate([th, jnp.ones((16, 4), jnp.float32)], axis=1)
    base = np.asarray(engine.simulate(m, th, key, cfg))
    out = np.asarray(engine.simulate(m, thw, key, cfg, sched))
    np.testing.assert_array_equal(base, out)


def test_engine_contact_drop_suppresses_epidemic():
    """Scaling the contact-rate params to ~0 mid-horizon must flatten the
    infected trajectory relative to the unscaled run."""
    m = get_model("siard")
    days = 30
    cfg = EpiModelConfig(population=POP, num_days=days, a0=100.0)
    th = jnp.asarray([m.default_theta], jnp.float32)
    key = jax.random.PRNGKey(0)
    base = np.asarray(engine.simulate(m, th, key, cfg))  # [1, T, n_state]
    sched = InterventionSchedule.fixed(("alpha0", "alpha"), (10,), ((0.0, 0.0),))
    thw = jnp.concatenate([th, jnp.zeros((1, 2), jnp.float32)], axis=1)
    locked = np.asarray(engine.simulate(m, thw, key, cfg, sched))
    s_idx = m.compartments.index("S")
    # before the breakpoint the trajectories agree exactly (same noise)
    np.testing.assert_array_equal(base[:, :10], locked[:, :10])
    # with zero infection hazard, S stops draining after the breakpoint
    assert locked[0, -1, s_idx] == pytest.approx(locked[0, 10, s_idx])
    assert base[0, -1, s_idx] < locked[0, -1, s_idx]


def test_traced_breakpoints_match_static():
    m = get_model("sir")
    cfg = EpiModelConfig(population=POP, num_days=12, a0=50.0)
    sched = InterventionSchedule.fixed(("beta",), (6,), (0.5,))
    p = schedule_prior(m, sched)
    th = p.sample(jax.random.PRNGKey(3), (8,))
    key = jax.random.PRNGKey(4)
    a = engine.simulate_observed(m, th, key, cfg, sched)
    b = engine.simulate_observed(
        m, th, key, cfg, sched, breakpoints=jnp.asarray([6], jnp.int32)
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- kernel layer

_SCHEDULES = {
    "one_window_fixed": lambda tv: InterventionSchedule.fixed((tv,), (4,), (0.3,)),
    "two_window_inferred": lambda tv: InterventionSchedule.inferred(
        (tv,), (3, 8), low=0.2, high=1.5
    ),
}


@pytest.mark.parametrize("model_name,tv", [("siard", "alpha"), ("sir", "beta")])
@pytest.mark.parametrize("sched_name", sorted(_SCHEDULES))
def test_kernel_matches_ref_under_schedule(model_name, tv, sched_name):
    m = get_model(model_name)
    sched = _SCHEDULES[sched_name](tv)
    obs = _observed(m, 12)
    # 384 = 3 tiles of 128: a non-power-of-two batch that still divides the
    # explicit tile (explicit tiles never ghost-pad since the resolve_tile
    # validation landed; odd batches go through tile=None)
    th = schedule_prior(m, sched).sample(jax.random.PRNGKey(12), (384,))
    d_k = ops.abc_sim_distance(
        th, jnp.uint32(7), obs, tile=128, interpret=True, model=m,
        schedule=sched, **KW
    )
    d_r = ref.abc_sim_distance_ref(
        th, jnp.uint32(7), obs, model=m, schedule=sched, **KW
    )
    np.testing.assert_allclose(
        np.asarray(d_k), np.asarray(d_r), rtol=2e-6, atol=1e-3
    )


def test_kernel_schedule_tile_invariance():
    m = get_model("siard")
    sched = InterventionSchedule.inferred(("alpha",), (5,))
    obs = _observed(m, 10)
    th = schedule_prior(m, sched).sample(jax.random.PRNGKey(5), (512,))
    d1 = ops.abc_sim_distance(th, jnp.uint32(9), obs, tile=128,
                              interpret=True, model=m, schedule=sched, **KW)
    d2 = ops.abc_sim_distance(th, jnp.uint32(9), obs, tile=512,
                              interpret=True, model=m, schedule=sched, **KW)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


def test_kernel_traced_breakpoints_share_compile():
    """Sweeping the lockdown day must not grow the jit cache: breakpoints
    ride the iconst lanes, so only the schedule SHAPE is a compile key."""
    m = get_model("siard")
    obs = _observed(m, 10)
    base = ops._abc_sim_distance_jit._cache_size()
    for day in (3, 5, 7):
        sched = InterventionSchedule.fixed(("alpha",), (day,), (0.5,))
        th = schedule_prior(m, sched).sample(jax.random.PRNGKey(day), (128,))
        ops.abc_sim_distance(th, jnp.uint32(1), obs, tile=128,
                             interpret=True, model=m, schedule=sched, **KW)
    assert ops._abc_sim_distance_jit._cache_size() == base + 1


# ---------------------------------------------------------------- ABC layer

def _abc_cfg(**kw):
    base = dict(
        batch_size=2048, tolerance=5e3, target_accepted=20, strategy="outfeed",
        chunk_size=2048, max_runs=20, num_days=12, backend="xla_fused",
        model="siard",
    )
    base.update(kw)
    return ABCConfig(**base)


def test_run_abc_empty_schedule_same_accepted_set():
    """Regression pin: schedule=None (the pre-intervention code path) and an
    EMPTY schedule produce the SAME accepted set for the same seed."""
    ds = get_dataset("synthetic_small", num_days=12)
    for wave_loop in ("host", "device"):
        p_none = run_abc(ds, _abc_cfg(wave_loop=wave_loop), key=0)
        p_empty = run_abc(
            ds, _abc_cfg(wave_loop=wave_loop, schedule=EMPTY_SCHEDULE), key=0
        )
        np.testing.assert_array_equal(p_none.theta, p_empty.theta)
        np.testing.assert_array_equal(p_none.distances, p_empty.distances)
        assert p_none.runs == p_empty.runs
        assert tuple(p_empty.param_names) == tuple(p_none.param_names)


def test_intervention_fit_recovers_contact_drop():
    """The acceptance scenario: a SIARD country-style dataset generated WITH
    a mid-horizon contact-rate drop (alpha0 x0.1 from day 10) is fitted with
    an inferred single-window schedule. Differential check: the same fit
    pipeline on the SAME dynamics without the drop must place the scale
    posterior clearly higher — the intervention is detected from data."""
    import dataclasses as dc

    from repro.core.abc import calibrate_tolerance

    days = 24
    theta = (0.4, 30.0, 0.8, 0.05, 0.3, 0.01, 0.5, 1.0)
    fit_sched = InterventionSchedule.inferred(("alpha0",), (10,), 0.0, 2.0)
    means = {}
    for label, gen_sched in (
        ("drop", InterventionSchedule.fixed(("alpha0",), (10,), (0.1,))),
        ("flat", None),
    ):
        ds = synthetic_dataset(
            theta=theta, population=POP, num_days=days, a0=100.0, seed=11,
            name=f"synthetic_{label}", model="siard", schedule=gen_sched,
        )
        cfg = _abc_cfg(
            batch_size=8192, num_days=days, schedule=fit_sched,
            target_accepted=40, max_runs=40, chunk_size=8192,
        )
        eps = calibrate_tolerance(ds, cfg, key=1, quantile=1e-3, n_pilot=16384)
        post = run_abc(ds, dc.replace(cfg, tolerance=eps), key=1)
        assert len(post) >= 40
        assert post.param_names[-1] == "alpha0_w1"
        means[label] = float(post.theta[:, -1].mean())
    # prior mean is 1.0, generating value 0.1: the lockdown posterior sits
    # well below both the prior mean and the no-lockdown posterior
    assert means["drop"] < 0.9, means
    assert means["flat"] > means["drop"] + 0.2, means


def test_campaign_intervention_sweep_one_compile(tmp_path):
    """lockdown-day x scale grid: 4 scenarios, ONE compiled wave loop."""
    from repro.core.campaign import CampaignConfig, run_campaign

    ivs = tuple(
        InterventionSchedule.fixed(("alpha",), (day,), (scale,))
        for day in (5, 8)
        for scale in (0.4, 0.8)
    )
    cfg = CampaignConfig(
        datasets=("synthetic_small",), models=("siard",),
        backends=("xla_fused",), seeds=(0,), interventions=ivs,
        batch_size=1024, num_days=12, target_accepted=5,
        auto_quantile=0.02, pilot_size=1024, max_runs=30,
        out_dir=str(tmp_path / "iv_campaign"), checkpoint_every=8,
    )
    report = run_campaign(cfg)
    assert len(report.scenarios) == 4
    assert report.compiled_shapes == 1
    names = set()
    for r in report.scenarios:
        assert r.status == "ok", (r.name, r.status, r.detail)
        names.add(r.name)
        # the pinned scale comes back exactly (zero-width prior dim)
        sc = [s for s in ivs if s.tag() in r.name][0]
        want = sc.fixed_scales()[0][0]
        assert r.posterior_mean["alpha_w1"] == pytest.approx(want, rel=1e-5)
    assert len(names) == 4  # schedule tag disambiguates scenario names
    payload = json.loads(
        (tmp_path / "iv_campaign" / "campaign_report.json").read_text()
    )
    assert len(payload["scenarios"]) == 4


@pytest.mark.slow
def test_distributed_runners_use_widened_prior():
    """Sharded runner factories must sample the schedule-widened prior: a
    base-width prior would silently clamp the scale-column read (wrong
    distances) and then crash building the Posterior."""
    from conftest import run_in_subprocess

    out = run_in_subprocess(
        """
import jax
from repro.core.abc import ABCConfig, run_abc
from repro.core.distributed import make_runner, make_wave_runner
from repro.epi.data import get_dataset
from repro.epi.spec import InterventionSchedule
from repro.launch.mesh import make_host_mesh

ds = get_dataset("synthetic_small", num_days=12)
cfg = ABCConfig(batch_size=1024, tolerance=5e3, target_accepted=10,
                strategy="outfeed", chunk_size=256, max_runs=10, num_days=12,
                backend="xla_fused", model="siard",
                schedule=InterventionSchedule.inferred(("alpha0",), (6,)))
mesh = make_host_mesh(model=1)
p1 = run_abc(ds, cfg, key=0, run_fn=make_runner(mesh, ds, cfg))
p2 = run_abc(ds, cfg, key=0, wave_runner=make_wave_runner(mesh, ds, cfg))
assert p1.theta.shape[1] == 9 and p2.theta.shape[1] == 9
assert p1.param_names[-1] == "alpha0_w1"
print("OK", len(p1), len(p2))
""",
        n_devices=4,
    )
    assert "OK" in out


def test_smc_schedule_pinned_dims_survive_perturbation():
    """SMC with a mixed inferred+pinned schedule: pinned scale columns get
    zero perturbation noise and stay exactly at their value through every
    round; weights remain a valid distribution."""
    from repro.core.smc import SMCConfig, run_smc_abc

    ds = get_dataset("synthetic_small", num_days=12)
    sched = InterventionSchedule(
        ("alpha0",), (4, 8), ((0.0,), (0.5,)), ((2.0,), (0.5,))
    )
    cfg = SMCConfig(
        n_particles=32, batch_size=1024, n_rounds=2, num_days=12,
        schedule=sched,
    )
    post = run_smc_abc(ds, cfg, key=0)
    assert post.param_names[-2:] == ("alpha0_w1", "alpha0_w2")
    assert np.all(post.theta[:, -1] == np.float32(0.5))
    assert np.isfinite(post.weights).all() and post.weights.sum() > 0


# ------------------------------------------------------------ forecast + CLI

def test_posterior_forecast_strict_json():
    from repro.launch.abc_run import posterior_forecast

    ds = get_dataset("synthetic_small", num_days=12)
    cfg = _abc_cfg()
    post = run_abc(ds, cfg, key=0)
    bands = posterior_forecast(post.theta, ds, cfg, horizon=6, key=5)
    text = json.dumps(bands, allow_nan=False)  # strict JSON round-trip
    back = json.loads(text)
    assert back["total_days"] == 18 and back["fit_days"] == 12
    for name in ("A", "R", "D"):
        ch = back["channels"][name]
        assert len(ch["mean"]) == 18
        for lo, mid, hi in zip(ch["q05"], ch["q50"], ch["q95"]):
            assert lo <= mid <= hi
        assert len(back["observed"][name]) == 12


def test_posterior_forecast_counterfactual_schedule():
    """Forecasting under a DIFFERENT fixed schedule replaces the fitted
    scale columns with the counterfactual's pinned values."""
    from repro.launch.abc_run import posterior_forecast

    ds = get_dataset("synthetic_small", num_days=12)
    fit_sched = InterventionSchedule.inferred(("alpha",), (6,))
    cfg = _abc_cfg(schedule=fit_sched, tolerance=8e3)
    post = run_abc(ds, cfg, key=0)
    assert len(post) > 0
    cf = InterventionSchedule.fixed(("alpha",), (6,), (0.0,))
    bands = posterior_forecast(post.theta, ds, cfg, horizon=4, schedule=cf, key=2)
    assert bands["schedule"]["scale_lows"] == [[0.0]]
    json.dumps(bands, allow_nan=False)


def test_parse_intervention_grammar():
    from repro.launch.abc_run import parse_intervention

    assert parse_intervention("") is None
    assert parse_intervention("none") is None
    s = parse_intervention("alpha@25=0.3")
    assert s.breakpoints == (25,) and s.fixed_scales() == ((0.3,),)
    s = parse_intervention("alpha@25=0.1:1,40")
    assert s.breakpoints == (25, 40)
    assert s.scale_lows == ((0.1,), (0.0,))
    assert s.scale_highs == ((1.0,), (2.0,))
    s = parse_intervention("alpha+gamma@30=0.5+0.8")
    assert s.tv_params == ("alpha", "gamma")
    assert s.fixed_scales() == ((0.5, 0.8),)
    with pytest.raises(ValueError):
        parse_intervention("alpha25")


# ----------------------------------------------------------- interpret flag

def test_auto_interpret_is_backend_aware():
    # on this CPU container auto mode must pick the interpreter...
    assert jax.default_backend() == "cpu"
    assert abc_sim.auto_interpret() is True
    # ...and the auto decision is what a None flag resolves to
    assert ops._auto_interpret() is abc_sim.auto_interpret()


def test_abcconfig_interpret_plumbs_to_kernel(monkeypatch):
    from repro.core.abc import make_simulator
    from repro.kernels import ops as kernel_ops

    seen = {}
    real = kernel_ops.abc_sim_distance

    def spy(*a, **kw):
        seen["interpret"] = kw.get("interpret")
        return real(*a, **kw)

    monkeypatch.setattr(kernel_ops, "abc_sim_distance", spy)
    ds = get_dataset("synthetic_small", num_days=8)
    sim = make_simulator(ds, _abc_cfg(backend="pallas", interpret=True))
    d = sim(get_model("siard").prior().sample(jax.random.PRNGKey(0), (128,)),
            jax.random.PRNGKey(1))
    assert seen["interpret"] is True
    assert np.isfinite(np.asarray(d)).all()
    # None flows through so the kernel wrapper applies the backend default
    sim = make_simulator(ds, _abc_cfg(backend="pallas"))
    sim(get_model("siard").prior().sample(jax.random.PRNGKey(0), (128,)),
        jax.random.PRNGKey(1))
    assert seen["interpret"] is None
