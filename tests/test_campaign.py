"""Campaign subsystem: grid fan-out, compile reuse, checkpoint/resume,
aggregated report (the acceptance surface of the multi-scenario runner)."""

import json
from pathlib import Path

import numpy as np
import pytest

from repro.core.campaign import CampaignConfig, Scenario, run_campaign
from repro.core.abc import ABCConfig, run_abc
from repro.epi.data import get_dataset


def _cfg(tmp_path, **kw):
    base = dict(
        datasets=("italy", "new_zealand", "usa"),
        models=("siard", "seiard"),
        backends=("xla_fused",),
        seeds=(0,),
        batch_size=1024,
        num_days=10,
        target_accepted=6,
        auto_quantile=0.02,
        pilot_size=1024,
        max_runs=40,
        out_dir=str(tmp_path / "camp"),
        checkpoint_every=8,
    )
    base.update(kw)
    return CampaignConfig(**base)


@pytest.fixture(scope="module")
def campaign(tmp_path_factory):
    """One 3-countries x 2-models campaign shared by the assertions below."""
    tmp = tmp_path_factory.mktemp("campaign")
    cfg = _cfg(tmp)
    report = run_campaign(cfg)
    return cfg, report


def test_campaign_completes_all_scenarios(campaign):
    cfg, report = campaign
    assert len(report.scenarios) == 6  # 3 countries x 2 models
    for r in report.scenarios:
        assert r.status == "ok", (r.name, r.status, r.detail)
        assert r.n_accepted >= cfg.target_accepted
        assert r.runs >= 1
        assert r.simulations == r.runs * cfg.batch_size
        assert r.posterior_mean and r.posterior_std
        assert len(r.eps_schedule) >= 1 and r.tolerance == r.eps_schedule[-1]


def test_campaign_reuses_compiled_shapes(campaign):
    _, report = campaign
    # 2 models x 1 (days, batch, backend) shape -> 2 compiles for 6 scenarios
    assert report.compiled_shapes == 2


def test_campaign_writes_report_and_checkpoints(campaign):
    cfg, report = campaign
    out = Path(cfg.out_dir)
    payload = json.loads((out / "campaign_report.json").read_text())
    assert len(payload["scenarios"]) == 6
    for r in report.scenarios:
        ckpt = Path(r.checkpoint_dir)
        assert ckpt.is_dir() and list(ckpt.glob("step_*")), r.name
    assert "scenario" in report.summary_table()
    assert "6/6 scenarios complete" in report.summary_table()


def test_campaign_resumes_completed_scenarios_instantly(campaign):
    cfg, _ = campaign
    report2 = run_campaign(cfg)
    for r in report2.scenarios:
        assert r.status == "resumed_complete", (r.name, r.status)
        assert r.n_accepted >= cfg.target_accepted


def test_campaign_scenario_matches_solo_run(campaign):
    """A campaign cell is the SAME inference as a solo run_abc with that
    scenario's seed and tolerance — fanning out must not change streams."""
    cfg, report = campaign
    r = next(s for s in report.scenarios if s.dataset == "italy"
             and s.model == "siard")
    ds = get_dataset("italy", num_days=cfg.num_days, model="siard")
    solo = run_abc(
        ds,
        ABCConfig(
            batch_size=cfg.batch_size, tolerance=r.tolerance,
            target_accepted=cfg.target_accepted, strategy="outfeed",
            chunk_size=cfg.batch_size, max_runs=cfg.max_runs,
            num_days=cfg.num_days, backend="xla_fused", model="siard",
            wave_loop="device",
        ),
        key=0,
    )
    assert len(solo) == r.n_accepted
    assert solo.runs == r.runs
    np.testing.assert_allclose(
        solo.theta.mean(axis=0),
        np.asarray(list(r.posterior_mean.values()), np.float32),
        rtol=1e-5,
    )


def test_campaign_skips_incompatible_cells(tmp_path):
    """sir observes (I, R); the bundled country series are (A, R, D) — the
    cell must be recorded as skipped, not crash the campaign."""
    cfg = _cfg(tmp_path, datasets=("italy",), models=("sir", "siard"),
               max_runs=20)
    report = run_campaign(cfg)
    by_model = {r.model: r for r in report.scenarios}
    assert by_model["sir"].status == "skipped"
    assert "observes" in by_model["sir"].detail
    assert by_model["siard"].status == "ok"


def test_scenario_grid_expansion():
    cfg = CampaignConfig(datasets=("a", "b"), models=("m",), seeds=(0, 1),
                         backends=("xla", "xla_fused"))
    grid = cfg.scenarios()
    assert len(grid) == 2 * 1 * 2 * 2
    assert grid[0] == Scenario(dataset="a", model="m", backend="xla", seed=0)
    names = [s.name for s in grid]
    assert len(set(names)) == len(names)
