"""Cost model, tuning cache and stream-invariance tests (repro.core.tuning).

Three contracts:

  * the analytic cost model is DERIVED from the model spec — its per-day op
    count cross-checks against a jaxpr count of the full kernels/ref.py
    oracle (same counting currency) for every registered model, and its byte
    model reproduces the seed's hardwired SIARD constants exactly;
  * the tuning cache round-trips, a hit skips all measurement, and corrupt
    caches fail LOUDLY instead of silently retuning;
  * the auto-applied knobs are pure scheduling: accepted sets are
    bit-identical across Pallas tiles, and xla_fused distances are
    bit-identical across scan unroll factors.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tuning
from repro.core.abc import ABCConfig, make_simulator, run_abc
from repro.core.priors import schedule_prior
from repro.epi.data import get_dataset
from repro.epi.models import get_model
from repro.kernels import ref

DAYS = 10


@pytest.fixture(scope="module")
def ds():
    return get_dataset("synthetic_small", num_days=DAYS)


# --------------------------------------------------------------------------
# Cost model: spec-derived, cross-checked against the full oracle trace
# --------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["sir", "seir", "siard"])
def test_cost_model_flops_cross_check_vs_ref(model):
    """The one-day trace behind `cost_model` must agree with a jaxpr count of
    the FULL kernels/ref.py simulation (same currency: count_fn_ops), per
    sample-day, for every registered model — the 'derived from the spec, not
    hardwired' guarantee."""
    spec = get_model(model)
    days, batch = 30, 256
    cm = tuning.cost_model(model, days)
    obs = jnp.ones((spec.n_observed, days), jnp.float32)
    theta = jnp.ones((batch, spec.n_params), jnp.float32)

    def full(th):
        return ref.abc_sim_distance_ref(
            th, jnp.uint32(0), obs,
            population=1e6, a0=100.0, r0=5.0, d0=1.0, model=spec,
        )

    per_sample_day = tuning.count_fn_ops(full, theta) / (batch * days)
    # the full trace adds initial_state + finalize + observed preprocessing,
    # amortized over batch*days — agreement must be tight, not order-of-mag
    np.testing.assert_allclose(per_sample_day, cm.flops_per_sample_day,
                               rtol=0.15)
    assert cm.flops_per_sample_day > 50  # sanity: a real op count, not 0


def test_cost_model_bytes_reproduce_seed_constants():
    """SIARD byte model == the seed's hardwired roofline constants:
    fused 8*4+4 = 36 B/sample, naive (5+3+2*6)*4 = 80 B/sample-day."""
    cm = tuning.cost_model("siard", 49)
    assert cm.fused_bytes_per_sample == 36.0
    assert cm.naive_bytes_per_sample_day == 80.0
    assert cm.theta_width == 8
    # smaller models shrink proportionally (derived, not constant)
    sir = tuning.cost_model("sir", 49)
    assert sir.fused_bytes_per_sample == (sir.theta_width + 1) * 4.0
    assert sir.fused_bytes_per_sample < 36.0


def test_cost_model_schedule_widens_theta():
    from repro.epi.spec import InterventionSchedule

    sched = InterventionSchedule(
        tv_params=("beta",), breakpoints=(10,),
        scale_lows=((0.1,),), scale_highs=((1.0,),),
    )
    base = tuning.cost_model("siard", 49)
    wide = tuning.cost_model("siard", 49, schedule=sched)
    assert wide.theta_width > base.theta_width
    assert wide.fused_bytes_per_sample > base.fused_bytes_per_sample


def test_roofline_fields_shape_and_ceiling():
    cm = tuning.cost_model("siard", 49)
    out = tuning.roofline_metrics(cm, n_samples=1e6, wall_s=1.0)
    assert set(out) == {"achieved_flops", "achieved_bytes_per_s",
                        "arithmetic_intensity", "roofline_efficiency"}
    assert out["achieved_flops"] == pytest.approx(cm.flops(1e6))
    assert 0 < out["roofline_efficiency"] < 1  # CPU-second against TPU peak
    # doubling the wall clock halves achieved flops and efficiency
    slow = tuning.roofline_metrics(cm, n_samples=1e6, wall_s=2.0)
    assert slow["roofline_efficiency"] == pytest.approx(
        out["roofline_efficiency"] / 2
    )


# --------------------------------------------------------------------------
# Tuning cache: round-trip, hit-skips-measurement, loud corruption
# --------------------------------------------------------------------------

def _cfg(**kw):
    base = dict(batch_size=512, chunk_size=512, num_days=DAYS,
                tolerance=1.6e4, target_accepted=5, max_runs=2,
                backend="pallas")
    base.update(kw)
    return ABCConfig(**base)


def test_cache_round_trip(tmp_path):
    path = tmp_path / "cache.json"
    cache = tuning.TuningCache(path)
    assert cache.get("k") is None
    cache.put("k", {"tile": 256})
    assert cache.get("k") == {"tile": 256}
    # a fresh instance reads the persisted file
    assert tuning.TuningCache(path).get("k") == {"tile": 256}
    payload = json.loads(path.read_text())
    assert payload["schema"] == tuning.CACHE_SCHEMA


def test_corrupt_cache_raises_loudly(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{not json")
    with pytest.raises(ValueError, match="corrupt tuning cache"):
        tuning.TuningCache(path).get("k")
    path.write_text(json.dumps({"schema": "something-else", "entries": {}}))
    with pytest.raises(ValueError, match="not a tuning-cache/v1"):
        tuning.TuningCache(path).get("k")
    path.write_text(json.dumps({"schema": tuning.CACHE_SCHEMA}))
    with pytest.raises(ValueError, match="not a tuning-cache/v1"):
        tuning.TuningCache(path).get("k")


def test_autotune_hit_skips_measurement(tmp_path, ds):
    cache = tuning.TuningCache(tmp_path / "cache.json")
    cfg = _cfg(autotune=True)
    calls = []

    def fake_measure(c, batch=None):
        calls.append((c.tile, c.scan_unroll, batch))
        return 1.0 if c.tile != 256 else 0.5  # tile 256 "wins"

    entry = tuning.autotune(ds, cfg, cache=cache, measure=fake_measure,
                            measure_batches=False)
    assert calls, "a cache miss must measure"
    assert entry["tile"] == 256
    # a HIT returns the persisted entry without measuring anything
    calls.clear()
    entry2 = tuning.autotune(ds, cfg, cache=cache, measure=fake_measure)
    assert calls == []
    assert entry2 == entry
    # ... even through a fresh cache instance on the same file
    fresh = tuning.TuningCache(tmp_path / "cache.json")
    entry3 = tuning.autotune(ds, cfg, cache=fresh, measure=fake_measure)
    assert calls == [] and entry3["tile"] == 256


def test_autotune_xla_fused_searches_unroll(tmp_path, ds):
    cache = tuning.TuningCache(tmp_path / "cache.json")
    cfg = _cfg(backend="xla_fused", autotune=True)

    def fake_measure(c, batch=None):
        return 0.25 if c.scan_unroll == 4 else 1.0

    entry = tuning.autotune(ds, cfg, cache=cache, measure=fake_measure,
                            measure_batches=False)
    assert entry["scan_unroll"] == 4
    assert "tile" not in entry


def test_resolve_tuned_applies_winner_but_explicit_wins(tmp_path, ds):
    cache = tuning.TuningCache(tmp_path / "cache.json")
    cfg = _cfg(autotune=True)
    cache.put(tuning.cfg_cache_key(cfg),
              {"tile": 256, "scan_unroll": 4, "best_batch": 1024})
    tuned = tuning.resolve_tuned(ds, cfg, cache=cache)
    assert tuned.tile == 256
    assert tuned.autotune is False  # never re-enters the tuner downstream
    assert tuned.batch_size == cfg.batch_size  # best_batch is advisory ONLY
    # an explicit user tile beats the cached winner
    explicit = dataclasses.replace(cfg, tile=128)
    tuned2 = tuning.resolve_tuned(ds, explicit, cache=cache)
    assert tuned2.tile == 128
    # autotune=False is a no-op passthrough
    off = dataclasses.replace(cfg, autotune=False)
    assert tuning.resolve_tuned(ds, off, cache=cache) is off


def test_tile_candidates_respect_divisibility():
    assert tuning.tile_candidates(8192) == (256, 512, 1024, 2048, 4096)
    assert tuning.tile_candidates(512) == (256, 512)
    # nothing divides 300: no explicit candidates (auto would ghost-pad)
    assert tuning.tile_candidates(300) == ()


def test_cache_key_separates_the_tuning_dimensions():
    keys = {
        tuning.cache_key(backend=b, model=m, days=d, batch=n)
        for b in ("pallas", "xla_fused")
        for m in ("siard", "sir")
        for d in (10, 49)
        for n in (512, 8192)
    }
    assert len(keys) == 16


# --------------------------------------------------------------------------
# Stream invariance of the auto-applied knobs (the safety contract)
# --------------------------------------------------------------------------

def test_accepted_sets_bit_identical_across_tiles(ds):
    """The ISSUE 6 pin: tile is pure scheduling — run_abc on the pallas
    backend must accept the SAME particles (bit-identical theta and
    distances) for every compatible tile."""
    posts = [
        run_abc(ds, _cfg(tile=t), key=0) for t in (128, 256, 512)
    ]
    base = posts[0]
    assert base.simulations > 0
    for p in posts[1:]:
        assert p.simulations == base.simulations
        assert np.array_equal(p.theta, base.theta)
        assert np.array_equal(p.distances, base.distances)


def test_xla_fused_distances_bit_identical_across_unroll(ds):
    theta = schedule_prior(get_model("siard")).sample(
        jax.random.PRNGKey(0), (512,)
    )
    key = jax.random.PRNGKey(1)
    sims = [
        make_simulator(ds, _cfg(backend="xla_fused", scan_unroll=u))
        for u in (1, 4)
    ]
    d1, d4 = (np.asarray(s(theta, key)) for s in sims)
    assert np.array_equal(d1, d4)
