"""Property tests for core/priors.py and core/distances.py.

Hypothesis-driven where available (nightly CI installs it); each property
also has a seeded non-hypothesis variant so tier-1 keeps coverage in
environments without the package (see tests/_hypothesis_compat.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.distances import DISTANCES
from repro.core.priors import UniformBoxPrior

# a deliberately lopsided box: zero-width-adjacent, negative lows, big highs
BOXES = [
    ((1.0, 100.0, 2.0), None),
    ((2.5, 0.1, 7.0, 1.0), (-1.0, 0.0, 3.0, 0.5)),
    ((1e-3,), (-1e-3,)),
]


# ---------------------------------------------------------------- priors
@pytest.mark.parametrize("highs,lows", BOXES)
def test_prior_samples_inside_box(highs, lows):
    prior = UniformBoxPrior(highs=highs, lows=lows)
    th = np.asarray(prior.sample(jax.random.PRNGKey(0), (4096,)))
    lo = np.asarray(prior.lows)
    hi = np.asarray(prior.highs)
    assert (th >= lo).all() and (th <= hi).all()
    # every dimension actually spreads over its box (not collapsed)
    span = th.max(axis=0) - th.min(axis=0)
    assert (span > 0.5 * (hi - lo)).all()


@pytest.mark.parametrize("highs,lows", BOXES)
def test_prior_log_pdf_finite_exactly_inside(highs, lows):
    prior = UniformBoxPrior(highs=highs, lows=lows)
    lo = np.asarray(prior.lows, np.float32)
    hi = np.asarray(prior.highs, np.float32)
    inside = (lo + hi) / 2.0
    on_edge = hi.copy()
    outside = hi + (hi - lo) * 0.01 + 1e-6
    lp = np.asarray(prior.log_pdf(jnp.asarray([inside, on_edge, outside])))
    assert np.isfinite(lp[0])
    assert np.isfinite(lp[1])  # closed box: the boundary is inside
    assert lp[2] == -np.inf
    # the density integrates to one => log_pdf == -log(volume)
    np.testing.assert_allclose(lp[0], -np.sum(np.log(hi - lo)), rtol=1e-5)


@given(
    lows=st.lists(st.floats(-10, 10, allow_nan=False, width=32), min_size=1,
                  max_size=6),
    widths=st.lists(st.floats(0.01, 20, allow_nan=False, width=32), min_size=1,
                    max_size=6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_prior_sample_logpdf_consistent(lows, widths, seed):
    n = min(len(lows), len(widths))
    lows = tuple(lows[:n])
    highs = tuple(l + w for l, w in zip(lows, widths[:n]))
    prior = UniformBoxPrior(highs=highs, lows=lows)
    th = prior.sample(jax.random.PRNGKey(seed), (256,))
    lp = np.asarray(prior.log_pdf(th))
    assert np.isfinite(lp).all()  # own samples always have finite log-prob
    th_np = np.asarray(th)
    assert (th_np >= np.asarray(lows, np.float32)).all()
    assert (th_np <= np.asarray(highs, np.float32)).all()


# -------------------------------------------------------------- distances
def _fake_series(key, batch=32, channels=3, days=20):
    ks, ko = jax.random.split(jax.random.PRNGKey(key))
    sim = jax.random.uniform(ks, (batch, channels, days), jnp.float32) * 1e3
    obs = jax.random.uniform(ko, (channels, days), jnp.float32) * 1e3
    return sim, obs


@pytest.mark.parametrize("name", sorted(DISTANCES))
def test_distance_nonnegative_and_zero_on_identical(name):
    dist = DISTANCES[name]
    sim, obs = _fake_series(0)
    d = np.asarray(dist(sim, obs))
    assert d.shape == (sim.shape[0],)
    assert (d >= 0).all()
    # a batch row equal to the observation has distance exactly zero
    sim_eq = sim.at[3].set(obs)
    d_eq = np.asarray(dist(sim_eq, obs))
    assert d_eq[3] == 0.0


@pytest.mark.parametrize("name", sorted(DISTANCES))
def test_distance_permutation_stable_across_batch(name):
    """Permuting the batch axis permutes distances identically — no row may
    influence another's distance (the independence ABC relies on)."""
    dist = DISTANCES[name]
    sim, obs = _fake_series(1)
    perm = np.random.default_rng(0).permutation(sim.shape[0])
    d = np.asarray(dist(sim, obs))
    d_perm = np.asarray(dist(sim[perm], obs))
    np.testing.assert_array_equal(d[perm], d_perm)


@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-2, 1e4, allow_nan=False, width=32),
)
@settings(max_examples=25, deadline=None)
def test_distance_triangle_like_properties(seed, scale):
    """Euclidean distance: symmetry under sim/obs swap and absolute
    homogeneity under scaling of the difference."""
    sim, obs = _fake_series(seed % 1000, batch=4)
    dist = DISTANCES["euclidean"]
    d = np.asarray(dist(sim, obs))
    # swap: d(sim_i, obs) == d(obs_broadcast, sim_i) computed rowwise
    d_swapped = np.asarray(
        jnp.stack([dist(obs[None], sim[i]) for i in range(4)]).ravel()
    )
    np.testing.assert_allclose(d, d_swapped, rtol=1e-5)
    # homogeneity: scaling both by c scales the distance by c
    d_scaled = np.asarray(dist(sim * scale, obs * scale))
    np.testing.assert_allclose(d_scaled, d * scale, rtol=1e-4)


def test_hypothesis_shim_status():
    """Document (in the test report) whether the property tests above ran
    under hypothesis or as seeded fallbacks."""
    assert HAVE_HYPOTHESIS in (True, False)
