"""Integration: the production step builders actually EXECUTE on a sharded
mesh (8 host devices, 2x4 data x model), for train, prefill and decode."""

import pytest

from conftest import run_in_subprocess


@pytest.mark.slow
def test_train_prefill_decode_execute_sharded():
    out = run_in_subprocess(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.shapes import InputShape
from repro.launch.steps import build_train_step, build_prefill_step, build_decode_step
from repro.models.registry import get_model
from repro.optim import adamw_init

from repro.launch.mesh import make_compat_mesh, set_mesh_compat
mesh = make_compat_mesh((2, 4), ("data", "model"))
results = []
with set_mesh_compat(mesh):
    for arch in ("gemma2-27b", "qwen3-moe-30b-a3b", "mamba2-130m"):
        model = get_model(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab

        # --- train step
        shape = InputShape("t", "train", 32, 4)
        built = build_train_step(model, mesh, shape, microbatch=2)
        opt = adamw_init(params)
        toks = jnp.asarray(np.arange(4 * 32).reshape(4, 32) % 7, jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        params_s = jax.device_put(params, built.in_shardings[0])
        opt_s = jax.device_put(opt, built.in_shardings[1])
        batch_s = jax.device_put(batch, built.in_shardings[2])
        p2, o2, metrics = built.fn(params_s, opt_s, batch_s)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), (arch, loss)

        # --- prefill
        shape_p = InputShape("p", "prefill", 32, 4)
        built_p = build_prefill_step(model, mesh, shape_p)
        params = model.init_params(jax.random.PRNGKey(0))  # p2 was donated
        spec, _ = model.make_inputs("prefill", 4, 32)
        concrete = {k: jnp.zeros(s.shape, s.dtype) + (1 if s.dtype == jnp.int32 else 0.1)
                    for k, s in spec.items()}
        params_p = jax.device_put(params, built_p.in_shardings[0])
        concrete = jax.device_put(concrete, built_p.in_shardings[1])
        logits = built_p.fn(params_p, concrete)
        assert logits.shape[-1] == vocab and logits.shape[1] == 1
        assert bool(jnp.all(jnp.isfinite(logits)))

        # --- decode (skip encdec-style extras; these 3 are decoder-like)
        shape_d = InputShape("d", "decode", 32, 4)
        built_d = build_decode_step(model, mesh, shape_d)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             model.init_cache_shape(4, 32),
                             is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        dbatch = {"tokens": jnp.ones((4, 1), jnp.int32), "pos": jnp.asarray(0, jnp.int32)}
        params_d = jax.device_put(params, built_d.in_shardings[0])
        cache = jax.device_put(cache, built_d.in_shardings[1])
        dbatch = jax.device_put(dbatch, built_d.in_shardings[2])
        dl, cache = built_d.fn(params_d, cache, dbatch)
        assert dl.shape == (4, 1, vocab)
        assert bool(jnp.all(jnp.isfinite(dl)))
        results.append((arch, loss))
print("OK", results)
""",
        n_devices=8,
        timeout=900,
    )
    assert "OK" in out
