"""Statistical known-recovery suite: ABC must find planted ground truth.

Synthetic observations are simulated from a registry model at KNOWN
parameters; the posterior mean must land within a prior-width-scaled
tolerance of the truth (SBI validation baseline: if this fails, the sampler
is silently wrong no matter how fast it runs). Fast seeded variants run in
tier-1; the wider sweeps are `slow`-marked for the nightly job.
"""

import jax
import numpy as np
import pytest

from repro.core.abc import ABCConfig, make_simulator, run_abc
from repro.core.smc import SMCConfig, run_smc_abc
from repro.epi.data import synthetic_dataset
from repro.epi.models import get_model

DAYS = 15
POP = 1e6

#: generating parameters (chosen well inside the prior box so recovery is
#: identifiable within small test budgets)
TRUTH = {
    "sir": (0.5, 0.2, 1.0),
    "seir": (0.6, 0.3, 0.2, 1.0),
}

#: per-parameter error budget as a fraction of the prior width: |post_mean -
#: truth| <= REL_TOL * (high - low). Wide enough for small seeded runs,
#: tight enough that a silently-wrong sampler (shifted stream, broken accept
#: compaction, wrong prior box) fails decisively.
REL_TOL = 0.30


def _dataset(model: str):
    return synthetic_dataset(
        theta=TRUTH[model], population=POP, num_days=DAYS, a0=100.0,
        seed=11, name=f"recovery_{model}", model=model,
    )


def _tolerance(ds, model: str, quantile: float) -> float:
    cfg = ABCConfig(batch_size=4096, num_days=DAYS, chunk_size=4096,
                    backend="xla_fused", model=model)
    sim = jax.jit(make_simulator(ds, cfg))
    th = get_model(model).prior().sample(jax.random.PRNGKey(5), (4096,))
    d = np.asarray(sim(th, jax.random.PRNGKey(6)))
    return float(np.quantile(d[np.isfinite(d)], quantile))


def _assert_recovers(theta_post: np.ndarray, model: str, rel_tol=REL_TOL):
    spec = get_model(model)
    prior = spec.prior()
    true = np.asarray(TRUTH[model], np.float32)
    width = np.asarray(prior.highs, np.float32) - np.asarray(
        prior.lows, np.float32
    )
    post_mean = theta_post.mean(axis=0)
    err = np.abs(post_mean - true) / width
    assert (err <= rel_tol).all(), (
        f"{model}: normalized posterior-mean error {err} exceeds {rel_tol} "
        f"(post_mean={post_mean}, truth={true})"
    )
    # ...and the posterior must genuinely contract vs the prior
    prior_mean = (np.asarray(prior.highs) + np.asarray(prior.lows)) / 2.0
    err_prior = np.abs(prior_mean - true) / width
    assert err.mean() < err_prior.mean()


@pytest.mark.parametrize("model", ["sir", "seir"])
def test_run_abc_recovers_truth(model):
    ds = _dataset(model)
    eps = _tolerance(ds, model, quantile=5e-3)
    cfg = ABCConfig(
        batch_size=4096, tolerance=eps, target_accepted=60, chunk_size=4096,
        max_runs=60, num_days=DAYS, backend="xla_fused", model=model,
    )
    post = run_abc(ds, cfg, key=0)
    assert len(post) >= 60
    _assert_recovers(post.theta, model)


@pytest.mark.parametrize("model", ["sir", "seir"])
@pytest.mark.parametrize("wave_loop", ["host", "device"])
def test_run_smc_abc_recovers_truth(model, wave_loop):
    """SMC-ABC recovery, on both the host proposal loop and the
    device-resident round loop (different RNG streams, same statistics)."""
    ds = _dataset(model)
    cfg = SMCConfig(
        n_particles=96, batch_size=4096, n_rounds=3, quantile=0.4,
        num_days=DAYS, backend="xla_fused", model=model, wave_loop=wave_loop,
    )
    post = run_smc_abc(ds, cfg, key=1)
    assert len(post) == 96
    assert np.isfinite(post.distances).all()
    _assert_recovers(post.theta, model)


@pytest.mark.slow
@pytest.mark.parametrize("model", ["sir", "seir"])
def test_run_abc_recovery_tightens_with_tolerance(model):
    """Nightly: decreasing epsilon must (weakly) improve recovery — the
    hallmark of a correct ABC approximation, and exactly the property a
    silently-wrong device loop would break."""
    ds = _dataset(model)
    errs = []
    for q in (5e-2, 5e-3):
        eps = _tolerance(ds, model, quantile=q)
        cfg = ABCConfig(
            batch_size=8192, tolerance=eps, target_accepted=100,
            chunk_size=8192, max_runs=200, num_days=DAYS,
            backend="xla_fused", model=model,
        )
        post = run_abc(ds, cfg, key=2)
        assert len(post) >= 100
        spec = get_model(model)
        width = np.asarray(spec.prior().highs) - np.asarray(spec.prior().lows)
        err = np.abs(post.theta.mean(axis=0) - np.asarray(TRUTH[model])) / width
        errs.append(err.mean())
    assert errs[1] <= errs[0] * 1.25, errs  # allow MC noise, forbid blowup
    _assert_recovers_final = errs[1]
    assert _assert_recovers_final <= 0.2
