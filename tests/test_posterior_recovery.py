"""Statistical known-recovery suite: ABC must find planted ground truth.

Synthetic observations are simulated from a registry model at KNOWN
parameters; the posterior mean must land within a prior-width-scaled
tolerance of the truth (SBI validation baseline: if this fails, the sampler
is silently wrong no matter how fast it runs). Fast seeded variants run in
tier-1; the wider sweeps are `slow`-marked for the nightly job.

The amortized backend (`backend="npe"`, repro.core.npe) is additionally
held to the ABC posterior as an ACCURACY ORACLE: its credible intervals
must overlap ABC's and its posterior mean must not drift from the ABC mean
by more than a prior-width-scaled bound — the validation story every SBI
method comparison relies on.
"""

import jax
import numpy as np
import pytest

from repro.core.abc import ABCConfig, make_simulator, run_abc
from repro.core.npe import NPEConfig
from repro.core.smc import SMCConfig, run_smc_abc
from repro.epi.data import synthetic_dataset
from repro.epi.models import get_model

DAYS = 15
POP = 1e6

#: generating parameters (chosen well inside the prior box so recovery is
#: identifiable within small test budgets)
TRUTH = {
    "sir": (0.5, 0.2, 1.0),
    "seir": (0.6, 0.3, 0.2, 1.0),
}

#: per-parameter error budget as a fraction of the prior width: |post_mean -
#: truth| <= REL_TOL * (high - low). Wide enough for small seeded runs,
#: tight enough that a silently-wrong sampler (shifted stream, broken accept
#: compaction, wrong prior box) fails decisively.
REL_TOL = 0.30


def _dataset(model: str):
    return synthetic_dataset(
        theta=TRUTH[model], population=POP, num_days=DAYS, a0=100.0,
        seed=11, name=f"recovery_{model}", model=model,
    )


def _tolerance(ds, model: str, quantile: float) -> float:
    cfg = ABCConfig(batch_size=4096, num_days=DAYS, chunk_size=4096,
                    backend="xla_fused", model=model)
    sim = jax.jit(make_simulator(ds, cfg))
    th = get_model(model).prior().sample(jax.random.PRNGKey(5), (4096,))
    d = np.asarray(sim(th, jax.random.PRNGKey(6)))
    return float(np.quantile(d[np.isfinite(d)], quantile))


def _assert_recovers(theta_post: np.ndarray, model: str, rel_tol=REL_TOL):
    spec = get_model(model)
    prior = spec.prior()
    true = np.asarray(TRUTH[model], np.float32)
    width = np.asarray(prior.highs, np.float32) - np.asarray(
        prior.lows, np.float32
    )
    post_mean = theta_post.mean(axis=0)
    err = np.abs(post_mean - true) / width
    assert (err <= rel_tol).all(), (
        f"{model}: normalized posterior-mean error {err} exceeds {rel_tol} "
        f"(post_mean={post_mean}, truth={true})"
    )
    # ...and the posterior must genuinely contract vs the prior
    prior_mean = (np.asarray(prior.highs) + np.asarray(prior.lows)) / 2.0
    err_prior = np.abs(prior_mean - true) / width
    assert err.mean() < err_prior.mean()


@pytest.mark.parametrize("model", ["sir", "seir"])
def test_run_abc_recovers_truth(model):
    ds = _dataset(model)
    eps = _tolerance(ds, model, quantile=5e-3)
    cfg = ABCConfig(
        batch_size=4096, tolerance=eps, target_accepted=60, chunk_size=4096,
        max_runs=60, num_days=DAYS, backend="xla_fused", model=model,
    )
    post = run_abc(ds, cfg, key=0)
    assert len(post) >= 60
    _assert_recovers(post.theta, model)


@pytest.mark.parametrize("model", ["sir", "seir"])
@pytest.mark.parametrize("wave_loop", ["host", "device"])
def test_run_smc_abc_recovers_truth(model, wave_loop):
    """SMC-ABC recovery, on both the host proposal loop and the
    device-resident round loop (different RNG streams, same statistics)."""
    ds = _dataset(model)
    cfg = SMCConfig(
        n_particles=96, batch_size=4096, n_rounds=3, quantile=0.4,
        num_days=DAYS, backend="xla_fused", model=model, wave_loop=wave_loop,
    )
    post = run_smc_abc(ds, cfg, key=1)
    assert len(post) == 96
    assert np.isfinite(post.distances).all()
    _assert_recovers(post.theta, model)


# ------------------------------------------------- NPE vs the ABC oracle

#: CI-sized estimator: ~1e5 simulated pairs, seconds of training. The
#: oracle bounds below are calibrated to THIS budget; raising the budget
#: only tightens the posteriors.
NPE_TEST = NPEConfig(train_steps=300, train_batch=256, n_pilot=256)

#: NPE-vs-ABC posterior-mean drift budget, as a fraction of prior width
#: (looser than REL_TOL: both posteriors carry their own MC/optimization
#: noise, and the bound must catch a silently-wrong estimator, not noise)
ORACLE_DRIFT = 0.25


def _npe_cfg(model: str) -> ABCConfig:
    return ABCConfig(num_days=DAYS, backend="npe", model=model,
                     target_accepted=256, npe=NPE_TEST)


def _abc_oracle(model: str, ds):
    eps = _tolerance(ds, model, quantile=5e-3)
    cfg = ABCConfig(
        batch_size=4096, tolerance=eps, target_accepted=60, chunk_size=4096,
        max_runs=60, num_days=DAYS, backend="xla_fused", model=model,
    )
    return run_abc(ds, cfg, key=0)


@pytest.mark.parametrize("model", ["sir", "seir"])
def test_npe_recovers_truth_and_agrees_with_abc_oracle(model):
    """backend='npe' through the run_abc front door: the amortized
    posterior must (a) recover the planted truth under the same bound as
    the wave backends, and (b) agree with the ABC oracle posterior —
    overlapping 90% credible intervals and bounded posterior-mean drift on
    every parameter."""
    ds = _dataset(model)
    npe_post = run_abc(ds, _npe_cfg(model), key=0)
    # the amortized contract: no waves, no tolerance, same Posterior type
    assert npe_post.runs == 0 and npe_post.tolerance == 0.0
    assert npe_post.theta.shape == (256, len(TRUTH[model]))
    assert np.isfinite(npe_post.distances).all()
    _assert_recovers(npe_post.theta, model)

    abc_post = _abc_oracle(model, ds)
    spec = get_model(model)
    width = np.asarray(spec.prior().highs, np.float32) - np.asarray(
        spec.prior().lows, np.float32
    )
    drift = np.abs(
        npe_post.theta.mean(axis=0) - abc_post.theta.mean(axis=0)
    ) / width
    assert (drift <= ORACLE_DRIFT).all(), (
        f"{model}: NPE-vs-ABC posterior-mean drift {drift} exceeds "
        f"{ORACLE_DRIFT} (npe={npe_post.theta.mean(axis=0)}, "
        f"abc={abc_post.theta.mean(axis=0)})"
    )
    for j, name in enumerate(npe_post.param_names):
        if width[j] < 1e-6:
            continue  # pinned dimension: both posteriors are a point
        npe_lo, npe_hi = np.quantile(npe_post.theta[:, j], [0.05, 0.95])
        abc_lo, abc_hi = np.quantile(abc_post.theta[:, j], [0.05, 0.95])
        overlap = min(npe_hi, abc_hi) - max(npe_lo, abc_lo)
        assert overlap > 0.0, (
            f"{model}.{name}: disjoint 90% credible intervals — "
            f"npe [{npe_lo:.4f}, {npe_hi:.4f}] vs "
            f"abc [{abc_lo:.4f}, {abc_hi:.4f}]"
        )


def test_npe_fixed_seed_is_deterministic():
    """Training and sampling are threefry-keyed jitted programs: the same
    seed must reproduce the posterior bit-for-bit (estimator weights AND
    mixture draws)."""
    from repro.core.npe import train_npe

    ds = _dataset("sir")
    tiny = ABCConfig(
        num_days=DAYS, backend="npe", model="sir", target_accepted=64,
        npe=NPEConfig(train_steps=30, train_batch=64, n_pilot=64, hidden=32),
    )
    a = run_abc(ds, tiny, key=7)
    b = run_abc(ds, tiny, key=7)
    np.testing.assert_array_equal(a.theta, b.theta)
    np.testing.assert_array_equal(a.distances, b.distances)
    # ...and the estimators themselves match, leaf by leaf
    e1 = train_npe(ds, tiny, key=7)
    e2 = train_npe(ds, tiny, key=7)
    for l1, l2 in zip(jax.tree.leaves(e1.params), jax.tree.leaves(e2.params)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    # a different seed must actually change the draw (guards against a
    # key being silently ignored somewhere in the pipeline)
    c = run_abc(ds, tiny, key=8)
    assert not np.array_equal(a.theta, c.theta)


@pytest.mark.slow
@pytest.mark.parametrize("model", ["sir", "seir"])
def test_run_abc_recovery_tightens_with_tolerance(model):
    """Nightly: decreasing epsilon must (weakly) improve recovery — the
    hallmark of a correct ABC approximation, and exactly the property a
    silently-wrong device loop would break."""
    ds = _dataset(model)
    errs = []
    for q in (5e-2, 5e-3):
        eps = _tolerance(ds, model, quantile=q)
        cfg = ABCConfig(
            batch_size=8192, tolerance=eps, target_accepted=100,
            chunk_size=8192, max_runs=200, num_days=DAYS,
            backend="xla_fused", model=model,
        )
        post = run_abc(ds, cfg, key=2)
        assert len(post) >= 100
        spec = get_model(model)
        width = np.asarray(spec.prior().highs) - np.asarray(spec.prior().lows)
        err = np.abs(post.theta.mean(axis=0) - np.asarray(TRUTH[model])) / width
        errs.append(err.mean())
    assert errs[1] <= errs[0] * 1.25, errs  # allow MC noise, forbid blowup
    _assert_recovers_final = errs[1]
    assert _assert_recovers_final <= 0.2
