"""core/distributed.py edge cases that only bite on real multi-device
meshes: uneven batch splits, lopsided per-shard accept buffers, chunk flags
on a partially accepting final wave, and the 1-device-mesh degenerate."""

import jax
import numpy as np

from conftest import run_in_subprocess
from repro.core.abc import ABCConfig, ABCState, run_abc
from repro.epi.data import get_dataset

DAYS = 12


def test_uneven_batch_per_device_split_raises():
    """A global batch that does not divide the device count must be refused
    loudly by every sharded runner — a silent floor-div would change the
    sample stream and the simulation budget."""
    out = run_in_subprocess(
        f"""
import jax
from repro.core.abc import ABCConfig, make_simulator, make_parametric_simulator
from repro.core import distributed
from repro.core.scaling import device_mesh
from repro.epi.data import get_dataset
from repro.epi.models import get_model

mesh = device_mesh(4)
ds = get_dataset("synthetic_small", num_days={DAYS})
cfg = ABCConfig(batch_size=1023, tolerance=1.6e4, chunk_size=1023,
                num_days={DAYS}, wave_loop="device")
prior = get_model(cfg.model).prior()
sim = make_simulator(ds, cfg)
spec = get_model(cfg.model)
for maker in (distributed.make_shardmap_runner,
              distributed.make_shardmap_wave_runner):
    try:
        maker(mesh, prior, sim, cfg)
    except ValueError as e:
        assert "not divisible" in str(e), e
    else:
        raise AssertionError(f"{{maker.__name__}} accepted an uneven split")
try:
    distributed.make_shardmap_scenario_runner(
        mesh, prior, make_parametric_simulator(spec, cfg), cfg)
except ValueError as e:
    assert "not divisible" in str(e), e
else:
    raise AssertionError("scenario runner accepted an uneven split")
print("OK")
""",
        n_devices=4,
    )
    assert "OK" in out


def test_one_shard_overflows_while_others_stay_empty():
    """A lopsided accept pattern (only shard 0's region of parameter space
    accepts) must clamp that shard's fill to its capacity, leave the other
    segments untouched, and still count every acceptance globally."""
    out = run_in_subprocess(
        """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.core.abc import ABCConfig, build_wave_loop, WaveLoopOutput
from repro.core.distributed import shard_map
from repro.core.scaling import device_mesh
from repro.epi.models import get_model

n_dev, local_b = 4, 64
cfg = ABCConfig(batch_size=n_dev * local_b, tolerance=1.0,
                target_accepted=10**6, chunk_size=n_dev * local_b,
                max_runs=3, num_days=10, wave_loop="device")
prior = get_model("siard").prior()
mesh = device_mesh(n_dev)
cap = local_b  # deliberately small: one all-accept wave fills it exactly

def sim(theta, key, _data):
    # only shard 0 accepts anything, ever
    dev = jax.lax.axis_index("data")
    return jnp.where(dev == 0, 0.0, jnp.inf) * jnp.ones((theta.shape[0],))

loop = build_wave_loop(
    prior, sim, cfg, batch_size=local_b, capacity=cap,
    fold_axis=lambda: jax.lax.axis_index("data"),
    count_all=lambda c: jax.lax.psum(c, "data"),
)

@partial(shard_map, mesh=mesh,
         in_specs=(P(), P(), P("data"), P("data"), P(), P("data"), P(), P(),
                   P()),
         out_specs=WaveLoopOutput(P("data"), P("data"), P(), P(), P("data")))
def sharded(key, run_idx0, th, d, n0, fills, max_waves, tol, data):
    return loop(key, run_idx0, th, d, n0, fills[0], max_waves, tol, data)

th0 = jnp.zeros((n_dev * cap, prior.dim), jnp.float32)
d0 = jnp.full((n_dev * cap,), jnp.inf, jnp.float32)
out = sharded(jax.random.PRNGKey(0), jnp.int32(0), th0, d0, jnp.int32(0),
              jnp.zeros((n_dev,), jnp.int32), jnp.int32(3), jnp.float32(1.0),
              jnp.zeros((), jnp.int32))
fills = np.asarray(out.fill_counts)
np.testing.assert_array_equal(fills, [cap, 0, 0, 0])
assert int(out.waves_done) == 3
assert int(out.n_accepted) == 3 * local_b  # every acceptance counted
d = np.asarray(out.dist_buf)
assert np.isfinite(d[:cap]).all()          # shard 0: clamped but full
assert np.isinf(d[cap:]).all()             # other segments untouched
print("OK")
""",
        n_devices=4,
    )
    assert "OK" in out


def test_effective_chunk_flags_on_partial_final_wave():
    """On a wave that accepts into only SOME chunks (the partially filled
    final wave of a run), the sharded runner's chunk flags must mark exactly
    the chunks holding accepts, and harvesting flagged chunks must recover
    every accepted sample."""
    out = run_in_subprocess(
        f"""
import jax, numpy as np
from repro.core.abc import ABCConfig, make_simulator
from repro.core.distributed import effective_chunk_flags, make_shardmap_runner
from repro.core.scaling import device_mesh
from repro.epi.data import get_dataset
from repro.epi.models import get_model

mesh = device_mesh(4)
ds = get_dataset("synthetic_small", num_days={DAYS})
# epsilon tight enough that most 128-sample chunks are empty: the partially
# accepting wave the outfeed path exists for
cfg = ABCConfig(batch_size=4 * 1024, tolerance=2.7e3, target_accepted=10**9,
                chunk_size=128, num_days={DAYS}, max_runs=1)
prior = get_model(cfg.model).prior()
runner = make_shardmap_runner(mesh, prior, make_simulator(ds, cfg), cfg)
out = runner(jax.random.PRNGKey(5))
d = np.asarray(out.dist)
flags = np.asarray(effective_chunk_flags(out))
expected = (d <= cfg.tolerance).any(axis=1)
np.testing.assert_array_equal(flags, expected)
assert 0 < flags.sum() < flags.size, flags.sum()  # partial, not degenerate
# harvesting only flagged chunks recovers every accepted sample
n_flagged = sum(int((d[ci] <= cfg.tolerance).sum())
                for ci in np.nonzero(flags)[0])
assert n_flagged == int(out.accept_count) > 0
print("OK", int(out.accept_count), int(flags.sum()), flags.size)
""",
        n_devices=4,
    )
    assert "OK" in out


def test_single_device_mesh_wave_runner_runs():
    """Degenerate 1-device mesh: WaveRunner.init hands the sharded loop a
    SCALAR fill (the shards==1 special case) — the runner must promote it to
    the rank-1 in_spec instead of crashing, and complete a run."""
    from repro.core import distributed

    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = ABCConfig(batch_size=1024, tolerance=1.8e4, target_accepted=10,
                    chunk_size=1024, max_runs=5, num_days=DAYS,
                    wave_loop="device")
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    wr = distributed.make_wave_runner(mesh, ds, cfg, style="shard_map")
    assert wr.shards == 1
    post = run_abc(ds, cfg, key=0, wave_runner=wr)
    assert len(post) >= cfg.target_accepted
    # the carry round-trips through carry_of (scalar fill) and back
    out = wr(jax.random.PRNGKey(0), 0, wr.init(ABCState(n_params=wr.n_params)),
             2)
    carry = wr.carry_of(out)
    assert np.asarray(carry[3]).ndim == 0  # scalar fill for shards == 1
    wr(jax.random.PRNGKey(0), 2, carry, 2)
