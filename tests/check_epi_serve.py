"""CI tier-2 smoke: end-to-end `serve --epi` against a synthetic dataset.

Standalone (no pytest): builds a toy dataset file, pre-fits its posterior
with one `abc_serve --once` sweep, then answers a mixed batch of 8
forecast + counterfactual queries with `serve --epi` and asserts the
responses are well-formed STRICT-JSON credible bands (no NaN/Infinity
tokens), answered from the store (zero fits on the query path) in at most
2 batched compiled calls.

    PYTHONPATH=src python tests/check_epi_serve.py
"""

import json
import os
import sys
import tempfile

import numpy as np

FIT_DAYS = 8
HORIZON = 6
FIT_ARGS = ["--days", str(FIT_DAYS), "--fit-particles", "16",
            "--fit-batch", "256", "--fit-rounds", "1"]


def build_dataset(path: str) -> None:
    from repro.core.serving import save_dataset_file
    from repro.epi.data import synthetic_dataset

    ds = synthetic_dataset(
        theta=(0.5, 0.2, 1.0), population=1e6, num_days=12, a0=100.0,
        seed=11, name="toy", model="sir",
    )
    save_dataset_file(path, ds)


def build_queries(path: str) -> int:
    queries = [
        {"dataset": "toy", "model": "sir", "horizon": HORIZON, "seed": s}
        for s in range(4)
    ] + [
        {"dataset": "toy", "model": "sir", "horizon": HORIZON, "seed": s,
         "schedule": "beta@4=0.5"}
        for s in range(4)
    ]
    with open(path, "w") as f:
        json.dump({"queries": queries}, f)
    return len(queries)


def strict_loads(text: str):
    def refuse(token):
        raise AssertionError(f"non-strict JSON token {token!r} in response")

    return json.loads(text, parse_constant=refuse)


def check_bands(resp: dict) -> None:
    assert resp["total_days"] == FIT_DAYS + HORIZON, resp["total_days"]
    assert resp["fit_days"] == FIT_DAYS
    assert resp["channels"], "no channels in response"
    for name, bands in resp["channels"].items():
        for key in ("mean", "q05", "q25", "q50", "q75", "q95"):
            assert key in bands, f"{name}: missing {key}"
            vals = bands[key]
            assert len(vals) == FIT_DAYS + HORIZON, (name, key, len(vals))
            assert all(np.isfinite(vals)), (name, key)
        lo, mid, hi = (np.asarray(bands[k]) for k in ("q05", "q50", "q95"))
        assert (lo <= mid).all() and (mid <= hi).all(), (
            f"{name}: quantile bands cross"
        )
    assert len(resp["observed"]) == len(resp["channels"])
    for vals in resp["observed"].values():
        assert len(vals) == FIT_DAYS


def main() -> int:
    from repro.launch import abc_serve, serve

    with tempfile.TemporaryDirectory() as tmp:
        data_dir = os.path.join(tmp, "data")
        store = os.path.join(tmp, "store")
        out = os.path.join(tmp, "responses.json")
        os.makedirs(data_dir)
        build_dataset(os.path.join(data_dir, "toy.json"))
        n_queries = build_queries(os.path.join(tmp, "queries.json"))

        # offline phase: the daemon fits the store entry (one cold fit) ...
        refits = abc_serve.main(
            ["--once", "--data-dir", data_dir, "--store", store,
             "--models", "sir"] + FIT_ARGS
        )
        assert refits == 1, f"expected 1 cold fit, got {refits}"

        # ... the query server answers WITHOUT fitting, <= 2 compiled calls
        served = serve.main(
            ["--epi", "--queries", os.path.join(tmp, "queries.json"),
             "--data-dir", data_dir, "--store", store, "--out", out,
             "--slots", "4", "--particles", "16"] + FIT_ARGS
        )
        assert served == n_queries, (served, n_queries)

        with open(out) as f:
            payload = strict_loads(f.read())
        responses = payload["responses"]
        stats = payload["stats"]
        assert len(responses) == n_queries, len(responses)
        for i, resp in enumerate(responses):
            check_bands(resp)
            assert resp["schedule"] is None if i < 4 else resp["schedule"], i
        assert stats["fits"] == 0, f"query path fitted: {stats}"
        assert stats["batched_calls"] <= 2, stats
        print(f"[check_epi_serve] OK: {n_queries} queries, "
              f"{stats['batched_calls']} batched calls, 0 query-path fits")
    return 0


if __name__ == "__main__":
    sys.exit(main())
