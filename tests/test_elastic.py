"""Elastic rescale: a checkpoint written on one topology restores onto a
different device count with the new mesh's shardings (reshard-on-load)."""

import pytest

from conftest import run_in_subprocess


@pytest.mark.slow
def test_checkpoint_restores_across_device_counts(tmp_path):
    ck = str(tmp_path)
    # phase 1: single device writes the checkpoint
    run_in_subprocess(
        f"""
import jax, jax.numpy as jnp
from repro.checkpoint import save_checkpoint
from repro.models.registry import get_model
from repro.optim import adamw_init
model = get_model("gemma2-27b", smoke=True)
params = model.init_params(jax.random.PRNGKey(0))
save_checkpoint({ck!r}, 5, {{"params": params, "opt": adamw_init(params)}},
                metadata={{"arch": "gemma2-27b"}})
print("SAVED")
""",
        n_devices=1,
    )
    # phase 2: 8-device mesh restores under sharded placement and trains
    out = run_in_subprocess(
        f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import load_checkpoint
from repro.launch.shapes import InputShape
from repro.launch.steps import build_train_step
from repro.models.registry import get_model
from repro.optim import adamw_init

assert len(jax.devices()) == 8
from repro.launch.mesh import make_compat_mesh, set_mesh_compat
mesh = make_compat_mesh((2, 4), ("data", "model"))
with set_mesh_compat(mesh):
    model = get_model("gemma2-27b", smoke=True)
    like_p = model.param_shapes()
    like_o = jax.eval_shape(adamw_init, like_p)
    built = build_train_step(model, mesh, InputShape("t", "train", 32, 4))
    state, meta, step = load_checkpoint(
        {ck!r},
        {{"params": like_p, "opt": like_o}},
        shardings={{"params": built.in_shardings[0], "opt": built.in_shardings[1]}},
    )
    assert step == 5 and meta["arch"] == "gemma2-27b"
    # restored leaves actually carry the new mesh's shardings
    emb = state["params"]["embed"]
    assert len(emb.sharding.device_set) > 1, emb.sharding
    toks = jnp.ones((4, 32), jnp.int32)
    batch = jax.device_put({{"tokens": toks, "labels": toks}}, built.in_shardings[2])
    p2, o2, metrics = built.fn(state["params"], state["opt"], batch)
    assert np.isfinite(float(metrics["loss"]))
    print("OK", float(metrics["loss"]))
""",
        n_devices=8,
    )
    assert "OK" in out
