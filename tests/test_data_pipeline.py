"""Data pipeline: determinism, shard disjointness, learnable signal."""

import numpy as np

from repro.data import SyntheticTokenDataset, make_batches


def test_deterministic_by_address():
    ds = SyntheticTokenDataset(vocab=512, seq_len=32, seed=4)
    a = ds.batch(step=7, batch_size=8)
    b = ds.batch(step=7, batch_size=8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch(step=8, batch_size=8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_shards_are_distinct_and_stable():
    ds = SyntheticTokenDataset(vocab=512, seq_len=32, seed=0)
    s0 = ds.batch(3, 8, shard=0, n_shards=4)
    s1 = ds.batch(3, 8, shard=1, n_shards=4)
    assert s0["tokens"].shape == (2, 32)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    np.testing.assert_array_equal(
        s0["tokens"], ds.batch(3, 8, shard=0, n_shards=4)["tokens"]
    )


def test_labels_are_next_tokens():
    ds = SyntheticTokenDataset(vocab=512, seq_len=16, seed=1)
    b = ds.batch(0, 4)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_stream_has_learnable_signal():
    """The copy-mixture makes label == previous token ~50% of the time —
    a unigram model can't reach that, a context model can."""
    ds = SyntheticTokenDataset(vocab=512, seq_len=128, seed=2)
    b = ds.batch(0, 16)
    copy_rate = (b["labels"] == b["tokens"]).mean()
    assert 0.25 < copy_rate < 0.75, copy_rate


def test_make_batches_iterates():
    ds = SyntheticTokenDataset(vocab=64, seq_len=8, seed=0)
    batches = list(make_batches(ds, batch_size=4, steps=3))
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (4, 8)
