"""Numerics of the shared layers: blockwise-vs-dense attention equivalence,
SSD chunked-vs-recurrent equivalence, rope/softcap invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# degrades to skip-markers when hypothesis is absent (tier-1 container)
from _hypothesis_compat import given, settings, st

from repro.models import common as cm
from repro.models import ssm as ssm_lib


# ------------------------------------------------------ attention equivalence
@pytest.mark.parametrize("kh,window", [(4, None), (2, None), (1, None), (4, 8)])
def test_blockwise_matches_dense(kh, window):
    b, s, h, d = 2, 64, 4, 16
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, kh, d), jnp.float32)
    v = jax.random.normal(kv, (b, s, kh, d), jnp.float32)
    dense = cm.dense_attention(q, k, v, causal=True, window=window)
    block = cm.blockwise_attention(q, k, v, causal=True, window=window,
                                   q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_matches_dense_with_softcap():
    b, s, h, d = 1, 32, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, d), jnp.float32)
    dense = cm.dense_attention(q, k, v, causal=True, attn_softcap=50.0)
    block = cm.blockwise_attention(q, k, v, causal=True, attn_softcap=50.0,
                                   q_block=8, kv_block=8)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                               rtol=2e-4, atol=2e-5)


def test_decode_attention_matches_dense_last_row():
    """One-token decode == last row of full causal attention."""
    b, s, h, d = 2, 24, 4, 8
    kh = 2
    q = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, kh, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, kh, d), jnp.float32)
    full = cm.dense_attention(q, k, v, causal=True)
    dec = cm.decode_attention(
        q[:, -1:], k, v, valid_len=jnp.full((b,), s, jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(dec),
                               rtol=2e-5, atol=2e-6)


# ----------------------------------------------------------- SSD equivalence
def _ssd_recurrent_ref(x, dt, A, B_in, C_in):
    """Step-by-step SSM recurrence (the definition SSD must match)."""
    b, s, h, p = x.shape
    g, n = B_in.shape[2], B_in.shape[3]
    hg = h // g
    Bh = jnp.repeat(B_in, hg, axis=2)  # [b, s, h, n]
    Ch = jnp.repeat(C_in, hg, axis=2)
    state = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * A[None, :])  # [b, h]
        upd = (dt[:, t, :, None] * Bh[:, t].astype(jnp.float32))[..., None] * \
            x[:, t].astype(jnp.float32)[:, :, None, :]
        state = decay[..., None, None] * state + upd
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t].astype(jnp.float32), state))
    return jnp.stack(ys, axis=1), state  # [b, s, h, p]


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(chunk):
    b, s, h, p, g, n = 2, 16, 4, 8, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B_in = jax.random.normal(ks[3], (b, s, g, n), jnp.float32) * 0.5
    C_in = jax.random.normal(ks[0], (b, s, g, n), jnp.float32) * 0.5
    y, st = ssm_lib.ssd_chunked(x, dt, A, B_in, C_in, chunk=chunk)
    y_ref, st_ref = _ssd_recurrent_ref(x, dt, A, B_in, C_in)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_forward_prefix():
    """Token-by-token decode reproduces the chunked forward activations."""
    cfg = ssm_lib.Mamba2Config(
        name="t", n_layers=1, d_model=32, d_state=8, vocab=64, head_dim=8,
        chunk=4, remat="none",
    )
    p = ssm_lib.init_mamba_layer(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.float32) * 0.5
    x = x.astype(jnp.bfloat16)
    full = ssm_lib.mamba_block(x, p, cfg)
    ssm = jnp.zeros((1, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32)
    conv = jnp.zeros((1, cfg.conv_width - 1, cfg.conv_channels), cm.DEFAULT_DTYPE)
    outs = []
    for t in range(8):
        o, ssm, conv = ssm_lib.mamba_decode_block(x[:, t : t + 1], p, cfg, ssm, conv)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(dec, np.float32),
        rtol=0.05, atol=0.05,  # bf16 path
    )


# ------------------------------------------------------------- invariants
@settings(max_examples=20, deadline=None)
@given(cap=st.floats(1.0, 100.0), scale=st.floats(0.1, 100.0))
def test_softcap_bounds_and_monotone(cap, scale):
    x = jnp.linspace(-scale, scale, 64, dtype=jnp.float32)
    y = cm.softcap(x, cap)
    assert float(jnp.max(jnp.abs(y))) <= cap + 1e-3
    assert bool(jnp.all(jnp.diff(y) >= -1e-6))


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 16), jnp.float32)
    pos = jnp.arange(8)
    y = cm.rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 16), jnp.float32)
    def dot_at(i, j):
        qi = cm.rope(q, jnp.asarray([i]))
        kj = cm.rope(k, jnp.asarray([j]))
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_chunked_ce_matches_full():
    b, s, d, v = 2, 16, 8, 32
    x = jax.random.normal(jax.random.PRNGKey(5), (b, s, d), jnp.bfloat16)
    table = jax.random.normal(jax.random.PRNGKey(6), (v, d), jnp.bfloat16)
    labels = jax.random.randint(jax.random.PRNGKey(7), (b, s), 0, v)
    full = cm.cross_entropy_loss(cm.unembed(x, table), labels)
    chunked = cm.cross_entropy_chunked(x, table, labels, chunk=4)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)
