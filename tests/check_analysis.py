#!/usr/bin/env python
"""CI gate over the static-analysis passes (modeled on check_new_failures).

Runs the AST lint pass and the jaxpr trace auditor (src/repro/analysis) and
compares every finding key against the committed baseline
`tests/analysis_baseline.txt`. The job:

  * FAILS (exit 1) if any finding is not in the baseline — a new contract
    violation is caught at PR time;
  * FAILS (exit 1) if a baseline entry matches no finding — a stale entry
    is a fixed violation still allowlisted, i.e. a site that could regress
    silently. Delete the line;
  * PASSES only when findings and baseline agree exactly (both empty, in
    the healthy state).

Usage (what CI runs):

    PYTHONPATH=src python tests/check_analysis.py            # both passes
    PYTHONPATH=src python tests/check_analysis.py --pass lint
    PYTHONPATH=src python tests/check_analysis.py --quick    # axis-coverage
    PYTHONPATH=src python tests/check_analysis.py --json-out report.json

The gate decision itself is `repro.analysis.report.evaluate` — a pure
function of (baseline keys, findings) unit-tested by
tests/test_analysis_rules.py; this script only wires the committed baseline
path in front of `python -m repro.analysis`.
"""

from __future__ import annotations

import sys
from pathlib import Path

HERE = Path(__file__).resolve().parent
BASELINE = HERE / "analysis_baseline.txt"
sys.path.insert(0, str(HERE.parent / "src"))

from repro.analysis.__main__ import main as analysis_main  # noqa: E402


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if not any(a == "--baseline" or a.startswith("--baseline=") for a in argv):
        argv = ["--baseline", str(BASELINE)] + argv
    return analysis_main(argv)


if __name__ == "__main__":
    sys.exit(main())
