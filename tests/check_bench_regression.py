#!/usr/bin/env python
"""Benchmark-regression gate: the nightly artifacts finally get READ.

Compares freshly produced `experiments/bench/*.json` against the committed
baselines under `experiments/bench/baselines/` and FAILS (exit 1) on:

  * a wall-clock regression — any cell whose fresh `wall_s` exceeds the
    baseline's by more than the threshold (default 25%);
  * a roofline-efficiency regression — any cell whose fresh
    `roofline_efficiency` (measured throughput over the analytic ceiling,
    see repro.core.tuning) falls below the baseline's by more than the
    efficiency threshold (default 25%), or that LOSES the instrumentation
    a baseline carries — efficiency drift catches hot-path degradation
    that wall clock alone can hide when the cell's work changes;
  * any parity-metric drift — entries under "parity" must be EXACTLY equal
    (parity values are deterministic by construction: simulation counts
    under a fixed wave budget, scenario statuses, device counts — never
    wall-clock-derived numbers);
  * a baselined artifact or cell that the fresh run no longer produces — a
    silently narrowed benchmark could otherwise hide a regression
    (downgrade to a warning with --allow-missing for partial local runs).

Fresh artifacts (or cells) WITHOUT a baseline only print a note: a new
benchmark is not a regression, it just needs its baseline committed.

Only artifacts in the `bench-artifact/v1` envelope (see
benchmarks/_harness.py) are gated; anything else is skipped with a note.

Usage (what the nightly job runs after the benchmark steps):

    PYTHONPATH=src python tests/check_bench_regression.py

    # options
    --fresh-dir experiments/bench --baseline-dir experiments/bench/baselines
    --threshold 0.25 --eff-threshold 0.25 --allow-missing

Refreshing baselines is deliberate: re-run the benchmarks and copy the new
artifacts over `experiments/bench/baselines/` in a reviewed commit — ideally
from a nightly run's uploaded artifacts, so the baseline and the gated runs
share the same machine class (wall clocks are not portable across hosts).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
FRESH_DIR = REPO / "experiments" / "bench"
BASELINE_DIR = FRESH_DIR / "baselines"
SCHEMA = "bench-artifact/v1"
DEFAULT_THRESHOLD = 0.25
#: allowed fractional roofline_efficiency DROP before the gate trips
DEFAULT_EFF_THRESHOLD = 0.25


def compare_artifacts(name: str, baseline: dict, fresh: dict,
                      threshold: float = DEFAULT_THRESHOLD,
                      eff_threshold: float = DEFAULT_EFF_THRESHOLD):
    """Pure comparison of one (baseline, fresh) artifact pair.

    Returns (problems, notes): `problems` are gate failures, `notes` are
    informational lines (new cells, new parity keys).
    """
    problems, notes = [], []
    if fresh.get("schema") != SCHEMA:
        problems.append(
            f"{name}: fresh artifact is not {SCHEMA} "
            f"(got {fresh.get('schema')!r}) but the baseline is gated"
        )
        return problems, notes

    base_cells = baseline.get("cells", {})
    fresh_cells = fresh.get("cells", {})
    for key, base_cell in sorted(base_cells.items()):
        cell = fresh_cells.get(key)
        if cell is None:
            problems.append(
                f"{name}: cell {key!r} is baselined but missing from the "
                "fresh run (narrowed benchmark?)"
            )
            continue
        b, f = base_cell.get("wall_s"), cell.get("wall_s")
        if b is not None and f is not None and b > 0 and f > b * (1.0 + threshold):
            problems.append(
                f"{name}: wall-clock regression in {key!r}: "
                f"{f:.4g}s vs baseline {b:.4g}s "
                f"(+{(f / b - 1) * 100:.0f}% > {threshold * 100:.0f}%)"
            )
        be = base_cell.get("roofline_efficiency")
        fe = cell.get("roofline_efficiency")
        if be is not None and be > 0:
            if fe is None:
                problems.append(
                    f"{name}: cell {key!r} lost its roofline_efficiency "
                    "instrumentation (baselined but absent in the fresh "
                    "artifact)"
                )
            elif fe < be * (1.0 - eff_threshold):
                problems.append(
                    f"{name}: roofline-efficiency regression in {key!r}: "
                    f"{fe:.3g} vs baseline {be:.3g} "
                    f"(-{(1 - fe / be) * 100:.0f}% > "
                    f"{eff_threshold * 100:.0f}%)"
                )
    for key in sorted(set(fresh_cells) - set(base_cells)):
        notes.append(f"{name}: new cell {key!r} (no baseline yet)")

    base_parity = baseline.get("parity", {})
    fresh_parity = fresh.get("parity", {})
    for key, base_val in sorted(base_parity.items()):
        if key not in fresh_parity:
            problems.append(
                f"{name}: parity metric {key!r} is baselined but missing "
                "from the fresh run"
            )
        elif fresh_parity[key] != base_val:
            problems.append(
                f"{name}: parity drift in {key!r}: "
                f"{fresh_parity[key]!r} != baseline {base_val!r}"
            )
    for key in sorted(set(fresh_parity) - set(base_parity)):
        notes.append(f"{name}: new parity metric {key!r} (no baseline yet)")
    return problems, notes


def evaluate_dirs(baseline_dir: Path, fresh_dir: Path,
                  threshold: float = DEFAULT_THRESHOLD,
                  allow_missing: bool = False,
                  eff_threshold: float = DEFAULT_EFF_THRESHOLD):
    """Gate every baselined artifact against its fresh counterpart.

    Returns (problems, notes); the gate passes iff `problems` is empty.
    """
    problems, notes = [], []
    baseline_dir, fresh_dir = Path(baseline_dir), Path(fresh_dir)
    baselines = sorted(baseline_dir.glob("*.json"))
    if not baselines:
        problems.append(f"no baseline artifacts under {baseline_dir}")
        return problems, notes
    gated = 0
    for bpath in baselines:
        name = bpath.name
        try:
            baseline = json.loads(bpath.read_text())
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{name}: unreadable baseline ({e})")
            continue
        if not isinstance(baseline, dict) or baseline.get("schema") != SCHEMA:
            notes.append(f"{name}: baseline is not {SCHEMA}; skipped")
            continue
        fpath = fresh_dir / name
        if not fpath.exists():
            msg = (f"{name}: baselined benchmark produced no fresh artifact "
                   f"(expected {fpath})")
            (notes if allow_missing else problems).append(
                msg + (" [allowed]" if allow_missing else "")
            )
            continue
        try:
            fresh = json.loads(fpath.read_text())
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{name}: unreadable fresh artifact ({e})")
            continue
        if not isinstance(fresh, dict):
            problems.append(
                f"{name}: fresh artifact is not a {SCHEMA} object but the "
                "baseline is gated"
            )
            continue
        gated += 1
        p, n = compare_artifacts(name, baseline, fresh, threshold,
                                 eff_threshold)
        if allow_missing:
            kept = [x for x in p if "missing from the fresh run" not in x]
            n = n + [x + " [allowed]" for x in p if x not in kept]
            p = kept
        problems.extend(p)
        notes.extend(n)
    for fpath in sorted(fresh_dir.glob("*.json")):
        if not (baseline_dir / fpath.name).exists():
            try:
                payload = json.loads(fpath.read_text())
                if isinstance(payload, dict) and payload.get("schema") == SCHEMA:
                    notes.append(
                        f"{fpath.name}: gate-compatible artifact without a "
                        "committed baseline — consider baselining it"
                    )
            except (OSError, json.JSONDecodeError):
                pass
    if gated == 0 and not problems:
        problems.append(
            f"no {SCHEMA} baseline/fresh artifact pairs were gated"
        )
    return problems, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default=str(FRESH_DIR))
    ap.add_argument("--baseline-dir", default=str(BASELINE_DIR))
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="allowed fractional wall-clock slowdown (0.25 = "
                         "fail beyond +25%%)")
    ap.add_argument("--eff-threshold", type=float,
                    default=DEFAULT_EFF_THRESHOLD,
                    help="allowed fractional roofline-efficiency drop "
                         "(0.25 = fail beyond -25%%)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="downgrade missing fresh artifacts/cells to "
                         "warnings (partial local runs)")
    args = ap.parse_args(argv)
    problems, notes = evaluate_dirs(
        Path(args.baseline_dir), Path(args.fresh_dir),
        threshold=args.threshold, allow_missing=args.allow_missing,
        eff_threshold=args.eff_threshold,
    )
    for n in notes:
        print(f"[bench-gate] note: {n}")
    if problems:
        print(f"[bench-gate] {len(problems)} problem(s):")
        for p in problems:
            print(f"  {p}")
        return 1
    print("[bench-gate] OK: all gated artifacts within "
          f"+{args.threshold * 100:.0f}% wall clock, "
          f"-{args.eff_threshold * 100:.0f}% roofline efficiency, "
          "parity exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
