"""Pallas kernel vs pure-jnp oracle: shape/tile/horizon sweeps (interpret mode).

Per the kernel contract every sweep asserts allclose against ref.py. The RNG
primitive is shared (kernels/rng.py) so agreement checks the kernel's
tiling/loop/layout logic; the dynamics are independently implemented.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.priors import paper_prior
from repro.kernels import ops, ref

POP = 1e6
KW = dict(population=POP, a0=100.0, r0=5.0, d0=1.0)


def _observed(days: int, seed: int = 0) -> jnp.ndarray:
    """A plausible observed series: simulate one trajectory with fixed params."""
    from repro.epi import model as em

    cfg = em.EpiModelConfig(population=POP, num_days=days, a0=100.0, r0=5.0, d0=1.0)
    th = jnp.asarray([[0.4, 30.0, 0.8, 0.05, 0.3, 0.01, 0.5, 1.0]], jnp.float32)
    return em.simulate_observed(th, jax.random.PRNGKey(seed), cfg)[0]


def _theta(batch: int, seed: int = 0) -> jnp.ndarray:
    return paper_prior().sample(jax.random.PRNGKey(seed), (batch,))


@pytest.mark.parametrize("batch", [64, 128, 300, 512, 1000])
@pytest.mark.parametrize("tile", [128, 256])
def test_kernel_matches_ref_batch_tile_sweep(batch, tile):
    obs = _observed(10)
    th = _theta(batch, seed=batch)
    seed = jnp.uint32(77)
    d_k = ops.abc_sim_distance(th, seed, obs, tile=tile, interpret=True, **KW)
    d_r = ref.abc_sim_distance_ref(th, seed, obs, **KW)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-6, atol=1e-3)


@pytest.mark.parametrize("days", [1, 7, 49])
def test_kernel_matches_ref_horizon_sweep(days):
    obs = _observed(days)
    th = _theta(256, seed=days)
    d_k = ops.abc_sim_distance(th, jnp.uint32(5), obs, tile=128, interpret=True, **KW)
    d_r = ref.abc_sim_distance_ref(th, jnp.uint32(5), obs, **KW)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-6, atol=1e-3)


@pytest.mark.parametrize(
    "pop,a0,r0,d0",
    [(1e5, 10.0, 0.0, 0.0), (60.36e6, 155.0, 2.0, 3.0), (328.2e6, 104.0, 7.0, 6.0)],
)
def test_kernel_matches_ref_population_sweep(pop, a0, r0, d0):
    """Country-scale populations (f32 stress: S ~ 3e8)."""
    from repro.epi import model as em

    cfg = em.EpiModelConfig(population=pop, num_days=12, a0=a0, r0=r0, d0=d0)
    th_true = jnp.asarray([[0.38, 36.0, 0.6, 0.013, 0.385, 0.009, 0.48, 0.83]], jnp.float32)
    obs = em.simulate_observed(th_true, jax.random.PRNGKey(1), cfg)[0]
    th = _theta(256, seed=9)
    kw = dict(population=pop, a0=a0, r0=r0, d0=d0)
    d_k = ops.abc_sim_distance(th, jnp.uint32(3), obs, tile=128, interpret=True, **kw)
    d_r = ref.abc_sim_distance_ref(th, jnp.uint32(3), obs, **kw)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5, atol=1.0)


def test_kernel_seed_sensitivity():
    """Different seeds give different (but finite) distances; same seed exact."""
    obs = _observed(8)
    th = _theta(128)
    a = ops.abc_sim_distance(th, jnp.uint32(1), obs, tile=128, interpret=True, **KW)
    b = ops.abc_sim_distance(th, jnp.uint32(1), obs, tile=128, interpret=True, **KW)
    c = ops.abc_sim_distance(th, jnp.uint32(2), obs, tile=128, interpret=True, **KW)
    assert bool(jnp.all(a == b))
    assert not bool(jnp.all(a == c))
    assert bool(jnp.all(jnp.isfinite(a)))


def test_kernel_tile_invariance():
    """Distances must not depend on the tiling (pure layout parameter)."""
    obs = _observed(10)
    th = _theta(512, seed=2)
    d1 = ops.abc_sim_distance(th, jnp.uint32(9), obs, tile=128, interpret=True, **KW)
    d2 = ops.abc_sim_distance(th, jnp.uint32(9), obs, tile=512, interpret=True, **KW)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


def test_kernel_statistics_match_threefry_reference():
    """Hash-RNG simulation must be statistically indistinguishable from the
    paper-faithful threefry path at the distance-distribution level."""
    from repro.epi import model as em
    from repro.core.distances import euclidean_distance

    days = 15
    obs = _observed(days)
    cfg = em.EpiModelConfig(population=POP, num_days=days, a0=100.0, r0=5.0, d0=1.0)
    th = _theta(2048, seed=4)
    d_hash = np.asarray(
        ops.abc_sim_distance(th, jnp.uint32(11), obs, tile=512, interpret=True, **KW)
    )
    sim = em.simulate_observed(th, jax.random.PRNGKey(12), cfg)
    d_tf = np.asarray(euclidean_distance(sim, obs))
    ok = np.isfinite(d_hash) & np.isfinite(d_tf)
    qs = np.linspace(0.05, 0.95, 19)
    np.testing.assert_allclose(
        np.quantile(d_hash[ok], qs), np.quantile(d_tf[ok], qs), rtol=0.1
    )
