"""Pallas kernel vs pure-jnp oracle: shape/tile/horizon sweeps.

Per the kernel contract every sweep asserts allclose against ref.py. The RNG
primitive is shared (kernels/rng.py) so agreement checks the kernel's
tiling/loop/layout logic; the dynamics are independently implemented.

By default the kernel runs in interpret mode (CPU correctness). Set
REPRO_KERNEL_COMPILED=1 to run the SAME parity sweeps through the compiled
lowering (Triton on GPU, Mosaic on TPU) — the workflow_dispatch GPU leg in
CI does exactly that; interpret=None auto-selects compiled on accelerators.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.priors import paper_prior
from repro.kernels import ops, ref

POP = 1e6
KW = dict(population=POP, a0=100.0, r0=5.0, d0=1.0)
#: interpret=INTERPRET on CPU; None (auto -> compiled) under REPRO_KERNEL_COMPILED
INTERPRET = None if os.environ.get("REPRO_KERNEL_COMPILED") else True


def _observed(days: int, seed: int = 0) -> jnp.ndarray:
    """A plausible observed series: simulate one trajectory with fixed params."""
    from repro.epi import model as em

    cfg = em.EpiModelConfig(population=POP, num_days=days, a0=100.0, r0=5.0, d0=1.0)
    th = jnp.asarray([[0.4, 30.0, 0.8, 0.05, 0.3, 0.01, 0.5, 1.0]], jnp.float32)
    return em.simulate_observed(th, jax.random.PRNGKey(seed), cfg)[0]


def _theta(batch: int, seed: int = 0) -> jnp.ndarray:
    return paper_prior().sample(jax.random.PRNGKey(seed), (batch,))


@pytest.mark.parametrize(
    "batch,tile",
    [
        # tile=None auto-resolves (and may pad odd batches, the legacy
        # behavior); explicit tiles must divide the batch exactly
        (64, None), (300, None), (1000, None),
        (128, 128), (512, 128), (512, 256), (1024, 256),
    ],
)
def test_kernel_matches_ref_batch_tile_sweep(batch, tile):
    obs = _observed(10)
    th = _theta(batch, seed=batch)
    seed = jnp.uint32(77)
    d_k = ops.abc_sim_distance(th, seed, obs, tile=tile, interpret=INTERPRET,
                               **KW)
    d_r = ref.abc_sim_distance_ref(th, seed, obs, **KW)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-6, atol=1e-3)


def test_resolve_tile_auto_matches_legacy_clamp():
    """tile=None keeps the exact legacy auto numerics (min(1024, pow2(B)))."""
    assert ops.resolve_tile(64) == 128
    assert ops.resolve_tile(300) == 512
    assert ops.resolve_tile(1000) == 1024
    assert ops.resolve_tile(8192) == 1024
    assert ops.resolve_tile(100_000) == 1024


def test_resolve_tile_explicit_validation():
    """Explicit tiles are taken literally and bad ones fail LOUDLY — the old
    silent clamp/over-pad at ops.py is gone."""
    assert ops.resolve_tile(8192, 2048) == 2048  # no clamp to 1024 any more
    with pytest.raises(ValueError, match="does not divide batch"):
        ops.resolve_tile(300, 128)
    with pytest.raises(ValueError, match="does not divide batch"):
        ops.resolve_tile(1000, 256)
    with pytest.raises(ValueError, match="multiple of 128"):
        ops.resolve_tile(512, 100)
    with pytest.raises(ValueError, match="multiple of 128"):
        ops.resolve_tile(512, 64)
    with pytest.raises(ValueError, match="batch must be positive"):
        ops.resolve_tile(0, 128)


def test_incompatible_tile_errors_loudly_end_to_end():
    obs = _observed(5)
    th = _theta(300)
    with pytest.raises(ValueError, match="does not divide batch"):
        ops.abc_sim_distance(th, jnp.uint32(1), obs, tile=128,
                             interpret=INTERPRET, **KW)


@pytest.mark.parametrize("days", [1, 7, 49])
def test_kernel_matches_ref_horizon_sweep(days):
    obs = _observed(days)
    th = _theta(256, seed=days)
    d_k = ops.abc_sim_distance(th, jnp.uint32(5), obs, tile=128, interpret=INTERPRET, **KW)
    d_r = ref.abc_sim_distance_ref(th, jnp.uint32(5), obs, **KW)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=2e-6, atol=1e-3)


@pytest.mark.parametrize(
    "pop,a0,r0,d0",
    [(1e5, 10.0, 0.0, 0.0), (60.36e6, 155.0, 2.0, 3.0), (328.2e6, 104.0, 7.0, 6.0)],
)
def test_kernel_matches_ref_population_sweep(pop, a0, r0, d0):
    """Country-scale populations (f32 stress: S ~ 3e8)."""
    from repro.epi import model as em

    cfg = em.EpiModelConfig(population=pop, num_days=12, a0=a0, r0=r0, d0=d0)
    th_true = jnp.asarray([[0.38, 36.0, 0.6, 0.013, 0.385, 0.009, 0.48, 0.83]], jnp.float32)
    obs = em.simulate_observed(th_true, jax.random.PRNGKey(1), cfg)[0]
    th = _theta(256, seed=9)
    kw = dict(population=pop, a0=a0, r0=r0, d0=d0)
    d_k = ops.abc_sim_distance(th, jnp.uint32(3), obs, tile=128, interpret=INTERPRET, **kw)
    d_r = ref.abc_sim_distance_ref(th, jnp.uint32(3), obs, **kw)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-5, atol=1.0)


def test_kernel_seed_sensitivity():
    """Different seeds give different (but finite) distances; same seed exact."""
    obs = _observed(8)
    th = _theta(128)
    a = ops.abc_sim_distance(th, jnp.uint32(1), obs, tile=128, interpret=INTERPRET, **KW)
    b = ops.abc_sim_distance(th, jnp.uint32(1), obs, tile=128, interpret=INTERPRET, **KW)
    c = ops.abc_sim_distance(th, jnp.uint32(2), obs, tile=128, interpret=INTERPRET, **KW)
    assert bool(jnp.all(a == b))
    assert not bool(jnp.all(a == c))
    assert bool(jnp.all(jnp.isfinite(a)))


def test_kernel_tile_invariance():
    """Distances must not depend on the tiling (pure layout parameter)."""
    obs = _observed(10)
    th = _theta(512, seed=2)
    d1 = ops.abc_sim_distance(th, jnp.uint32(9), obs, tile=128, interpret=INTERPRET, **KW)
    d2 = ops.abc_sim_distance(th, jnp.uint32(9), obs, tile=512, interpret=INTERPRET, **KW)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


def test_kernel_statistics_match_threefry_reference():
    """Hash-RNG simulation must be statistically indistinguishable from the
    paper-faithful threefry path at the distance-distribution level."""
    from repro.epi import model as em
    from repro.core.distances import euclidean_distance

    days = 15
    obs = _observed(days)
    cfg = em.EpiModelConfig(population=POP, num_days=days, a0=100.0, r0=5.0, d0=1.0)
    th = _theta(2048, seed=4)
    d_hash = np.asarray(
        ops.abc_sim_distance(th, jnp.uint32(11), obs, tile=512, interpret=INTERPRET, **KW)
    )
    sim = em.simulate_observed(th, jax.random.PRNGKey(12), cfg)
    d_tf = np.asarray(euclidean_distance(sim, obs))
    ok = np.isfinite(d_hash) & np.isfinite(d_tf)
    qs = np.linspace(0.05, 0.95, 19)
    np.testing.assert_allclose(
        np.quantile(d_hash[ok], qs), np.quantile(d_tf[ok], qs), rtol=0.1
    )
