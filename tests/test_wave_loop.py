"""Device-resident wave loop: same-seed equivalence with the host loop.

The contract pinned here is the acceptance criterion of the device loop: for
the same (key, config), the device-resident lax.while_loop driver must
produce the IDENTICAL accepted-sample set — same samples, same order, same
run count — as the legacy per-wave host loop, on the "xla" and "xla_fused"
backends, for every registered model.
"""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abc import (
    ABCConfig,
    ABCState,
    make_simulator,
    make_wave_runner,
    run_abc,
    wave_capacity,
)
from repro.epi.data import get_dataset
from repro.epi.models import get_model, list_models

DAYS = 12


def _model_tolerance(model: str, backend: str = "xla_fused") -> float:
    """Per-model epsilon at a ~2% pilot acceptance rate (models have very
    different distance scales; a hardcoded epsilon would accept nothing or
    everything depending on the model)."""
    ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
    cfg = ABCConfig(batch_size=1024, num_days=DAYS, chunk_size=1024,
                    backend=backend, model=model)
    sim = jax.jit(make_simulator(ds, cfg))
    th = get_model(model).prior().sample(jax.random.PRNGKey(99), (1024,))
    d = np.asarray(sim(th, jax.random.PRNGKey(98)))
    return float(np.quantile(d[np.isfinite(d)], 0.02))


def _cfg(model: str, backend: str, tol: float, **kw) -> ABCConfig:
    base = dict(
        batch_size=1024, tolerance=tol, target_accepted=20, chunk_size=128,
        strategy="outfeed", max_runs=10, num_days=DAYS, backend=backend,
        model=model,
    )
    base.update(kw)
    return ABCConfig(**base)


@pytest.mark.parametrize("model", list_models())
@pytest.mark.parametrize("backend", ["xla", "xla_fused"])
def test_device_loop_identical_to_host_loop(model, backend):
    tol = _model_tolerance(model, "xla_fused")
    ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
    p_host = run_abc(ds, _cfg(model, backend, tol, wave_loop="host"), key=0)
    p_dev = run_abc(ds, _cfg(model, backend, tol, wave_loop="device"), key=0)
    assert len(p_dev) == len(p_host) > 0
    assert p_dev.runs == p_host.runs
    assert p_dev.simulations == p_host.simulations
    np.testing.assert_array_equal(p_host.theta, p_dev.theta)
    np.testing.assert_array_equal(p_host.distances, p_dev.distances)


def test_device_loop_budget_exhaustion_identical():
    """With an unreachable target both drivers must burn the same wave budget
    and keep every accepted sample (including sub-target harvests)."""
    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    kw = dict(target_accepted=10**6, max_runs=4)
    # 10**6 target forces the host fallback in auto mode — request explicitly
    p_host = run_abc(ds, _cfg("siard", "xla_fused", tol, wave_loop="host", **kw),
                     key=3)
    p_dev = run_abc(ds, _cfg("siard", "xla_fused", tol, wave_loop="device", **kw),
                    key=3)
    assert p_host.runs == p_dev.runs == 4
    np.testing.assert_array_equal(p_host.theta, p_dev.theta)


def test_device_loop_checkpoint_resume_identical():
    """Segmented (checkpointing) and interrupted+resumed device runs must
    reproduce the uninterrupted accepted set exactly."""
    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = _cfg("siard", "xla_fused", tol, target_accepted=40, max_runs=20,
               wave_loop="device")
    p_full = run_abc(ds, cfg, key=7)

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "wave_state.npz")
        # segmented run: checkpoint every 2 waves
        p_seg = run_abc(ds, cfg, key=7, checkpoint_every=2, checkpoint_path=path)
        np.testing.assert_array_equal(p_full.theta, p_seg.theta)

        # interrupted at a small budget, then resumed to the full budget
        cfg_cut = dataclasses.replace(cfg, max_runs=2)
        st = ABCState()
        run_abc(ds, cfg_cut, key=7, state=st, checkpoint_every=1,
                checkpoint_path=path)
        resumed = ABCState.load(path)
        assert resumed.run_idx == st.run_idx
        p_res = run_abc(ds, cfg, key=7, state=resumed)
        assert len(p_res) == len(p_full)
        np.testing.assert_array_equal(p_full.theta, p_res.theta)


def test_auto_mode_picks_device_for_outfeed():
    from repro.core.abc import _auto_device_loop

    assert _auto_device_loop(ABCConfig(strategy="outfeed"))
    assert not _auto_device_loop(ABCConfig(strategy="topk"))
    assert not _auto_device_loop(ABCConfig(strategy="outfeed", wave_loop="host"))
    # absurd buffer sizes fall back to the host loop in auto mode only
    big = ABCConfig(strategy="outfeed", target_accepted=10**9)
    assert not _auto_device_loop(big)
    assert _auto_device_loop(dataclasses.replace(big, wave_loop="device"))


def test_wave_capacity_never_overflows():
    """fill <= capacity by construction: entering a wave requires
    accepted < target, and a wave adds at most one batch."""
    cfg = ABCConfig(batch_size=512, target_accepted=10, tolerance=np.inf,
                    chunk_size=512, num_days=DAYS, max_runs=3)
    ds = get_dataset("synthetic_small", num_days=DAYS)
    prior = get_model("siard").prior()
    runner = make_wave_runner(prior, make_simulator(ds, cfg), cfg)
    carry = runner.init(ABCState(n_params=prior.dim))
    out = runner(jax.random.PRNGKey(0), 0, carry, 3)
    # everything accepted (eps = inf): one wave overshoots to a full batch
    assert int(out.n_accepted) == 512
    assert int(out.waves_done) == 1
    assert int(out.fill_counts[0]) == 512 <= wave_capacity(cfg)


def test_pjit_wave_runner_matches_single_device_stream():
    """GSPMD wave-loop style: sharding hints must not change sample values."""
    from repro.core.distributed import make_wave_runner as make_dist_wave_runner

    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = _cfg("siard", "xla_fused", tol)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    wr = make_dist_wave_runner(mesh, ds, cfg, style="pjit")
    p_pjit = run_abc(ds, cfg, key=0, wave_runner=wr)
    p_single = run_abc(ds, cfg, key=0)
    np.testing.assert_array_equal(p_single.theta, p_pjit.theta)


# ------------------------------------------------------------------------
# Accept-buffer edge cases: compact_accepted semantics at the capacity edge
# ------------------------------------------------------------------------

def _buffers(capacity, p=2, fill=0):
    th = np.full((capacity, p), -1.0, np.float32)
    d = np.full((capacity,), np.inf, np.float32)
    return jnp.asarray(th), jnp.asarray(d), jnp.int32(fill)


def test_compact_accepted_zero_accepts_is_a_noop():
    """An all-reject wave must leave the buffers bitwise untouched."""
    from repro.core.abc import compact_accepted

    cap, B, p = 8, 4, 2
    th_buf, d_buf, fill = _buffers(cap, p, fill=3)
    theta = jnp.arange(B * p, dtype=jnp.float32).reshape(B, p)
    dist = jnp.arange(B, dtype=jnp.float32)
    accept = jnp.zeros((B,), bool)
    th2, d2, fill2 = compact_accepted(th_buf, d_buf, fill, theta, dist,
                                      accept, cap)
    assert int(fill2) == 3
    np.testing.assert_array_equal(np.asarray(th2), np.asarray(th_buf))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d_buf))


def test_compact_accepted_fills_capacity_exactly():
    """fill + accepts == capacity: every accepted row lands, in order, and
    the buffer reports exactly full."""
    from repro.core.abc import compact_accepted

    cap, B, p = 6, 4, 2
    th_buf, d_buf, fill = _buffers(cap, p, fill=2)
    theta = jnp.arange(B * p, dtype=jnp.float32).reshape(B, p)
    dist = jnp.asarray([10.0, 11.0, 12.0, 13.0], jnp.float32)
    accept = jnp.asarray([True, True, True, True])
    th2, d2, fill2 = compact_accepted(th_buf, d_buf, fill, theta, dist,
                                      accept, cap)
    assert int(fill2) == cap
    np.testing.assert_array_equal(np.asarray(d2)[2:], np.asarray(dist))
    np.testing.assert_array_equal(np.asarray(th2)[2:], np.asarray(theta))
    # pre-existing rows untouched
    np.testing.assert_array_equal(np.asarray(d2)[:2], np.inf)


def test_compact_accepted_overflow_drops_excess_keeps_prefix():
    """More accepts than free slots: the first (capacity - fill) accepted
    rows land in stream order, the excess is dropped by the scatter, and the
    returned fill OVERCOUNTS (callers clamp with min(fill, capacity) — the
    WaveLoopOutput contract)."""
    from repro.core.abc import compact_accepted

    cap, B, p = 4, 6, 2
    th_buf, d_buf, fill = _buffers(cap, p, fill=2)
    theta = jnp.arange(B * p, dtype=jnp.float32).reshape(B, p)
    dist = jnp.arange(10.0, 10.0 + B, dtype=jnp.float32)
    accept = jnp.asarray([True, False, True, True, True, False])  # 4 accepts
    th2, d2, fill2 = compact_accepted(th_buf, d_buf, fill, theta, dist,
                                      accept, cap)
    # 2 free slots -> accepted samples 0 and 2 land; 3 and 4 are dropped
    np.testing.assert_array_equal(np.asarray(d2)[2:], [10.0, 12.0])
    np.testing.assert_array_equal(
        np.asarray(th2)[2:], np.asarray(theta)[[0, 2]]
    )
    assert int(fill2) == 2 + 4  # overcount by design
    assert min(int(fill2), cap) == cap


def test_wave_loop_single_wave_overflow_reports_clamped_fill(small_dataset):
    """A capacity-capped loop whose single wave over-accepts must clamp
    fill_counts to capacity while n_accepted counts every acceptance."""
    from repro.core.abc import build_wave_loop, make_simulator
    from repro.epi.models import get_model

    B = 256
    cfg = ABCConfig(batch_size=B, tolerance=np.inf, target_accepted=10**6,
                    chunk_size=B, num_days=15, max_runs=2)
    prior = get_model("siard").prior()
    sim = make_simulator(small_dataset, cfg)
    cap = B // 2  # deliberately too small: one all-accept wave overflows
    loop = jax.jit(build_wave_loop(
        prior, lambda th, k, _d: sim(th, k), cfg, capacity=cap))
    th0 = jnp.zeros((cap, prior.dim), jnp.float32)
    d0 = jnp.full((cap,), jnp.inf, jnp.float32)
    out = loop(jax.random.PRNGKey(0), 0, th0, d0, 0, 0, 1, np.inf, None)
    assert int(out.waves_done) == 1
    assert int(out.n_accepted) == B  # every sample accepted (eps = inf)
    assert int(out.fill_counts[0]) == cap  # clamped to the buffer
    assert bool(jnp.all(jnp.isfinite(out.dist_buf)))  # fully populated


def test_wave_capacity_reaches_exactly_full(small_dataset):
    """target == capacity via an explicit override: the loop stops when the
    buffer is exactly full, with every row valid."""
    from repro.core.abc import build_wave_loop, make_simulator
    from repro.epi.models import get_model

    B = 128
    cfg = ABCConfig(batch_size=B, tolerance=np.inf, target_accepted=2 * B,
                    chunk_size=B, num_days=15, max_runs=4)
    prior = get_model("siard").prior()
    sim = make_simulator(small_dataset, cfg)
    cap = 2 * B  # two all-accept waves fill it to the brim, exactly
    loop = jax.jit(build_wave_loop(
        prior, lambda th, k, _d: sim(th, k), cfg, capacity=cap))
    th0 = jnp.zeros((cap, prior.dim), jnp.float32)
    d0 = jnp.full((cap,), jnp.inf, jnp.float32)
    out = loop(jax.random.PRNGKey(0), 0, th0, d0, 0, 0, 4, np.inf, None)
    assert int(out.waves_done) == 2
    assert int(out.n_accepted) == 2 * B
    assert int(out.fill_counts[0]) == cap
    assert bool(jnp.all(jnp.isfinite(out.dist_buf)))


@pytest.mark.skipif(not hasattr(jax, "shard_map"),
                    reason="jax.shard_map not available in this jax")
def test_shardmap_wave_runner_matches_host_distributed_stream():
    """Per-device-replica wave loop vs the legacy shard_map host loop: the
    union of accepted samples must match (ordering differs across shards)."""
    from repro.core.distributed import (
        make_runner,
        make_wave_runner as make_dist_wave_runner,
    )

    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = _cfg("siard", "xla_fused", tol)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    p_host = run_abc(ds, cfg, key=0, run_fn=make_runner(mesh, ds, cfg))
    wr = make_dist_wave_runner(mesh, ds, cfg, style="shard_map")
    p_dev = run_abc(ds, cfg, key=0, wave_runner=wr)
    assert len(p_host) == len(p_dev)
    np.testing.assert_array_equal(
        np.sort(p_host.distances), np.sort(p_dev.distances)
    )
