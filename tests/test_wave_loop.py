"""Device-resident wave loop: same-seed equivalence with the host loop.

The contract pinned here is the acceptance criterion of the device loop: for
the same (key, config), the device-resident lax.while_loop driver must
produce the IDENTICAL accepted-sample set — same samples, same order, same
run count — as the legacy per-wave host loop, on the "xla" and "xla_fused"
backends, for every registered model.
"""

import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest

from repro.core.abc import (
    ABCConfig,
    ABCState,
    make_simulator,
    make_wave_runner,
    run_abc,
    wave_capacity,
)
from repro.epi.data import get_dataset
from repro.epi.models import get_model, list_models

DAYS = 12


def _model_tolerance(model: str, backend: str = "xla_fused") -> float:
    """Per-model epsilon at a ~2% pilot acceptance rate (models have very
    different distance scales; a hardcoded epsilon would accept nothing or
    everything depending on the model)."""
    ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
    cfg = ABCConfig(batch_size=1024, num_days=DAYS, chunk_size=1024,
                    backend=backend, model=model)
    sim = jax.jit(make_simulator(ds, cfg))
    th = get_model(model).prior().sample(jax.random.PRNGKey(99), (1024,))
    d = np.asarray(sim(th, jax.random.PRNGKey(98)))
    return float(np.quantile(d[np.isfinite(d)], 0.02))


def _cfg(model: str, backend: str, tol: float, **kw) -> ABCConfig:
    base = dict(
        batch_size=1024, tolerance=tol, target_accepted=20, chunk_size=128,
        strategy="outfeed", max_runs=10, num_days=DAYS, backend=backend,
        model=model,
    )
    base.update(kw)
    return ABCConfig(**base)


@pytest.mark.parametrize("model", list_models())
@pytest.mark.parametrize("backend", ["xla", "xla_fused"])
def test_device_loop_identical_to_host_loop(model, backend):
    tol = _model_tolerance(model, "xla_fused")
    ds = get_dataset("synthetic_small", num_days=DAYS, model=model)
    p_host = run_abc(ds, _cfg(model, backend, tol, wave_loop="host"), key=0)
    p_dev = run_abc(ds, _cfg(model, backend, tol, wave_loop="device"), key=0)
    assert len(p_dev) == len(p_host) > 0
    assert p_dev.runs == p_host.runs
    assert p_dev.simulations == p_host.simulations
    np.testing.assert_array_equal(p_host.theta, p_dev.theta)
    np.testing.assert_array_equal(p_host.distances, p_dev.distances)


def test_device_loop_budget_exhaustion_identical():
    """With an unreachable target both drivers must burn the same wave budget
    and keep every accepted sample (including sub-target harvests)."""
    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    kw = dict(target_accepted=10**6, max_runs=4)
    # 10**6 target forces the host fallback in auto mode — request explicitly
    p_host = run_abc(ds, _cfg("siard", "xla_fused", tol, wave_loop="host", **kw),
                     key=3)
    p_dev = run_abc(ds, _cfg("siard", "xla_fused", tol, wave_loop="device", **kw),
                    key=3)
    assert p_host.runs == p_dev.runs == 4
    np.testing.assert_array_equal(p_host.theta, p_dev.theta)


def test_device_loop_checkpoint_resume_identical():
    """Segmented (checkpointing) and interrupted+resumed device runs must
    reproduce the uninterrupted accepted set exactly."""
    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = _cfg("siard", "xla_fused", tol, target_accepted=40, max_runs=20,
               wave_loop="device")
    p_full = run_abc(ds, cfg, key=7)

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "wave_state.npz")
        # segmented run: checkpoint every 2 waves
        p_seg = run_abc(ds, cfg, key=7, checkpoint_every=2, checkpoint_path=path)
        np.testing.assert_array_equal(p_full.theta, p_seg.theta)

        # interrupted at a small budget, then resumed to the full budget
        cfg_cut = dataclasses.replace(cfg, max_runs=2)
        st = ABCState()
        run_abc(ds, cfg_cut, key=7, state=st, checkpoint_every=1,
                checkpoint_path=path)
        resumed = ABCState.load(path)
        assert resumed.run_idx == st.run_idx
        p_res = run_abc(ds, cfg, key=7, state=resumed)
        assert len(p_res) == len(p_full)
        np.testing.assert_array_equal(p_full.theta, p_res.theta)


def test_auto_mode_picks_device_for_outfeed():
    from repro.core.abc import _auto_device_loop

    assert _auto_device_loop(ABCConfig(strategy="outfeed"))
    assert not _auto_device_loop(ABCConfig(strategy="topk"))
    assert not _auto_device_loop(ABCConfig(strategy="outfeed", wave_loop="host"))
    # absurd buffer sizes fall back to the host loop in auto mode only
    big = ABCConfig(strategy="outfeed", target_accepted=10**9)
    assert not _auto_device_loop(big)
    assert _auto_device_loop(dataclasses.replace(big, wave_loop="device"))


def test_wave_capacity_never_overflows():
    """fill <= capacity by construction: entering a wave requires
    accepted < target, and a wave adds at most one batch."""
    cfg = ABCConfig(batch_size=512, target_accepted=10, tolerance=np.inf,
                    chunk_size=512, num_days=DAYS, max_runs=3)
    ds = get_dataset("synthetic_small", num_days=DAYS)
    prior = get_model("siard").prior()
    runner = make_wave_runner(prior, make_simulator(ds, cfg), cfg)
    carry = runner.init(ABCState(n_params=prior.dim))
    out = runner(jax.random.PRNGKey(0), 0, carry, 3)
    # everything accepted (eps = inf): one wave overshoots to a full batch
    assert int(out.n_accepted) == 512
    assert int(out.waves_done) == 1
    assert int(out.fill_counts[0]) == 512 <= wave_capacity(cfg)


def test_pjit_wave_runner_matches_single_device_stream():
    """GSPMD wave-loop style: sharding hints must not change sample values."""
    from repro.core.distributed import make_wave_runner as make_dist_wave_runner

    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = _cfg("siard", "xla_fused", tol)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    wr = make_dist_wave_runner(mesh, ds, cfg, style="pjit")
    p_pjit = run_abc(ds, cfg, key=0, wave_runner=wr)
    p_single = run_abc(ds, cfg, key=0)
    np.testing.assert_array_equal(p_single.theta, p_pjit.theta)


@pytest.mark.skipif(not hasattr(jax, "shard_map"),
                    reason="jax.shard_map not available in this jax")
def test_shardmap_wave_runner_matches_host_distributed_stream():
    """Per-device-replica wave loop vs the legacy shard_map host loop: the
    union of accepted samples must match (ordering differs across shards)."""
    from repro.core.distributed import (
        make_runner,
        make_wave_runner as make_dist_wave_runner,
    )

    tol = _model_tolerance("siard")
    ds = get_dataset("synthetic_small", num_days=DAYS)
    cfg = _cfg("siard", "xla_fused", tol)
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    p_host = run_abc(ds, cfg, key=0, run_fn=make_runner(mesh, ds, cfg))
    wr = make_dist_wave_runner(mesh, ds, cfg, style="shard_map")
    p_dev = run_abc(ds, cfg, key=0, wave_runner=wr)
    assert len(p_host) == len(p_dev)
    np.testing.assert_array_equal(
        np.sort(p_host.distances), np.sort(p_dev.distances)
    )
