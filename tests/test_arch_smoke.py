"""Per-architecture smoke tests: reduced config of the same family, one
forward + one grad step + one decode step on CPU; shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_model, list_archs

ARCHS = list(list_archs())


def _concrete_inputs(model, mode, batch=2, seq=32):
    spec, _ = model.make_inputs(mode, batch, seq)
    out = {}
    for k, s in spec.items():
        if s.dtype == jnp.int32:
            if k == "pos":
                out[k] = jnp.asarray(seq - 1, jnp.int32)
            else:
                out[k] = jnp.zeros(s.shape, jnp.int32) + (np.arange(s.shape[-1]) % 7)
        else:
            out[k] = jax.random.normal(jax.random.PRNGKey(3), s.shape, jnp.float32).astype(
                s.dtype
            )
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _concrete_inputs(model, "train")

    logits = jax.jit(model.prefill)(params, batch)
    vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab
    assert logits.shape[0] == 2 and logits.shape[-1] == vocab
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss {loss}"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: NaN grads"
    # at least one nonzero gradient
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    cache_len = 32
    cache_shapes = model.init_cache_shape(batch=2, cache_len=cache_len)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    batch = _concrete_inputs(model, "decode", batch=2, seq=cache_len)

    step = jax.jit(model.decode_step)
    logits, new_cache = step(params, cache, batch)
    vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab
    assert logits.shape == (2, 1, vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode logits"
    # cache structure preserved
    jax.tree.map(lambda a, b: None, cache, new_cache)
    # a second step must also be finite (state actually evolves)
    logits2, _ = step(params, new_cache, batch)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_logical_tree_matches_params(arch):
    """Every param leaf must have a logical spec of matching rank."""
    model = get_model(arch, smoke=True)
    shapes = model.param_shapes()
    logical = model.param_logical()
    flat_s = {jax.tree_util.keystr(k): v for k, v in jax.tree_util.tree_leaves_with_path(shapes)}
    flat_l = {
        jax.tree_util.keystr(k): v
        for k, v in jax.tree_util.tree_leaves_with_path(
            logical,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )
    }
    assert set(flat_s) == set(flat_l), (
        set(flat_s) ^ set(flat_l)
    )
    for k in flat_s:
        assert len(flat_l[k]) == len(flat_s[k].shape), (
            arch, k, flat_l[k], flat_s[k].shape,
        )


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    expect = {
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
                              d_ff=16384, vocab=92544),
        "gemma2-27b": dict(n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16,
                           d_ff=36864, vocab=256000),
        "minitron-8b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
                            d_ff=16384, vocab=256000),
        "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                         d_ff=16384, vocab=256000),
        "deepseek-moe-16b": dict(n_layers=28, d_model=2048, n_heads=16,
                                 n_kv_heads=16, vocab=102400),
        "qwen3-moe-30b-a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab=151936),
        "mamba2-130m": dict(n_layers=24, d_model=768, d_state=128, vocab=50280),
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, d_state=64, vocab=32000,
                            n_heads=32, n_kv_heads=32, d_ff=10240),
    }
    for arch, fields in expect.items():
        cfg = get_model(arch).cfg
        for f, v in fields.items():
            assert getattr(cfg, f) == v, (arch, f, getattr(cfg, f), v)
    w = get_model("whisper-large-v3").cfg
    assert (w.n_enc_layers, w.d_model, w.n_heads, w.d_ff, w.vocab) == (
        32, 1280, 20, 5120, 51866,
    )
    v = get_model("internvl2-2b").cfg
    assert (v.lm.n_layers, v.lm.d_model, v.lm.n_heads, v.lm.n_kv_heads,
            v.lm.d_ff, v.lm.vocab) == (24, 2048, 16, 8, 8192, 92553)
    # MoE structure
    dm = get_model("deepseek-moe-16b").cfg.moe
    assert (dm.n_experts, dm.top_k, dm.n_shared, dm.d_expert) == (64, 6, 2, 1408)
    qm = get_model("qwen3-moe-30b-a3b").cfg.moe
    assert (qm.n_experts, qm.top_k, qm.d_expert) == (128, 8, 768)


def test_param_counts_plausible():
    """Sanity: full-config param counts land near the advertised sizes."""
    expect_b = {
        "internlm2-20b": (17, 23),
        "gemma2-27b": (24, 30),
        "minitron-8b": (7, 10),
        "gemma-2b": (2, 3.5),
        "deepseek-moe-16b": (14, 19),
        "qwen3-moe-30b-a3b": (26, 33),
        "whisper-large-v3": (1.2, 2.0),
        "mamba2-130m": (0.1, 0.2),
        "internvl2-2b": (1.5, 2.6),
        "zamba2-2.7b": (2.2, 3.6),
    }
    for arch, (lo, hi) in expect_b.items():
        n = get_model(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo}, {hi}]"


def test_kv_quant_decode_matches_bf16(monkeypatch):
    """int8 KV cache decode stays close to the bf16-cache decode."""
    model = get_model("internlm2-20b", smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 1), jnp.int32),
             "pos": jnp.asarray(0, jnp.int32)}

    def run(quant):
        if quant:
            monkeypatch.setenv("REPRO_KV_QUANT", "1")
        else:
            monkeypatch.delenv("REPRO_KV_QUANT", raising=False)
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            model.init_cache_shape(batch=2, cache_len=16),
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        logits, cache = jax.jit(model.decode_step)(params, cache, batch)
        b2 = dict(batch, pos=jnp.asarray(1, jnp.int32))
        logits2, _ = jax.jit(model.decode_step)(params, cache, b2)
        return np.asarray(logits2, np.float32)

    ref = run(False)
    qnt = run(True)
    assert np.isfinite(qnt).all()
    # int8 cache introduces bounded error only
    denom = np.abs(ref).max() + 1e-6
    assert np.abs(qnt - ref).max() / denom < 0.1, np.abs(qnt - ref).max() / denom
