"""Serving layer: batched posterior queries, cache semantics, warm re-fits.

Pins the serving acceptance contract: a mixed batch of >= 8 forecast /
counterfactual queries across >= 2 schedules is answered via <= 2 compiled
calls (jit-cache-size pinned), responses BIT-IDENTICAL to sequential
`posterior_forecast` calls for the same (query, seed); a posterior-cache
hit skips fitting entirely; a warm-started SMC re-fit reaches the
recovery-test accuracy bar with fewer simulations than the cold fit; and
truncated forecasts subsample with a seeded permutation instead of the
biased first-k rows.
"""

import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.core.posterior import Posterior
from repro.core.serving import (
    EpiServer,
    ForecastQuery,
    PosteriorStore,
    ServeConfig,
    dataset_version,
    forecast_bands,
    load_dataset_file,
    save_dataset_file,
    subsample_particles,
)
from repro.core.smc import SMCConfig, run_smc_abc
from repro.epi.data import synthetic_dataset
from repro.epi.models import get_model
from repro.epi.spec import EMPTY_SCHEDULE
from repro.launch import abc_serve
from repro.launch.abc_run import parse_intervention, posterior_forecast
from test_posterior_recovery import DAYS, TRUTH, _assert_recovers, _dataset

TINY_FIT = SMCConfig(
    n_particles=16, batch_size=256, n_rounds=1, quantile=0.5, num_days=8,
    backend="xla_fused", model="siard",
)


def _fake_posterior(model="siard", n=48, seed=0) -> Posterior:
    """Prior samples standing in for a fit — forecasting is fit-agnostic."""
    spec = get_model(model)
    theta = np.asarray(
        spec.prior().sample(jax.random.PRNGKey(seed), (n,)), np.float32
    )
    return Posterior(
        theta=theta, distances=np.arange(n, dtype=np.float32),
        tolerance=1.0, param_names=spec.param_names,
    )


# ------------------------------------------------------- batched answering
def test_mixed_batch_bit_identical_in_two_compiled_calls():
    """The acceptance pin: 8 queries (4 forecasts + 4 counterfactuals, two
    schedule shapes) -> exactly 2 batched compiled calls, each compiled
    ONCE (jit cache size), responses dict-equal (hence bit-identical
    band floats) to sequential posterior_forecast."""
    from repro.core.abc import ABCConfig

    cfg = ServeConfig(
        slots=4, forecast_particles=32, fit=dataclasses.replace(
            TINY_FIT, num_days=10),
    )
    server = EpiServer(cfg)
    post = _fake_posterior()
    server.preload("synthetic_small", "siard", post)

    sched = parse_intervention("alpha@5=0.5")
    queries = [
        ForecastQuery(dataset="synthetic_small", horizon=7, seed=i)
        for i in range(4)
    ] + [
        ForecastQuery(dataset="synthetic_small", horizon=7, schedule=sched,
                      seed=i)
        for i in range(4)
    ]
    responses = server.answer(queries)
    assert len(responses) == 8
    assert server.fits == 0  # preloaded: no fitting on the query path
    assert server.batched_calls == 2
    assert server.kernels.n_compiled == 2
    for _, batched in server.kernels._fns.values():
        assert batched._cache_size() == 1

    ds, _ = server.dataset("synthetic_small", "siard")
    acfg = ABCConfig(num_days=10, model="siard")
    for i, q in enumerate(queries):
        seq = posterior_forecast(
            post.theta, ds, acfg, q.horizon, schedule=q.schedule,
            key=q.seed, max_particles=cfg.forecast_particles,
        )
        assert responses[i] == seq, f"query {i} diverged from sequential"
        # strict JSON end to end
        json.dumps(responses[i], allow_nan=False)


def test_padded_final_chunk_still_matches_sequential():
    """A group smaller than `slots` pads lanes by repeating lane 0 — the
    padding must never leak into real responses."""
    from repro.core.abc import ABCConfig

    server = EpiServer(ServeConfig(
        slots=4, forecast_particles=16,
        fit=dataclasses.replace(TINY_FIT, num_days=10),
    ))
    post = _fake_posterior(n=20)
    server.preload("synthetic_small", "siard", post)
    queries = [
        ForecastQuery(dataset="synthetic_small", horizon=5, seed=7),
        ForecastQuery(dataset="synthetic_small", horizon=5, seed=8),
        ForecastQuery(dataset="synthetic_small", horizon=5,
                      schedule=EMPTY_SCHEDULE, seed=9),
    ]
    responses = server.answer(queries)
    # empty-schedule counterfactuals share the no-schedule forecast SHAPE
    # (scales ride theta columns), so all 3 queries fit one padded chunk
    assert server.batched_calls == 1
    ds, _ = server.dataset("synthetic_small", "siard")
    acfg = ABCConfig(num_days=10, model="siard")
    for q, resp in zip(queries, responses):
        seq = posterior_forecast(post.theta, ds, acfg, q.horizon,
                                 schedule=q.schedule, key=q.seed,
                                 max_particles=16)
        assert resp == seq


# ------------------------------------------------------ subsample bugfix
def test_truncated_bands_statistically_match_full_bands():
    """topk accepted sets are distance-ordered; first-k truncation biases
    the bands. The seeded-permutation subsample must track the full-set
    bands closely while the first-k bands drift."""
    model = "sir"
    spec = get_model(model)
    n = 512
    raw = np.asarray(
        spec.prior().sample(jax.random.PRNGKey(3), (n,)), np.float32
    )
    # a concentrated accepted-set-like cloud around the truth, then
    # emulate distance ordering correlated with a parameter (low-distance
    # particles have low beta) by sorting on the first column
    truth = np.asarray(TRUTH[model], np.float32)
    theta = truth + (raw - truth) * 0.3
    theta = theta[np.argsort(theta[:, 0])]
    ds = synthetic_dataset(theta=TRUTH[model], population=1e6, num_days=15,
                           a0=100.0, seed=11, name="subsample_ds",
                           model=model)

    def bands(th, k):
        return forecast_bands(th, ds, model=model, fit_days=15, horizon=5,
                              key=4, max_particles=k)

    full = bands(theta, n)
    perm = bands(theta, 128)  # seeded-permutation subsample (the fix)
    firstk = bands(theta[:128], 128)  # the old biased truncation

    ch = spec.observed[0]
    ref = np.asarray(full["channels"][ch]["q50"])
    scale = np.abs(ref).mean() + 1.0

    def err(b):
        return np.abs(np.asarray(b["channels"][ch]["q50"]) - ref).mean() / scale

    assert err(perm) < 0.15, "permutation subsample drifted from full bands"
    assert err(perm) < err(firstk), (
        f"seeded subsample ({err(perm):.3f}) should beat first-k "
        f"truncation ({err(firstk):.3f})"
    )


def test_subsample_is_seeded_and_unbiased():
    theta = np.arange(1000, dtype=np.float32).reshape(-1, 1)
    a = subsample_particles(theta, 5, 200)
    b = subsample_particles(theta, 5, 200)
    c = subsample_particles(theta, 6, 200)
    np.testing.assert_array_equal(a, b)  # deterministic in the seed
    assert not np.array_equal(a, c)
    assert abs(a.mean() - theta.mean()) < 40  # unbiased (first-k mean: 99.5)
    np.testing.assert_array_equal(subsample_particles(theta, 5, 1000), theta)


# ------------------------------------------------------------ cache hits
def test_posterior_cache_hit_skips_fitting(tmp_path):
    server = EpiServer(ServeConfig(
        slots=2, forecast_particles=8, fit=TINY_FIT,
        store_dir=str(tmp_path / "store"),
    ))
    q = ForecastQuery(dataset="synthetic_small", horizon=3, seed=0)
    server.answer([q])
    assert server.fits == 1
    server.answer([dataclasses.replace(q, seed=5)])
    assert server.fits == 1  # in-memory hit
    # a FRESH server with the same store answers without fitting at all
    server2 = EpiServer(ServeConfig(
        slots=2, forecast_particles=8, fit=TINY_FIT,
        store_dir=str(tmp_path / "store"),
    ))
    server2.answer([q])
    assert server2.fits == 0  # store hit


# ------------------------------------------------------------- warm start
def test_warm_started_refit_fewer_sims_same_accuracy():
    """Warm-starting SMC from a cached posterior must reach the recovery
    bar of tests/test_posterior_recovery.py with FEWER simulations than
    the cold fit (round 0 re-simulates n_particles instead of consuming
    prior waves)."""
    model = "sir"
    ds = _dataset(model)
    cold_cfg = SMCConfig(
        n_particles=96, batch_size=4096, n_rounds=3, quantile=0.4,
        num_days=DAYS, backend="xla_fused", model=model,
    )
    cold = run_smc_abc(ds, cold_cfg, key=1)
    assert cold.weights is not None and cold.weights.shape == (96,)
    warm_cfg = dataclasses.replace(
        cold_cfg, n_rounds=2,
        initial_particles=cold.theta, initial_weights=cold.weights,
    )
    warm = run_smc_abc(ds, warm_cfg, key=2)
    assert warm.simulations < cold.simulations, (
        warm.simulations, cold.simulations)
    assert warm.tolerance <= cold.tolerance  # refined, not reset
    _assert_recovers(warm.theta, model)


def test_smc_initial_particles_validation():
    with pytest.raises(ValueError, match="initial_weights"):
        SMCConfig(initial_weights=np.ones(4))
    with pytest.raises(ValueError):
        SMCConfig(initial_particles=np.zeros((0, 3)))
    with pytest.raises(ValueError):
        SMCConfig(initial_particles=np.ones((4, 3)),
                  initial_weights=np.ones(5))
    with pytest.raises(ValueError):
        SMCConfig(initial_particles=np.ones((4, 3)),
                  initial_weights=np.zeros(4))  # zero-sum weights


# ------------------------------------------------------------------ store
def test_posterior_store_atomic_swap(tmp_path):
    store = PosteriorStore(str(tmp_path))
    p1, p2 = _fake_posterior(n=8, seed=1), _fake_posterior(n=8, seed=2)
    store.put("k", "v1", p1)
    assert store.version_of("k") == "v1"
    np.testing.assert_array_equal(store.get("k", "v1").theta, p1.theta)
    store.put("k", "v2", p2)
    assert store.get("k", "v1") is None  # stale version: miss, not p1
    version, latest = store.latest("k")
    assert version == "v2"
    np.testing.assert_array_equal(latest.theta, p2.theta)
    npz = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(npz) == 1 and "v2" in npz[0]  # v1 payload pruned


# ------------------------------------------------- dataset files & daemon
def _write_dataset(path, scale=1.0, num_days=12):
    ds = synthetic_dataset(theta=TRUTH["sir"], population=1e6,
                           num_days=num_days, a0=100.0, seed=11,
                           name="served", model="sir")
    ds = dataclasses.replace(
        ds, observed=(ds.observed * scale).astype(np.float32))
    save_dataset_file(str(path), ds)
    return ds


def test_dataset_file_round_trip_and_version(tmp_path):
    path = tmp_path / "served.json"
    ds = _write_dataset(path)
    back = load_dataset_file(str(path))
    np.testing.assert_array_equal(back.observed, ds.observed)
    assert back.name == ds.name and back.population == ds.population
    assert dataset_version(back) == dataset_version(ds)
    _write_dataset(path, scale=1.1)
    assert dataset_version(load_dataset_file(str(path))) != dataset_version(ds)
    with pytest.raises(ValueError, match="malformed"):
        bad = tmp_path / "bad.json"
        bad.write_text('{"name": "x"}')
        load_dataset_file(str(bad))


def test_daemon_refits_on_content_change_with_warm_start(tmp_path):
    data_dir, store_dir = tmp_path / "data", tmp_path / "store"
    data_dir.mkdir()
    _write_dataset(data_dir / "served.json")
    fit = dataclasses.replace(TINY_FIT, model="sir")

    def make_server():
        return EpiServer(ServeConfig(
            fit=fit, data_dir=str(data_dir), store_dir=str(store_dir)))

    s1 = make_server()
    assert s1.refresh("served", "sir") == "cold_fit"
    assert s1.refresh("served", "sir") == "cached"
    # new daily data (content change) -> a FRESH process re-fits WARM from
    # the stored previous version
    _write_dataset(data_dir / "served.json", scale=1.05)
    s2 = make_server()
    assert s2.refresh("served", "sir") == "warm_refit"
    assert s2.warm_fits == 1
    assert s2.refresh("served", "sir") == "cached"


def test_abc_serve_once_cli(tmp_path):
    data_dir, store_dir = tmp_path / "data", tmp_path / "store"
    data_dir.mkdir()
    _write_dataset(data_dir / "served.json")
    argv = ["--once", "--data-dir", str(data_dir), "--store", str(store_dir),
            "--models", "sir", "--days", "8", "--fit-particles", "16",
            "--fit-batch", "256", "--fit-rounds", "1"]
    assert abc_serve.main(argv) == 1  # first sweep: one cold fit
    assert abc_serve.main(argv) == 0  # content unchanged: all cached


# ---------------------------------------------------------------- queries
def test_forecast_query_from_json():
    q = ForecastQuery.from_json({
        "dataset": "italy", "model": "siard", "horizon": 10,
        "schedule": "alpha@5=0.5", "seed": 3,
    })
    assert q.kind == "counterfactual"
    assert q.schedule.breakpoints == (5,)
    lifted = ForecastQuery.from_json({"dataset": "italy", "schedule": "none"})
    assert lifted.schedule is EMPTY_SCHEDULE and lifted.kind == "counterfactual"
    plain = ForecastQuery.from_json({"dataset": "italy"})
    assert plain.schedule is None and plain.kind == "forecast"
    with pytest.raises(ValueError, match="grammar string"):
        ForecastQuery.from_json({"dataset": "italy", "schedule": {"day": 5}})
