"""Optional-`hypothesis` shim for the property-based test modules.

This container does not ship `hypothesis`; a bare import poisoned tier-1 with
collection errors that aborted the whole suite. A plain
`pytest.importorskip("hypothesis")` would skip the ENTIRE module, losing the
non-property tests that live alongside — so instead the property decorators
degrade to `pytest.mark.skip` when the package is absent and everything else
in the module keeps running. With `hypothesis` installed (e.g. in CI) the
real decorators are re-exported untouched.

Usage in a test module:

    from _hypothesis_compat import given, settings, st
"""

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False
    hypothesis = None

    class _StrategyStub:
        """Accepts any strategy-construction call (st.floats(...), ...)."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None

            return _strategy

    st = _StrategyStub()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco


requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)
