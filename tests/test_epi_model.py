"""Unit + property tests for the stochastic epidemiology model (paper §2.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# degrades to skip-markers when hypothesis is absent (tier-1 container)
from _hypothesis_compat import given, settings, st

from repro.epi import model as em

CFG = em.EpiModelConfig(population=1e6, num_days=12, a0=100.0, r0=5.0, d0=1.0)


def _theta(batch=4, seed=0):
    from repro.core.priors import paper_prior

    return paper_prior().sample(jax.random.PRNGKey(seed), (batch,))


def test_initial_state_matches_paper_step1():
    th = jnp.asarray([[0.5, 10.0, 1.0, 0.1, 0.2, 0.05, 0.5, 1.5]], jnp.float32)
    s0 = em.initial_state(th, CFG)[0]
    assert float(s0[5]) == 0.0  # Ru = 0
    assert float(s0[1]) == pytest.approx(1.5 * 100.0)  # I0 = kappa * A0
    assert float(s0[0]) == pytest.approx(1e6 - (100 + 5 + 1 + 150))
    assert float(s0[2]) == 100.0 and float(s0[3]) == 5.0 and float(s0[4]) == 1.0


def test_hazards_eq5():
    th = jnp.asarray([[0.5, 10.0, 1.0, 0.1, 0.2, 0.05, 0.5, 1.5]], jnp.float32)
    state = jnp.asarray([[9e5, 150.0, 100.0, 5.0, 1.0, 0.0]], jnp.float32)
    h = em.hazards(state, th, CFG.population)[0]
    g = 0.5 + 10.0 / (1.0 + (100.0 + 5.0 + 1.0) ** 1.0)
    np.testing.assert_allclose(float(h[0]), g * 9e5 * 150.0 / 1e6, rtol=1e-5)
    np.testing.assert_allclose(float(h[1]), 0.2 * 150.0, rtol=1e-6)  # gamma*I
    np.testing.assert_allclose(float(h[2]), 0.1 * 100.0, rtol=1e-6)  # beta*A
    np.testing.assert_allclose(float(h[3]), 0.05 * 100.0, rtol=1e-6)  # delta*A
    np.testing.assert_allclose(float(h[4]), 0.1 * 0.5 * 150.0, rtol=1e-6)  # beta*eta*I


def test_trajectory_shapes_and_finiteness():
    traj = em.simulate(_theta(8), jax.random.PRNGKey(1), CFG)
    assert traj.shape == (8, CFG.num_days, 6)
    assert bool(jnp.all(jnp.isfinite(traj)))
    obs = em.simulate_observed(_theta(8), jax.random.PRNGKey(1), CFG)
    assert obs.shape == (8, 3, CFG.num_days)


def test_population_conservation_and_nonnegativity():
    """Mass moves between compartments but the total never changes, and no
    compartment goes negative — the clamping contract."""
    th = _theta(64, seed=3)
    traj = em.simulate(th, jax.random.PRNGKey(2), CFG)
    total = jnp.sum(traj, axis=-1)
    init_total = jnp.sum(em.initial_state(th, CFG), axis=-1)
    expected = np.broadcast_to(np.asarray(init_total)[:, None], total.shape)
    np.testing.assert_allclose(np.asarray(total), expected, rtol=1e-6)
    assert float(jnp.min(traj)) >= 0.0


def test_cumulative_channels_monotone():
    """R, D, Ru only ever receive mass — must be non-decreasing."""
    traj = em.simulate(_theta(32, seed=5), jax.random.PRNGKey(3), CFG)
    for ch in (3, 4, 5):
        diffs = jnp.diff(traj[:, :, ch], axis=1)
        assert float(jnp.min(diffs)) >= 0.0
    # S only loses mass
    assert float(jnp.max(jnp.diff(traj[:, :, 0], axis=1))) <= 0.0


def test_simulate_matches_lowmem_fused_path():
    """The beyond-paper fused path must be bit-compatible with the reference."""
    th = _theta(16, seed=7)
    key = jax.random.PRNGKey(11)
    obs_ref = em.simulate_observed(th, key, CFG)  # [B, 3, T]
    from repro.core.distances import euclidean_distance

    observed = obs_ref[0]  # use sample 0's trajectory as "data"
    d_full = euclidean_distance(obs_ref, observed)
    d_fused, state_f = em.simulate_observed_lowmem(th, key, CFG, observed)
    np.testing.assert_allclose(np.asarray(d_full), np.asarray(d_fused), rtol=1e-5)
    assert float(d_fused[0]) == 0.0  # self-distance exactly zero


def test_deterministic_given_key():
    th = _theta(4)
    a = em.simulate(th, jax.random.PRNGKey(42), CFG)
    b = em.simulate(th, jax.random.PRNGKey(42), CFG)
    assert bool(jnp.all(a == b))
    c = em.simulate(th, jax.random.PRNGKey(43), CFG)
    assert not bool(jnp.all(a == c))


@settings(max_examples=25, deadline=None)
@given(
    alpha0=st.floats(0.0, 1.0),
    alpha=st.floats(0.0, 100.0),
    n=st.floats(0.0, 2.0),
    beta=st.floats(0.0, 1.0),
    gamma=st.floats(0.0, 1.0),
    delta=st.floats(0.0, 1.0),
    eta=st.floats(0.0, 1.0),
    kappa=st.floats(0.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_conservation_over_prior_box(
    alpha0, alpha, n, beta, gamma, delta, eta, kappa, seed
):
    """Invariant holds for EVERY parameter point in the prior box."""
    th = jnp.asarray([[alpha0, alpha, n, beta, gamma, delta, eta, kappa]], jnp.float32)
    cfg = em.EpiModelConfig(population=5e5, num_days=8, a0=50.0)
    traj = em.simulate(th, jax.random.PRNGKey(seed % (2**31)), cfg)
    assert bool(jnp.all(jnp.isfinite(traj)))
    assert float(jnp.min(traj)) >= 0.0
    total = np.asarray(jnp.sum(traj, axis=-1))
    expected = float(jnp.sum(em.initial_state(th, cfg)))
    np.testing.assert_allclose(total, expected, rtol=1e-5)


def test_infection_rate_monotone_decreasing_in_cases():
    """g(A,R,D) must decrease as confirmed cases grow (behavioural response)."""
    th = jnp.asarray([[0.3, 50.0, 1.5, 0, 0, 0, 0, 0]], jnp.float32)
    ard = jnp.asarray([0.0, 10.0, 100.0, 1e4])
    g = em.infection_rate(th[:, None, :], ard[None, :])
    diffs = jnp.diff(g[0])
    assert float(jnp.max(diffs)) <= 0.0
    # limits: g -> alpha0 + alpha at ARD=0, -> alpha0 as ARD -> inf
    assert float(g[0, 0]) == pytest.approx(0.3 + 50.0, rel=1e-6)
    assert float(g[0, -1]) == pytest.approx(0.3 + 50.0 / (1 + 1e4**1.5), rel=1e-5)
