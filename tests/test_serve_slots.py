"""Continuous-batching slot scheduler: per-slot decode positions.

Regression suite for the shared-position bug: the serve loop used to drive
every decode slot with one scalar `pos = slot_pos.max()`, so a slot whose
request was behind the longest one (shorter prompt, or admitted into a
freed slot mid-stream) wrote its KV cache at the wrong position and read
the previous occupant's stale rows. The fix: a [slots] pos vector into
`decode_step` (per-slot cache writes + per-slot valid lengths) and zeroing
a slot's cache lanes on admission. The pinned property: batched
mixed-length outputs are token-for-token identical to serving each request
alone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh, set_mesh_compat
from repro.launch.serve import _is_axes, run_lm_server, zero_slot
from repro.models.registry import get_model

#: mixed prompt lengths + more requests than slots forces BOTH failure
#: modes of the old code: lagging slots (unequal lengths) and slot reuse
#: (request 3+ lands in a lane holding a finished request's cache)
PROMPT_LENS = (5, 9, 3, 7)
GEN = 3
SLOTS = 2


def _prompts(vocab):
    rng = np.random.default_rng(0)
    return [
        rng.integers(0, vocab, size=n).astype(np.int32).tolist()
        for n in PROMPT_LENS
    ]


@pytest.mark.parametrize(
    "arch",
    [
        "gemma-2b",  # decoder: per-slot KV writes + valid lengths
        "mamba2-130m",  # ssm: position-free, but state zeroing on reuse
        "zamba2-2.7b",  # hybrid: per-slot KV AND ssm/conv state zeroing
    ],
)
def test_mixed_length_batched_matches_single(arch):
    model = get_model(arch, smoke=True)
    vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab
    prompts = _prompts(vocab)
    cache_len = max(PROMPT_LENS) + GEN
    with set_mesh_compat(make_host_mesh()):
        batched, _ = run_lm_server(model, prompts, GEN, SLOTS, cache_len)
        singles = [
            run_lm_server(model, [p], GEN, slots=1, cache_len=cache_len)[0][0]
            for p in prompts
        ]
    assert batched == singles, (
        f"{arch}: batched continuous-batching outputs diverged from "
        f"single-request decoding: {batched} vs {singles}"
    )


def test_decode_step_vector_pos_matches_scalar():
    """decode_step with a [B] pos vector of one shared value must agree
    with the legacy scalar pos (the lockstep special case)."""
    model = get_model("gemma-2b", smoke=True)
    with set_mesh_compat(make_host_mesh()):
        params = model.init_params(jax.random.PRNGKey(0))
        shapes = model.init_cache_shape(2, 8)
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        toks = jnp.asarray([[3], [5]], jnp.int32)
        scalar_logits, scalar_cache = model.decode_step(
            params, cache, {"tokens": toks, "pos": jnp.asarray(2, jnp.int32)}
        )
        vec_logits, vec_cache = model.decode_step(
            params, cache, {"tokens": toks,
                            "pos": jnp.asarray([2, 2], jnp.int32)}
        )
        np.testing.assert_array_equal(
            np.asarray(scalar_logits), np.asarray(vec_logits)
        )
        for a, b in zip(jax.tree.leaves(scalar_cache),
                        jax.tree.leaves(vec_cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero_slot_clears_only_that_lane():
    model = get_model("zamba2-2.7b", smoke=True)  # kv + ssm + conv leaves
    with set_mesh_compat(make_host_mesh()):
        logical = model.cache_logical()
        shapes = model.init_cache_shape(3, 6)
        cache = jax.tree.map(
            lambda s: jnp.ones(s.shape, s.dtype), shapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        wiped = zero_slot(cache, logical, 1)
        for arr, axes in zip(
            jax.tree.leaves(wiped),
            jax.tree.leaves(logical, is_leaf=_is_axes),
        ):
            b = axes.index("batch")
            arr = np.moveaxis(np.asarray(arr, np.float32), b, 0)
            assert (arr[1] == 0).all()  # the admitted slot is clean
            assert (arr[0] == 1).all() and (arr[2] == 1).all()  # others kept
