"""MoE dispatch correctness: grouped EP vs global baseline vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, init_moe, moe_ffn, moe_ffn_global


def _setup(e=8, k=2, d=32, f=16, n_shared=0, seed=0):
    cfg = MoEConfig(n_experts=e, top_k=k, d_expert=f, n_shared=n_shared,
                    capacity_factor=8.0)  # ample capacity: no drops
    p = init_moe(jax.random.PRNGKey(seed), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, d), jnp.float32) * 0.3
    return cfg, p, x.astype(jnp.bfloat16)


def _dense_oracle(x, p, cfg):
    """Every expert on every token, combined with top-k router weights."""
    xf = x.reshape(-1, x.shape[-1])
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / w.sum(-1, keepdims=True)
    h = jnp.einsum("nd,edf->enf", xf, p["wg"])
    hu = jnp.einsum("nd,edf->enf", xf, p["wu"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("enf,efd->end", h * hu, p["wd"])  # [E, N, d]
    mask = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)  # [N,k,E]
    comb = jnp.einsum("nke,end->nkd", mask, out.astype(jnp.float32))
    y = (comb * w[..., None].astype(jnp.float32)).sum(1)
    return y.reshape(x.shape).astype(x.dtype)


def test_grouped_matches_dense_oracle():
    cfg, p, x = _setup()
    y, aux = moe_ffn(x, p, cfg)
    y_ref = _dense_oracle(x, p, cfg)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=0.08, atol=0.05,  # bf16 combine vs f32 oracle
    )
    assert float(aux) >= 0


def test_grouped_matches_global_formulation():
    cfg, p, x = _setup(seed=3)
    y_g, _ = moe_ffn(x, p, cfg)
    y_n, _ = moe_ffn_global(x, p, cfg)
    np.testing.assert_allclose(
        np.asarray(y_g, np.float32), np.asarray(y_n, np.float32),
        rtol=0.08, atol=0.05,
    )


def test_shared_experts_added():
    cfg, p, x = _setup(n_shared=2, seed=5)
    y, _ = moe_ffn(x, p, cfg)
    cfg0, p0, _ = _setup(n_shared=0, seed=5)
    # zero-out router path by comparing against shared-only contribution
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


def test_capacity_drops_tokens_not_correctness():
    """With capacity factor < needed, dropped slots contribute zeros (no NaN,
    no misrouting)."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.25)
    p = init_moe(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16), jnp.bfloat16)
    y, aux = moe_ffn(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    # with ample capacity output magnitude should be >= dropped version
    cfg2 = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=8.0)
    y2, _ = moe_ffn(x, p, cfg2)
    assert float(jnp.abs(y2.astype(jnp.float32)).sum()) >= float(
        jnp.abs(y.astype(jnp.float32)).sum()
    ) - 1e-3


def test_aux_loss_penalizes_imbalance():
    """Uniform routing gives ~the minimum aux value (= weight)."""
    cfg, p, x = _setup(e=4, k=1, seed=7)
    _, aux = moe_ffn(x, p, cfg)
    # aux = w * E * sum(f_e p_e); for near-uniform ~ w
    assert 0.5 * cfg.router_aux_weight < float(aux) < 6 * cfg.router_aux_weight
