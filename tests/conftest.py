"""Shared fixtures. NOTE: device count stays 1 here (the 512-device forcing is
only in launch/dryrun.py, per the multi-pod dry-run contract); multi-device
tests spawn subprocesses with their own XLA_FLAGS."""

import os
import subprocess
import sys

import pytest


@pytest.fixture(scope="session")
def small_dataset():
    from repro.epi.data import get_dataset

    return get_dataset("synthetic_small", num_days=15)


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run `code` in a fresh python with a forced host device count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"subprocess failed:\nSTDOUT:{out.stdout}\nSTDERR:{out.stderr}"
    return out.stdout
