"""Statistical quality checks for the counter-based in-kernel RNG."""

import jax.numpy as jnp
import numpy as np

from repro.kernels import rng as krng


def _uniforms(n=1 << 16, seed=3, ctr=0):
    idx = jnp.arange(n, dtype=jnp.uint32)
    return np.asarray(krng.uniform_open(jnp.uint32(seed), idx, jnp.uint32(ctr)))


def test_uniform_range_and_moments():
    u = _uniforms()
    assert u.min() > 0.0 and u.max() <= 1.0
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.var() - 1.0 / 12.0) < 0.002


def test_uniform_bucket_uniformity():
    u = _uniforms(1 << 17)
    counts, _ = np.histogram(u, bins=64, range=(0, 1))
    expected = len(u) / 64
    chi2 = ((counts - expected) ** 2 / expected).sum()
    # 63 dof; 5-sigma-ish bound
    assert chi2 < 150.0, chi2


def test_normal_moments():
    idx = jnp.arange(1 << 16, dtype=jnp.uint32)
    z = np.asarray(krng.normal(jnp.uint32(1), idx, jnp.uint32(5)))
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02
    assert abs(((z**3).mean())) < 0.05  # skewness ~ 0
    assert abs((z**4).mean() - 3.0) < 0.15  # kurtosis ~ 3


def test_streams_decorrelated_across_counters_and_indices():
    idx = jnp.arange(1 << 14, dtype=jnp.uint32)
    a = np.asarray(krng.normal(jnp.uint32(1), idx, jnp.uint32(0)))
    b = np.asarray(krng.normal(jnp.uint32(1), idx, jnp.uint32(1)))
    c = np.asarray(krng.normal(jnp.uint32(1), idx + jnp.uint32(1), jnp.uint32(0)))
    assert abs(np.corrcoef(a, b)[0, 1]) < 0.03
    assert abs(np.corrcoef(a, c)[0, 1]) < 0.03
    # lag-1 autocorrelation along the index stream
    assert abs(np.corrcoef(a[:-1], a[1:])[0, 1]) < 0.03


def test_seed_separation():
    idx = jnp.arange(1024, dtype=jnp.uint32)
    a = np.asarray(krng.hash_u32(jnp.uint32(1), idx, jnp.uint32(0)))
    b = np.asarray(krng.hash_u32(jnp.uint32(2), idx, jnp.uint32(0)))
    assert (a == b).mean() < 0.01


def test_fmix32_bijective_on_sample():
    x = jnp.arange(1 << 16, dtype=jnp.uint32)
    y = np.asarray(krng.fmix32(x))
    assert len(np.unique(y)) == len(y)
