"""Integration + property tests for the parallel ABC engine (paper §3)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.abc import ABCConfig, ABCState, abc_run_batch, make_simulator, run_abc
from repro.core.priors import paper_prior
from repro.epi.data import get_dataset

DAYS = 15
TOL = 1.6e4  # ~1% acceptance on synthetic_small@15d — fast tests


def _cfg(**kw):
    base = dict(
        batch_size=2048,
        tolerance=TOL,
        target_accepted=25,
        chunk_size=256,
        strategy="outfeed",
        max_runs=50,
        num_days=DAYS,
        backend="xla_fused",
    )
    base.update(kw)
    return ABCConfig(**base)


@pytest.fixture(scope="module")
def ds():
    return get_dataset("synthetic_small", num_days=DAYS)


def test_rejection_abc_reaches_target(ds):
    post = run_abc(ds, _cfg(), key=0)
    assert len(post) >= 25
    assert np.all(post.distances <= TOL)
    assert post.runs <= 50


def test_outfeed_and_topk_agree_on_same_stream(ds):
    """Paper claim C1 (engine level): the two fixed-shape host-return
    strategies harvest the SAME accepted samples from the same stream."""
    p_out = run_abc(ds, _cfg(), key=0)
    p_top = run_abc(ds, _cfg(strategy="topk", top_k=256, chunk_size=2048), key=0)
    n = min(len(p_out), len(p_top))
    np.testing.assert_allclose(
        np.sort(p_out.distances)[:n], np.sort(p_top.distances)[:n], rtol=1e-6
    )


def test_topk_truncation_caveat(ds):
    """With k too small, top-k may drop accepted samples (the paper's stated
    caveat). The engine must still count them correctly on-device."""
    cfg = _cfg(strategy="topk", top_k=1, target_accepted=5, max_runs=30)
    sim = make_simulator(ds, cfg)
    run = jax.jit(abc_run_batch(paper_prior(), sim, cfg))
    out = run(jax.random.fold_in(jax.random.PRNGKey(0), 0))
    assert out.theta.shape == (1, 8)
    assert int(out.accept_count) >= 0  # count is exact even when k < count


def test_acceptance_monotone_in_tolerance(ds):
    """P(accept) must be non-decreasing in epsilon (ABC definition, eq. 7)."""
    cfg = _cfg()
    sim = jax.jit(make_simulator(ds, cfg))
    th = paper_prior().sample(jax.random.PRNGKey(1), (4096,))
    d = np.asarray(sim(th, jax.random.PRNGKey(2)))
    rates = [(d <= eps).mean() for eps in (TOL / 4, TOL, TOL * 4, TOL * 16)]
    assert all(a <= b for a, b in zip(rates, rates[1:]))
    assert rates[-1] > rates[0]


def test_deterministic_and_resumable(ds):
    """Restarting from a checkpointed ABCState must reproduce the exact same
    posterior as an uninterrupted run (fault-tolerance contract)."""
    cfg4 = _cfg(target_accepted=10**9, max_runs=4)
    sim = make_simulator(ds, cfg4)
    run_fn = jax.jit(abc_run_batch(paper_prior(), sim, cfg4))
    p_full = run_abc(ds, cfg4, key=7, run_fn=run_fn)
    assert p_full.runs == 4

    # interrupted run: stop after 2 runs, checkpoint, reload, resume to 4
    state = ABCState()
    cfg2 = dataclasses.replace(cfg4, max_runs=2)
    run_abc(ds, cfg2, key=7, state=state, run_fn=run_fn)
    assert state.run_idx == 2

    import tempfile, os

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "abc_state.npz")
        state.save(path)
        resumed = ABCState.load(path)
    assert resumed.run_idx == 2
    p_res = run_abc(ds, cfg4, key=7, state=resumed, run_fn=run_fn)
    assert len(p_res) == len(p_full)
    np.testing.assert_allclose(
        np.sort(p_full.distances), np.sort(p_res.distances), rtol=1e-6
    )


def test_posterior_recovery_synthetic_truth(ds):
    """Paper claim C2: the ABC posterior concentrates around the generating
    parameters relative to the prior."""
    post = run_abc(ds, _cfg(tolerance=8e3, target_accepted=30, max_runs=400), key=3)
    assert len(post) >= 20
    true = np.asarray(ds.true_theta)
    highs = np.asarray(paper_prior().highs)
    prior_mean = highs / 2.0
    post_mean = post.theta.mean(axis=0)
    # normalized error must shrink vs the prior mean for most parameters
    err_prior = np.abs(prior_mean - true) / highs
    err_post = np.abs(post_mean - true) / highs
    assert (err_post <= err_prior + 0.05).mean() >= 0.6
    assert err_post.mean() < err_prior.mean()


def test_backends_agree_statistically(ds):
    """xla / xla_fused / pallas produce the same distance distribution."""
    th = paper_prior().sample(jax.random.PRNGKey(5), (1024,))
    key = jax.random.PRNGKey(6)
    outs = {}
    for backend in ("xla", "xla_fused", "pallas"):
        cfg = _cfg(backend=backend, batch_size=1024)
        sim = jax.jit(make_simulator(ds, cfg))
        d = np.asarray(sim(th, key))
        outs[backend] = d[np.isfinite(d)]
    # xla vs xla_fused share RNG -> near-identical
    np.testing.assert_allclose(outs["xla"], outs["xla_fused"], rtol=1e-4)
    # pallas has its own RNG stream -> compare quantiles
    qs = np.linspace(0.1, 0.9, 9)
    qa = np.quantile(outs["xla"], qs)
    qp = np.quantile(outs["pallas"], qs)
    np.testing.assert_allclose(qa, qp, rtol=0.15)


def test_nan_simulations_never_accepted(ds):
    cfg = _cfg(batch_size=256, chunk_size=256, max_runs=1, target_accepted=10**9)

    def bad_sim(theta, key):
        d = jnp.full((theta.shape[0],), jnp.nan, jnp.float32)
        return d

    run = jax.jit(abc_run_batch(paper_prior(), bad_sim, cfg))
    out = run(jax.random.PRNGKey(0))
    assert int(out.accept_count) == 0
    assert not bool(out.chunk_flags.any())


def test_chunk_flag_semantics(ds):
    """A chunk flag is set iff its chunk holds >= 1 accepted sample."""
    cfg = _cfg(max_runs=1)
    sim = make_simulator(ds, cfg)
    run = jax.jit(abc_run_batch(paper_prior(), sim, cfg))
    out = run(jax.random.fold_in(jax.random.PRNGKey(4), 0))
    d = np.asarray(out.dist)  # [nc, cs]
    flags = np.asarray(out.chunk_flags)
    np.testing.assert_array_equal(flags, (d <= cfg.tolerance).any(axis=1))
    assert int(out.accept_count) == int((d <= cfg.tolerance).sum())


def test_calibrate_tolerance_controls_acceptance(ds):
    """Auto-calibrated epsilon yields ~the requested acceptance rate."""
    from repro.core.abc import calibrate_tolerance

    cfg = _cfg()
    q = 5e-3
    eps = calibrate_tolerance(ds, cfg, key=11, quantile=q, n_pilot=8192)
    assert eps > 0
    sim = jax.jit(make_simulator(ds, cfg))
    th = paper_prior().sample(jax.random.PRNGKey(12), (8192,))
    d = np.asarray(sim(th, jax.random.PRNGKey(13)))
    rate = float((d[np.isfinite(d)] <= eps).mean())
    assert q / 4 < rate < q * 4, (eps, rate)
