"""Amortized-inference subsystem tests (repro.core.npe).

Statistical accuracy lives in tests/test_posterior_recovery.py (the ABC
oracle-agreement suite); this file pins the MECHANICS: config validation,
the run_abc dispatch contract, estimator persistence, the summary-feature
lowering, and — the acceptance-critical pin — that a serving query answered
from a trained NPE performs ZERO simulation waves.
"""

import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.core import npe as npe_mod
from repro.core.abc import ABCConfig, make_parametric_simulator, \
    make_simulator, run_abc
from repro.core.npe import NPEConfig, NPEstimator, fine_tune, train_npe
from repro.core.summaries import SummarySpec, flush_columns, get_summary, \
    summary_features
from repro.epi.data import synthetic_dataset
from repro.epi.models import get_model

DAYS = 12
TINY = NPEConfig(train_steps=25, train_batch=64, n_pilot=64, hidden=32,
                 n_components=3, fine_tune_steps=4)


def _dataset(name="npe_unit", seed=3, scale=1.0, num_days=DAYS):
    ds = synthetic_dataset(theta=(0.5, 0.2, 1.0), population=1e6,
                           num_days=num_days, a0=100.0, seed=seed,
                           name=name, model="sir")
    if scale != 1.0:
        ds = dataclasses.replace(
            ds, observed=(ds.observed * scale).astype(np.float32))
    return ds


def _cfg(**kw):
    base = dict(num_days=DAYS, backend="npe", model="sir",
                target_accepted=32, npe=TINY)
    base.update(kw)
    return ABCConfig(**base)


@pytest.fixture(scope="module")
def trained():
    """One tiny trained estimator shared by the mechanics tests."""
    return train_npe(_dataset(), _cfg(), key=0)


# ------------------------------------------------------------- validation
def test_npe_config_validation():
    with pytest.raises(ValueError, match="train_steps"):
        NPEConfig(train_steps=0)
    with pytest.raises(ValueError, match="train_batch"):
        NPEConfig(train_batch=1)
    with pytest.raises(ValueError, match="MDN shape"):
        NPEConfig(n_components=0)
    with pytest.raises(ValueError, match="fine_tune_steps"):
        NPEConfig(fine_tune_steps=-1)
    with pytest.raises(ValueError, match="sigma_min"):
        NPEConfig(sigma_min=0.0)


def test_abc_config_npe_field_validation():
    with pytest.raises(TypeError, match="NPEConfig"):
        ABCConfig(backend="npe", npe={"train_steps": 10})
    with pytest.raises(ValueError, match="backend"):
        ABCConfig(backend="xla_fused", npe=TINY)
    # bare backend="npe" with default hyperparameters is valid
    assert ABCConfig(backend="npe").npe is None


def test_simulator_builders_reject_npe():
    ds = _dataset()
    with pytest.raises(ValueError, match="amortized"):
        make_simulator(ds, _cfg())
    with pytest.raises(ValueError, match="amortized"):
        make_parametric_simulator(get_model("sir"), _cfg())


def test_run_abc_npe_rejects_wave_machinery():
    from repro.core.abc import ABCState

    ds = _dataset()
    with pytest.raises(ValueError, match="waves"):
        run_abc(ds, _cfg(), key=0, state=ABCState())
    with pytest.raises(ValueError, match="waves"):
        run_abc(ds, _cfg(), key=0, run_fn=lambda k: None)


# -------------------------------------------------------- summary features
def test_flush_columns_layout():
    np.testing.assert_array_equal(flush_columns(12, 5), [4, 9, 11])
    np.testing.assert_array_equal(flush_columns(10, 5), [4, 9])
    np.testing.assert_array_equal(flush_columns(4, 1), [0, 1, 2, 3])


def test_summary_features_identity_is_flat_series():
    """With the identity summary every day is a flush column: the feature
    vector is exactly the flattened raw series — the paper-faithful
    conditioning baseline."""
    ds = _dataset()
    feats = np.asarray(
        summary_features(get_summary(None), ds.observed, 1)
    )
    np.testing.assert_allclose(
        feats, ds.observed.astype(np.float32).reshape(-1), rtol=1e-6
    )


def test_summary_features_match_abc_flush_values():
    """Binned summaries condition on the same values the ABC running
    accumulator compares: the bin-closing columns of apply_summary."""
    from repro.core.summaries import apply_summary

    spec = SummarySpec(name="cum5", cumulative=True, bin_days=5)
    ds = _dataset()
    feats = np.asarray(summary_features(spec, ds.observed, 1))
    full = np.asarray(apply_summary(spec, ds.observed.astype(np.float32)))
    np.testing.assert_allclose(
        feats, full[:, flush_columns(DAYS, 5)].reshape(-1), rtol=1e-6
    )


# ------------------------------------------------------------- persistence
def test_estimator_save_load_roundtrip(tmp_path, trained):
    ds = _dataset()
    path = str(tmp_path / "est.npz")
    trained.save(path)
    back = NPEstimator.load(path)
    assert back.model == "sir" and back.num_days == DAYS
    assert back.param_names == trained.param_names
    assert back.train_sims == trained.train_sims
    a = trained.sample_posterior(ds.observed, 64, key=5)
    b = back.sample_posterior(ds.observed, 64, key=5)
    np.testing.assert_array_equal(a.theta, b.theta)
    np.testing.assert_array_equal(a.distances, b.distances)


def test_estimator_load_rejects_corrupt_file(tmp_path):
    path = tmp_path / "bad.npz"
    path.write_bytes(b"not an npz at all")
    with pytest.raises(ValueError, match="corrupt"):
        NPEstimator.load(str(path))
    with pytest.raises(FileNotFoundError):
        NPEstimator.load(str(tmp_path / "missing.npz"))


def test_estimator_rejects_wrong_observed_shape(trained):
    short = np.zeros((2, DAYS - 3), np.float32)
    with pytest.raises(ValueError, match="days"):
        trained.features_of(short)
    wrong_channels = np.zeros((5, DAYS), np.float32)
    with pytest.raises(ValueError, match="features"):
        trained.features_of(wrong_channels)


# --------------------------------------------------------------- fine-tune
def test_fine_tune_zero_steps_is_identity(trained):
    assert fine_tune(trained, _dataset(), key=1, steps=0) is trained


def test_fine_tune_updates_weights_and_accounting(trained):
    ds = _dataset(scale=1.05)
    ft = fine_tune(trained, ds, key=1, steps=3)
    assert ft is not trained
    assert ft.train_steps_done == trained.train_steps_done + 3
    assert ft.train_sims == trained.train_sims + 3 * TINY.train_batch
    # standardization is frozen from original training (weights assume it)
    np.testing.assert_array_equal(ft.feat_mean, trained.feat_mean)
    np.testing.assert_array_equal(ft.feat_std, trained.feat_std)
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(ft.params),
                        jax.tree.leaves(trained.params))
    )
    assert changed


def test_fine_tune_rejects_incompatible_channels(trained):
    # seir would be fine (same observed channels as sir — legitimate model
    # comparison); siard observes a different channel set and must refuse
    siard_ds = synthetic_dataset(
        theta=(0.2, 0.4, 6.0, 0.1, 0.05, 0.01, 0.02, 1.0), population=1e6,
        num_days=DAYS, a0=100.0, seed=3, name="wrong", model="siard")
    with pytest.raises(ValueError, match="trained for"):
        fine_tune(trained, siard_ds, key=1, steps=1)


# ------------------------------------------------- serving: zero waves pin
def test_serving_npe_query_runs_zero_simulation_waves(tmp_path, monkeypatch):
    """THE amortized-serving acceptance pin: with a trained estimator and
    fine_tune_steps=0, a posterior query — including one for a CHANGED
    dataset version — never enters the SMC/ABC wave machinery and adds
    zero simulations beyond the training budget."""
    from repro.core import serving
    from repro.core.serving import EpiServer, ForecastQuery, ServeConfig, \
        save_dataset_file
    from repro.core.smc import SMCConfig

    data_dir = tmp_path / "data"
    data_dir.mkdir()
    save_dataset_file(str(data_dir / "served.json"), _dataset("served"))

    def _no_waves(*a, **k):  # any wave fit is an immediate failure
        raise AssertionError("NPE serving path entered the SMC wave fitter")

    monkeypatch.setattr(serving, "run_smc_abc", _no_waves)

    cfg = ServeConfig(
        slots=2, forecast_particles=16,
        fit=SMCConfig(n_particles=48, batch_size=512, n_rounds=2,
                      quantile=0.5, num_days=DAYS, backend="xla_fused",
                      model="sir"),
        data_dir=str(data_dir), store_dir=str(tmp_path / "store"),
        fit_backend="npe",
        npe=dataclasses.replace(TINY, fine_tune_steps=0),
    )
    server = EpiServer(cfg)
    q = ForecastQuery(dataset="served", model="sir", horizon=4)
    server.answer([q])
    stats = server.stats()
    assert stats["fits"] == 0 and stats["npe_trains"] == 1
    post, _ = server.get_posterior("served", "sir")
    assert post.runs == 0 and len(post) == 48
    train_sims = post.simulations

    # dataset content moves: refresh must stay wave-free AND sim-free
    save_dataset_file(str(data_dir / "served.json"),
                      _dataset("served", scale=1.1))
    assert server.refresh("served", "sir") == "warm_refit"
    stats = server.stats()
    assert stats["fits"] == 0 and stats["npe_fine_tunes"] == 1
    post2, _ = server.get_posterior("served", "sir")
    assert post2.simulations == train_sims  # fine_tune_steps=0: free refresh
    # posterior conditions on the NEW observed features, so it moved
    assert not np.array_equal(post.theta, post2.theta)


def test_serving_npe_estimator_persists_across_servers(tmp_path):
    """A second server process finds the trained estimator on disk: no
    retrain (npe_trains stays 0), posterior answered from the store."""
    from repro.core.serving import EpiServer, ServeConfig, save_dataset_file
    from repro.core.smc import SMCConfig

    data_dir = tmp_path / "data"
    data_dir.mkdir()
    save_dataset_file(str(data_dir / "served.json"), _dataset("served"))
    cfg = ServeConfig(
        slots=2, forecast_particles=16,
        fit=SMCConfig(n_particles=32, batch_size=512, n_rounds=2,
                      quantile=0.5, num_days=DAYS, backend="xla_fused",
                      model="sir"),
        data_dir=str(data_dir), store_dir=str(tmp_path / "store"),
        fit_backend="npe", npe=TINY,
    )
    s1 = EpiServer(cfg)
    assert s1.refresh("served", "sir") == "cold_fit"
    est_dir = os.path.join(str(tmp_path / "store"), "npe")
    assert len(os.listdir(est_dir)) == 1

    s2 = EpiServer(cfg)
    assert s2.refresh("served", "sir") == "cached"
    assert s2.stats()["npe_trains"] == 0 and s2.stats()["fits"] == 0

    # content moves: the fresh server fine-tunes the PERSISTED estimator
    save_dataset_file(str(data_dir / "served.json"),
                      _dataset("served", scale=1.2))
    s3 = EpiServer(cfg)
    assert s3.refresh("served", "sir") == "warm_refit"
    assert s3.stats()["npe_trains"] == 0
    assert s3.stats()["npe_fine_tunes"] == 1


def test_serve_config_validates_npe_fields():
    from repro.core.serving import ServeConfig

    with pytest.raises(ValueError, match="fit_backend"):
        ServeConfig(fit_backend="mcmc")
    with pytest.raises(ValueError, match="npe"):
        ServeConfig(fit_backend="smc", npe=TINY)


# -------------------------------------------------------------- accounting
def test_posterior_contract_from_sampler(trained):
    """The Posterior NPE emits must satisfy the consumers' contract:
    finite distances (densest-first under top()), store-safe tolerance,
    amortized simulation accounting."""
    ds = _dataset()
    post = trained.sample_posterior(ds.observed, 40, key=2)
    assert post.theta.shape == (40, 3)
    assert np.isfinite(post.distances).all()
    assert post.tolerance == 0.0 and post.runs == 0
    assert post.simulations == trained.train_sims
    lo = np.asarray(trained.lows)
    hi = np.asarray(trained.highs)
    assert (post.theta >= lo - 1e-6).all() and (post.theta <= hi + 1e-6).all()
    # top(k) returns the k highest-density draws
    top = post.top(5)
    assert np.all(np.sort(post.distances)[:5] == np.sort(top.distances))
