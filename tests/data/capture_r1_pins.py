"""Capture R=1 golden outputs for the registered models on all backends.

Run ONCE against the pre-metapop tree (PR 9) to freeze the exact f32
distances each backend produced before the region-axis refactor; the
committed r1_pins.npz is then asserted bit-identical by
tests/test_metapop.py::test_r1_bit_identity_pins forever after. Re-running
this script against a tree whose R=1 paths changed would regenerate (and
silently launder) the pins — only do that for an intentional, documented
stream change.

Usage: PYTHONPATH=src python tests/data/capture_r1_pins.py
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.summaries import get_summary, lower_summary, summary_distance
from repro.epi import engine
from repro.epi.data import get_dataset
from repro.epi.models import get_model, list_models
from repro.kernels import ops, ref

BATCH = 16
DAYS = 14
SEED = 123  # hash-RNG seed (pallas + oracle)
KEY = 5  # threefry key (xla paths)
PIN_MODELS = ("seiard", "seir", "siard", "sir")


def main() -> None:
    out = {}
    for name in PIN_MODELS:
        assert name in list_models(), name
        spec = get_model(name)
        ds = get_dataset("synthetic_small", num_days=DAYS, model=spec)
        cfg = ds.model_config()
        theta = spec.prior().sample(jax.random.PRNGKey(0), (BATCH,))
        obs = jnp.asarray(ds.observed, jnp.float32)
        common = dict(
            population=cfg.population, a0=cfg.a0, r0=cfg.r0, d0=cfg.d0
        )
        key = jax.random.PRNGKey(KEY)

        # pallas kernel (interpret on CPU) + its hash-RNG oracle
        out[f"{name}/pallas"] = np.asarray(
            ops.abc_sim_distance(theta, np.uint32(SEED), obs, model=spec, **common)
        )
        out[f"{name}/oracle"] = np.asarray(
            ref.abc_sim_distance_ref(theta, np.uint32(SEED), obs, model=spec, **common)
        )
        # fused scan (threefry)
        d_fused, _ = engine.simulate_observed_lowmem(spec, theta, key, cfg, obs)
        out[f"{name}/xla_fused"] = np.asarray(d_fused)
        # post-hoc xla (threefry, same stream as fused)
        sim = engine.simulate_observed(spec, theta, key, cfg)
        lowered = lower_summary(get_summary(None), "euclidean", obs)
        out[f"{name}/xla"] = np.asarray(
            summary_distance("euclidean", lowered, sim)
        )
        out[f"{name}/theta"] = np.asarray(theta)
        out[f"{name}/observed"] = np.asarray(obs)

    path = os.path.join(os.path.dirname(__file__), "r1_pins.npz")
    np.savez(path, **out)
    print(f"wrote {path}:")
    for k in sorted(out):
        v = out[k]
        print(f"  {k}: shape={v.shape} first={v.ravel()[0]:.6f}")


if __name__ == "__main__":
    main()
