"""Fault-tolerance control plane: ledger, worker loss, stragglers, resume."""

import random

from repro.runtime import ChunkLedger, WorkScheduler


def test_all_chunks_complete_happy_path():
    sched = WorkScheduler(n_chunks=10)
    t = 0.0
    while not sched.finished:
        t += 0.1
        for w in ("w0", "w1", "w2"):
            c = sched.request_work(w, t)
            if c is not None:
                sched.report_done(w, c, t)
    assert sched.ledger.done == set(range(10))
    assert sched.wasted_completions == 0


def test_worker_death_requeues_chunks():
    sched = WorkScheduler(n_chunks=4, timeout=1.0)
    c0 = sched.request_work("dead", now=0.0)
    assert c0 is not None
    # dead worker never reports; others keep beating past the timeout
    t = 0.0
    while not sched.finished:
        t += 0.5
        c = sched.request_work("alive", t)
        if c is not None:
            sched.report_done("alive", c, t)
        assert t < 60
    assert c0 in sched.ledger.done  # recovered despite owner death


def test_straggler_speculation_bounds_tail():
    """With one 100x-slow worker, speculative duplicates finish the job
    without waiting for it."""
    sched = WorkScheduler(n_chunks=6, timeout=1e9)  # no death reaping
    slow_chunk = sched.request_work("slow", now=0.0)  # slow worker grabs one
    t = 0.0
    while not sched.finished:
        t += 0.1
        c = sched.request_work("fast", t)
        if c is not None:
            sched.report_done("fast", c, t)
        assert t < 30
    assert sched.duplicates_issued >= 1
    assert slow_chunk in sched.ledger.done
    # late completion by the slow worker is counted as wasted, not an error
    sched.report_done("slow", slow_chunk, t + 100)
    assert sched.wasted_completions >= 1


def test_ledger_resume_roundtrip():
    led = ChunkLedger(n_chunks=8)
    for c in (0, 3, 5):
        led.next_chunk("w")
        led.complete(c)
    state = led.to_state()
    led2 = ChunkLedger.from_state(state)
    assert led2.done == {0, 3, 5}
    remaining = set()
    while True:
        c = led2.next_chunk("w")
        if c is None:
            break
        remaining.add(c)
        led2.complete(c)
    assert remaining == {1, 2, 4, 6, 7}


def test_randomized_chaos_all_work_completes():
    """Property-ish: random worker deaths/speculation never lose a chunk."""
    rng = random.Random(0)
    sched = WorkScheduler(n_chunks=40, timeout=2.0)
    workers = {f"w{i}": True for i in range(6)}
    t = 0.0
    while not sched.finished and t < 1000:
        t += 0.5
        for w, alive in list(workers.items()):
            if not alive:
                continue
            if rng.random() < 0.02:  # sudden death
                workers[w] = False
                continue
            c = sched.request_work(w, t)
            if c is not None and rng.random() < 0.9:
                sched.report_done(w, c, t)
        if all(not a for a in workers.values()):  # elastic scale-up
            workers[f"w{len(workers)}"] = True
    assert sched.finished
    assert sched.ledger.done == set(range(40))
