"""AdamW with global-norm clipping and cosine schedule (own implementation —
no optax in the container). Moment tensors are f32 and shaped like the params,
so they inherit the params' shardings (including the stacked layer axis)."""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params, grads, state: dict, cfg: AdamWConfig
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    # three passes instead of one unzip: params trees may contain tuples as
    # internal nodes (stacked layer stacks), so tuple-leaf tricks are unsafe.
    # XLA CSE merges the duplicated moment math.
    def new_mu_fn(g, mu):
        return cfg.b1 * mu + (1 - cfg.b1) * g.astype(jnp.float32) * scale

    def new_nu_fn(g, nu):
        gs = g.astype(jnp.float32) * scale
        return cfg.b2 * nu + (1 - cfg.b2) * gs * gs

    new_mu = jax.tree.map(new_mu_fn, grads, state["mu"])
    new_nu = jax.tree.map(new_nu_fn, grads, state["nu"])

    def upd(p, mu, nu):
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_mu, new_nu)
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
