"""int8 gradient compression with error feedback (distributed-optimization
trick for bandwidth-bound DP training at 1000+ nodes).

Per-tensor symmetric int8 quantization before the DP all-reduce, residual
(error-feedback) carried in f32 so the compression bias vanishes over steps
(Seide et al. / Karimireddy et al.). Used as an optional stage in
launch/train.py; correctness bounds tested in tests/test_optim.py."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_gradients(grads, error_state=None):
    """Returns (compressed tree {q, scale}, new error_state).

    error_state is a pytree like grads holding the f32 residuals.
    """
    if error_state is None:
        error_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    # grad trees may contain tuple internal nodes — return parallel trees
    # instead of (q, scale) tuple leaves.
    def q_fn(g, e):
        q, _ = _quantize(g.astype(jnp.float32) + e)
        return q

    def s_fn(g, e):
        _, s = _quantize(g.astype(jnp.float32) + e)
        return s

    qs = jax.tree.map(q_fn, grads, error_state)
    scales = jax.tree.map(s_fn, grads, error_state)
    new_err = jax.tree.map(
        lambda g, e, q, s: (g.astype(jnp.float32) + e) - _dequantize(q, s),
        grads, error_state, qs, scales,
    )
    return {"q": qs, "scale": scales}, new_err


def decompress_gradients(comp):
    return jax.tree.map(_dequantize, comp["q"], comp["scale"])
