from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.optim.compress import compress_gradients, decompress_gradients
