"""Spatial metapopulation SEIR: coupled SEIR patches with a mobility matrix.

Four compartments [S, E, I, R] per region and four shared parameters
[beta, sigma, gamma, kappa]. The exposure hazard in region r uses the
mobility-weighted infectious mass instead of the local I:

  S_r -> E_r   beta * S_r * (sum_q M[r, q] * I_q) / P_r
  E_r -> I_r   sigma * E_r
  I_r -> R_r   gamma * I_r

M is the row-stochastic mobility matrix (`CompartmentalModel.mobility`);
row r says where region r's contacts happen. The coupled infectious mass
arrives as an EXTRA state row appended after the local compartments —
declared by `coupled=("I",)` on the spec — so this hazard body stays
row-level and lowers unchanged to the XLA engine (trailing region axis)
and the Pallas kernel (per-region VREG rows).

With M = I (identity mobility) each region is an independent SEIR patch of
population P/R — the invariant pinned by tests/test_metapop.py. The
registered default is R=4 on a ring (each region keeps 90% of contacts
local, 5% to each ring neighbour); `repro.epi.spec.regionalize` rescales
it to any R (the 100-region campaign example in the README).

Seeding: region `seed_region` (0) receives the dataset's day-0 counts
exactly as single-region SEIR does; every other region starts fully
susceptible at P/R.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.epi.models import register
from repro.epi.spec import CompartmentalModel, make_mobility


def _hazard_rows(sc, pc, population):
    s, e, i, _r, i_eff = sc  # i_eff = mobility-weighted I (coupled row)
    beta, sigma, gamma, _kappa = pc
    return (
        beta * s * i_eff / population,  # S -> E (coupled exposure)
        sigma * e,  # E -> I
        gamma * i,  # I -> R
    )


def _initial_rows(pc, population, a0, r0, _d0):
    kappa = pc[3]
    e0 = kappa * a0
    zeros = jnp.zeros_like(a0) * kappa
    i0 = zeros + a0
    s0 = population - (e0 + a0 + r0)
    return (s0, e0, i0, zeros + r0)


N_REGIONS = 4

MODEL = register(
    CompartmentalModel(
        name="metapop_seir",
        compartments=("S", "E", "I", "R"),
        param_names=("beta", "sigma", "gamma", "kappa"),
        prior_highs=(2.0, 1.0, 1.0, 2.0),
        stoichiometry=(
            # S   E   I   R
            (-1, +1, 0, 0),  # S -> E
            (0, -1, +1, 0),  # E -> I
            (0, 0, -1, +1),  # I -> R
        ),
        observed=("I", "R"),
        hazard_rows=_hazard_rows,
        initial_rows=_initial_rows,
        default_theta=(0.6, 0.3, 0.2, 1.0),
        n_regions=N_REGIONS,
        mobility=make_mobility("ring:0.1", N_REGIONS),
        coupled=("I",),
        doc="4-region metapopulation SEIR on a ring (10% mobility leakage).",
    )
)
