"""The paper's 6-compartment COVID-19 model (§2.1) as a registry spec.

Six sub-populations X = [S, I, A, R, D, Ru]:
  S  — Susceptible
  I  — undocumented Infected                (latent)
  A  — Active confirmed cases              (observed)
  R  — confirmed Recoveries                (observed)
  D  — confirmed fatalities                (observed)
  Ru — unconfirmed Removed                 (latent)

Eight parameters theta = [alpha0, alpha, n, beta, gamma, delta, eta, kappa]
with the paper's uniform prior U(0, [1, 100, 2, 1, 1, 1, 1, 2])  (eq. 2).

Dynamics (eq. 4-5):
  g  = alpha0 + alpha / (1 + (A + R + D)^n)
  h  = (g*S*I/P,  gamma*I,  beta*A,  delta*A,  beta*eta*I)
  transitions applied in order  S->I, I->A, A->R, A->D, I->Ru.

The declaration order of the stoichiometry rows IS the clamp order of the
sequential source-draining scheme, so this spec reproduces the original
hand-unrolled implementation (and the paper's IPU clamping) bit-for-bit:
A->R drains A before A->D, I->A drains I before I->Ru.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.epi.models import register
from repro.epi.spec import CompartmentalModel


def behavioural_infection_rate(alpha0, alpha, n, ard_sum):
    """g = alpha0 + alpha / (1 + (A+R+D)^n), eq. (4), on channel rows.

    The single source of truth for the paper's behaviour-modulated rate;
    shared by the SIARD and SEIARD hazard functions and `infection_rate`.
    (A+R+D) >= 0 always; power of a non-negative base is safe.
    """
    return alpha0 + alpha / (1.0 + jnp.power(jnp.maximum(ard_sum, 0.0), n))


def infection_rate(theta: jax.Array, ard_sum: jax.Array) -> jax.Array:
    """Eq. (4) over stacked theta [..., 8]; broadcastable batch shapes."""
    return behavioural_infection_rate(
        theta[..., 0], theta[..., 1], theta[..., 2], ard_sum
    )


def _hazard_rows(sc, pc, population):
    """Eq. (5) as channel rows; runs both in XLA and inside the Pallas body."""
    s, i, a, r, d, _ru = sc
    alpha0, alpha, n, beta, gamma, delta, eta, _kappa = pc
    g = behavioural_infection_rate(alpha0, alpha, n, a + r + d)
    return (
        g * s * i / population,  # S -> I
        gamma * i,  # I -> A
        beta * a,  # A -> R
        delta * a,  # A -> D
        beta * eta * i,  # I -> Ru
    )


def _initial_rows(pc, population, a0, r0, d0):
    """Paper step 1: Ru = 0, I0 = kappa * A0, S = P - (A0 + R0 + D0 + I0)."""
    kappa = pc[7]
    i0 = kappa * a0
    s0 = population - (a0 + r0 + d0 + i0)
    zeros = jnp.zeros_like(kappa)
    return (s0, i0, zeros + a0, zeros + r0, zeros + d0, zeros)


MODEL = register(
    CompartmentalModel(
        name="siard",
        compartments=("S", "I", "A", "R", "D", "Ru"),
        param_names=("alpha0", "alpha", "n", "beta", "gamma", "delta", "eta", "kappa"),
        prior_highs=(1.0, 100.0, 2.0, 1.0, 1.0, 1.0, 1.0, 2.0),
        stoichiometry=(
            # S   I   A   R   D  Ru
            (-1, +1, 0, 0, 0, 0),  # S -> I   g*S*I/P
            (0, -1, +1, 0, 0, 0),  # I -> A   gamma*I
            (0, 0, -1, +1, 0, 0),  # A -> R   beta*A
            (0, 0, -1, 0, +1, 0),  # A -> D   delta*A
            (0, -1, 0, 0, 0, +1),  # I -> Ru  beta*eta*I
        ),
        observed=("A", "R", "D"),
        hazard_rows=_hazard_rows,
        initial_rows=_initial_rows,
        # paper Table 8 Italy posterior means — a plausible generating point
        default_theta=(0.384, 36.054, 0.595, 0.013, 0.385, 0.009, 0.477, 0.830),
        doc="Paper §2.1 six-compartment COVID-19 model (the reproduction default).",
    )
)
