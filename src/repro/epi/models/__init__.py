"""Registry of stochastic compartmental models.

Every model is a `CompartmentalModel` spec (see `repro.epi.spec`); the same
spec drives the reference XLA engine, the fused low-memory path and the
Pallas kernel. Register a new model with:

    from repro.epi.models import register
    register(CompartmentalModel(name="my_model", ...))

or simply add a module here that calls `register` at import time. The paper's
SIARD model is the default everywhere (`DEFAULT_MODEL`), keeping the original
reproduction bit-for-bit intact.
"""

from __future__ import annotations

from typing import Dict, Tuple, Union

from repro.epi.spec import CompartmentalModel

_REGISTRY: Dict[str, CompartmentalModel] = {}


def _code_fingerprint(code) -> tuple:
    """Bytecode + constants of a code object, recursing into nested code
    objects (whose default repr embeds memory addresses and would make the
    fingerprint unstable across module reloads)."""
    import types

    consts = tuple(
        _code_fingerprint(c) if isinstance(c, types.CodeType) else repr(c)
        for c in code.co_consts
    )
    return (code.co_code, consts)


def _fn_key(fn) -> tuple:
    """Identity of a spec function for idempotency checks: source location
    plus compiled bytecode/constants. A module-reloaded function (new object,
    same source — including nested helpers/lambdas) matches itself; a
    different function body — even a lambda defined at the same spot — does
    not. Closure cells compare by value repr; objects whose repr embeds an
    address err on the conservative side (re-registration raises rather than
    silently replacing the dynamics)."""
    code = getattr(fn, "__code__", None)
    body = _code_fingerprint(code) if code is not None else repr(fn)
    cells = tuple(repr(c.cell_contents) for c in (getattr(fn, "__closure__", None) or ()))
    return (
        getattr(fn, "__module__", ""),
        getattr(fn, "__qualname__", repr(fn)),
        body,
        cells,
    )


def _declarative_key(model: CompartmentalModel) -> tuple:
    """Identity of a spec for idempotency checks. Function-valued fields are
    compared by `_fn_key` rather than object identity, so a module-reloaded
    spec still matches itself, while a same-named spec with *different*
    dynamics — even with identical shape tuples — is rejected instead of
    silently replacing the registered model."""
    return (
        model.name,
        model.compartments,
        model.param_names,
        model.prior_highs,
        model.prior_lows,
        model.stoichiometry,
        model.observed,
        model.default_theta,
        model.n_regions,
        model.mobility,
        model.coupled,
        model.seed_region,
        _fn_key(model.hazard_rows),
        _fn_key(model.initial_rows),
    )


def register(model: CompartmentalModel) -> CompartmentalModel:
    """Add a model spec to the registry (idempotent for declaratively
    identical specs — a reloaded module re-registering the same model is
    fine and replaces the entry)."""
    existing = _REGISTRY.get(model.name)
    if existing is not None and _declarative_key(existing) != _declarative_key(model):
        raise ValueError(f"model {model.name!r} already registered with a different spec")
    _REGISTRY[model.name] = model
    return model


def get_model(model: Union[str, CompartmentalModel]) -> CompartmentalModel:
    """Resolve a registry name (or pass a spec through)."""
    if isinstance(model, CompartmentalModel):
        return model
    try:
        return _REGISTRY[model]
    except KeyError:
        raise KeyError(
            f"unknown model {model!r}; registered: {list_models()}"
        ) from None


def list_models() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# Import order fixes registry contents; siard first (the paper default).
from repro.epi.models import siard as _siard  # noqa: E402
from repro.epi.models import sir as _sir  # noqa: E402
from repro.epi.models import seir as _seir  # noqa: E402
from repro.epi.models import seiard as _seiard  # noqa: E402
from repro.epi.models import metapop_seir as _metapop_seir  # noqa: E402

DEFAULT_MODEL = _siard.MODEL

__all__ = [
    "CompartmentalModel",
    "DEFAULT_MODEL",
    "get_model",
    "list_models",
    "register",
]
