"""Classic stochastic SIR model as a registry spec.

Three compartments [S, I, R] and three parameters [beta, gamma, kappa]:

  S -> I   beta * S * I / P      (infection)
  I -> R   gamma * I             (recovery/removal)

The initial-state rule mirrors the paper's seeding convention: I0 = kappa*A0
(A0 is the dataset's day-0 case count), R0 from the dataset, S = P - I0 - R0.
Observed channels are (I, R), so datasets for this model carry [2, T] series.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.epi.models import register
from repro.epi.spec import CompartmentalModel


def _hazard_rows(sc, pc, population):
    s, i, _r = sc
    beta, gamma, _kappa = pc
    return (
        beta * s * i / population,  # S -> I
        gamma * i,  # I -> R
    )


def _initial_rows(pc, population, a0, r0, _d0):
    kappa = pc[2]
    i0 = kappa * a0
    s0 = population - (i0 + r0)
    zeros = jnp.zeros_like(kappa)
    return (s0, i0, zeros + r0)


MODEL = register(
    CompartmentalModel(
        name="sir",
        compartments=("S", "I", "R"),
        param_names=("beta", "gamma", "kappa"),
        prior_highs=(2.0, 1.0, 2.0),
        stoichiometry=(
            # S   I   R
            (-1, +1, 0),  # S -> I
            (0, -1, +1),  # I -> R
        ),
        observed=("I", "R"),
        hazard_rows=_hazard_rows,
        initial_rows=_initial_rows,
        default_theta=(0.5, 0.2, 1.0),
        doc="Kermack-McKendrick stochastic SIR (tau-leaped).",
    )
)
