"""SEIARD: the paper's SIARD model extended with an exposed compartment.

Seven compartments [S, E, I, A, R, D, Ru] and nine parameters
[alpha0, alpha, n, beta, gamma, delta, eta, kappa, epsilon]. The infection
pathway gains a latent stage governed by epsilon (1/epsilon mean incubation):

  S -> E   g(A,R,D) * S * I / P     (behaviour-modulated exposure, eq. 4)
  E -> I   epsilon * E              (incubation)
  I -> A   gamma * I                (case confirmation)
  A -> R   beta * A                 (confirmed recovery)
  A -> D   delta * A                (confirmed death)
  I -> Ru  beta * eta * I           (unconfirmed removal)

Observed channels are the paper's (A, R, D), so this model is directly
comparable against the SIARD fit on the same country series — the
model-comparison workload Wieland et al. 2025 argue SBI pipelines need.

Seeding extends the paper's step 1: I0 = kappa*A0, E0 = kappa*A0 (the latent
pool mirrors the undocumented pool at day 0), S = P - (A0+R0+D0+I0+E0).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.epi.models import register
from repro.epi.models.siard import behavioural_infection_rate
from repro.epi.spec import CompartmentalModel


def _hazard_rows(sc, pc, population):
    s, e, i, a, r, d, _ru = sc
    alpha0, alpha, n, beta, gamma, delta, eta, _kappa, epsilon = pc
    g = behavioural_infection_rate(alpha0, alpha, n, a + r + d)
    return (
        g * s * i / population,  # S -> E
        epsilon * e,  # E -> I
        gamma * i,  # I -> A
        beta * a,  # A -> R
        delta * a,  # A -> D
        beta * eta * i,  # I -> Ru
    )


def _initial_rows(pc, population, a0, r0, d0):
    kappa = pc[7]
    i0 = kappa * a0
    e0 = kappa * a0
    s0 = population - (a0 + r0 + d0 + i0 + e0)
    zeros = jnp.zeros_like(kappa)
    return (s0, e0, i0, zeros + a0, zeros + r0, zeros + d0, zeros)


MODEL = register(
    CompartmentalModel(
        name="seiard",
        compartments=("S", "E", "I", "A", "R", "D", "Ru"),
        param_names=(
            "alpha0", "alpha", "n", "beta", "gamma", "delta", "eta", "kappa",
            "epsilon",
        ),
        prior_highs=(1.0, 100.0, 2.0, 1.0, 1.0, 1.0, 1.0, 2.0, 1.0),
        stoichiometry=(
            # S   E   I   A   R   D  Ru
            (-1, +1, 0, 0, 0, 0, 0),  # S -> E
            (0, -1, +1, 0, 0, 0, 0),  # E -> I
            (0, 0, -1, +1, 0, 0, 0),  # I -> A
            (0, 0, 0, -1, +1, 0, 0),  # A -> R
            (0, 0, 0, -1, 0, +1, 0),  # A -> D
            (0, 0, -1, 0, 0, 0, +1),  # I -> Ru
        ),
        observed=("A", "R", "D"),
        hazard_rows=_hazard_rows,
        initial_rows=_initial_rows,
        default_theta=(0.384, 36.054, 0.595, 0.013, 0.385, 0.009, 0.477, 0.830, 0.4),
        doc="Paper SIARD extended with an exposed/latent compartment.",
    )
)
