"""Stochastic SEIR model as a registry spec.

Four compartments [S, E, I, R] and four parameters [beta, sigma, gamma, kappa]:

  S -> E   beta * S * I / P      (exposure)
  E -> I   sigma * E             (incubation, 1/sigma mean latent period)
  I -> R   gamma * I             (removal)

Seeding: I0 = A0 (the dataset's day-0 case count), E0 = kappa * A0 (latent
pool scales with observed seed), R0 from the dataset, S = P - E0 - I0 - R0.
Observed channels are (I, R) -> datasets carry [2, T] series.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.epi.models import register
from repro.epi.spec import CompartmentalModel


def _hazard_rows(sc, pc, population):
    s, e, i, _r = sc
    beta, sigma, gamma, _kappa = pc
    return (
        beta * s * i / population,  # S -> E
        sigma * e,  # E -> I
        gamma * i,  # I -> R
    )


def _initial_rows(pc, population, a0, r0, _d0):
    kappa = pc[3]
    e0 = kappa * a0
    zeros = jnp.zeros_like(kappa)
    i0 = zeros + a0
    s0 = population - (e0 + a0 + r0)
    return (s0, e0, i0, zeros + r0)


MODEL = register(
    CompartmentalModel(
        name="seir",
        compartments=("S", "E", "I", "R"),
        param_names=("beta", "sigma", "gamma", "kappa"),
        prior_highs=(2.0, 1.0, 1.0, 2.0),
        stoichiometry=(
            # S   E   I   R
            (-1, +1, 0, 0),  # S -> E
            (0, -1, +1, 0),  # E -> I
            (0, 0, -1, +1),  # I -> R
        ),
        observed=("I", "R"),
        hazard_rows=_hazard_rows,
        initial_rows=_initial_rows,
        default_theta=(0.6, 0.3, 0.2, 1.0),
        doc="SEIR with exposed/latent compartment (tau-leaped).",
    )
)
