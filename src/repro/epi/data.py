"""Dataset registry for the epidemiology models.

The paper fits Johns Hopkins CSSE daily (A, R, D) series for Italy, New
Zealand and the USA, 49 days starting from the first day with 100 detected
cases. This container is offline, so we provide:

  * `synthetic_dataset(...)` — simulate a ground-truth trajectory from known
    parameters with ANY registered model spec. This is the scientifically
    strongest validation target: the ABC posterior must concentrate around
    the generating parameters (EXPERIMENTS.md claim C2).
  * Bundled demo series for italy / new_zealand / usa, generated from the
    paper's Table 8 posterior-mean parameters with fixed seeds and realistic
    (P, A0, R0, D0) starting points. These are clearly labeled approximations
    standing in for the JHU feed — NOT the actual JHU numbers. They are SIARD
    series (the paper model), but any model whose observed channels are
    (A, R, D) — e.g. seiard — can be fitted against them.

Every dataset is a `CountryData` with observed [n_observed, T] series; the
`model` field names the spec whose observed channels the rows correspond to.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import jax
import numpy as np

from repro.epi import engine
from repro.epi.models import get_model
from repro.epi.spec import CompartmentalModel, EpiModelConfig


@dataclasses.dataclass(frozen=True)
class CountryData:
    name: str
    population: float
    a0: float
    r0: float
    d0: float
    observed: np.ndarray  # [n_observed, T] float32 — per-day observed channels
    #: tolerance the paper used for this dataset (Table 8), where applicable
    paper_tolerance: float | None = None
    #: generating parameters if synthetic, else None
    true_theta: Tuple[float, ...] | None = None
    synthetic: bool = True
    #: registry name of the model whose observed channels the rows match
    model: str = "siard"
    #: the observed channel names themselves — carried on the dataset so
    #: compatibility never needs a registry lookup (datasets may come from
    #: unregistered or since-replaced specs). Metapop datasets carry the
    #: region-major flattened labels ("I@r0", "R@r0", "I@r1", ...).
    observed_channels: Tuple[str, ...] = ("A", "R", "D")

    @property
    def num_days(self) -> int:
        return int(self.observed.shape[1])

    def model_config(self, num_days: int | None = None) -> EpiModelConfig:
        return EpiModelConfig(
            population=self.population,
            num_days=int(num_days or self.num_days),
            a0=self.a0,
            r0=self.r0,
            d0=self.d0,
        )

    def compatible_with(self, spec: CompartmentalModel) -> bool:
        """A spec can fit this dataset iff its observed channels line up
        (for metapop specs: the flattened per-region labels, so region
        count mismatches are caught too)."""
        return spec.observed_labels == self.observed_channels


def synthetic_dataset(
    theta: Tuple[float, ...],
    population: float,
    num_days: int = 49,
    a0: float = 100.0,
    r0: float = 0.0,
    d0: float = 0.0,
    seed: int = 0,
    name: str = "synthetic",
    paper_tolerance: float | None = None,
    model: Union[str, CompartmentalModel] = "siard",
    schedule=None,
) -> CountryData:
    """Generate a ground-truth dataset by simulating with known parameters.

    `schedule` (an InterventionSchedule with FIXED scales) generates the
    series under a known intervention — e.g. a mid-horizon contact-rate drop
    — which is the validation target for intervention-aware inference.
    `theta` is the base parameter vector; the schedule's pinned scales are
    appended automatically (pass a full widened theta to override).
    """
    spec = get_model(model)
    cfg = EpiModelConfig(
        population=population, num_days=num_days, a0=a0, r0=r0, d0=d0
    )
    th = np.asarray([theta], np.float32)
    width = spec.n_params
    if schedule is not None and not schedule.is_empty:
        width = schedule.param_width(spec)
        if th.shape[1] == spec.n_params:
            scales = np.asarray(
                [s for row in schedule.fixed_scales() for s in row],
                np.float32,
            )
            th = np.concatenate([th, scales[None, :]], axis=1)
    if th.shape[1] != width:
        raise ValueError(
            f"theta has {th.shape[1]} entries; model {spec.name!r} "
            f"expects {width}"
        )
    obs = engine.simulate_observed(
        spec, th, jax.random.PRNGKey(seed), cfg, schedule
    )[0]
    return CountryData(
        name=name,
        population=population,
        a0=a0,
        r0=r0,
        d0=d0,
        observed=np.asarray(obs, np.float32),
        paper_tolerance=paper_tolerance,
        true_theta=tuple(float(x) for x in theta),
        synthetic=True,
        model=spec.name,
        observed_channels=spec.observed_labels,
    )


# Paper Table 8 posterior means (100-sample rows) — used as generating
# parameters for the bundled demo series.
_TABLE8_THETA = {
    "italy": (0.384, 36.054, 0.595, 0.013, 0.385, 0.009, 0.477, 0.830),
    "new_zealand": (0.474, 46.603, 1.223, 0.030, 0.499, 0.001, 0.520, 1.198),
    "usa": (0.329, 10.667, 0.322, 0.007, 0.435, 0.005, 0.490, 0.716),
}

# (population, A0, R0, D0, paper tolerance, seed)
_COUNTRY_META = {
    "italy": (60.36e6, 155.0, 2.0, 3.0, 5e4, 1),
    "new_zealand": (4.917e6, 102.0, 0.0, 0.0, 1250.0, 2),
    "usa": (328.2e6, 104.0, 7.0, 6.0, 2e5, 3),
}

#: generating parameters for the per-model synthetic_small problem. SIARD
#: keeps its historical values so existing tolerances/baselines stay valid;
#: other models use their spec's default_theta.
_SYNTH_SMALL_THETA = {"siard": (0.4, 30.0, 0.8, 0.05, 0.3, 0.01, 0.5, 1.0)}

_CACHE: Dict[tuple, CountryData] = {}


def list_datasets() -> Tuple[str, ...]:
    return tuple(sorted(_COUNTRY_META)) + ("synthetic_small",)


def list_countries() -> Tuple[str, ...]:
    """The bundled country series (the paper's three-country study grid) —
    the default dataset axis of a campaign (repro.core.campaign)."""
    return tuple(sorted(_COUNTRY_META))


def get_dataset(
    name: str,
    num_days: int = 49,
    model: Union[str, CompartmentalModel] = "siard",
) -> CountryData:
    """Fetch a bundled dataset by name ('italy' | 'new_zealand' | 'usa' |
    'synthetic_small').

    `model` selects which registry spec generates (and is fitted against)
    the series. The bundled country series are SIARD-generated; they can be
    requested for any model with matching observed channels (e.g. seiard).
    """
    spec = get_model(model)
    # key on the spec object itself (hashable by design), not its name: two
    # different unregistered specs sharing a name must not alias cached data
    key = (name, num_days, spec)
    if key in _CACHE:
        return _CACHE[key]
    if name == "synthetic_small":
        # A tiny, fast-converging problem for tests / quickstart: small
        # population keeps distances small so moderate tolerances accept.
        ds = synthetic_dataset(
            theta=_SYNTH_SMALL_THETA.get(spec.name, spec.default_theta),
            population=1e6,
            num_days=num_days,
            a0=100.0,
            seed=7,
            name="synthetic_small",
            paper_tolerance=None,
            model=spec,
        )
    elif name in _COUNTRY_META:
        if spec.name != "siard":
            # the series stays SIARD-generated; re-tag the cached siard entry
            # (no re-simulation) iff the requested model observes the same
            # channels and can therefore fit it
            base = get_dataset(name, num_days=num_days, model="siard")
            if not base.compatible_with(spec):
                raise ValueError(
                    f"dataset {name!r} holds (A, R, D) series; model "
                    f"{spec.name!r} observes {spec.observed_labels}"
                )
            ds = dataclasses.replace(base, model=spec.name, true_theta=None)
        else:
            population, a0, r0, d0, tol, seed = _COUNTRY_META[name]
            # demo series: generated from the paper's posterior means,
            # standing in for the (offline) JHU feed.
            ds = synthetic_dataset(
                theta=_TABLE8_THETA[name],
                population=population,
                num_days=num_days,
                a0=a0,
                r0=r0,
                d0=d0,
                seed=seed,
                name=name,
                paper_tolerance=tol,
                model="siard",
            )
    else:
        raise KeyError(f"unknown dataset {name!r}; available: {list_datasets()}")
    _CACHE[key] = ds
    return ds
