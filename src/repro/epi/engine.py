"""Generic tau-leap simulation engine over a `CompartmentalModel` spec.

This is the model-agnostic generalization of the paper's §2.1 scheme: a
`lax.scan` over days, each day drawing Gaussian tau-leap transition counts
from the spec's hazards, clamping them with sequential source draining, and
applying the stoichiometry matrix. With the SIARD spec it reproduces the
original hand-unrolled implementation bit-for-bit (same noise layout, same
clamp order, same accumulation order — pinned by tests/test_model_registry).

Three entry points mirror the original module:

  * `simulate`                 — full [B, T, n_state] trajectory
  * `simulate_observed`        — observed channels only, [B, n_obs, T]
  * `simulate_observed_lowmem` — fused simulate + running squared distance
                                 (the beyond-paper memory optimization)

The Pallas path (`repro.kernels.abc_sim`) inlines the same spec into a fused
VMEM-resident kernel; this module is the paper-faithful XLA reference.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.epi.spec import CompartmentalModel, EpiModelConfig


def initial_state(
    model: CompartmentalModel, theta: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Spec step 1: theta [..., n_params] -> state [..., n_state]."""
    theta = jnp.asarray(theta, jnp.float32)
    pc = tuple(theta[..., k] for k in range(model.n_params))
    rows = model.initial_rows(
        pc,
        cfg.population,
        jnp.asarray(cfg.a0, jnp.float32),
        jnp.asarray(cfg.r0, jnp.float32),
        jnp.asarray(cfg.d0, jnp.float32),
    )
    return jnp.stack(list(rows), axis=-1).astype(jnp.float32)


def hazards(
    model: CompartmentalModel, state: jax.Array, theta: jax.Array, population: float
) -> jax.Array:
    """Transition rates: state [..., n_state] -> h [..., n_transitions]."""
    sc = tuple(state[..., k] for k in range(model.n_state))
    pc = tuple(theta[..., k] for k in range(model.n_params))
    h = jnp.stack(list(model.hazard_rows(sc, pc, population)), axis=-1)
    # Hazards are rates of counting processes; they cannot be negative.
    return jnp.maximum(h, 0.0)


def drain_and_apply(model: CompartmentalModel, sc, raw_counts):
    """Clamp raw transition-count rows and apply the stoichiometry matrix.

    Transitions are clamped in declaration order with sequential source
    draining: each clamp is bounded by what its source compartment still has
    after earlier transitions out of the same source. Guarantees
    non-negativity and exact mass conservation for any spec.

    Operates on channel rows (`sc`: one array per compartment, `raw_counts`:
    one per transition) so the SAME code serves this XLA engine and the
    Pallas kernel body — the mass-conservation-critical logic exists once.
    Returns the next-state rows.
    """
    sc = list(sc)
    remaining = {}  # source compartment -> undrained budget
    counts = []
    for k, src in enumerate(model.transition_sources):
        avail = remaining.get(src, sc[src])
        n_k = jnp.clip(raw_counts[k], 0.0, avail)
        remaining[src] = avail - n_k
        counts.append(n_k)
    for k, row in enumerate(model.stoichiometry):
        for j, coef in enumerate(row):
            if coef == 1:
                sc[j] = sc[j] + counts[k]
            elif coef == -1:
                sc[j] = sc[j] - counts[k]
    return sc


def apply_transitions(
    model: CompartmentalModel, state: jax.Array, n_raw: jax.Array
) -> jax.Array:
    """Tensor-layout wrapper around `drain_and_apply`."""
    sc = (state[..., k] for k in range(model.n_state))
    raw = [n_raw[..., k] for k in range(model.n_transitions)]
    return jnp.stack(drain_and_apply(model, sc, raw), axis=-1)


def tau_leap_step(
    model: CompartmentalModel,
    state: jax.Array,
    theta: jax.Array,
    noise: jax.Array,
    population: float,
) -> jax.Array:
    """One day of tau-leaping given standard-normal noise [..., n_transitions].

    n_k = floor(h_k + sqrt(h_k) * z_k), clamped to sources (paper steps 2-4).
    """
    h = hazards(model, state, theta, population)
    n_raw = jnp.floor(h + jnp.sqrt(h) * noise)
    return apply_transitions(model, state, n_raw)


def simulate(
    model: CompartmentalModel, theta: jax.Array, key: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Full state trajectory [B, T, n_state] (state *after* each day's update).

    Noise is drawn with jax.random (threefry) — the paper-faithful path.
    """
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    state0 = initial_state(model, theta, cfg)

    def step(state, day):
        # Per-day fold_in keeps this bit-identical with the fused low-memory
        # path (simulate_observed_lowmem) for the same key.
        z = jax.random.normal(
            jax.random.fold_in(key, day),
            batch_shape + (model.n_transitions,),
            jnp.float32,
        )
        nxt = tau_leap_step(model, state, theta, z, cfg.population)
        return nxt, nxt

    _, traj = jax.lax.scan(step, state0, jnp.arange(cfg.num_days))
    # traj: [T, B, n_state] -> [B, T, n_state]
    return jnp.moveaxis(traj, 0, -2)


def simulate_observed(
    model: CompartmentalModel, theta: jax.Array, key: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Observed channels only: [B, n_observed, T] (the paper's D_s layout)."""
    traj = simulate(model, theta, key, cfg)  # [B, T, n_state]
    obs = traj[..., model.observed_idx]  # [B, T, n_obs]
    return jnp.swapaxes(obs, -1, -2)  # [B, n_obs, T]


def simulate_observed_lowmem(
    model: CompartmentalModel,
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    observed: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Fused simulate + running squared-distance accumulation.

    The beyond-paper memory optimization (DESIGN.md §2): never materialize
    the [B, n_obs, T] trajectory; accumulate sum-of-squares against
    `observed` [n_obs, T] per day. Returns (distance [B], final state).

    This is the pure-XLA analogue of the Pallas kernel; the kernel
    additionally keeps the whole loop in VMEM.
    """
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    obs_idx = model.observed_idx
    state0 = initial_state(model, theta, cfg)
    # derive from state0 so the carry inherits its varying mesh axes when this
    # runs inside shard_map (scan carries must have uniform vma types)
    acc0 = state0[..., 0] * 0.0
    obs_by_day = jnp.swapaxes(jnp.asarray(observed, jnp.float32), 0, 1)  # [T, n_obs]

    def step(carry, inp):
        state, acc = carry
        day, obs_t = inp
        z = jax.random.normal(
            jax.random.fold_in(key, day),
            batch_shape + (model.n_transitions,),
            jnp.float32,
        )
        nxt = tau_leap_step(model, state, theta, z, cfg.population)
        diff = nxt[..., obs_idx] - obs_t
        acc = acc + jnp.sum(diff * diff, axis=-1)
        return (nxt, acc), None

    days = jnp.arange(cfg.num_days)
    (state_f, acc_f), _ = jax.lax.scan(step, (state0, acc0), (days, obs_by_day))
    return jnp.sqrt(acc_f), state_f
