"""Generic tau-leap simulation engine over a `CompartmentalModel` spec.

This is the model-agnostic generalization of the paper's §2.1 scheme: a
`lax.scan` over days, each day drawing Gaussian tau-leap transition counts
from the spec's hazards, clamping them with sequential source draining, and
applying the stoichiometry matrix. With the SIARD spec it reproduces the
original hand-unrolled implementation bit-for-bit (same noise layout, same
clamp order, same accumulation order — pinned by tests/test_model_registry).

Four entry points mirror the original module:

  * `simulate`                 — full [B, T, n_state] trajectory
  * `simulate_observed`        — observed channels only, [B, n_obs, T]
  * `simulate_observed_lowmem` — fused simulate + running squared distance
                                 (the beyond-paper memory optimization)
  * `simulate_features`        — simulate + summary FEATURE vectors,
                                 [B, n_features] (the NPE backend's batched
                                 training-pair generator, repro.core.npe)

The Pallas path (`repro.kernels.abc_sim`) inlines the same spec into a fused
VMEM-resident kernel; this module is the paper-faithful XLA reference.

All entry points optionally take an `InterventionSchedule`: theta then
carries extra per-window scale columns and each day's hazards are computed
with that day's window-effective parameters (`effective_param_rows` — the
row-level helper the Pallas kernel shares, like `drain_and_apply`).

Spatial metapopulation specs (`model.is_regional`) take a tensor region
path: state/noise/observed flatten region-major to `[..., R * n]` (see the
spec module docstring), channel rows carry a trailing region axis `[..., R]`
with parameter rows broadcast as `[..., 1]`, and the coupled-mass rows are a
single `[R, R] @ [..., R]` einsum per coupled compartment — so a 100-region
model costs one contraction per day, not an unrolled R^2 expression. The
flat R=1 uncoupled branch is untouched code, keeping every registered model
bit-identical to pre-metapop releases (pinned by tests/test_metapop.py).
An optional traced `mobility` [R, R] override (like the `breakpoints`
override) lets mobility sweeps share one compilation.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.epi.spec import (
    CompartmentalModel,
    EpiModelConfig,
    InterventionSchedule,
    ScheduleShape,
    identity_mobility,
)


def mobility_matrix(model: CompartmentalModel, mobility=None) -> jax.Array:
    """Resolve the [R, R] f32 coupling matrix: a traced override, the spec's
    static matrix, or the identity."""
    mob = model.mobility if mobility is None else mobility
    if mob is None:
        mob = identity_mobility(model.n_regions)
    return jnp.asarray(mob, jnp.float32)


def _seed_vector(model: CompartmentalModel, value) -> jax.Array:
    """[R] day-0 seed counts: `value` in `seed_region`, zero elsewhere."""
    return (
        jnp.zeros((model.n_regions,), jnp.float32)
        .at[model.seed_region]
        .set(jnp.asarray(value, jnp.float32))
    )


def initial_state(
    model: CompartmentalModel, theta: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Spec step 1: theta [..., n_params] -> state [..., total_state].

    Metapop specs seed region `seed_region` with (a0, r0, d0); every other
    region starts fully susceptible at population / R.
    """
    theta = jnp.asarray(theta, jnp.float32)
    if not model.is_regional:
        pc = tuple(theta[..., k] for k in range(model.n_params))
        rows = model.initial_rows(
            pc,
            cfg.population,
            jnp.asarray(cfg.a0, jnp.float32),
            jnp.asarray(cfg.r0, jnp.float32),
            jnp.asarray(cfg.d0, jnp.float32),
        )
        return jnp.stack(list(rows), axis=-1).astype(jnp.float32)
    R, C = model.n_regions, model.n_state
    batch = theta.shape[:-1]
    pc = tuple(theta[..., k : k + 1] for k in range(model.n_params))
    rows = model.initial_rows(
        pc,
        cfg.population / R,
        _seed_vector(model, cfg.a0),
        _seed_vector(model, cfg.r0),
        _seed_vector(model, cfg.d0),
    )
    rows = [
        jnp.broadcast_to(jnp.asarray(r, jnp.float32), batch + (R,)) for r in rows
    ]
    # [..., R, C] -> region-major flat [..., R*C]
    return jnp.stack(rows, axis=-1).reshape(batch + (R * C,)).astype(jnp.float32)


def effective_param_rows(
    model: CompartmentalModel,
    shape: Optional[ScheduleShape],
    pc: Sequence,
    day,
    breakpoints: Sequence,
):
    """Apply an intervention schedule's window scales to parameter rows.

    `pc` holds the widened parameter channels (n_params base rows followed by
    window-major scale rows); `day` is a (traced) scalar day index and
    `breakpoints` a sequence of n_windows (traced or concrete) scalar days.
    Returns the n_params EFFECTIVE rows for that day: window 0 is the base
    parameters untouched, window w >= 1 multiplies each time-varying
    parameter by its scale row.

    Row-level like `drain_and_apply`, so the SAME code runs in the XLA
    engine (rows are [...] slices) and inside the Pallas kernel body (rows
    are (1, TB) VREGs); the Python loops unroll at trace time into
    straight-line selects — the schedule never adds control flow.
    """
    if shape is None or shape.n_windows == 0:
        return tuple(pc[: model.n_params])
    day = jnp.asarray(day, jnp.int32)
    w = jnp.zeros((), jnp.int32)  # window index: #{breakpoints <= day}
    for b in breakpoints:
        w = w + (day >= jnp.asarray(b, jnp.int32)).astype(jnp.int32)
    out = list(pc[: model.n_params])
    for j, pi in enumerate(shape.tv_indices):
        scale = jnp.ones_like(out[pi])  # window 0: base params, scale 1
        for win in range(shape.n_windows):
            row = pc[model.n_params + win * shape.n_tv + j]
            scale = jnp.where(w == win + 1, row, scale)
        out[pi] = out[pi] * scale
    return tuple(out)


def effective_theta(
    model: CompartmentalModel,
    schedule: Optional[InterventionSchedule],
    theta: jax.Array,
    day,
    breakpoints=None,
) -> jax.Array:
    """Tensor-layout wrapper: widened theta [..., n_params + n_scales] ->
    day-effective theta [..., n_params]. `breakpoints` optionally overrides
    the schedule's static days with traced scalars (campaign sweeps)."""
    if schedule is None or schedule.is_empty:
        return theta
    shape = schedule.shape(model)
    bp = schedule.breakpoints if breakpoints is None else breakpoints
    width = schedule.param_width(model)
    pc = tuple(theta[..., k] for k in range(width))
    rows = effective_param_rows(model, shape, pc, day, bp)
    return jnp.stack(list(rows), axis=-1)


def _breakpoint_scalars(schedule, breakpoints):
    """Resolve the per-window breakpoint scalars for the scan helpers."""
    if schedule is None or schedule.is_empty:
        return ()
    if breakpoints is None:
        return schedule.breakpoints
    bp = jnp.asarray(breakpoints, jnp.int32)
    return tuple(bp[i] for i in range(schedule.n_windows))


def hazards(
    model: CompartmentalModel,
    state: jax.Array,
    theta: jax.Array,
    population: float,
    mobility=None,
) -> jax.Array:
    """Transition rates: state [..., total_state] -> h [..., total_transitions].

    Metapop specs evaluate all regions at once: channel rows carry a trailing
    region axis, parameters broadcast as [..., 1] rows, and each coupled
    compartment contributes one mobility-weighted mass row via a single
    [R, R] contraction. `mobility` optionally overrides the spec's static
    matrix with a traced [R, R] value (mobility sweeps share one compile).
    """
    if not model.is_regional:
        sc = tuple(state[..., k] for k in range(model.n_state))
        pc = tuple(theta[..., k] for k in range(model.n_params))
        h = jnp.stack(list(model.hazard_rows(sc, pc, population)), axis=-1)
        # Hazards are rates of counting processes; they cannot be negative.
        return jnp.maximum(h, 0.0)
    R, C, T = model.n_regions, model.n_state, model.n_transitions
    batch = state.shape[:-1]
    st = state.reshape(batch + (R, C))
    sc = tuple(st[..., k] for k in range(C))  # each [..., R]
    pc = tuple(theta[..., k : k + 1] for k in range(model.n_params))
    mob = mobility_matrix(model, mobility)
    coupled = tuple(
        jnp.einsum("rq,...q->...r", mob, st[..., j]) for j in model.coupled_idx
    )
    rows = model.hazard_rows(sc + coupled, pc, population / R)
    h = jnp.stack(
        [jnp.broadcast_to(r, batch + (R,)) for r in rows], axis=-1
    )  # [..., R, T]
    return jnp.maximum(h, 0.0).reshape(batch + (R * T,))


def drain_and_apply(model: CompartmentalModel, sc, raw_counts):
    """Clamp raw transition-count rows and apply the stoichiometry matrix.

    Transitions are clamped in declaration order with sequential source
    draining: each clamp is bounded by what its source compartment still has
    after earlier transitions out of the same source. Guarantees
    non-negativity and exact mass conservation for any spec.

    Operates on channel rows (`sc`: one array per compartment, `raw_counts`:
    one per transition) so the SAME code serves this XLA engine and the
    Pallas kernel body — the mass-conservation-critical logic exists once.
    Returns the next-state rows.
    """
    sc = list(sc)
    remaining = {}  # source compartment -> undrained budget
    counts = []
    for k, src in enumerate(model.transition_sources):
        avail = remaining.get(src, sc[src])
        n_k = jnp.clip(raw_counts[k], 0.0, avail)
        remaining[src] = avail - n_k
        counts.append(n_k)
    for k, row in enumerate(model.stoichiometry):
        for j, coef in enumerate(row):
            if coef == 1:
                sc[j] = sc[j] + counts[k]
            elif coef == -1:
                sc[j] = sc[j] - counts[k]
    return sc


def apply_transitions(
    model: CompartmentalModel, state: jax.Array, n_raw: jax.Array
) -> jax.Array:
    """Tensor-layout wrapper around `drain_and_apply`.

    Metapop specs drain per region: the channel/count rows carry a trailing
    region axis, so the shared row-level clamp logic applies unchanged.
    """
    if not model.is_regional:
        sc = (state[..., k] for k in range(model.n_state))
        raw = [n_raw[..., k] for k in range(model.n_transitions)]
        return jnp.stack(drain_and_apply(model, sc, raw), axis=-1)
    R, C, T = model.n_regions, model.n_state, model.n_transitions
    batch = state.shape[:-1]
    st = state.reshape(batch + (R, C))
    nr = n_raw.reshape(batch + (R, T))
    sc = (st[..., k] for k in range(C))
    raw = [nr[..., k] for k in range(T)]
    out = drain_and_apply(model, sc, raw)  # rows [..., R]
    return jnp.stack(out, axis=-1).reshape(batch + (R * C,))


def tau_leap_step(
    model: CompartmentalModel,
    state: jax.Array,
    theta: jax.Array,
    noise: jax.Array,
    population: float,
    mobility=None,
) -> jax.Array:
    """One day of tau-leaping given standard-normal noise
    [..., total_transitions] (region-major: slot r * n_transitions + k is
    region r's transition k).

    n_k = floor(h_k + sqrt(h_k) * z_k), clamped to sources (paper steps 2-4).
    """
    h = hazards(model, state, theta, population, mobility)
    n_raw = jnp.floor(h + jnp.sqrt(h) * noise)
    return apply_transitions(model, state, n_raw)


def simulate(
    model: CompartmentalModel,
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    schedule: Optional[InterventionSchedule] = None,
    breakpoints=None,
    mobility=None,
) -> jax.Array:
    """Full state trajectory [B, T, total_state] (state *after* each day's
    update; region-major channels for metapop specs, reshape with
    `regional_view` for an explicit [B, R, T, n_state] axis).

    Noise is drawn with jax.random (threefry) — the paper-faithful path.
    With a `schedule`, theta is the widened [..., n_params + n_scales] layout
    and each day's hazards use that day's window-effective parameters; the
    noise stream is unchanged, and schedule=None stays bit-identical to the
    constant-theta path.
    """
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    state0 = initial_state(model, theta, cfg)
    bp = _breakpoint_scalars(schedule, breakpoints)

    def step(state, day):
        # Per-day fold_in keeps this bit-identical with the fused low-memory
        # path (simulate_observed_lowmem) for the same key.
        z = jax.random.normal(
            jax.random.fold_in(key, day),
            batch_shape + (model.total_transitions,),
            jnp.float32,
        )
        th_d = effective_theta(model, schedule, theta, day, bp)
        nxt = tau_leap_step(model, state, th_d, z, cfg.population, mobility)
        return nxt, nxt

    _, traj = jax.lax.scan(step, state0, jnp.arange(cfg.num_days))
    # traj: [T, B, total_state] -> [B, T, total_state]
    return jnp.moveaxis(traj, 0, -2)


def regional_view(series: jax.Array, model: CompartmentalModel) -> jax.Array:
    """Unflatten the region-major channel axis: [..., R*n, T] -> [..., R, n, T]
    (works for observed series and, with n = n_state, state trajectories
    transposed channel-major)."""
    R = model.n_regions
    n = series.shape[-2] // R
    return series.reshape(series.shape[:-2] + (R, n) + series.shape[-1:])


def simulate_observed(
    model: CompartmentalModel,
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    schedule: Optional[InterventionSchedule] = None,
    breakpoints=None,
    mobility=None,
) -> jax.Array:
    """Observed channels only: [B, total_observed, T] (the paper's D_s
    layout; metapop channels flatten region-major, channel r*n_obs + m)."""
    traj = simulate(model, theta, key, cfg, schedule, breakpoints, mobility)
    obs = traj[..., model.total_observed_idx]  # [B, T, total_obs]
    return jnp.swapaxes(obs, -1, -2)  # [B, total_obs, T]


def simulate_observed_lowmem(
    model: CompartmentalModel,
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    observed: jax.Array,
    schedule: Optional[InterventionSchedule] = None,
    breakpoints=None,
    summary=None,
    distance: str = "euclidean",
    unroll: int = 1,
    mobility=None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused simulate + running summary-distance accumulation.

    The beyond-paper memory optimization (DESIGN.md §2): never materialize
    the [B, n_obs, T] trajectory; fold the (summary, distance) pair into the
    day scan via the generalized running accumulator (core.summaries).
    Returns (distance [B], final state).

    `summary` is a SummarySpec / registry name / None; the default
    (identity, "euclidean") reduces to exactly the legacy running
    sum-of-squares — flush and weights are constant 1.0 and every transform
    select is constant-false, so outputs stay bit-identical to pre-summary
    releases (pinned by tests/test_summaries.py).

    This is the pure-XLA analogue of the Pallas kernel; the kernel
    additionally keeps the whole loop in VMEM.
    """
    from repro.core.summaries import (
        get_distance_kind,
        get_summary,
        lower_summary,
        pool_channels,
        pool_factor,
        running_day,
        running_finalize,
    )

    spec = get_summary(summary)
    kind = get_distance_kind(distance)
    lowered = lower_summary(spec, distance, observed, n_regions=model.n_regions)
    pool = pool_factor(spec, model.n_regions)
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    obs_idx = model.total_observed_idx
    state0 = initial_state(model, theta, cfg)
    # derive from state0 so the carries inherit its varying mesh axes when
    # this runs inside shard_map (scan carries must have uniform vma types)
    acc0 = state0[..., 0] * 0.0
    # [..., n_chan] cum/bin carries (region-pooled channels pool the sims)
    chan0 = pool_channels(state0[..., obs_idx], pool) * 0.0
    obs_by_day = jnp.swapaxes(lowered.obs_summary, 0, 1)  # [T, n_chan]
    bp = _breakpoint_scalars(schedule, breakpoints)

    def step(carry, inp):
        state, cum, binv, acc = carry
        day, obs_t, flush_t = inp
        z = jax.random.normal(
            jax.random.fold_in(key, day),
            batch_shape + (model.total_transitions,),
            jnp.float32,
        )
        th_d = effective_theta(model, schedule, theta, day, bp)
        nxt = tau_leap_step(model, state, th_d, z, cfg.population, mobility)
        cum, binv, acc = running_day(
            spec, kind, lowered.weights,
            pool_channels(nxt[..., obs_idx], pool), obs_t, flush_t,
            cum, binv, acc,
        )
        return (nxt, cum, binv, acc), None

    days = jnp.arange(cfg.num_days)
    # `unroll` is the xla_fused chunking knob searched by the autotuner
    # (repro.core.tuning): pure scheduling, the day streams are unchanged so
    # distances stay bit-identical across unroll factors (pinned by tests)
    (state_f, _, _, acc_f), _ = jax.lax.scan(
        step, (state0, chan0, chan0, acc0), (days, obs_by_day, lowered.flush),
        unroll=max(1, int(unroll)),
    )
    return running_finalize(kind, lowered.mean_scale, acc_f), state_f


def simulate_features(
    model: CompartmentalModel,
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    schedule: Optional[InterventionSchedule] = None,
    breakpoints=None,
    summary=None,
    mobility=None,
) -> jax.Array:
    """Simulate + summary feature vectors: [B, p] theta -> [B, n_features].

    The batched training-pair generator of the NPE backend (repro.core.npe):
    one call yields a device-resident batch of `(theta, x)` pairs where
    `x = summary_features(summary, simulate_observed(theta))` — the same
    summary values the ABC running accumulator compares, flattened to the
    flush-day columns (core.summaries.summary_features). Noise streams are
    the paper-faithful `simulate` streams, so a feature batch under a given
    key is reproducible across runs and backends.
    """
    from repro.core.summaries import get_summary, summary_features

    sim = simulate_observed(model, theta, key, cfg, schedule, breakpoints,
                            mobility)
    return summary_features(get_summary(summary), sim, model.n_regions)
