"""Declarative specification of a stochastic compartmental model.

A `CompartmentalModel` is the single source of truth every layer consumes:

  * the reference tau-leap engine (`repro.epi.engine`) — pure jax.numpy,
  * the fused Pallas kernel (`repro.kernels.abc_sim`) — the spec's hazards and
    stoichiometry are inlined into the kernel body at trace time,
  * the ABC/SMC drivers (`repro.core.abc`, `repro.core.smc`) — prior bounds,
    parameter names and output shapes all derive from the spec,
  * datasets (`repro.epi.data`) — synthetic ground truth is simulated from
    `default_theta` with the spec's own dynamics.

The spec is declarative: compartments and parameters are *names*, transitions
are a stoichiometry matrix plus a hazard function, and the initial state is a
rule mapping parameters to compartment counts. Dynamics follow the paper's
tau-leap scheme (§2.1, steps 2-4) generically:

    h   = hazard_rows(state, theta)              one rate per transition
    n_k = floor(Normal(h_k, sqrt(h_k)))          Gaussian tau-leap counts
    n_k = clip(n_k, 0, remaining[source_k])      sequential source draining
    x'  = x + stoichiometry^T @ n                apply transitions

Sequential source draining means transitions are clamped in declaration
order, each one reducing the budget of its source compartment, so no
compartment ever goes negative and total mass is conserved exactly — the
same clamping contract the paper's IPU implementation applies (its cycle
table shows `Clamp` compute sets).

Layout contract for `hazard_rows` / `initial_rows`: they receive the state
and parameters as *sequences of channel arrays* (one array per compartment /
parameter) rather than stacked tensors. The same function body therefore
runs unchanged in the reference engine (channels are slices of a [..., n]
tensor) and inside the Pallas kernel (channels are (1, TILE) VREG rows).

Known limitation: the seeding interface is the paper's three scalars
(a0, r0, d0) — `initial_rows` receives exactly those, and the kernel's
constant layout reserves the same three slots. Models are free to
reinterpret them (SIR/SEIR treat a0 as a generic day-0 case count), but a
model needing MORE day-0 inputs requires widening `InitialFn`, the fconsts
layout in kernels/abc_sim.py and `CountryData` together.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

Rows = Sequence  # sequence of same-shape arrays, one per channel

#: (state_rows, param_rows, population) -> one rate array per transition
HazardFn = Callable[[Rows, Rows, object], Tuple]
#: (param_rows, population, a0, r0, d0) -> one array per compartment
InitialFn = Callable[[Rows, object, object, object, object], Tuple]


@dataclasses.dataclass(frozen=True)
class CompartmentalModel:
    """Declarative spec of a stochastic compartmental epidemic model.

    Frozen and hashable (fields are tuples / callables), so a model can be a
    `static_argnames` entry of a jitted function — the Pallas kernel builder
    relies on this to specialize the kernel body per model.
    """

    name: str
    compartments: Tuple[str, ...]
    param_names: Tuple[str, ...]
    #: uniform-box prior upper bounds, one per parameter (lows default to 0)
    prior_highs: Tuple[float, ...]
    #: stoichiometry matrix [n_transitions][n_state]: each row moves one unit
    #: of mass out of exactly one source (-1) into one destination (+1)
    stoichiometry: Tuple[Tuple[int, ...], ...]
    #: names of observed compartments, compared against data [n_observed, T]
    observed: Tuple[str, ...]
    hazard_rows: HazardFn
    initial_rows: InitialFn
    #: plausible generating parameters — used for synthetic ground-truth data
    default_theta: Tuple[float, ...]
    prior_lows: Tuple[float, ...] | None = None
    doc: str = ""

    def __post_init__(self):
        ns, np_, nt = len(self.compartments), len(self.param_names), len(self.stoichiometry)
        if len(self.prior_highs) != np_:
            raise ValueError(f"{self.name}: prior_highs must have {np_} entries")
        if self.prior_lows is not None and len(self.prior_lows) != np_:
            raise ValueError(f"{self.name}: prior_lows must have {np_} entries")
        if len(self.default_theta) != np_:
            raise ValueError(f"{self.name}: default_theta must have {np_} entries")
        for k, row in enumerate(self.stoichiometry):
            if len(row) != ns:
                raise ValueError(f"{self.name}: stoichiometry row {k} has wrong width")
            if sum(row) != 0:
                raise ValueError(
                    f"{self.name}: transition {k} does not conserve mass: {row}"
                )
            if sorted(row) != sorted((-1, 1) + (0,) * (ns - 2)):
                raise ValueError(
                    f"{self.name}: transition {k} must move one unit from one "
                    f"source to one destination, got {row}"
                )
        for name in self.observed:
            if name not in self.compartments:
                raise ValueError(f"{self.name}: observed {name!r} is not a compartment")
        if nt > 8:
            # the counter-based RNG reserves 8 counter slots per day
            # (kernels/rng.day_transition_ctr); widen the layout to go beyond
            raise ValueError(f"{self.name}: at most 8 transitions supported, got {nt}")

    # ------------------------------------------------------------ dimensions
    @property
    def n_state(self) -> int:
        return len(self.compartments)

    @property
    def n_params(self) -> int:
        return len(self.param_names)

    @property
    def n_transitions(self) -> int:
        return len(self.stoichiometry)

    @property
    def n_observed(self) -> int:
        return len(self.observed)

    @property
    def observed_idx(self) -> Tuple[int, ...]:
        return tuple(self.compartments.index(c) for c in self.observed)

    @property
    def transition_sources(self) -> Tuple[int, ...]:
        """Source compartment index of each transition (the -1 entry)."""
        return tuple(row.index(-1) for row in self.stoichiometry)

    # ------------------------------------------------------------------ misc
    def prior(self):
        """The model's uniform box prior U(lows, highs)."""
        from repro.core.priors import UniformBoxPrior

        return UniformBoxPrior(highs=self.prior_highs, lows=self.prior_lows)

    def describe(self) -> str:
        lines = [
            f"model {self.name}: {self.n_state} compartments "
            f"({', '.join(self.compartments)}), {self.n_params} params, "
            f"{self.n_transitions} transitions, observed ({', '.join(self.observed)})"
        ]
        for row, src in zip(self.stoichiometry, self.transition_sources):
            dst = row.index(1)
            lines.append(f"  {self.compartments[src]} -> {self.compartments[dst]}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class EpiModelConfig:
    """Static simulation configuration (shared across all registry models)."""

    population: float  # P — total population at day 0
    num_days: int  # T — simulation horizon (paper uses 49 for fitting)
    # initial observed values (A0, R0, D0) at day 0; the spec's initial-state
    # rule decides how they seed the compartments
    a0: float = 100.0
    r0: float = 0.0
    d0: float = 0.0
