"""Declarative specification of a stochastic compartmental model.

A `CompartmentalModel` is the single source of truth every layer consumes:

  * the reference tau-leap engine (`repro.epi.engine`) — pure jax.numpy,
  * the fused Pallas kernel (`repro.kernels.abc_sim`) — the spec's hazards and
    stoichiometry are inlined into the kernel body at trace time,
  * the ABC/SMC drivers (`repro.core.abc`, `repro.core.smc`) — prior bounds,
    parameter names and output shapes all derive from the spec,
  * datasets (`repro.epi.data`) — synthetic ground truth is simulated from
    `default_theta` with the spec's own dynamics.

The spec is declarative: compartments and parameters are *names*, transitions
are a stoichiometry matrix plus a hazard function, and the initial state is a
rule mapping parameters to compartment counts. Dynamics follow the paper's
tau-leap scheme (§2.1, steps 2-4) generically:

    h   = hazard_rows(state, theta)              one rate per transition
    n_k = floor(Normal(h_k, sqrt(h_k)))          Gaussian tau-leap counts
    n_k = clip(n_k, 0, remaining[source_k])      sequential source draining
    x'  = x + stoichiometry^T @ n                apply transitions

Sequential source draining means transitions are clamped in declaration
order, each one reducing the budget of its source compartment, so no
compartment ever goes negative and total mass is conserved exactly — the
same clamping contract the paper's IPU implementation applies (its cycle
table shows `Clamp` compute sets).

Layout contract for `hazard_rows` / `initial_rows`: they receive the state
and parameters as *sequences of channel arrays* (one array per compartment /
parameter) rather than stacked tensors. The same function body therefore
runs unchanged in the reference engine (channels are slices of a [..., n]
tensor) and inside the Pallas kernel (channels are (1, TILE) VREG rows).

Known limitation: the seeding interface is the paper's three scalars
(a0, r0, d0) — `initial_rows` receives exactly those, and the kernel's
constant layout reserves the same three slots. Models are free to
reinterpret them (SIR/SEIR treat a0 as a generic day-0 case count), but a
model needing MORE day-0 inputs requires widening `InitialFn`, the fconsts
layout in kernels/abc_sim.py and `CountryData` together.

Spatial metapopulation models: a spec may declare `n_regions` (R) copies of
its compartments coupled through a row-stochastic `mobility` matrix. State,
transitions and observed channels then flatten region-major — channel
`r * n_state + c` is compartment c of region r — and every layer (engine,
fused scan, Pallas kernel, summaries, datasets) consumes that layout through
the `total_*` properties, with R=1 degenerating bit-identically to the flat
single-population layout. Hazards see coupling through the `coupled` field:
for each named compartment, the engine appends one EXTRA state row per
region holding the mobility-weighted mass sum_q mobility[r][q] * x_q, so a
metapop-aware `hazard_rows` receives n_state local rows followed by
len(coupled) coupled rows and stays row-level (the same body runs in the XLA
engine, where rows carry a trailing region axis, and in the Pallas kernel,
where regions unroll into separate VREG rows at trace time). Each region
holds population / R people; the dataset's (a0, r0, d0) day-0 counts seed
`seed_region` only, every other region starting fully susceptible.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Sequence, Tuple

Rows = Sequence  # sequence of same-shape arrays, one per channel

#: (state_rows, param_rows, population) -> one rate array per transition;
#: metapop-aware hazards additionally receive len(coupled) coupled-mass rows
#: appended to state_rows
HazardFn = Callable[[Rows, Rows, object], Tuple]
#: (param_rows, population, a0, r0, d0) -> one array per compartment
InitialFn = Callable[[Rows, object, object, object, object], Tuple]

#: tolerance for row-stochasticity of mobility rows (f32 inputs)
_ROW_SUM_TOL = 1e-5


def identity_mobility(n_regions: int) -> Tuple[Tuple[float, ...], ...]:
    """The zero-coupling matrix: every region keeps all of its own mass."""
    return tuple(
        tuple(1.0 if q == r else 0.0 for q in range(n_regions))
        for r in range(n_regions)
    )


def validate_mobility(mobility, n_regions: int) -> Tuple[Tuple[float, ...], ...]:
    """Normalize + validate a mobility matrix: [R][R], non-negative rows each
    summing to 1 (row-stochastic). Raises a loud ValueError otherwise."""
    rows = tuple(tuple(float(x) for x in row) for row in mobility)
    if len(rows) != n_regions or any(len(r) != n_regions for r in rows):
        raise ValueError(
            f"mobility must be a [{n_regions}][{n_regions}] matrix, got "
            f"shape ({len(rows)}, {tuple(len(r) for r in rows)})"
        )
    for r, row in enumerate(rows):
        if any(x < 0.0 for x in row):
            raise ValueError(
                f"mobility row {r} has negative entries: {row} — rows must "
                "be non-negative probabilities"
            )
        s = sum(row)
        if abs(s - 1.0) > _ROW_SUM_TOL:
            raise ValueError(
                f"mobility row {r} sums to {s!r}, not 1: mobility must be "
                "row-stochastic (each region's mass weights sum to 1)"
            )
    return rows


def make_mobility(spec: str, n_regions: int) -> Tuple[Tuple[float, ...], ...]:
    """Build a mobility matrix from the CLI grammar (--mobility):

      * "identity"     — no inter-region coupling (block-diagonal dynamics)
      * "uniform:EPS"  — each region keeps 1-EPS, spreads EPS evenly over
                         the other R-1 regions (fully-mixed gravity-free)
      * "ring:EPS"     — each region keeps 1-EPS, sends EPS/2 to each ring
                         neighbour (1-D lattice with wraparound)
    """
    kind, _, arg = spec.partition(":")
    if kind == "identity":
        if arg:
            raise ValueError(f"identity mobility takes no argument: {spec!r}")
        return identity_mobility(n_regions)
    if kind not in ("uniform", "ring"):
        raise ValueError(
            f"unknown mobility kind {spec!r}; grammar: identity | "
            "uniform:EPS | ring:EPS"
        )
    if not arg:
        raise ValueError(f"mobility {kind!r} needs a coupling strength: {spec!r}")
    eps = float(arg)
    if not 0.0 <= eps <= 1.0:
        raise ValueError(f"mobility coupling must be in [0, 1], got {eps}")
    if n_regions == 1:
        return identity_mobility(1)
    rows = []
    for r in range(n_regions):
        row = [0.0] * n_regions
        row[r] = 1.0 - eps
        if kind == "uniform":
            for q in range(n_regions):
                if q != r:
                    row[q] = eps / (n_regions - 1)
        else:  # ring
            if n_regions == 2:
                row[(r + 1) % 2] = eps
            else:
                row[(r - 1) % n_regions] += eps / 2.0
                row[(r + 1) % n_regions] += eps / 2.0
        rows.append(tuple(row))
    return validate_mobility(rows, n_regions)


@dataclasses.dataclass(frozen=True)
class CompartmentalModel:
    """Declarative spec of a stochastic compartmental epidemic model.

    Frozen and hashable (fields are tuples / callables), so a model can be a
    `static_argnames` entry of a jitted function — the Pallas kernel builder
    relies on this to specialize the kernel body per model.
    """

    name: str
    compartments: Tuple[str, ...]
    param_names: Tuple[str, ...]
    #: uniform-box prior upper bounds, one per parameter (lows default to 0)
    prior_highs: Tuple[float, ...]
    #: stoichiometry matrix [n_transitions][n_state]: each row moves one unit
    #: of mass out of exactly one source (-1) into one destination (+1)
    stoichiometry: Tuple[Tuple[int, ...], ...]
    #: names of observed compartments, compared against data [n_observed, T]
    observed: Tuple[str, ...]
    hazard_rows: HazardFn
    initial_rows: InitialFn
    #: plausible generating parameters — used for synthetic ground-truth data
    default_theta: Tuple[float, ...]
    prior_lows: Tuple[float, ...] | None = None
    doc: str = ""
    #: spatial metapopulation: number of coupled regions sharing these
    #: dynamics; R=1 (the default) is the flat single-population layout
    n_regions: int = 1
    #: row-stochastic [R][R] coupling matrix — mobility[r][q] weights region
    #: q's mass in region r's coupled rows. None defaults to the identity
    #: (zero coupling) whenever regions or coupled compartments are declared.
    mobility: Tuple[Tuple[float, ...], ...] | None = None
    #: compartments whose mobility-weighted mass rows are appended to the
    #: state rows seen by hazard_rows (in this order) — a metapop-aware
    #: hazard reads its force-of-infection mass from these instead of the
    #: local rows
    coupled: Tuple[str, ...] = ()
    #: region seeded with the dataset's (a0, r0, d0) day-0 counts; all other
    #: regions start fully susceptible at population / n_regions
    seed_region: int = 0

    def __post_init__(self):
        ns, np_, nt = len(self.compartments), len(self.param_names), len(self.stoichiometry)
        if len(self.prior_highs) != np_:
            raise ValueError(f"{self.name}: prior_highs must have {np_} entries")
        if self.prior_lows is not None and len(self.prior_lows) != np_:
            raise ValueError(f"{self.name}: prior_lows must have {np_} entries")
        if len(self.default_theta) != np_:
            raise ValueError(f"{self.name}: default_theta must have {np_} entries")
        for k, row in enumerate(self.stoichiometry):
            if len(row) != ns:
                raise ValueError(f"{self.name}: stoichiometry row {k} has wrong width")
            if sum(row) != 0:
                raise ValueError(
                    f"{self.name}: transition {k} does not conserve mass: {row}"
                )
            if sorted(row) != sorted((-1, 1) + (0,) * (ns - 2)):
                raise ValueError(
                    f"{self.name}: transition {k} must move one unit from one "
                    f"source to one destination, got {row}"
                )
        for name in self.observed:
            if name not in self.compartments:
                raise ValueError(f"{self.name}: observed {name!r} is not a compartment")
        if nt > 8:
            # the counter-based RNG reserves 8 counter slots per day PER
            # REGION at R=1 (kernels/rng.day_transition_ctr); metapop models
            # widen the per-day stride via `ctr_slots`, but the per-region
            # transition count stays capped
            raise ValueError(f"{self.name}: at most 8 transitions supported, got {nt}")
        # ---- spatial metapopulation fields ----
        if not isinstance(self.n_regions, int) or self.n_regions < 1:
            raise ValueError(
                f"{self.name}: n_regions must be a positive int, got "
                f"{self.n_regions!r}"
            )
        object.__setattr__(self, "coupled", tuple(self.coupled))
        for name in self.coupled:
            if name not in self.compartments:
                raise ValueError(
                    f"{self.name}: coupled {name!r} is not a compartment"
                )
        if not 0 <= self.seed_region < self.n_regions:
            raise ValueError(
                f"{self.name}: seed_region {self.seed_region} out of range "
                f"for {self.n_regions} regions"
            )
        if self.mobility is None:
            if self.coupled or self.n_regions > 1:
                object.__setattr__(
                    self, "mobility", identity_mobility(self.n_regions)
                )
        else:
            object.__setattr__(
                self, "mobility", validate_mobility(self.mobility, self.n_regions)
            )

    # ------------------------------------------------------------ dimensions
    @property
    def n_state(self) -> int:
        return len(self.compartments)

    @property
    def n_params(self) -> int:
        return len(self.param_names)

    @property
    def n_transitions(self) -> int:
        return len(self.stoichiometry)

    @property
    def n_observed(self) -> int:
        return len(self.observed)

    @property
    def observed_idx(self) -> Tuple[int, ...]:
        return tuple(self.compartments.index(c) for c in self.observed)

    @property
    def transition_sources(self) -> Tuple[int, ...]:
        """Source compartment index of each transition (the -1 entry)."""
        return tuple(row.index(-1) for row in self.stoichiometry)

    # ------------------------------------------------- region-major totals
    # The flattened metapop layout: channel r * n_state + c is compartment c
    # of region r; transitions and observed channels flatten the same way.
    # At R=1 every total_* equals its per-region counterpart, so generic
    # layers index with these unconditionally.
    @property
    def total_state(self) -> int:
        return self.n_regions * self.n_state

    @property
    def total_transitions(self) -> int:
        return self.n_regions * self.n_transitions

    @property
    def total_observed(self) -> int:
        return self.n_regions * self.n_observed

    @property
    def total_observed_idx(self) -> Tuple[int, ...]:
        """Observed channel indices into the region-major flattened state."""
        local = self.observed_idx
        return tuple(
            r * self.n_state + c for r in range(self.n_regions) for c in local
        )

    @property
    def observed_labels(self) -> Tuple[str, ...]:
        """Per-channel labels of the flattened observed layout (dataset
        rows): the plain compartment names at R=1, `C@rN` per region else."""
        if self.n_regions == 1:
            return self.observed
        return tuple(
            f"{c}@r{r}" for r in range(self.n_regions) for c in self.observed
        )

    @property
    def coupled_idx(self) -> Tuple[int, ...]:
        return tuple(self.compartments.index(c) for c in self.coupled)

    @property
    def is_regional(self) -> bool:
        """True when the spec leaves the flat R=1 uncoupled layout — the
        engine/kernel then take the generalized region paths."""
        return self.n_regions > 1 or bool(self.coupled)

    @property
    def ctr_slots(self) -> int:
        """Per-day counter stride of the hash RNG: 8 at R=1 (the legacy
        layout, bit-identity-critical), widened in sublane-sized steps for
        metapop models whose total transition count exceeds it."""
        return max(8, -(-self.total_transitions // 8) * 8)

    # ------------------------------------------------------------------ misc
    def prior(self):
        """The model's uniform box prior U(lows, highs)."""
        from repro.core.priors import UniformBoxPrior

        return UniformBoxPrior(highs=self.prior_highs, lows=self.prior_lows)

    def describe(self) -> str:
        lines = [
            f"model {self.name}: {self.n_state} compartments "
            f"({', '.join(self.compartments)}), {self.n_params} params, "
            f"{self.n_transitions} transitions, observed ({', '.join(self.observed)})"
        ]
        if self.n_regions > 1:
            lines[0] += f", {self.n_regions} regions"
        for row, src in zip(self.stoichiometry, self.transition_sources):
            dst = row.index(1)
            lines.append(f"  {self.compartments[src]} -> {self.compartments[dst]}")
        if self.coupled:
            lines.append(f"  coupled mass rows: {', '.join(self.coupled)}")
        return "\n".join(lines)


def regionalize(
    model: CompartmentalModel,
    n_regions: int,
    mobility=None,
    name: str | None = None,
    seed_region: int = 0,
) -> CompartmentalModel:
    """A spatial variant of `model` with R regions coupled by `mobility`.

    `mobility` is a matrix, a `make_mobility` grammar string ("ring:0.1") or
    None (identity). The per-region dynamics are unchanged; only metapop-
    aware models (non-empty `coupled`) actually exchange mass — regionalizing
    an uncoupled model yields R independent copies, useful for scaling
    studies. Validation (row-stochasticity, shape) happens in the spec's
    __post_init__ and fails loudly.
    """
    if isinstance(mobility, str):
        mobility = make_mobility(mobility, n_regions)
    return dataclasses.replace(
        model,
        name=name or (model.name if n_regions == model.n_regions
                      else f"{model.name}_r{n_regions}"),
        n_regions=n_regions,
        mobility=mobility,
        seed_region=seed_region,
    )


class ScheduleShape(NamedTuple):
    """The compile-relevant part of an intervention schedule.

    Two schedules with the same shape — same window count, same set of scaled
    parameters — compile to the same kernel / wave loop: the breakpoint DAYS
    and the per-window SCALES are runtime values (traced scalars / extra theta
    columns), not constants. Campaigns rely on this to sweep lockdown-day x
    scale grids with one compilation.
    """

    n_windows: int
    tv_indices: Tuple[int, ...]  # positions of the scaled params in param_names

    @property
    def n_tv(self) -> int:
        return len(self.tv_indices)

    @property
    def n_scales(self) -> int:
        return self.n_windows * self.n_tv


@dataclasses.dataclass(frozen=True)
class InterventionSchedule:
    """Piecewise-constant time-varying scaling of selected hazard parameters.

    Models policy changes (lockdowns, reopenings) as per-window multiplicative
    scales on a subset of the model's parameters. Day d falls in window
    `w = #{i : d >= breakpoints[i]}`: window 0 (before the first breakpoint)
    always uses the base parameters unscaled; window w >= 1 multiplies each
    parameter named in `tv_params` by that window's scale factor.

    The scales are ordinary inference parameters: theta widens from
    [n_params] to [n_params + n_windows * n_tv], laid out as the base
    parameters followed by window-major scale blocks
    (w1: tv_0..tv_{n_tv-1}, w2: ..., ...). Each scale gets a uniform box
    prior [scale_lows[w][j], scale_highs[w][j]]; a zero-width box
    (low == high) pins the scale to a known value — that is how fixed
    counterfactual scenarios ("alpha drops to 0.3 on day 20") are expressed
    without a separate code path.

    Frozen and hashable, so a schedule can ride along static jit arguments
    (the Pallas kernel builder keys on `shape(model)` only, see ScheduleShape).
    """

    #: names of the scaled ("time-varying") parameters, subset of param_names
    tv_params: Tuple[str, ...]
    #: strictly increasing, positive day indices; window i+1 starts at day
    #: breakpoints[i]. n_windows == len(breakpoints).
    breakpoints: Tuple[int, ...]
    #: per-window scale prior bounds, [n_windows][n_tv]
    scale_lows: Tuple[Tuple[float, ...], ...]
    scale_highs: Tuple[Tuple[float, ...], ...]

    def __post_init__(self):
        object.__setattr__(self, "tv_params", tuple(self.tv_params))
        object.__setattr__(
            self, "breakpoints", tuple(int(b) for b in self.breakpoints)
        )
        object.__setattr__(
            self,
            "scale_lows",
            tuple(tuple(float(x) for x in row) for row in self.scale_lows),
        )
        object.__setattr__(
            self,
            "scale_highs",
            tuple(tuple(float(x) for x in row) for row in self.scale_highs),
        )
        nw, nt = len(self.breakpoints), len(self.tv_params)
        if nw and not nt:
            raise ValueError("schedule has breakpoints but no tv_params")
        if nt and not nw:
            raise ValueError("schedule has tv_params but no breakpoints")
        if any(b <= 0 for b in self.breakpoints):
            raise ValueError(f"breakpoints must be positive days: {self.breakpoints}")
        if any(
            b2 <= b1 for b1, b2 in zip(self.breakpoints, self.breakpoints[1:])
        ):
            raise ValueError(
                f"breakpoints must be strictly increasing: {self.breakpoints}"
            )
        if len(self.scale_lows) != nw or len(self.scale_highs) != nw:
            raise ValueError(f"need {nw} scale bound rows, one per window")
        for lo_row, hi_row in zip(self.scale_lows, self.scale_highs):
            if len(lo_row) != nt or len(hi_row) != nt:
                raise ValueError(f"each scale bound row must have {nt} entries")
            if any(h < l for l, h in zip(lo_row, hi_row)):
                raise ValueError("scale_highs must be >= scale_lows")
        if nw > 16:
            # the kernel packs breakpoints into iconst lanes 1..n_windows;
            # 16 is far beyond any realistic policy timeline
            raise ValueError(f"at most 16 intervention windows supported, got {nw}")

    # ------------------------------------------------------------ constructors
    @staticmethod
    def fixed(tv_params, breakpoints, scales) -> "InterventionSchedule":
        """Known (counterfactual) scales: `scales` is [n_windows][n_tv], or a
        flat [n_windows] sequence when there is a single tv param."""
        rows = tuple(
            (float(s),) if not isinstance(s, (tuple, list)) else tuple(s)
            for s in scales
        )
        return InterventionSchedule(
            tv_params=tuple(tv_params),
            breakpoints=tuple(breakpoints),
            scale_lows=rows,
            scale_highs=rows,
        )

    @staticmethod
    def inferred(
        tv_params, breakpoints, low: float = 0.0, high: float = 2.0
    ) -> "InterventionSchedule":
        """Unknown scales, inferred by ABC under U(low, high) per window."""
        nt = len(tuple(tv_params))
        return InterventionSchedule(
            tv_params=tuple(tv_params),
            breakpoints=tuple(breakpoints),
            scale_lows=tuple((float(low),) * nt for _ in breakpoints),
            scale_highs=tuple((float(high),) * nt for _ in breakpoints),
        )

    # ------------------------------------------------------------- dimensions
    @property
    def n_windows(self) -> int:
        return len(self.breakpoints)

    @property
    def n_tv(self) -> int:
        return len(self.tv_params)

    @property
    def n_scales(self) -> int:
        return self.n_windows * self.n_tv

    @property
    def is_empty(self) -> bool:
        return self.n_windows == 0

    def shape(self, model: CompartmentalModel) -> ScheduleShape:
        """Static (compile-key) part; validates tv_params against the model."""
        idx = []
        for name in self.tv_params:
            if name not in model.param_names:
                raise ValueError(
                    f"schedule scales {name!r}, which is not a parameter of "
                    f"model {model.name!r} ({model.param_names})"
                )
            idx.append(model.param_names.index(name))
        return ScheduleShape(n_windows=self.n_windows, tv_indices=tuple(idx))

    def param_width(self, model: CompartmentalModel) -> int:
        return model.n_params + self.n_scales

    def scale_param_names(self) -> Tuple[str, ...]:
        """Names of the widened theta columns, window-major: alpha_w1, ..."""
        return tuple(
            f"{p}_w{w + 1}"
            for w in range(self.n_windows)
            for p in self.tv_params
        )

    def param_names(self, model: CompartmentalModel) -> Tuple[str, ...]:
        return model.param_names + self.scale_param_names()

    def fixed_scales(self) -> Tuple[Tuple[float, ...], ...]:
        """The pinned scale values; raises if any window's scales are inferred."""
        for lo_row, hi_row in zip(self.scale_lows, self.scale_highs):
            if any(h > l for l, h in zip(lo_row, hi_row)):
                raise ValueError(
                    "schedule has inferred (non-degenerate) scale priors; "
                    "fixed_scales() needs every low == high"
                )
        return self.scale_lows

    def tag(self) -> str:
        """Compact filesystem-safe label for scenario/checkpoint names."""
        if self.is_empty:
            return "none"
        wins = []
        for w, b in enumerate(self.breakpoints):
            parts = []
            for l, h in zip(self.scale_lows[w], self.scale_highs[w]):
                parts.append(f"{l:g}" if l == h else f"{l:g}to{h:g}")
            wins.append(f"d{b}s" + "+".join(parts))
        return "iv_" + "+".join(self.tv_params) + "_" + "_".join(wins)


#: the canonical no-op schedule — simulating under it is bit-identical to
#: passing schedule=None (pinned by tests/test_interventions.py)
EMPTY_SCHEDULE = InterventionSchedule(
    tv_params=(), breakpoints=(), scale_lows=(), scale_highs=()
)


@dataclasses.dataclass(frozen=True)
class EpiModelConfig:
    """Static simulation configuration (shared across all registry models)."""

    population: float  # P — total population at day 0
    num_days: int  # T — simulation horizon (paper uses 49 for fitting)
    # initial observed values (A0, R0, D0) at day 0; the spec's initial-state
    # rule decides how they seed the compartments
    a0: float = 100.0
    r0: float = 0.0
    d0: float = 0.0
