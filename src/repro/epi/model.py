"""The stochastic COVID-19 compartmental model of the paper (§2.1).

This module is the backwards-compatible facade for the paper's 6-compartment
SIARD model. Since the stoichiometry-driven refactor the actual spec lives in
`repro.epi.models.siard` and the dynamics in the generic tau-leap engine
(`repro.epi.engine`); every function here simply binds that engine to the
paper spec. Equivalence with the original hand-unrolled implementation is
bit-for-bit (pinned by tests/test_model_registry.py).

Numerical notes (recorded in DESIGN.md §5):
  * The paper says "variance sqrt(h)"; a Poisson has variance h (std sqrt(h)).
    We use std = sqrt(h), matching the Poisson moments — and matching the
    reference implementation the paper builds on (Warne et al.).
  * Transition counts are clamped to [0, available source], draining sources
    sequentially (A->R before A->D, I->A before I->Ru). The paper's IPU cycle
    table shows `Clamp` compute sets, confirming the original does this too.
  * Everything is float32, as in all the paper's experiments.

This is the *paper-faithful reference path* (pure jax.numpy +
jax.random.normal, lax.scan over days). The performance path is the fused
Pallas kernel in `repro.kernels.abc_sim` (same math, in-kernel RNG), which
consumes the same spec.
"""

from __future__ import annotations

from typing import Tuple

import jax

from repro.epi import engine
from repro.epi.models.siard import MODEL as PAPER_MODEL
from repro.epi.models.siard import infection_rate  # noqa: F401  (re-export)
from repro.epi.spec import EpiModelConfig  # noqa: F401  (re-export)

N_PARAMS = PAPER_MODEL.n_params
N_STATE = PAPER_MODEL.n_state
N_TRANSITIONS = PAPER_MODEL.n_transitions
N_OBSERVED = PAPER_MODEL.n_observed  # (A, R, D) — indices 2, 3, 4

PARAM_NAMES = PAPER_MODEL.param_names
STATE_NAMES = PAPER_MODEL.compartments

#: Uniform-prior upper bounds, eq. (2) of the paper.
PRIOR_HIGHS = PAPER_MODEL.prior_highs

OBSERVED_IDX = PAPER_MODEL.observed_idx


def hazards(state: jax.Array, theta: jax.Array, population: float) -> jax.Array:
    """Hazard vector h, eq. (5). state: [..., 6], theta: [..., 8] -> [..., 5]."""
    return engine.hazards(PAPER_MODEL, state, theta, population)


def initial_state(theta: jax.Array, cfg: EpiModelConfig) -> jax.Array:
    """Paper step 1: Ru = 0, I0 = kappa * A0, S = P - (A0 + R0 + D0 + I0)."""
    return engine.initial_state(PAPER_MODEL, theta, cfg)


def tau_leap_step(
    state: jax.Array, theta: jax.Array, noise: jax.Array, population: float
) -> jax.Array:
    """One day of tau-leaping given standard-normal noise [..., 5]."""
    return engine.tau_leap_step(PAPER_MODEL, state, theta, noise, population)


def simulate(theta: jax.Array, key: jax.Array, cfg: EpiModelConfig) -> jax.Array:
    """Simulate the full state trajectory. theta: [B, 8] -> [B, T, 6]."""
    return engine.simulate(PAPER_MODEL, theta, key, cfg)


def simulate_observed(
    theta: jax.Array, key: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Simulate only the observed channels. Returns [B, 3, T] = (A, R, D)."""
    return engine.simulate_observed(PAPER_MODEL, theta, key, cfg)


def simulate_observed_lowmem(
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    observed: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Fused simulate + running squared-distance accumulation (no [B,3,T])."""
    return engine.simulate_observed_lowmem(PAPER_MODEL, theta, key, cfg, observed)
