"""The stochastic COVID-19 compartmental model of the paper (§2.1).

Six sub-populations X = [S, I, A, R, D, Ru]:
  S  — Susceptible
  I  — undocumented Infected                (latent)
  A  — Active confirmed cases              (observed)
  R  — confirmed Recoveries                (observed)
  D  — confirmed fatalities                (observed)
  Ru — unconfirmed Removed                 (latent)

Eight parameters theta = [alpha0, alpha, n, beta, gamma, delta, eta, kappa]
with the paper's uniform prior U(0, [1, 100, 2, 1, 1, 1, 1, 2])  (eq. 2).

Dynamics (tau-leaping, one day per step; paper steps 2-4):
  g  = alpha0 + alpha / (1 + (A + R + D)^n)                       (eq. 4)
  h  = (g*S*I/P,  gamma*I,  beta*A,  delta*A,  beta*eta*I)        (eq. 5)
  n_i = floor(Normal(mean=h_i, std=sqrt(h_i)))   -- Gaussian tau-leap approx
  transitions applied in order  S->I, I->A, A->R, A->D, I->Ru.

Numerical notes (recorded in DESIGN.md §5):
  * The paper says "variance sqrt(h)"; a Poisson has variance h (std sqrt(h)).
    We use std = sqrt(h), matching the Poisson moments — and matching the
    reference implementation the paper builds on (Warne et al.).
  * Transition counts are clamped to [0, available source], draining sources
    sequentially (A->R before A->D, I->A before I->Ru). The paper's IPU cycle
    table shows `Clamp` compute sets, confirming the original does this too.
  * Everything is float32, as in all the paper's experiments.

This module is the *paper-faithful reference path* (pure jax.numpy +
jax.random.normal, lax.scan over days). The performance path is the fused
Pallas kernel in `repro.kernels.abc_sim` (same math, in-kernel RNG).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

N_PARAMS = 8
N_STATE = 6
N_TRANSITIONS = 5
N_OBSERVED = 3  # (A, R, D) — indices 2, 3, 4 of the state vector

PARAM_NAMES = ("alpha0", "alpha", "n", "beta", "gamma", "delta", "eta", "kappa")
STATE_NAMES = ("S", "I", "A", "R", "D", "Ru")

#: Uniform-prior upper bounds, eq. (2) of the paper.
PRIOR_HIGHS = (1.0, 100.0, 2.0, 1.0, 1.0, 1.0, 1.0, 2.0)

OBSERVED_IDX = (2, 3, 4)


@dataclasses.dataclass(frozen=True)
class EpiModelConfig:
    """Static simulation configuration."""

    population: float  # P — total population at day 0
    num_days: int  # T — simulation horizon (paper uses 49 for fitting)
    # initial observed values (A0, R0, D0) at day 0
    a0: float = 100.0
    r0: float = 0.0
    d0: float = 0.0


def infection_rate(theta: jax.Array, ard_sum: jax.Array) -> jax.Array:
    """Total infection rate g_(A,R,D) = alpha0 + alpha / (1 + (A+R+D)^n), eq. (4).

    theta: [..., 8]; ard_sum: [...] — broadcastable batch shapes.
    """
    alpha0, alpha, n = theta[..., 0], theta[..., 1], theta[..., 2]
    # (A+R+D) >= 0 always; power of a non-negative base is safe.
    return alpha0 + alpha / (1.0 + jnp.power(jnp.maximum(ard_sum, 0.0), n))


def hazards(state: jax.Array, theta: jax.Array, population: float) -> jax.Array:
    """Hazard vector h, eq. (5). state: [..., 6], theta: [..., 8] -> [..., 5]."""
    s, i, a = state[..., 0], state[..., 1], state[..., 2]
    ard = state[..., 2] + state[..., 3] + state[..., 4]
    g = infection_rate(theta, ard)
    beta, gamma, delta, eta = theta[..., 3], theta[..., 4], theta[..., 5], theta[..., 6]
    h = jnp.stack(
        [
            g * s * i / population,  # S -> I
            gamma * i,  # I -> A
            beta * a,  # A -> R
            delta * a,  # A -> D
            beta * eta * i,  # I -> Ru
        ],
        axis=-1,
    )
    # Hazards are rates of counting processes; they cannot be negative.
    return jnp.maximum(h, 0.0)


def initial_state(theta: jax.Array, cfg: EpiModelConfig) -> jax.Array:
    """Paper step 1: Ru = 0, I0 = kappa * A0, S = P - (A0 + R0 + D0 + I0).

    theta: [..., 8] -> state [..., 6].
    """
    kappa = theta[..., 7]
    a0 = jnp.asarray(cfg.a0, jnp.float32)
    r0 = jnp.asarray(cfg.r0, jnp.float32)
    d0 = jnp.asarray(cfg.d0, jnp.float32)
    i0 = kappa * a0
    s0 = cfg.population - (a0 + r0 + d0 + i0)
    zeros = jnp.zeros_like(kappa)
    return jnp.stack(
        [s0, i0, zeros + a0, zeros + r0, zeros + d0, zeros], axis=-1
    ).astype(jnp.float32)


def _apply_transitions(state: jax.Array, n_raw: jax.Array) -> jax.Array:
    """Clamp raw transition counts to available sources and apply them.

    state: [..., 6], n_raw: [..., 5] (already floor(Normal(h, sqrt h))).
    Returns the next-day state, guaranteed non-negative, conserving total mass.
    """
    s, i, a, r, d, ru = (state[..., k] for k in range(N_STATE))
    n1 = jnp.clip(n_raw[..., 0], 0.0, s)  # S -> I
    n2 = jnp.clip(n_raw[..., 1], 0.0, i)  # I -> A
    n5 = jnp.clip(n_raw[..., 4], 0.0, i - n2)  # I -> Ru (I drained by n2 first)
    n3 = jnp.clip(n_raw[..., 2], 0.0, a)  # A -> R
    n4 = jnp.clip(n_raw[..., 3], 0.0, a - n3)  # A -> D (A drained by n3 first)
    return jnp.stack(
        [
            s - n1,
            i + n1 - n2 - n5,
            a + n2 - n3 - n4,
            r + n3,
            d + n4,
            ru + n5,
        ],
        axis=-1,
    )


def tau_leap_step(
    state: jax.Array, theta: jax.Array, noise: jax.Array, population: float
) -> jax.Array:
    """One day of tau-leaping given standard-normal noise [..., 5].

    n_i = floor(h_i + sqrt(h_i) * z_i), clamped to sources (paper steps 2-4).
    """
    h = hazards(state, theta, population)
    n_raw = jnp.floor(h + jnp.sqrt(h) * noise)
    return _apply_transitions(state, n_raw)


def simulate(
    theta: jax.Array, key: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Simulate the full state trajectory.

    theta: [B, 8]; returns trajectory [B, T, 6] (state *after* each day's update).
    Noise is drawn with jax.random (threefry) — the paper-faithful path.
    """
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    state0 = initial_state(theta, cfg)

    def step(state, day):
        # Per-day fold_in keeps this bit-identical with the fused low-memory
        # path (simulate_observed_lowmem) for the same key.
        z = jax.random.normal(
            jax.random.fold_in(key, day), batch_shape + (N_TRANSITIONS,), jnp.float32
        )
        nxt = tau_leap_step(state, theta, z, cfg.population)
        return nxt, nxt

    _, traj = jax.lax.scan(step, state0, jnp.arange(cfg.num_days))
    # traj: [T, B, 6] -> [B, T, 6]
    return jnp.moveaxis(traj, 0, -2)


def simulate_observed(
    theta: jax.Array, key: jax.Array, cfg: EpiModelConfig
) -> jax.Array:
    """Simulate only the observed channels. Returns [B, 3, T] = (A, R, D) per day.

    Matches the paper's D_s layout [batch, 3, num_days].
    """
    traj = simulate(theta, key, cfg)  # [B, T, 6]
    obs = traj[..., OBSERVED_IDX]  # [B, T, 3]
    return jnp.swapaxes(obs, -1, -2)  # [B, 3, T]


def simulate_observed_lowmem(
    theta: jax.Array,
    key: jax.Array,
    cfg: EpiModelConfig,
    observed: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Fused simulate + running squared-distance accumulation (no [B,3,T] output).

    The beyond-paper memory optimization (DESIGN.md §2): never materialize the
    trajectory; accumulate sum-of-squares against `observed` [3, T] per day.
    Returns (distance [B], final_state [B, 6]).

    This is the pure-XLA analogue of the Pallas kernel; the kernel additionally
    keeps the whole loop in VMEM.
    """
    theta = jnp.asarray(theta, jnp.float32)
    batch_shape = theta.shape[:-1]
    state0 = initial_state(theta, cfg)
    # derive from state0 so the carry inherits its varying mesh axes when this
    # runs inside shard_map (scan carries must have uniform vma types)
    acc0 = state0[..., 0] * 0.0
    obs_by_day = jnp.swapaxes(jnp.asarray(observed, jnp.float32), 0, 1)  # [T, 3]

    def step(carry, inp):
        state, acc = carry
        day, obs_t = inp
        z = jax.random.normal(
            jax.random.fold_in(key, day), batch_shape + (N_TRANSITIONS,), jnp.float32
        )
        nxt = tau_leap_step(state, theta, z, cfg.population)
        diff = nxt[..., OBSERVED_IDX] - obs_t
        acc = acc + jnp.sum(diff * diff, axis=-1)
        return (nxt, acc), None

    days = jnp.arange(cfg.num_days)
    (state_f, acc_f), _ = jax.lax.scan(step, (state0, acc0), (days, obs_by_day))
    return jnp.sqrt(acc_f), state_f
