"""Stochastic compartmental epidemiology substrate (Warne et al. 2020 / paper §2.1).

The package is organized around declarative model specs:

  * `repro.epi.spec`    — `CompartmentalModel` + `EpiModelConfig`
  * `repro.epi.engine`  — generic stoichiometry-driven tau-leap engine
  * `repro.epi.models`  — registry (siard — the paper model —, sir, seir, seiard)
  * `repro.epi.model`   — backwards-compatible facade for the paper model
  * `repro.epi.data`    — datasets (model-aware synthetic + bundled series)
"""

from repro.epi.model import (
    EpiModelConfig,
    N_PARAMS,
    N_STATE,
    PARAM_NAMES,
    PRIOR_HIGHS,
    hazards,
    initial_state,
    simulate,
    simulate_observed,
    tau_leap_step,
)
from repro.epi.models import (
    CompartmentalModel,
    DEFAULT_MODEL,
    get_model,
    list_models,
    register,
)
from repro.epi.data import CountryData, get_dataset, list_datasets, synthetic_dataset
