"""Stochastic compartmental epidemiology model substrate (Warne et al. 2020 / paper §2.1)."""

from repro.epi.model import (
    EpiModelConfig,
    N_PARAMS,
    N_STATE,
    PARAM_NAMES,
    PRIOR_HIGHS,
    hazards,
    initial_state,
    simulate,
    simulate_observed,
    tau_leap_step,
)
from repro.epi.data import CountryData, get_dataset, list_datasets, synthetic_dataset
