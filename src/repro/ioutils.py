"""The ONE atomic-artifact-write helper (repo contract, enforced by lint).

Three PRs in a row shipped fixes for the same bug class: an artifact
(posterior .npz, tuning cache, campaign report) written with a bare
``np.savez``/``json.dump``/``open(path, "w")`` that an interrupted process
leaves truncated at its final path — and every fix re-implemented the same
tmp + fsync + rename dance locally. This module factors that dance out of
``core/posterior.py``, ``core/tuning.py`` and ``checkpoint/checkpointer.py``
into one helper, and ``repro.analysis`` lints the rest of the tree so a new
bare write cannot land (rule ``non-atomic-artifact-write``).

Contract: within ``atomic_write`` the file object points at a temp file in
the TARGET directory (same filesystem, so the final rename is atomic); on a
clean exit the data is flushed + fsynced and renamed over ``path`` in one
``os.replace``; on any error the temp file is removed and the previous
complete artifact, if any, survives untouched. Writing through a file
object also keeps the EXACT path given (a bare ``np.savez(path)`` silently
appends ".npz" when the suffix is missing, so ``load(path)`` would miss
``save(path)`` — the PR 7 ``Posterior.save`` bug).
"""

from __future__ import annotations

import contextlib
import os
import tempfile
from pathlib import Path
from typing import IO, Iterator


@contextlib.contextmanager
def atomic_write(path: str | os.PathLike, mode: str = "w") -> Iterator[IO]:
    """Context manager yielding a temp-file object committed to `path`.

    ``mode`` is "w" (text) or "wb" (binary). The parent directory is created
    if missing. Usage::

        with atomic_write(out, "wb") as f:
            np.savez(f, **arrays)
    """
    if mode not in ("w", "wb"):
        raise ValueError(f"atomic_write mode must be 'w' or 'wb', got {mode!r}")
    path = os.fspath(path)
    directory = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        prefix=os.path.basename(path) + ".", suffix=".tmp", dir=directory
    )
    try:
        with os.fdopen(fd, mode) as f:
            yield f
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic commit
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def atomic_write_text(path: str | os.PathLike, text: str) -> Path:
    """Atomically replace `path` with `text` (the JSON-artifact one-liner)."""
    with atomic_write(path, "w") as f:
        f.write(text)
    return Path(path)
