"""Fault-tolerant work scheduling for the embarrassingly parallel ABC layer.

The unit of work is a (base_seed, chunk_id) pair: any worker can compute any
chunk deterministically, so the scheduler needs no data movement to recover
from failures — exactly the property the paper's scaling study relies on
(§4.5). This module provides the cluster-control logic that the paper's
TensorFlow implementation kept implicit:

  * ChunkLedger        — which chunks are done / in-flight / lost
  * WorkerPool         — worker health via heartbeats; failures re-enqueue
                         their in-flight chunks
  * straggler policy   — over-decomposition + speculative duplicates of the
                         slowest tail (classic backup-task mitigation)

On this container workers are simulated actors driven by `tick()`; on a real
pod the same ledger runs in the coordinator with heartbeats over RPC. The
logic is pure-python and fully unit-tested (tests/test_runtime.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class ChunkLedger:
    """Tracks chunk lifecycle. Chunks are ints 0..n-1."""

    n_chunks: int
    done: Set[int] = dataclasses.field(default_factory=set)
    in_flight: Dict[int, Set[str]] = dataclasses.field(default_factory=dict)
    pending: List[int] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.pending and not self.done:
            self.pending = list(range(self.n_chunks))

    def next_chunk(self, worker: str, speculate: bool = False) -> Optional[int]:
        while self.pending:
            c = self.pending.pop(0)
            if c in self.done:
                continue
            self.in_flight.setdefault(c, set()).add(worker)
            return c
        if speculate:
            # speculative duplicate of an in-flight chunk (straggler backup)
            for c, owners in self.in_flight.items():
                if c not in self.done and worker not in owners and len(owners) == 1:
                    owners.add(worker)
                    return c
        return None

    def complete(self, chunk: int) -> bool:
        """Returns True if this completion was the FIRST for the chunk."""
        first = chunk not in self.done
        self.done.add(chunk)
        self.in_flight.pop(chunk, None)
        return first

    def lose_worker(self, worker: str):
        """Re-enqueue chunks whose only owner died."""
        for c in list(self.in_flight):
            owners = self.in_flight[c]
            owners.discard(worker)
            if not owners and c not in self.done:
                del self.in_flight[c]
                self.pending.insert(0, c)

    @property
    def finished(self) -> bool:
        return len(self.done) >= self.n_chunks

    def to_state(self) -> dict:
        return {"n_chunks": self.n_chunks, "done": sorted(self.done)}

    @staticmethod
    def from_state(state: dict) -> "ChunkLedger":
        led = ChunkLedger(n_chunks=state["n_chunks"])
        led.done = set(state["done"])
        led.pending = [c for c in range(led.n_chunks) if c not in led.done]
        return led


@dataclasses.dataclass
class WorkerPool:
    """Heartbeat-based liveness. Workers that miss `timeout` ticks are
    declared dead and their chunks re-enqueued."""

    timeout: float = 3.0
    last_beat: Dict[str, float] = dataclasses.field(default_factory=dict)

    def heartbeat(self, worker: str, now: float):
        self.last_beat[worker] = now

    def dead_workers(self, now: float) -> List[str]:
        return [w for w, t in self.last_beat.items() if now - t > self.timeout]

    def remove(self, worker: str):
        self.last_beat.pop(worker, None)


class WorkScheduler:
    """Coordinator gluing ledger + pool + straggler policy.

    `speculate_after`: once pending is empty, workers receive speculative
    duplicates of in-flight chunks — the fastest completion wins, bounding
    the straggler tail at ~1 chunk latency instead of the slowest worker.
    """

    def __init__(self, n_chunks: int, timeout: float = 3.0, ledger=None):
        self.ledger = ledger or ChunkLedger(n_chunks)
        self.pool = WorkerPool(timeout=timeout)
        self.duplicates_issued = 0
        self.wasted_completions = 0

    def request_work(self, worker: str, now: float) -> Optional[int]:
        self.pool.heartbeat(worker, now)
        self._reap(now)
        chunk = self.ledger.next_chunk(worker, speculate=False)
        if chunk is None and not self.ledger.finished:
            chunk = self.ledger.next_chunk(worker, speculate=True)
            if chunk is not None:
                self.duplicates_issued += 1
        return chunk

    def report_done(self, worker: str, chunk: int, now: float):
        self.pool.heartbeat(worker, now)
        if not self.ledger.complete(chunk):
            self.wasted_completions += 1

    def _reap(self, now: float):
        for w in self.pool.dead_workers(now):
            self.pool.remove(w)
            self.ledger.lose_worker(w)

    @property
    def finished(self) -> bool:
        return self.ledger.finished
