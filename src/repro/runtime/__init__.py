from repro.runtime.scheduler import ChunkLedger, WorkScheduler, WorkerPool
