"""Finding/report containers shared by both analysis passes.

Everything the passes emit funnels through one `Finding` shape so the CLI,
the CI gate (`tests/check_analysis.py`) and the nightly artifact all speak
the same `analysis-report/v1` JSON:

    {
      "schema": "analysis-report/v1",
      "passes": ["lint", "trace_audit"],
      "counts": {"total": N, "by_rule": {...}},
      "findings": [
        {"rule": ..., "path": ..., "line": ..., "context": ...,
         "message": ..., "key": "rule:path:context"},
        ...
      ]
    }

`key` is the identity a baseline entry matches on. It deliberately omits
the line number (stable across unrelated edits drifting a file's lines)
but keeps the enclosing context — a function name for lint findings, a
combo tag like `seir/xla_fused/weekly/mae/sched2` for audit findings — so
two distinct violations of one rule in one file stay distinct entries.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional

SCHEMA = "analysis-report/v1"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    rule: str  # registry name, e.g. "non-atomic-artifact-write"
    path: str  # repo-relative file ("-" for audit findings with no file)
    line: int  # 1-based line (0 when not applicable)
    context: str  # enclosing function / combo tag — part of the baseline key
    message: str  # human-readable detail

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.context}"

    def to_json(self) -> Dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "context": self.context,
            "message": self.message,
            "key": self.key,
        }

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"[{self.rule}] {loc} ({self.context}): {self.message}"


def make_report(
    findings: Iterable[Finding], passes: Iterable[str]
) -> Dict:
    """Assemble the analysis-report/v1 payload (pure, JSON-serializable)."""
    findings = list(findings)
    by_rule: Dict[str, int] = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "schema": SCHEMA,
        "passes": sorted(passes),
        "counts": {"total": len(findings), "by_rule": by_rule},
        "findings": [f.to_json() for f in sorted(
            findings, key=lambda f: (f.path, f.line, f.rule)
        )],
    }


def dump_report(report: Dict, path: str | Path) -> Path:
    from repro.ioutils import atomic_write_text

    return atomic_write_text(path, json.dumps(report, indent=1, sort_keys=True))


def load_baseline(path: Optional[str | Path]) -> set:
    """Baseline keys, one per line, '#' comments — check_new_failures style.

    A missing file means an empty baseline (zero allowed findings), NOT an
    error: the healthy steady state is no baseline entries at all.
    """
    if path is None or not Path(path).exists():
        return set()
    known = set()
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            known.add(line)
    return known


def evaluate(
    known: set, findings: List[Finding], *, log=print
) -> int:
    """Pure gate decision: findings + baseline keys -> exit code.

    Mirrors check_new_failures.evaluate: any finding whose key is not in the
    baseline fails; a baseline entry matching no finding is STALE and also
    fails (an already-fixed violation must not stay allowlisted where it
    could silently regress).
    """
    keys = {f.key for f in findings}
    new = [f for f in findings if f.key not in known]
    stale = known - keys
    rc = 0
    if new:
        log(f"[check_analysis] {len(new)} finding(s) beyond the baseline:")
        for f in sorted(new, key=lambda f: f.key):
            log(f"  {f}")
        rc = 1
    if stale:
        log("[check_analysis] STALE: baseline entries match no finding — "
            "delete them from the baseline file:")
        for k in sorted(stale):
            log(f"  {k}")
        rc = 1
    if rc == 0:
        log(f"[check_analysis] OK: {len(findings)} finding(s), all in the "
            f"baseline ({len(known)} entries)")
    return rc
