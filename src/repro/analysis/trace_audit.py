"""Jaxpr-level trace auditor over every registered ABC combination.

For each (model x backend x summary x distance x schedule-shape) combo this
pass abstractly traces the device-resident wave loop — `jax.make_jaxpr`
only, no XLA compile — and statically checks the contracts the campaign
runner and the paper's perf numbers rely on:

  shape-cache-retrace     two scenarios that the campaign `_ShapeCache`
                          maps to ONE key must present identical abstract
                          signatures (shape/dtype per leaf) to the jitted
                          loop; a mismatch means a silent recompile per
                          scenario. (pallas is the documented per-dataset
                          compile exception and is skipped.)
  f64-promotion           any convert_element_type to float64 (or any
                          float64 intermediate) in the loop — the whole
                          stack is f32 by contract; an f64 leak doubles
                          memory traffic and detunes the kernel.
  weak-type-leak          weakly-typed loop outputs: a Python-scalar
                          promotion escaping the loop re-specializes every
                          downstream consumer.
  host-transfer-under-jit callback/infeed/outfeed/debug primitives inside
                          the loop body — a hidden device->host round trip
                          per wave.
  non-donated-buffer      the wave runner's accept buffers (theta_buf,
                          dist_buf) must be donated to XLA, and no other
                          large input may go un-donated; checked on the
                          lowered MLIR of one representative runner per
                          (backend, schedule-shape).

All checks are static; the audit runs on CPU in seconds and never executes
a wave. The generic helpers (`audit_jaxpr`, `audit_shape_cache`,
`audit_donation`) are pure so the planted-violation tests can drive them
directly (tests/test_analysis_rules.py).
"""

from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Finding

AUDIT_RULES: Dict[str, str] = {
    "shape-cache-retrace": (
        "scenarios sharing a _ShapeCache key present different abstract "
        "signatures — the 'one compile per shape' contract is broken"
    ),
    "f64-promotion": (
        "float64 promotion inside a traced region (the stack is f32 by "
        "contract)"
    ),
    "weak-type-leak": (
        "weakly-typed output escapes a traced region and re-specializes "
        "downstream consumers"
    ),
    "host-transfer-under-jit": (
        "callback/infeed/outfeed/debug primitive inside a jitted region — "
        "a hidden device->host round trip per invocation"
    ),
    "non-donated-buffer": (
        "a buffer the wave-loop contract donates (or any large input) is "
        "not marked as donated in the lowered computation"
    ),
    "audit-trace-error": (
        "a registered combo failed to trace at all — it cannot compile "
        "either"
    ),
}

#: primitives that cross the device boundary from inside a trace
_HOST_PRIMS = {
    "pure_callback", "io_callback", "callback", "debug_callback",
    "debug_print", "infeed", "outfeed", "host_local_array_to_global_array",
}

#: donated-arg marker in jax 0.4.x StableHLO text
_DONATION_MARKER = "tf.aliasing_output"
_ARG_RE = re.compile(r"%arg(\d+):\s*tensor<([^>]*)>(\s*\{[^}]*\})?")


# ---------------------------------------------------------------------------
# generic, pure checkers (driven by run_audit AND the planted tests)
# ---------------------------------------------------------------------------

def _walk_jaxprs(jaxpr) -> Iterable:
    """Yield this jaxpr and every sub-jaxpr (scan/while/cond/pjit bodies)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                val, is_leaf=lambda x: isinstance(
                    x, (jax.core.Jaxpr, jax.core.ClosedJaxpr)
                )
            ):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    yield from _walk_jaxprs(sub.jaxpr)
                elif isinstance(sub, jax.core.Jaxpr):
                    yield from _walk_jaxprs(sub)


def audit_jaxpr(closed_jaxpr, context: str) -> List[Finding]:
    """f64 / weak-type / host-transfer checks on one traced computation."""
    findings: List[Finding] = []
    seen_rules = set()

    def emit(rule: str, message: str):
        # one finding per (rule, context): a single f64 leak fans out into
        # dozens of downstream f64 eqns — report the class once
        if rule in seen_rules:
            return
        seen_rules.add(rule)
        findings.append(Finding(
            rule=rule, path="-", line=0, context=context, message=message,
        ))

    jaxpr = closed_jaxpr.jaxpr if hasattr(closed_jaxpr, "jaxpr") else closed_jaxpr
    for sub in _walk_jaxprs(jaxpr):
        for eqn in sub.eqns:
            name = eqn.primitive.name
            if name in _HOST_PRIMS:
                emit(
                    "host-transfer-under-jit",
                    f"primitive {name!r} inside the traced region",
                )
            if name == "convert_element_type" and (
                eqn.params.get("new_dtype") == jnp.float64
            ):
                emit(
                    "f64-promotion",
                    "convert_element_type to float64 inside the traced "
                    "region",
                )
            for v in eqn.outvars:
                dtype = getattr(getattr(v, "aval", None), "dtype", None)
                if dtype == jnp.float64:
                    emit(
                        "f64-promotion",
                        f"primitive {name!r} produces a float64 intermediate",
                    )
    for v in jaxpr.outvars:
        aval = getattr(v, "aval", None)
        if getattr(aval, "weak_type", False):
            emit(
                "weak-type-leak",
                f"traced output {v} is weakly typed ({aval}) — a Python "
                "scalar promotion escapes the region",
            )
    return findings


def _signature(tree) -> List[Tuple]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sig = [(tuple(np.shape(x)), str(jnp.result_type(x))) for x in leaves]
    return [str(treedef)] + sig


def audit_shape_cache(variants: Sequence, context: str) -> List[Finding]:
    """Scenario variants meant to share ONE compile must present identical
    abstract signatures (pytree structure + per-leaf shape/dtype). Identical
    signatures guarantee jit-cache reuse; any mismatch is a silent
    per-scenario recompile."""
    findings: List[Finding] = []
    if not variants:
        return findings
    ref = _signature(variants[0])
    for i, v in enumerate(variants[1:], start=1):
        sig = _signature(v)
        if sig != ref:
            diff = [
                f"leaf {j}: {a} != {b}"
                for j, (a, b) in enumerate(zip(ref, sig)) if a != b
            ] or [f"tree arity {len(ref)} != {len(sig)}"]
            findings.append(Finding(
                rule="shape-cache-retrace", path="-", line=0,
                context=context,
                message=(
                    f"variant {i} changes the traced signature "
                    f"({'; '.join(diff[:3])}) — the wave loop recompiles "
                    "per scenario instead of once per shape"
                ),
            ))
    return findings


def audit_donation(
    lowered_text: str,
    context: str,
    expected_donated: Sequence[int] = (),
    large_threshold_bytes: int = 1 << 23,
) -> List[Finding]:
    """Check the lowered MLIR's entry signature for donation markers.

    `expected_donated` are flat argument indices that the calling contract
    donates (the wave runner's theta_buf/dist_buf); additionally any input
    of at least `large_threshold_bytes` must be donated or is flagged.
    """
    findings: List[Finding] = []
    header = lowered_text.split("func.func public @main", 1)
    if len(header) < 2:
        return [Finding(
            rule="non-donated-buffer", path="-", line=0, context=context,
            message="could not locate @main entry in lowered MLIR",
        )]
    sig = header[1].split("->", 1)[0]
    args: Dict[int, Tuple[int, bool]] = {}
    for m in _ARG_RE.finditer(sig):
        idx = int(m.group(1))
        shape_spec = m.group(2).split("x")
        nbytes, bits = 1, 32
        for part in shape_spec:
            if part.isdigit():
                nbytes *= int(part)
            elif part and part[0] in "fiu" and part[1:].isdigit():
                bits = int(part[1:])
        nbytes *= bits // 8
        donated = bool(m.group(3)) and _DONATION_MARKER in m.group(3)
        args[idx] = (nbytes, donated)
    for idx in expected_donated:
        if idx in args and not args[idx][1]:
            findings.append(Finding(
                rule="non-donated-buffer", path="-", line=0, context=context,
                message=(
                    f"arg {idx} is a wave-loop accept buffer the contract "
                    "donates (donate_argnums) but carries no "
                    f"{_DONATION_MARKER} marker — XLA double-buffers it"
                ),
            ))
    for idx, (nbytes, donated) in sorted(args.items()):
        if idx in expected_donated or donated:
            continue
        if nbytes >= large_threshold_bytes:
            findings.append(Finding(
                rule="non-donated-buffer", path="-", line=0, context=context,
                message=(
                    f"arg {idx} is {nbytes / 2**20:.1f} MiB and not donated "
                    "— consider donate_argnums if the caller discards it"
                ),
            ))
    return findings


# ---------------------------------------------------------------------------
# the registered-combination grid
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Combo:
    model: str
    backend: str
    summary: Optional[str]
    distance: str
    sched_shape: int  # number of intervention windows (0 = no schedule)
    #: regionalize the model to this R at audit time (1 = as registered;
    #: note metapop_seir is ALREADY 4-region as registered, so the model
    #: axis audits the regional path even at regions=1)
    regions: int = 1

    @property
    def tag(self) -> str:
        return (
            f"{self.model}/{self.backend}/{self.summary or 'identity'}/"
            f"{self.distance}/sched{self.sched_shape}"
            + (f"/r{self.regions}" if self.regions > 1 else "")
        )


def registered_combos(quick: bool = False) -> List[Combo]:
    """The full registered grid; `quick` covers every axis value while
    holding the others at defaults (axis coverage, not the cross product)."""
    from repro.core.summaries import DISTANCE_KINDS, list_summaries
    from repro.epi.models import list_models

    models = list(list_models())
    backends = ["xla", "xla_fused", "pallas"]
    summaries = [None] + [s for s in list_summaries() if s != "identity"]
    distances = list(DISTANCE_KINDS)
    sched_shapes = [0, 2]
    if not quick:
        full = [
            Combo(m, b, su, d, ss)
            for m, b, su, d, ss in itertools.product(
                models, backends, summaries, distances, sched_shapes
            )
        ]
        # region-axis column: audit regionalize-at-audit-time cells (a
        # coupled metapop and an uncoupled base model) across every backend
        # and both pooling modes — a bounded slice, not a full R axis of
        # the cross product
        full += [
            Combo(m, b, su, distances[0], ss, regions=3)
            for m in ("metapop_seir", "seir") if m in models
            for b in backends
            for su in (None, "region_pooled")
            for ss in sched_shapes
        ]
        return full
    base = Combo(models[0], "xla_fused", None, distances[0], 0)
    combos = {base}
    for m in models:
        combos.add(dataclasses.replace(base, model=m))
    for b in backends:
        combos.add(dataclasses.replace(base, backend=b))
    for su in summaries:
        combos.add(dataclasses.replace(base, summary=su))
    for d in distances:
        combos.add(dataclasses.replace(base, distance=d))
    for ss in sched_shapes:
        combos.add(dataclasses.replace(base, sched_shape=ss))
    # region-axis coverage: one coupled and one uncoupled regionalized cell
    if "metapop_seir" in models:
        combos.add(dataclasses.replace(
            base, model="metapop_seir", regions=3, summary="region_pooled"
        ))
    if "seir" in models:
        combos.add(dataclasses.replace(base, model="seir", regions=3))
    return sorted(combos, key=lambda c: c.tag)


def _resolve_spec(combo: Combo):
    """The combo's model spec, regionalized at audit time if regions > 1."""
    from repro.epi.models import get_model
    from repro.epi.spec import regionalize

    spec = get_model(combo.model)
    if combo.regions > 1:
        spec = regionalize(spec, combo.regions, "ring:0.1")
    return spec


def _schedule_for(shape: int, days: Sequence[int], spec):
    if shape == 0:
        return None
    from repro.epi.spec import InterventionSchedule

    return InterventionSchedule.inferred(
        (spec.param_names[0],), tuple(days[:shape])
    )


def _build_combo(combo: Combo, batch_size: int, num_days: int,
                 sched_days: Sequence[int] = (7, 14)):
    """(cfg, prior, dataset, loop, scenario-or-None) for one combo."""
    from repro.core.abc import (
        ABCConfig,
        build_wave_loop,
        make_parametric_simulator,
        make_simulator,
        scenario_data,
    )
    from repro.core.priors import schedule_prior
    from repro.epi.data import get_dataset

    spec = _resolve_spec(combo)
    cfg = ABCConfig(
        batch_size=batch_size,
        chunk_size=batch_size,
        num_days=num_days,
        backend=combo.backend,
        model=spec,
        summary=combo.summary,
        distance=combo.distance,
        schedule=_schedule_for(combo.sched_shape, sched_days, spec),
        wave_loop="device",
        interpret=True if combo.backend == "pallas" else None,
    )
    prior = schedule_prior(spec, cfg.schedule)
    dataset = get_dataset("synthetic_small", num_days, spec)
    if combo.backend == "pallas":
        sim = make_simulator(dataset, cfg)
        loop = build_wave_loop(prior, lambda th, k, _d: sim(th, k), cfg)
        data = None
    else:
        parametric = make_parametric_simulator(spec, cfg)
        loop = build_wave_loop(prior, parametric, cfg)
        data = scenario_data(dataset, cfg)
    return cfg, prior, dataset, loop, data


def _loop_args(cfg, prior, data):
    from repro.core.abc import wave_capacity

    cap = wave_capacity(cfg)
    th_buf = jnp.zeros((cap, prior.dim), jnp.float32)
    d_buf = jnp.full((cap,), jnp.inf, jnp.float32)
    key = jax.random.PRNGKey(0)
    return (
        key, jnp.int32(0), th_buf, d_buf, jnp.int32(0), jnp.int32(0),
        jnp.int32(1), jnp.float32(cfg.tolerance), data,
    )


def _scenario_variants(combo: Combo, cfg, num_days: int):
    """Two scenarios the campaign _ShapeCache maps to one key: a different
    dataset AND different breakpoint days of the same window count."""
    from repro.core.abc import scenario_data
    from repro.epi.data import get_dataset, synthetic_dataset

    spec = _resolve_spec(combo)
    ds_a = get_dataset("synthetic_small", num_days, spec)
    ds_b = synthetic_dataset(
        theta=spec.default_theta, population=5e6, num_days=num_days,
        a0=50.0, seed=11, name="audit_variant", model=spec,
    )
    variants = [scenario_data(ds_a, cfg), scenario_data(ds_b, cfg)]
    if combo.sched_shape:
        cfg_late = dataclasses.replace(
            cfg, schedule=_schedule_for(combo.sched_shape, (9, 19), spec)
        )
        variants.append(scenario_data(ds_a, cfg_late))
    return variants


def audit_combo(combo: Combo, batch_size: int = 1024, num_days: int = 21
                ) -> List[Finding]:
    """Trace one combo's wave loop and run every jaxpr-level check."""
    try:
        cfg, prior, dataset, loop, data = _build_combo(
            combo, batch_size, num_days
        )
        args = _loop_args(cfg, prior, data)
        jaxpr = jax.make_jaxpr(loop)(*args)
    except Exception as e:  # a combo that cannot trace cannot compile
        return [Finding(
            rule="audit-trace-error", path="-", line=0, context=combo.tag,
            message=f"{type(e).__name__}: {e}",
        )]
    findings = audit_jaxpr(jaxpr, combo.tag)
    if combo.backend != "pallas":
        # pallas bakes dataset scalars into the kernel: the documented
        # per-dataset compile exception (campaign._ShapeCache.key_of)
        findings.extend(audit_shape_cache(
            _scenario_variants(combo, cfg, num_days), combo.tag
        ))
    return findings


def audit_runner_donation(backend: str, sched_shape: int,
                          batch_size: int = 1024, num_days: int = 21
                          ) -> List[Finding]:
    """Lower one representative jitted wave runner per (backend, schedule
    shape) and verify the accept buffers carry donation markers. The
    donation setup lives in make_wave_runner/make_shardmap_runner and is
    combo-independent, so representatives cover the grid."""
    from repro.core.abc import ABCState, make_simulator, make_wave_runner
    from repro.core.priors import schedule_prior
    from repro.epi.data import get_dataset
    from repro.epi.models import get_model

    combo = Combo(
        model="siard", backend=backend, summary=None,
        distance="euclidean", sched_shape=sched_shape,
    )
    context = f"wave_runner/{backend}/sched{sched_shape}"
    try:
        cfg, prior, dataset, _, _ = _build_combo(combo, batch_size, num_days)
        sim = make_simulator(dataset, cfg)
        runner = make_wave_runner(prior, sim, cfg)
        state = ABCState(n_params=prior.dim)
        th_buf, d_buf, n0, fill0 = runner.init(state)
        lowered = runner.fn.lower(
            jax.random.PRNGKey(0), np.int32(0), th_buf, d_buf, n0, fill0,
            np.int32(1), np.float32(cfg.tolerance), None,
        )
        text = lowered.as_text()
    except Exception as e:
        return [Finding(
            rule="audit-trace-error", path="-", line=0, context=context,
            message=f"{type(e).__name__}: {e}",
        )]
    # flat args: key(1) + run_idx0 + theta_buf + dist_buf + ... — indices 2,3
    return audit_donation(text, context, expected_donated=(2, 3))


def run_audit(quick: bool = False, log=None) -> List[Finding]:
    findings: List[Finding] = []
    combos = registered_combos(quick=quick)
    for i, combo in enumerate(combos):
        if log and (i % 30 == 0 or i + 1 == len(combos)):
            log(f"[trace_audit] combo {i + 1}/{len(combos)}: {combo.tag}")
        findings.extend(audit_combo(combo))
    sched_shapes = [0, 2]
    backends = ["xla", "xla_fused"] if quick else ["xla", "xla_fused",
                                                   "pallas"]
    for backend in backends:
        for ss in sched_shapes:
            findings.extend(audit_runner_donation(backend, ss))
    return findings
