"""AST lint pass: repo contracts as named, suppressible rules.

Each rule encodes an invariant a past PR fixed by hand (see ISSUE/CHANGES
history) so the violation class can never land again:

  non-atomic-artifact-write   artifacts must go through repro.ioutils
                              .atomic_write — a bare np.savez / json.dump /
                              open(path, "w") / Path.write_text to a final
                              path is exactly the truncation bug PR 7 fixed
                              in Posterior.save.
  host-sync-under-trace       .item() / jax.device_get / float()/int()/
                              np.asarray of a traced *parameter* inside a
                              jit / lax control-flow region forces a device
                              sync (or a tracer error) in the hot path.
  python-rng-under-trace      np.random.* / random.* under trace silently
                              bakes ONE host-drawn value into the compiled
                              program — every wave reuses it.
  time-under-trace            time.time()/perf_counter()/monotonic() under
                              trace is a compile-time constant, not a
                              measurement.
  scalar-closure-capture      a jitted function capturing `x = float(arg)` /
                              `int(arg)` from its factory's scope bakes a
                              per-call value as a compile constant — the
                              shape-cache contract wants it traced (or a
                              const lane). The silent in-jit tile clamp bug.
  suppression-missing-reason  `# analysis: allow(rule)` without a reason
                              comment — suppressions must say why.

Suppression: a trailing comment on the flagged line, or a comment in the
contiguous comment block directly above it, of the form

    # analysis: allow(rule-name) — reason why this site is exempt

Traced-context detection is intentionally structural (no imports resolved):
a function is traced if it is decorated with jit/pmap/vmap (directly or via
functools.partial), passed by name or as a lambda to jit / lax.while_loop /
lax.scan / lax.cond / lax.fori_loop / vmap / pmap / shard_map / pallas_call,
nested inside a traced function, or called by simple name from traced code
in the same module. Parameters bound in static_argnames/static_argnums are
NOT traced values and never trip the host-sync rule.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.report import Finding

#: rule registry: name -> one-line description (the README catalog renders
#: from here so docs and code cannot drift)
RULES: Dict[str, str] = {
    "non-atomic-artifact-write": (
        "artifact writes must go through repro.ioutils.atomic_write "
        "(bare np.savez/np.save/json.dump/pickle.dump/open(...,'w')/"
        "Path.write_text can leave a truncated file at the final path)"
    ),
    "host-sync-under-trace": (
        ".item()/jax.device_get, or float()/int()/np.asarray/np.array of a "
        "traced parameter, inside a jit/lax control-flow region"
    ),
    "python-rng-under-trace": (
        "np.random.*/random.* under trace bakes one host-drawn value into "
        "the compiled program"
    ),
    "time-under-trace": (
        "time.time()/perf_counter()/monotonic() under trace is a "
        "compile-time constant, not a measurement"
    ),
    "scalar-closure-capture": (
        "a traced function captures a float(param)/int(param) scalar from "
        "its factory scope — belongs in traced args or const lanes"
    ),
    "suppression-missing-reason": (
        "# analysis: allow(...) suppressions must carry a reason"
    ),
}

#: callables whose function-valued arguments become traced code
_TRACE_WRAPPERS = {
    "jit", "pmap", "vmap", "while_loop", "scan", "cond", "switch",
    "fori_loop", "shard_map", "pallas_call", "checkpoint", "remat", "grad",
    "value_and_grad",
}
#: decorator suffixes that make the decorated def traced
_TRACE_DECORATORS = {"jit", "pmap", "vmap", "pallas_call", "custom_jvp",
                     "custom_vjp"}
_HOST_SYNC_CONVERTERS = {"float", "int", "bool", "complex"}
_NP_ALIASES = {"np", "numpy"}
_WRITE_MODES = {"w", "wb", "a", "ab", "w+", "wb+", "a+", "x", "xb"}
#: file-writing calls checked by non-atomic-artifact-write:
#: dotted-suffix -> index of the file-object/path argument
_FILE_ARG_OF = {"savez": 0, "savez_compressed": 0, "save": 0, "dump": 1}

_ALLOW_RE = re.compile(
    r"#\s*analysis:\s*allow\(([A-Za-z0-9_-]+)\)\s*(.*)"
)


def _dotted(node: ast.AST) -> Tuple[str, ...]:
    """('jax','lax','while_loop') for jax.lax.while_loop; () if not a name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _root_names(node: ast.AST) -> Set[str]:
    """All Name roots loaded anywhere inside an expression."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


class _Suppressions:
    """Per-file `# analysis: allow(rule) — reason` directives."""

    def __init__(self, source: str, path: str):
        self.by_line: Dict[int, Set[str]] = {}
        self.missing_reason: List[Finding] = []
        self._comment_lines: Set[int] = set()
        for i, raw in enumerate(source.splitlines(), start=1):
            stripped = raw.strip()
            if stripped.startswith("#"):
                self._comment_lines.add(i)
            m = _ALLOW_RE.search(raw)
            if not m:
                continue
            rule, reason = m.group(1), m.group(2)
            self.by_line.setdefault(i, set()).add(rule)
            if not reason.strip(" -—:\t"):
                self.missing_reason.append(Finding(
                    rule="suppression-missing-reason", path=path, line=i,
                    context=f"allow({rule})",
                    message="suppression has no reason — say why this site "
                            "is exempt after the closing paren",
                ))

    def allows(self, rule: str, line: int) -> bool:
        """Directive on the line itself or in the comment block above it."""
        if rule in self.by_line.get(line, ()):
            return True
        lookback = line - 1
        while lookback in self._comment_lines:
            if rule in self.by_line.get(lookback, ()):
                return True
            lookback -= 1
        return False


class _FunctionInfo:
    def __init__(self, node: ast.FunctionDef, parent: Optional["_FunctionInfo"]):
        self.node = node
        self.parent = parent
        self.children: List[_FunctionInfo] = []
        self.traced = False
        self.params = self._param_names(node)
        self.static_params = self._static_params(node)
        # simple-name calls made directly by this function (for transitive
        # traced-closure propagation)
        self.called_names: Set[str] = set()

    @staticmethod
    def _param_names(node: ast.FunctionDef) -> Tuple[str, ...]:
        a = node.args
        names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
        return tuple(names)

    @staticmethod
    def _static_params(node: ast.FunctionDef) -> Set[str]:
        """Params named by static_argnames/static_argnums in a jit decorator
        (directly or through functools.partial)."""
        static: Set[str] = set()
        a = node.args
        positional = [p.arg for p in (*a.posonlyargs, *a.args)]
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            callee = _dotted(dec.func)
            calls = [dec]
            if callee and callee[-1] == "partial":
                # functools.partial(jax.jit, static_argnames=...)
                inner = dec.args[0] if dec.args else None
                if inner is None or _dotted(inner)[-1:] != ("jit",):
                    continue
            elif not (callee and callee[-1] == "jit"):
                continue
            for call in calls:
                for kw in call.keywords:
                    if kw.arg == "static_argnames":
                        for c in ast.walk(kw.value):
                            if isinstance(c, ast.Constant) and isinstance(
                                c.value, str
                            ):
                                static.add(c.value)
                    elif kw.arg == "static_argnums":
                        for c in ast.walk(kw.value):
                            if isinstance(c, ast.Constant) and isinstance(
                                c.value, int
                            ) and 0 <= c.value < len(positional):
                                static.add(positional[c.value])
        return static

    @property
    def traced_params(self) -> Set[str]:
        return set(self.params) - self.static_params


class _ModuleIndex(ast.NodeVisitor):
    """First pass: function tree, traced roots, call graph."""

    def __init__(self):
        self.functions: List[_FunctionInfo] = []
        self.by_name: Dict[Tuple[Optional[ast.AST], str], _FunctionInfo] = {}
        self._stack: List[_FunctionInfo] = []
        #: lambdas passed to trace wrappers: (lambda node, enclosing info)
        self.traced_lambdas: List[Tuple[ast.Lambda, Optional[_FunctionInfo]]] = []

    def _current(self) -> Optional[_FunctionInfo]:
        return self._stack[-1] if self._stack else None

    def visit_FunctionDef(self, node: ast.FunctionDef):
        info = _FunctionInfo(node, self._current())
        if info.parent is not None:
            info.parent.children.append(info)
        self.functions.append(info)
        scope = info.parent.node if info.parent else None
        self.by_name[(scope, node.name)] = info
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            callee = _dotted(target)
            if callee and callee[-1] == "partial" and isinstance(dec, ast.Call):
                if dec.args and _dotted(dec.args[0])[-1:] and \
                        _dotted(dec.args[0])[-1] in _TRACE_DECORATORS:
                    info.traced = True
            elif callee and callee[-1] in _TRACE_DECORATORS:
                info.traced = True
        self._stack.append(info)
        self.generic_visit(node)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call):
        cur = self._current()
        callee = _dotted(node.func)
        if callee and cur is not None and len(callee) == 1:
            cur.called_names.add(callee[0])
        if callee and callee[-1] in _TRACE_WRAPPERS:
            for arg in (*node.args, *(kw.value for kw in node.keywords)):
                if isinstance(arg, ast.Name):
                    self._mark_traced_name(arg.id)
                elif isinstance(arg, ast.Lambda):
                    self.traced_lambdas.append((arg, cur))
        self.generic_visit(node)

    def _mark_traced_name(self, name: str):
        # resolve in the lexical scope chain: innermost def wins
        scopes = [info.node for info in reversed(self._stack)] + [None]
        for scope in scopes:
            info = self.by_name.get((scope, name))
            if info is not None:
                info.traced = True
                return

    def propagate(self):
        """Traced closure: nested defs + same-module simple-name callees."""
        changed = True
        while changed:
            changed = False
            for info in self.functions:
                if not info.traced:
                    continue
                for child in info.children:
                    if not child.traced:
                        child.traced = True
                        changed = True
                for name in info.called_names:
                    # resolve against siblings upward through the chain,
                    # then module scope
                    scope_chain: List[Optional[ast.AST]] = []
                    p = info.parent
                    while p is not None:
                        scope_chain.append(p.node)
                        p = p.parent
                    scope_chain.append(None)
                    for scope in scope_chain:
                        callee = self.by_name.get((scope, name))
                        if callee is not None:
                            if not callee.traced:
                                callee.traced = True
                                changed = True
                            break


def _assigned_names(node: ast.FunctionDef) -> Set[str]:
    """Names bound inside a function body (stores, loop targets, withitems,
    params) — everything that is NOT a free variable."""
    bound: Set[str] = set(_FunctionInfo._param_names(node))
    for child in ast.walk(node):
        if child is node:
            continue
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(child.name)
        elif isinstance(child, ast.Name) and isinstance(
            child.ctx, (ast.Store, ast.Del)
        ):
            bound.add(child.id)
    return bound


class Linter:
    """Lint one file; collect Findings (suppressions already applied)."""

    def __init__(self, path: Path, repo_root: Path, source: Optional[str] = None):
        self.path = path
        self.rel = str(path.relative_to(repo_root))
        self.source = source if source is not None else path.read_text()
        self.findings: List[Finding] = []
        self.suppressions = _Suppressions(self.source, self.rel)

    # ------------------------------------------------------------------
    def run(self) -> List[Finding]:
        tree = ast.parse(self.source, filename=self.rel)
        index = _ModuleIndex()
        index.visit(tree)
        index.propagate()
        self._enclosing: Dict[int, str] = {}
        for info in index.functions:
            for child in ast.walk(info.node):
                lineno = getattr(child, "lineno", None)
                if lineno is not None and lineno not in self._enclosing:
                    self._enclosing[lineno] = info.node.name

        if not self.rel.endswith("ioutils.py"):
            self._check_atomic_writes(tree)
        for info in index.functions:
            if info.traced:
                self._check_traced_body(info)
                self._check_scalar_captures(info)
        for lam, encl in index.traced_lambdas:
            self._check_traced_expr(lam, encl)
        self.findings.extend(self.suppressions.missing_reason)
        return self.findings

    # ------------------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, context: str, message: str):
        line = getattr(node, "lineno", 0)
        if self.suppressions.allows(rule, line):
            return
        self.findings.append(Finding(
            rule=rule, path=self.rel, line=line, context=context,
            message=message,
        ))

    def _context_of(self, node: ast.AST) -> str:
        return self._enclosing.get(getattr(node, "lineno", 0), "<module>")

    # ------------------------------- rule: non-atomic-artifact-write --
    def _check_atomic_writes(self, tree: ast.Module):
        # names bound as `with atomic_write(...) as f` anywhere in the file;
        # scoping finer than per-file buys nothing here (a name bound from
        # atomic_write in one function shadowing a bare handle in another
        # would itself be flagged at its own open())
        atomic_handles: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    call = item.context_expr
                    if isinstance(call, ast.Call) and _dotted(call.func)[-1:] \
                            == ("atomic_write",):
                        if isinstance(item.optional_vars, ast.Name):
                            atomic_handles.add(item.optional_vars.id)

        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            ctx = self._context_of(node)
            if callee == ("open",):
                mode = None
                if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
                    mode = node.args[1].value
                for kw in node.keywords:
                    if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                        mode = kw.value.value
                if isinstance(mode, str) and mode in _WRITE_MODES:
                    self._emit(
                        "non-atomic-artifact-write", node, ctx,
                        f"open(..., {mode!r}) writes the final path directly; "
                        "use `with atomic_write(path, ...)` instead",
                    )
            elif callee and callee[-1] == "write_text" and len(callee) > 1:
                self._emit(
                    "non-atomic-artifact-write", node, ctx,
                    ".write_text() replaces the file non-atomically; use "
                    "repro.ioutils.atomic_write_text",
                )
            elif callee and callee[-1] in _FILE_ARG_OF and len(callee) > 1:
                # np.savez/np.save/json.dump/pickle.dump(file_or_path, ...)
                if callee[-1] in ("savez", "savez_compressed", "save") and \
                        callee[0] not in _NP_ALIASES:
                    continue
                if callee[-1] == "dump" and callee[0] not in (
                    "json", "pickle", "yaml", "toml"
                ):
                    continue
                idx = _FILE_ARG_OF[callee[-1]]
                file_arg = node.args[idx] if len(node.args) > idx else None
                if isinstance(file_arg, ast.Name) and \
                        file_arg.id in atomic_handles:
                    continue
                self._emit(
                    "non-atomic-artifact-write", node, ctx,
                    f"{'.'.join(callee)} must write through a "
                    "`with atomic_write(path, ...)` handle",
                )

    # ------------------------------------ rules in traced functions ---
    def _check_traced_body(self, info: _FunctionInfo):
        name = info.node.name
        for node in ast.walk(info.node):
            if not isinstance(node, ast.Call):
                continue
            self._check_traced_call(node, name, info.traced_params)

    def _check_traced_expr(self, lam: ast.Lambda, encl: Optional[_FunctionInfo]):
        name = f"{encl.node.name}.<lambda>" if encl else "<lambda>"
        params = {p.arg for p in (*lam.args.posonlyargs, *lam.args.args,
                                  *lam.args.kwonlyargs)}
        for node in ast.walk(lam):
            if isinstance(node, ast.Call):
                self._check_traced_call(node, name, params)

    def _check_traced_call(self, node: ast.Call, context: str,
                           traced_params: Set[str]):
        callee = _dotted(node.func)
        if not callee:
            # method call like x.item()
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                self._emit(
                    "host-sync-under-trace", node, context,
                    ".item() forces a device->host sync under trace",
                )
            return
        suffix = callee[-1]
        if suffix == "item" and len(callee) > 1:
            self._emit(
                "host-sync-under-trace", node, context,
                ".item() forces a device->host sync under trace",
            )
        elif suffix in ("device_get", "block_until_ready") and "jax" in callee:
            self._emit(
                "host-sync-under-trace", node, context,
                f"jax.{suffix} under trace forces a device->host sync",
            )
        elif (
            callee in {(c,) for c in _HOST_SYNC_CONVERTERS}
            or (callee[0] in _NP_ALIASES and suffix in ("asarray", "array"))
        ):
            roots = set()
            for arg in node.args:
                roots |= _root_names(arg)
            hit = roots & traced_params
            if hit:
                self._emit(
                    "host-sync-under-trace", node, context,
                    f"{'.'.join(callee)}() of traced value(s) "
                    f"{sorted(hit)} pulls them to host (or raises a "
                    "TracerConversionError) under trace",
                )
        elif len(callee) >= 2 and callee[0] in _NP_ALIASES and \
                callee[1] == "random":
            self._emit(
                "python-rng-under-trace", node, context,
                f"{'.'.join(callee)} draws on the HOST at trace time — the "
                "compiled program replays one fixed value; use jax.random",
            )
        elif len(callee) == 2 and callee[0] == "random":
            self._emit(
                "python-rng-under-trace", node, context,
                f"{'.'.join(callee)} draws on the host at trace time; use "
                "jax.random",
            )
        elif len(callee) == 2 and callee[0] == "time" and callee[1] in (
            "time", "perf_counter", "monotonic", "time_ns",
            "perf_counter_ns", "monotonic_ns",
        ):
            self._emit(
                "time-under-trace", node, context,
                f"time.{callee[1]}() under trace is evaluated ONCE at trace "
                "time and baked into the compiled program",
            )

    # ------------------------------- rule: scalar-closure-capture -----
    def _check_scalar_captures(self, info: _FunctionInfo):
        """A traced fn whose free variable is bound in an enclosing factory
        as float(...)/int(...) OF A FACTORY PARAMETER — a per-call scalar
        baked as a compile constant. Literal constants are deliberate
        statics and stay allowed."""
        if info.parent is None:
            return
        bound = _assigned_names(info.node)
        free = {
            n.id for n in ast.walk(info.node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
            and n.id not in bound
        }
        if not free:
            return
        anc = info.parent
        while anc is not None:
            anc_params = set(anc.params)
            for stmt in ast.walk(anc.node):
                if not isinstance(stmt, ast.Assign):
                    continue
                targets = [
                    t.id for t in stmt.targets if isinstance(t, ast.Name)
                ]
                captured = [t for t in targets if t in free]
                if not captured:
                    continue
                val = stmt.value
                if isinstance(val, ast.Call) and _dotted(val.func) in {
                    ("float",), ("int",)
                }:
                    arg_roots = set()
                    for a in val.args:
                        arg_roots |= _root_names(a)
                    if arg_roots & anc_params:
                        self._emit(
                            "scalar-closure-capture", stmt,
                            info.node.name,
                            f"{captured[0]} = "
                            f"{_dotted(val.func)[0]}(...) of factory "
                            f"parameter(s) {sorted(arg_roots & anc_params)} "
                            f"is captured by traced fn "
                            f"{info.node.name!r} as a compile constant — "
                            "pass it as a traced arg or const lane",
                        )
            # names bound in this ancestor are not free above it
            free -= set(anc.params) | _assigned_names(anc.node)
            anc = anc.parent


def default_targets(repo_root: Path) -> List[Path]:
    """The lint scope: src/repro + benchmarks (tests write fixtures freely)."""
    targets = []
    for sub in ("src/repro", "benchmarks"):
        base = repo_root / sub
        if base.exists():
            targets.extend(sorted(base.rglob("*.py")))
    return targets


def run_lint(repo_root: Path, paths: Optional[List[Path]] = None
             ) -> List[Finding]:
    findings: List[Finding] = []
    for path in (paths or default_targets(repo_root)):
        findings.extend(Linter(path, repo_root).run())
    return findings
