"""Static-analysis subsystem: the AST lint pass + the jaxpr trace auditor.

Run both passes locally with `python -m repro.analysis`; CI gates on them
through `tests/check_analysis.py` against the committed zero-entry baseline
`tests/analysis_baseline.txt`. See the README "Static analysis" section for
the rule catalog and the suppression/baseline policy.

NOTE: distinct from `repro.launch.analysis` (the HLO roofline/cost
analyzer) — this package checks source and jaxprs, that one costs compiled
modules.
"""

from repro.analysis.lint import RULES, run_lint
from repro.analysis.report import (
    SCHEMA,
    Finding,
    dump_report,
    evaluate,
    load_baseline,
    make_report,
)
from repro.analysis.trace_audit import AUDIT_RULES, run_audit

__all__ = [
    "AUDIT_RULES",
    "Finding",
    "RULES",
    "SCHEMA",
    "dump_report",
    "evaluate",
    "load_baseline",
    "make_report",
    "run_audit",
    "run_lint",
]
