"""CLI: `python -m repro.analysis [--pass lint|audit|all] [--quick] ...`

Exit code 0 when every finding is in the baseline (and no baseline entry is
stale), 1 otherwise — same contract as tests/check_analysis.py, which is a
thin wrapper over this module plus the committed baseline path.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.lint import run_lint
from repro.analysis.report import (
    dump_report,
    evaluate,
    load_baseline,
    make_report,
)
from repro.analysis.trace_audit import run_audit


def repo_root() -> Path:
    """The checkout root (this file lives at src/repro/analysis/)."""
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo static analysis: AST lint + jaxpr trace audit",
    )
    parser.add_argument(
        "--pass", dest="passes", choices=("lint", "audit", "all"),
        default="all", help="which pass to run (default: all)",
    )
    parser.add_argument(
        "--quick", action="store_true",
        help="audit axis-coverage combos instead of the full cross product",
    )
    parser.add_argument(
        "--baseline", type=Path, default=None,
        help="baseline file of allowed finding keys "
             "(default: tests/analysis_baseline.txt)",
    )
    parser.add_argument(
        "--json-out", type=Path, default=None,
        help="write the analysis-report/v1 JSON here",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        from repro.analysis.lint import RULES
        from repro.analysis.trace_audit import AUDIT_RULES

        for name, desc in {**RULES, **AUDIT_RULES}.items():
            print(f"{name:28s} {desc}")
        return 0

    root = repo_root()
    findings, passes = [], []
    if args.passes in ("lint", "all"):
        passes.append("lint")
        findings.extend(run_lint(root))
    if args.passes in ("audit", "all"):
        passes.append("trace_audit")
        findings.extend(run_audit(quick=args.quick, log=print))

    report = make_report(findings, passes)
    if args.json_out:
        dump_report(report, args.json_out)
        print(f"[analysis] report -> {args.json_out}")

    baseline_path = args.baseline
    if baseline_path is None:
        default = root / "tests" / "analysis_baseline.txt"
        baseline_path = default if default.exists() else None
    known = load_baseline(baseline_path)
    return evaluate(known, findings)


if __name__ == "__main__":
    sys.exit(main())
