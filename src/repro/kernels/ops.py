"""Jit'd user-facing wrappers around the Pallas kernels.

`abc_sim_distance` handles layout (transpose, padding), constant packing and
backend selection. On this CPU container interpret=True executes the kernel
body in Python for correctness; on TPU hardware set interpret=False (the
default is auto-detected from the backend).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.summaries import N_FLAGS, get_summary, lower_summary, pool_factor
from repro.epi import engine
from repro.kernels import abc_sim

_CONST_LANES = abc_sim._CONST_LANES


def _auto_interpret() -> bool:
    return abc_sim.auto_interpret()


def resolve_tile(batch: int, tile: int | None = None) -> int:
    """The kernel tile actually used for `batch` — the SINGLE tile authority.

    `tile=None` picks the legacy auto default: 1024 lanes, shrunk to the
    next power of two >= batch for small batches (so a 300-sample pilot run
    pads to one 512-lane cell instead of a mostly-empty 1024-lane one).

    An EXPLICIT tile is taken literally and validated loudly: it must be a
    positive multiple of 128 lanes that divides the batch exactly. The old
    behavior silently clamped the request and over-padded incompatible
    batches, so a tuned `tile=2048` could quietly run at 512 and a
    `batch=300, tile=256` cell could quietly simulate 212 ghost samples —
    invisible in the bench envelope it was supposed to explain.
    """
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    if tile is None:
        return min(1024, max(128, 1 << (batch - 1).bit_length()))
    tile = int(tile)
    if tile < 128 or tile % 128:
        raise ValueError(
            f"tile={tile} is not a positive multiple of 128 lanes; pass "
            "tile=None for the auto default"
        )
    if batch % tile:
        raise ValueError(
            f"tile={tile} does not divide batch={batch}; the kernel would "
            "silently pad {pad} ghost samples. Pick a divisor tile or "
            "tile=None for the auto default".replace(
                "{pad}", str((-batch) % tile)
            )
        )
    return tile


def abc_sim_distance(
    theta: jax.Array,  # [B, n_params (+ n_scales)] f32
    seed: jax.Array,  # uint32 scalar
    observed: jax.Array,  # [n_observed, T] f32
    *,
    population: float,
    a0: float,
    r0: float = 0.0,
    d0: float = 0.0,
    tile: int | None = None,
    interpret: bool | None = None,
    model=None,  # CompartmentalModel spec; defaults to the paper's SIARD
    schedule=None,  # InterventionSchedule; theta carries its scale columns
    breakpoints=None,  # [n_windows] i32 traced override of schedule days
    summary=None,  # SummarySpec / registry name / None (identity)
    distance: str = "euclidean",  # core.summaries.DISTANCE_KINDS name
    mobility=None,  # [R, R] row-stochastic override (metapop models)
) -> jax.Array:
    """Fused simulate+distance for a batch of parameter samples. Returns [B].

    `model` is a static argument of the underlying jitted function: each spec
    compiles its own specialized kernel with the stoichiometry and hazards
    inlined (see kernels/abc_sim). Defaults are resolved HERE, outside the
    jit boundary, so model=None and model=DEFAULT_MODEL share one cache entry.
    Of a `schedule`, only the SHAPE (window count, scaled params) is static:
    breakpoint days are traced i32 scalars, so sweeping lockdown days reuses
    one compiled kernel. The (summary, distance) pair is lowered the same
    way: the observed side is pre-summarized here and the selector flags /
    channel weights / mean scale are traced scalar-lane values, so a summary
    or distance sweep also reuses one compiled kernel (pinned by a jit-cache
    test in tests/test_summaries.py). For metapop models the [R, R] mobility
    matrix rides fconst lanes the same way (a mobility sweep reuses one
    compiled kernel) — which also caps the kernel at roughly R <= 10;
    larger metapop runs must use the XLA backends (loud ValueError here).
    """
    if model is None:
        from repro.epi.models import DEFAULT_MODEL as model  # noqa: N811
    if interpret is None:
        interpret = _auto_interpret()
    # resolve/validate OUTSIDE the jit boundary: tile=None and its resolved
    # value share a cache entry, and bad explicit tiles fail loudly up here
    tile = resolve_tile(int(theta.shape[0]), tile)
    sched = None
    if schedule is not None and not schedule.is_empty:
        sched = schedule.shape(model)
        if breakpoints is None:
            breakpoints = jnp.asarray(schedule.breakpoints, jnp.int32)
    if breakpoints is None:
        breakpoints = jnp.zeros((0,), jnp.int32)
    spec = get_summary(summary)
    pool = pool_factor(spec, model.n_regions)
    if not abc_sim.kernel_lane_budget_ok(model, pool):
        raise ValueError(
            f"model {model.name!r} (R={model.n_regions}, "
            f"{abc_sim.n_summary_channels(model, pool)} summary channels) "
            f"exceeds the kernel's {_CONST_LANES} const-lane budget for "
            "weights + mobility; use backend='xla_fused' (or 'xla') for "
            "large metapop models"
        )
    if model.is_regional:
        mob = engine.mobility_matrix(model, mobility)
    else:
        mob = jnp.zeros((0, 0), jnp.float32)
    lowered = lower_summary(spec, distance, observed, n_regions=model.n_regions)
    return _abc_sim_distance_jit(
        theta, seed, lowered.obs_summary, breakpoints, lowered.weights,
        lowered.mean_scale, lowered.flags, mob, population=population, a0=a0,
        r0=r0, d0=d0, tile=tile, interpret=interpret, model=model, sched=sched,
        pool=pool,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "population", "a0", "r0", "d0", "tile", "interpret", "model", "sched",
        "pool",
    ),
)
def _abc_sim_distance_jit(
    theta: jax.Array,
    seed: jax.Array,
    observed: jax.Array,  # PRE-SUMMARIZED observed side (running-bin layout)
    breakpoints: jax.Array,
    weights: jax.Array,  # [n_chan] f32 summary channel weights
    mean_scale: jax.Array,  # [] f32 distance finalizer scale
    flags: jax.Array,  # [N_FLAGS] i32 summary/distance selectors
    mob: jax.Array,  # [R, R] f32 mobility ([0, 0] for flat models)
    *,
    population: float,
    a0: float,
    r0: float,
    d0: float,
    tile: int,
    interpret: bool,
    model,
    sched,
    pool: int = 1,
) -> jax.Array:
    theta = jnp.asarray(theta, jnp.float32)
    batch, n_params = theta.shape
    width = abc_sim.theta_width(model, sched)
    n_chan = abc_sim.n_summary_channels(model, pool)
    assert n_params == width, (theta.shape, model.name, sched)
    assert observed.shape[0] == n_chan, (observed.shape, model.name, pool)
    num_days = observed.shape[1]
    n_windows = sched.n_windows if sched is not None else 0
    assert breakpoints.shape == (n_windows,), (breakpoints.shape, sched)
    assert weights.shape == (n_chan,), (weights.shape, model.name, pool)
    assert flags.shape == (N_FLAGS,), flags.shape
    n_mob = model.n_regions if model.is_regional else 0
    assert mob.shape == (n_mob, n_mob), (mob.shape, model.name)
    # lane-budget guards: breakpoints grow up from lane 1, summary flags sit
    # at fixed tail lanes, weights (then mobility) live above the four model
    # scalars — abc_sim_distance raises loudly before tracing ever gets here
    assert 1 + n_windows <= abc_sim._SUM_ILANE, n_windows
    assert abc_sim.kernel_lane_budget_ok(model, pool), (model.name, pool)

    # tile arrives pre-resolved (resolve_tile); only an auto tile may pad
    pad_b = (-batch) % tile
    p_pad = abc_sim.sublane_pad(width)
    theta_t = jnp.swapaxes(theta, 0, 1)  # [width, B]
    theta_t = jnp.pad(theta_t, ((0, p_pad - width), (0, pad_b)))

    o_pad = abc_sim.sublane_pad(n_chan)
    t_pad = int(np.ceil(num_days / 128) * 128)
    obs_pad = jnp.zeros((o_pad, t_pad), jnp.float32)
    obs_pad = obs_pad.at[:n_chan, :num_days].set(
        jnp.asarray(observed, jnp.float32)
    )

    fconsts = jnp.zeros((1, _CONST_LANES), jnp.float32)
    fconsts = fconsts.at[0, 0].set(population)
    fconsts = fconsts.at[0, 1].set(a0)
    fconsts = fconsts.at[0, 2].set(r0)
    fconsts = fconsts.at[0, 3].set(d0)
    fconsts = fconsts.at[0, abc_sim._MEAN_SCALE_LANE].set(
        jnp.asarray(mean_scale, jnp.float32)
    )
    wl = abc_sim._WEIGHT_LANE
    fconsts = fconsts.at[0, wl : wl + n_chan].set(
        jnp.asarray(weights, jnp.float32)
    )
    if n_mob:
        ml = abc_sim.mobility_lane(model, pool)
        fconsts = fconsts.at[0, ml : ml + n_mob * n_mob].set(
            jnp.asarray(mob, jnp.float32).reshape(-1)
        )
    iconsts = jnp.zeros((1, _CONST_LANES), jnp.int32)
    iconsts = iconsts.at[0, 0].set(jnp.asarray(seed, jnp.uint32).astype(jnp.int32))
    if n_windows:
        iconsts = iconsts.at[0, 1 : 1 + n_windows].set(
            jnp.asarray(breakpoints, jnp.int32)
        )
    sl = abc_sim._SUM_ILANE
    iconsts = iconsts.at[0, sl : sl + N_FLAGS].set(jnp.asarray(flags, jnp.int32))

    dist = abc_sim.abc_sim_distance_kernel(
        theta_t,
        obs_pad,
        fconsts,
        iconsts,
        model=model,
        num_days=num_days,
        tile=tile,
        interpret=interpret,
        sched=sched,
        pool=pool,
    )
    return dist[0, :batch]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "q_block",
                     "kv_block", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, S, H, D] (model layout)
    k: jax.Array,  # [B, T, KH, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    scale: float | None = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """User-facing flash attention: handles layout + padding. Returns the
    model-layout output [B, S, H, D]."""
    from repro.kernels import flash_attention as fa

    if interpret is None:
        interpret = _auto_interpret()
    b, s, h, d = q.shape
    t = k.shape[1]
    qt = jnp.moveaxis(q, 2, 1)  # [B, H, S, D]
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    q_block = min(q_block, max(8, 1 << (s - 1).bit_length()))
    kv_block = min(kv_block, max(8, 1 << (t - 1).bit_length()))
    pad_q = (-s) % q_block
    pad_t = (-t) % kv_block
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_t:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
    out = fa.flash_attention_kernel(
        qt, kt, vt, seq_len=t, causal=causal, window=window, softcap=softcap,
        scale=scale, q_block=q_block, kv_block=kv_block, interpret=interpret,
    )
    return jnp.moveaxis(out[:, :, :s], 1, 2)
