"""Pure-jnp oracle for the fused ABC simulation kernel.

Reuses the verified generic engine (`repro.epi.engine`) for the dynamics and
the shared counter-based RNG primitive (`repro.kernels.rng`) for the noise,
so kernel-vs-oracle tests check the kernel's tiling/looping/layout logic
against an independent formulation of the same math — for ANY registered
`CompartmentalModel` spec, not just the paper's SIARD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.epi import engine
from repro.epi.spec import CompartmentalModel, EpiModelConfig
from repro.kernels import rng as krng


def hash_normals(seed, idx: jax.Array, day, n_transitions: int = 5) -> jax.Array:
    """Noise block [B, n_transitions] for one day from the counter stream."""
    cols = []
    for k in range(n_transitions):
        cols.append(krng.normal(seed, idx, krng.day_transition_ctr(day, k)))
    return jnp.stack(cols, axis=-1)


def abc_sim_distance_ref(
    theta: jax.Array,  # [B, n_params (+ n_scales)] f32
    seed,  # uint32 scalar
    observed: jax.Array,  # [n_observed, T] f32
    *,
    population: float,
    a0: float,
    r0: float,
    d0: float,
    model: CompartmentalModel | None = None,
    schedule=None,  # InterventionSchedule; theta carries its scale columns
) -> jax.Array:
    """Distances [B]: simulate T days with hash RNG, Euclidean vs observed."""
    if model is None:
        from repro.epi.models import DEFAULT_MODEL as model  # noqa: N811
    theta = jnp.asarray(theta, jnp.float32)
    batch = theta.shape[0]
    num_days = observed.shape[1]
    cfg = EpiModelConfig(
        population=population, num_days=num_days, a0=a0, r0=r0, d0=d0
    )
    idx = jnp.arange(batch, dtype=jnp.uint32)
    state0 = engine.initial_state(model, theta, cfg)
    obs_by_day = jnp.swapaxes(jnp.asarray(observed, jnp.float32), 0, 1)  # [T, n_obs]

    def step(carry, inp):
        state, acc = carry
        day, obs_t = inp
        z = hash_normals(seed, idx, day, model.n_transitions)  # [B, n_trans]
        th_d = engine.effective_theta(model, schedule, theta, day)
        nxt = engine.tau_leap_step(model, state, th_d, z, cfg.population)
        diff = nxt[..., model.observed_idx] - obs_t
        return (nxt, acc + jnp.sum(diff * diff, axis=-1)), None

    days = jnp.arange(num_days, dtype=jnp.uint32)
    acc0 = state0[..., 0] * 0.0  # inherits varying mesh axes under shard_map
    (state_f, acc), _ = jax.lax.scan(step, (state0, acc0), (days, obs_by_day))
    del state_f
    return jnp.sqrt(acc)
