"""Pure-jnp oracle for the fused ABC simulation kernel.

Reuses the verified generic engine (`repro.epi.engine`) for the dynamics and
the shared counter-based RNG primitive (`repro.kernels.rng`) for the noise,
so kernel-vs-oracle tests check the kernel's tiling/looping/layout logic
against an independent formulation of the same math — for ANY registered
`CompartmentalModel` spec, not just the paper's SIARD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.summaries import (
    get_distance_kind,
    get_summary,
    lower_summary,
    pool_channels,
    pool_factor,
    running_day,
    running_finalize,
)
from repro.epi import engine
from repro.epi.spec import CompartmentalModel, EpiModelConfig
from repro.kernels import rng as krng


def hash_normals(
    seed, idx: jax.Array, day, n_transitions: int = 5, slots: int = 8
) -> jax.Array:
    """Noise block [B, n_transitions] for one day from the counter stream.

    For metapop models `n_transitions` is the flattened region-major total
    (R * per-region transitions) and `slots` is `model.ctr_slots`; at R=1
    both collapse to the legacy (n_transitions, 8) layout bit-exactly."""
    cols = []
    for k in range(n_transitions):
        cols.append(krng.normal(seed, idx, krng.day_transition_ctr(day, k, slots)))
    return jnp.stack(cols, axis=-1)


def abc_sim_distance_ref(
    theta: jax.Array,  # [B, n_params (+ n_scales)] f32
    seed,  # uint32 scalar
    observed: jax.Array,  # [n_observed, T] f32
    *,
    population: float,
    a0: float,
    r0: float,
    d0: float,
    model: CompartmentalModel | None = None,
    schedule=None,  # InterventionSchedule; theta carries its scale columns
    summary=None,  # SummarySpec / registry name / None (identity)
    distance: str = "euclidean",  # core.summaries.DISTANCE_KINDS name
    mobility=None,  # [R, R] row-stochastic override (metapop models)
) -> jax.Array:
    """Distances [B]: simulate T days with hash RNG, summary distance vs
    observed. Default (identity, euclidean) is the paper's raw Euclidean and
    reduces bit-exactly to the legacy running sum-of-squares; any other pair
    uses the same generalized running accumulator the kernel lowers
    (core.summaries.running_day), pinning kernel-vs-oracle parity per pair."""
    if model is None:
        from repro.epi.models import DEFAULT_MODEL as model  # noqa: N811
    spec = get_summary(summary)
    kind = get_distance_kind(distance)
    lowered = lower_summary(spec, distance, observed, n_regions=model.n_regions)
    pool = pool_factor(spec, model.n_regions)
    mob = engine.mobility_matrix(model, mobility) if model.is_regional else None
    theta = jnp.asarray(theta, jnp.float32)
    batch = theta.shape[0]
    num_days = observed.shape[1]
    cfg = EpiModelConfig(
        population=population, num_days=num_days, a0=a0, r0=r0, d0=d0
    )
    idx = jnp.arange(batch, dtype=jnp.uint32)
    state0 = engine.initial_state(model, theta, cfg)
    obs_by_day = jnp.swapaxes(lowered.obs_summary, 0, 1)  # [T, n_obs]

    obs_idx = model.total_observed_idx

    def step(carry, inp):
        state, cum, binv, acc = carry
        day, obs_t, flush_t = inp
        z = hash_normals(
            seed, idx, day, model.total_transitions, model.ctr_slots
        )  # [B, R * n_trans]
        th_d = engine.effective_theta(model, schedule, theta, day)
        nxt = engine.tau_leap_step(
            model, state, th_d, z, cfg.population, mobility=mob
        )
        cum, binv, acc = running_day(
            spec, kind, lowered.weights,
            pool_channels(nxt[..., obs_idx], pool), obs_t,
            flush_t, cum, binv, acc,
        )
        return (nxt, cum, binv, acc), None

    days = jnp.arange(num_days, dtype=jnp.uint32)
    acc0 = state0[..., 0] * 0.0  # inherits varying mesh axes under shard_map
    chan0 = pool_channels(state0[..., obs_idx], pool) * 0.0
    (state_f, _, _, acc), _ = jax.lax.scan(
        step, (state0, chan0, chan0, acc0), (days, obs_by_day, lowered.flush)
    )
    del state_f
    return running_finalize(kind, lowered.mean_scale, acc)
