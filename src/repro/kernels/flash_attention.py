"""Flash attention Pallas kernel (causal/GQA/softcap) — the §Perf next lever
for the dense train cells (EXPERIMENTS.md, gemma2-27b iteration log).

The dense cells' memory term is dominated by the f32 score/softmax round
trips of the pure-JAX blockwise path: every [q_block, kv_block] score tile
and its online-softmax statistics cross HBM at fusion boundaries. This
kernel keeps the entire (m, l, acc) state AND the score tile in VMEM for the
whole KV sweep — one HBM read per K/V tile, one write per O tile, nothing
else. Napkin (gemma2 train_4k, per layer per device): blockwise-JAX traffic
~ 3.4 GB of f32 score-chain tiles vs flash ~ 0.20 GB of bf16 q/k/v/o tiles
(~17x on the attention term, est. -30% on the cell's t_mem).

Validated against the dense oracle in interpret mode
(tests/test_kernel_flash.py). NOT wired into the model forward by default:
Pallas custom-calls are opaque to the dry-run HLO analyzer, so enabling it
would silently drop the attention term from the roofline accounting; on real
TPU hardware flip `attn_impl="flash_pallas"` (common.attention routes it).

Grid: (B, H, n_q_blocks); each cell sweeps the KV sequence with a fori_loop,
carrying (m, l, acc) as VMEM values. K/V arrive as full-sequence blocks per
(batch, kv-head) — VMEM budget 2*S*D bytes (bf16), fine to S=16k at D=128;
longer sequences would move KV to a fourth sequential grid axis.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, q_block: int, kv_block: int,
            seq_len: int, causal: bool, window: Optional[int],
            softcap: Optional[float], scale: float, kv_len: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # [q_block, D]
    n_kv = kv_len // kv_block

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)

    def body(ki, carry):
        m, l, acc = carry
        # Leading unit dims are indexed with size-1 dslices, NOT bare ints:
        # with a traced slice start (ki), jax 0.4.x's interpret-mode load
        # discharge assumes every non-Slice index is an array and calls
        # `.shape` on it — a python int there crashes the interpreter.
        kv = pl.dslice(ki * kv_block, kv_block)
        unit = pl.dslice(0, 1)
        k = pl.load(k_ref, (unit, unit, kv, slice(None)))[0, 0]
        v = pl.load(v_ref, (unit, unit, kv, slice(None)))[0, 0]
        s = jnp.dot(q, k[...].astype(jnp.float32).T)  # [q_block, kv_block]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        k_pos = ki * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (q_block, kv_block), 1
        )
        ok = k_pos < seq_len  # padding mask
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(p, v[...].astype(jnp.float32))
        return m_new, l_new, acc_new

    m0 = jnp.full((q_block,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((q_block,), jnp.float32)
    a0 = jnp.zeros((q_block, q_ref.shape[-1]), jnp.float32)
    if causal:
        # only sweep KV blocks that intersect the causal triangle
        hi = jnp.minimum(((qi + 1) * q_block + kv_block - 1) // kv_block, n_kv)
    else:
        hi = n_kv
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # [B, H, Sq_pad, D]
    k: jax.Array,  # [B, KH, Skv_pad, D]
    v: jax.Array,
    *,
    seq_len: int,  # true (unpadded) kv length
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    assert sq % q_block == 0 and skv % kv_block == 0
    grid = (b, h, sq // q_block)
    return pl.pallas_call(
        functools.partial(
            _kernel, q_block=q_block, kv_block=kv_block, seq_len=seq_len,
            causal=causal, window=window, softcap=softcap, scale=scale,
            kv_len=skv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, skv, d), lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, skv, d), lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
