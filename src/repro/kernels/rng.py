"""Counter-based RNG primitive shared by the Pallas kernel and its oracle.

The IPU samples Normal(h, sqrt(h)) on-tile; the TPU-native analogue is a
stateless counter-based generator evaluated inside the kernel, so the noise
tensor [B, T, 5] never exists in HBM. We use a murmur3-finalizer double-mix
hash on (seed, sample-index, counter) -> uint32 -> Box-Muller. It is NOT
crypto-grade but passes the statistical checks in tests/test_rng.py
(moments, uniformity, lag correlation). On real TPU hardware the production
alternative is `pltpu.prng_random_bits`; the hash path is kept because it is
bit-reproducible across CPU interpret mode and TPU, which is what makes the
kernel-vs-oracle tests exact and ABC runs replayable across backends.

All functions operate on uint32 arrays of any shape and are pure jnp, so the
same code runs inside a Pallas kernel body and in the pure-jnp oracle.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_P1 = np.uint32(0x9E3779B1)  # golden-ratio prime — sample index stream
_P2 = np.uint32(0x85EBCA77)  # counter stream
_X1 = np.uint32(0x1B873593)  # second-round decorrelation constant

_TWO_PI = np.float32(2.0 * np.pi)
_INV_2_24 = np.float32(1.0 / (1 << 24))


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (bijective mix)."""
    x = x ^ (x >> 16)
    x = x * _M1
    x = x ^ (x >> 13)
    x = x * _M2
    x = x ^ (x >> 16)
    return x


def hash_u32(seed: jnp.ndarray, idx: jnp.ndarray, ctr) -> jnp.ndarray:
    """Counter-based uint32 stream: h(seed, sample-idx, counter)."""
    ctr = jnp.asarray(ctr, jnp.uint32)
    h = (
        jnp.asarray(seed, jnp.uint32)
        ^ (jnp.asarray(idx, jnp.uint32) * _P1)
        ^ (ctr * _P2)
    )
    return fmix32(fmix32(h ^ _X1))


def uniform_open(seed, idx, ctr) -> jnp.ndarray:
    """U in (0, 1]: ((h >> 8) + 1) * 2^-24 — log-safe."""
    h = hash_u32(seed, idx, ctr)
    return ((h >> np.uint32(8)) + np.uint32(1)).astype(jnp.float32) * _INV_2_24


def normal(seed, idx, ctr) -> jnp.ndarray:
    """Standard normal via Box–Muller (cos branch).

    Consumes counters (2*ctr, 2*ctr + 1) of the (seed, idx) stream.
    """
    ctr = jnp.asarray(ctr, jnp.uint32)
    two = np.uint32(2)
    one = np.uint32(1)
    u1 = uniform_open(seed, idx, ctr * two)
    u2 = uniform_open(seed, idx, ctr * two + one)
    r = jnp.sqrt(np.float32(-2.0) * jnp.log(u1))
    return r * jnp.cos(_TWO_PI * u2)


def day_transition_ctr(day, k, slots: int = 8) -> jnp.ndarray:
    """Counter layout: `slots` transition slots per day (8 by default, 5
    used by the paper's SIARD). Metapop models widen to
    `CompartmentalModel.ctr_slots` (the next multiple of 8 above
    R * n_transitions, flattened region-major: slot r * n_transitions + k);
    at R=1 that is exactly 8, so single-region streams are unchanged."""
    return jnp.asarray(day, jnp.uint32) * np.uint32(slots) + jnp.asarray(k, jnp.uint32)
