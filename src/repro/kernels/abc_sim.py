"""Fused ABC simulation kernel: whole-horizon tau-leap + running distance in VMEM.

This is the TPU-native translation of the paper's IPU insight (DESIGN.md §2):
keep the ENTIRE simulation working set in near-compute memory for the whole
horizon. One `pallas_call` grid cell owns a tile of `TB` samples and:

    state  <- initial_state(theta)                  (VMEM registers)
    for day in 0..T-1:                              (fori_loop, on-chip)
        z      <- counter-based RNG (in-kernel)     (never touches HBM)
        h      <- hazards(state, theta)
        n      <- clamp(floor(h + sqrt(h) * z))
        state  <- apply_transitions(state, n)
        acc    += ||state[ARD] - obs[:, day]||^2    (running distance)
    dist   <- sqrt(acc)                             (single [TB] HBM write)

HBM traffic per sample: 8 floats of theta in + 1 float distance out = 36 B,
versus the naive path's >= (T*5 noise + T*3 trajectory + T*6 state round
trips) * 4 B ~ 2.3 KB/sample at T=49. Arithmetic intensity rises ~60x, which
is what moves the workload from the memory roofline to the compute roofline
(EXPERIMENTS.md §Perf, ABC rows).

Data layout: samples ride the 128-lane minor dimension; theta arrives
transposed [8, B] so each parameter is one (1, TB) VREG row; the 6 state
channels are six (1, TB) rows carried through the day loop as values (VREGs),
not refs. `TB` defaults to 1024 lanes -> peak VMEM per cell ~ 200 KB, far
under the ~16 MB/core budget, leaving room for multiple concurrent grid cells.

The kernel returns per-sample distances; accept/compaction stays in XLA
(lax.top_k / chunk flags) because it is O(B) cheap and the paper's two
host-return strategies live above the kernel (core/abc.py).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import rng as krng

# fconsts layout (f32): [population, a0, r0, d0, num_days, 0...]
# iconsts layout (i32): [seed, 0...]
_CONST_LANES = 128


def _kernel(theta_ref, obs_ref, fconst_ref, iconst_ref, dist_ref, *, num_days: int, tile: int):
    """Pallas kernel body. Shapes:
    theta_ref  (8, TB)   — params x samples (transposed)
    obs_ref    (8, Tp)   — rows 0..2 = observed A, R, D per day (padded)
    fconst_ref (1, 128)  — f32 scalars
    iconst_ref (1, 128)  — i32 scalars (seed)
    dist_ref   (1, TB)   — output Euclidean distances
    """
    population = fconst_ref[0, 0]
    a0 = fconst_ref[0, 1]
    r0 = fconst_ref[0, 2]
    d0 = fconst_ref[0, 3]
    seed = iconst_ref[0, 0].astype(jnp.uint32)

    # global sample index of each lane in this tile
    tile_idx = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, tile), 1)
    idx = lane + jnp.uint32(tile) * tile_idx.astype(jnp.uint32)

    # theta rows, each (1, TB)
    alpha0 = theta_ref[0:1, :]
    alpha = theta_ref[1:2, :]
    n_exp = theta_ref[2:3, :]
    beta = theta_ref[3:4, :]
    gamma = theta_ref[4:5, :]
    delta = theta_ref[5:6, :]
    eta = theta_ref[6:7, :]
    kappa = theta_ref[7:8, :]

    # paper step 1: initial state
    i_pop = kappa * a0
    s_pop = population - (a0 + r0 + d0 + i_pop)
    ones = jnp.ones_like(alpha0)
    a_pop = ones * a0
    r_pop = ones * r0
    d_pop = ones * d0
    ru_pop = jnp.zeros_like(alpha0)
    acc = jnp.zeros_like(alpha0)

    def day_step(day, carry):
        s, i, a, r, d, ru, acc = carry
        # paper step 2: hazards (eq. 4-5)
        ard = a + r + d
        g = alpha0 + alpha / (1.0 + jnp.power(jnp.maximum(ard, 0.0), n_exp))
        h1 = jnp.maximum(g * s * i / population, 0.0)  # S -> I
        h2 = jnp.maximum(gamma * i, 0.0)  # I -> A
        h3 = jnp.maximum(beta * a, 0.0)  # A -> R
        h4 = jnp.maximum(delta * a, 0.0)  # A -> D
        h5 = jnp.maximum(beta * eta * i, 0.0)  # I -> Ru

        # paper step 3: Gaussian tau-leap noise, generated in-register
        def draw(h, k):
            z = krng.normal(seed, idx, krng.day_transition_ctr(day, k))
            return jnp.floor(h + jnp.sqrt(h) * z)

        n1 = jnp.clip(draw(h1, 0), 0.0, s)
        n2 = jnp.clip(draw(h2, 1), 0.0, i)
        n3 = jnp.clip(draw(h3, 2), 0.0, a)
        n4 = jnp.clip(draw(h4, 3), 0.0, a - n3)
        n5 = jnp.clip(draw(h5, 4), 0.0, i - n2)

        # paper step 4: apply transitions
        s = s - n1
        i = i + n1 - n2 - n5
        a = a + n2 - n3 - n4
        r = r + n3
        d = d + n4
        ru = ru + n5

        # running Euclidean accumulation (beyond-paper fusion, DESIGN.md §2)
        obs_t = pl.load(obs_ref, (slice(0, 8), pl.dslice(day, 1)))  # (8, 1)
        da = a - obs_t[0:1]
        dr = r - obs_t[1:2]
        dd = d - obs_t[2:3]
        acc = acc + da * da + dr * dr + dd * dd
        return (s, i, a, r, d, ru, acc)

    carry = (s_pop, i_pop, a_pop, r_pop, d_pop, ru_pop, acc)
    carry = jax.lax.fori_loop(0, num_days, day_step, carry)
    dist_ref[...] = jnp.sqrt(carry[6])


def abc_sim_distance_kernel(
    theta_t: jax.Array,  # [8, B] f32 (transposed, B multiple of tile)
    obs_pad: jax.Array,  # [8, Tp] f32 (rows 0..2 = A,R,D)
    fconsts: jax.Array,  # [1, 128] f32
    iconsts: jax.Array,  # [1, 128] i32
    *,
    num_days: int,
    tile: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Raw pallas_call wrapper; returns distances [1, B]. See ops.py for the
    user-facing API (padding, layout, backend selection)."""
    n_params, batch = theta_t.shape
    assert n_params == 8 and batch % tile == 0
    t_pad = obs_pad.shape[1]
    grid = (batch // tile,)
    return pl.pallas_call(
        functools.partial(_kernel, num_days=num_days, tile=tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((8, tile), lambda i: (0, i)),  # theta tile
            pl.BlockSpec((8, t_pad), lambda i: (0, 0)),  # full obs each cell
            pl.BlockSpec((1, _CONST_LANES), lambda i: (0, 0)),
            pl.BlockSpec((1, _CONST_LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, batch), jnp.float32),
        interpret=interpret,
    )(theta_t, obs_pad, fconsts, iconsts)
