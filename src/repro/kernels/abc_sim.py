"""Fused ABC simulation kernel: whole-horizon tau-leap + running distance in VMEM.

This is the TPU-native translation of the paper's IPU insight (DESIGN.md §2):
keep the ENTIRE simulation working set in near-compute memory for the whole
horizon. One `pallas_call` grid cell owns a tile of `TB` samples and:

    state  <- initial_state(theta)                  (VMEM registers)
    for day in 0..T-1:                              (fori_loop, on-chip)
        z      <- counter-based RNG (in-kernel)     (never touches HBM)
        h      <- hazards(state, theta)
        n      <- clamp(floor(h + sqrt(h) * z))     (sequential source drain)
        state  <- state + stoichiometry^T @ n
        acc    += ||state[obs] - obs[:, day]||^2    (running distance)
    dist   <- sqrt(acc)                             (single [TB] HBM write)

Since the stoichiometry-driven refactor the kernel body is generic over any
`CompartmentalModel` spec (repro.epi.spec): the spec's hazard rows, initial
rows, clamp order and stoichiometry are *inlined at trace time* — the Python
loops over transitions/compartments below unroll into straight-line vector
code, exactly like the previous hand-unrolled SIARD body. The spec is a
static (hashable) argument, so each model compiles its own specialized
kernel with VMEM tiles sized from its `n_state` / `n_params`.

HBM traffic per sample: theta's row count in floats (n_params, plus
n_windows*n_tv scale rows under a schedule) + 1 float distance out (36 B for
the paper model unscheduled), versus the naive path's >= (T*n_trans noise +
T*n_obs trajectory + T*n_state state round trips) * 4 B ~ 2.3 KB/sample at
T=49. Arithmetic intensity rises ~60x, which is what moves the workload from
the memory roofline to the compute roofline (EXPERIMENTS.md §Perf, ABC rows).

Data layout: samples ride the 128-lane minor dimension; theta arrives
transposed [n_params_pad, B] (sublane-padded to a multiple of 8) so each
parameter is one (1, TB) VREG row. Under an intervention schedule the theta
block widens to [n_params + n_windows*n_tv, B]: the extra window-major scale
rows are selected per day by unrolled VREG selects against the window index
(breakpoint days arrive as iconst scalars, so they are runtime values — a
lockdown-day sweep reuses one compiled kernel). The n_state channels are
(1, TB) rows carried through the day loop as values (VREGs), not refs.
`TB` is a required tuning knob resolved by `kernels.ops.resolve_tile`
(auto default: 1024 lanes, shrunk to the batch's power-of-two for small
batches) and searched by the measured autotuner (repro.core.tuning) over
{256..4096}; peak VMEM per cell ~ TB/1024 * (n_state + n_params + n_trans
+ 2*n_obs) * 4 KB (the 2*n_obs rows are the summary accumulator's cum/bin
carries), far under the ~16 MB/core budget even at TB=4096, leaving room
for concurrent grid cells. The in-kernel RNG streams are indexed by the
GLOBAL sample index `idx = lane + TB * tile_idx`, so distances — and the
accepted particle sets above them — are bit-identical across tile sizes
(pinned by tests); the tile is pure scheduling.

The per-day distance accumulation is the traced-select lowering of the
generalized summary accumulator (repro.core.summaries): the observed block
arrives PRE-SUMMARIZED, and the channel weights / transform selectors /
distance finalizer ride fconst+iconst lanes exactly like the intervention
breakpoints — so a (summary, distance) sweep shares one compiled kernel,
and the default identity+euclidean lanes reproduce the legacy running
Euclidean bit-for-bit.

The kernel returns per-sample distances; accept/compaction stays in XLA
(lax.top_k / chunk flags) because it is O(B) cheap and the paper's two
host-return strategies live above the kernel (core/abc.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.epi import engine
from repro.epi.spec import CompartmentalModel, ScheduleShape
from repro.kernels import rng as krng

# fconsts layout (f32): [population, a0, r0, d0, mean_scale, 0...,
#                        summary channel weights at lanes 8..8+n_chan,
#                        row-major mobility matrix at lanes
#                        8+n_chan..8+n_chan+R*R (metapop models only)]
# iconsts layout (i32): [seed, breakpoint_0..breakpoint_{n_windows-1}, 0...,
#                        summary flags at lanes _SUM_ILANE.._SUM_ILANE+4]
_CONST_LANES = 128
#: sublane granularity for f32 tiles — theta/obs rows are padded to this
_SUBLANES = 8
#: first iconst lane of the summary selector vector (core.summaries.FLAG_*:
#: cumulative, log1p, power, root, bin_days). Selectors and weights are
#: RUNTIME values, exactly like the intervention breakpoints: a summary /
#: distance sweep reuses one compiled kernel (pinned by a jit-cache test).
_SUM_ILANE = 120
#: fconst lane of the distance finalizer's mean scale (1/n_terms or 1.0)
_MEAN_SCALE_LANE = 4
#: first fconst lane of the per-channel summary weights
_WEIGHT_LANE = 8


def n_summary_channels(model: CompartmentalModel, pool: int) -> int:
    """Summary channels the kernel accumulates: the region-major flattened
    observed count, divided by the static region-pooling factor."""
    assert model.total_observed % pool == 0, (model.name, pool)
    return model.total_observed // pool


def mobility_lane(model: CompartmentalModel, pool: int) -> int:
    """First fconst lane of the row-major mobility matrix (after the
    summary channel weights)."""
    return _WEIGHT_LANE + n_summary_channels(model, pool)


def kernel_lane_budget_ok(model: CompartmentalModel, pool: int) -> bool:
    """Whether this model's weights + mobility fit the 128 fconst lanes.

    Metapop models need n_chan weight lanes plus R*R mobility lanes; at
    R=10 with 2 unpooled observed channels that is exactly 128. Larger R
    must route through the XLA backends (ops.abc_sim_distance raises a
    loud ValueError pointing there)."""
    lanes = mobility_lane(model, pool)
    if model.is_regional:
        lanes += model.n_regions * model.n_regions
    return lanes <= _CONST_LANES


def sublane_pad(n: int) -> int:
    """Round a row count up to the f32 sublane tile granularity (min 8)."""
    return max(_SUBLANES, -(-n // _SUBLANES) * _SUBLANES)


def auto_interpret() -> bool:
    """Backend-aware Pallas dispatch: the interpreter is a CPU-only
    correctness fallback — on TPU (and GPU/triton) the kernel must compile.
    """
    return jax.default_backend() == "cpu"


def theta_width(model: CompartmentalModel, sched: ScheduleShape | None) -> int:
    """Rows of the (possibly schedule-widened) transposed theta layout."""
    return model.n_params + (sched.n_scales if sched is not None else 0)


def _kernel(
    theta_ref,
    obs_ref,
    fconst_ref,
    iconst_ref,
    dist_ref,
    *,
    model: CompartmentalModel,
    num_days: int,
    tile: int,
    sched: ScheduleShape | None = None,
    pool: int = 1,
):
    """Generic Pallas kernel body, specialized per model spec. Shapes:
    theta_ref  (Pp, TB)  — params x samples (transposed, sublane-padded);
                           rows n_params.. are window-major intervention
                           scales when `sched` is set
    obs_ref    (Op, Tp)  — rows 0..n_chan-1 = OBSERVED-SIDE SUMMARY values
                           per day (running-bin layout, padded; region-major
                           flattened — or region-pooled when `pool` > 1)
    fconst_ref (1, 128)  — f32 scalars (incl. summary weights / mean scale /
                           row-major mobility matrix for metapop models)
    iconst_ref (1, 128)  — i32 scalars (seed, breakpoint days, summary flags)
    dist_ref   (1, TB)   — output summary distances

    Metapop models carry the region axis as extra UNROLLED (1, TB) rows:
    state/hazard/noise rows are region-major flattened (row r * n + j),
    per-region seeding and population P/R match the XLA engine, the
    mobility-weighted coupled rows are built from fconst mobility lanes, and
    the RNG counter stream widens to `model.ctr_slots` slots per day. At
    R=1 every regional term collapses (one region, slot stride 8, identity
    weights), so single-region kernels stay bit-identical to the pre-metapop
    body — pinned by tests/test_metapop.py.
    """
    population = fconst_ref[0, 0]
    a0 = fconst_ref[0, 1]
    r0 = fconst_ref[0, 2]
    d0 = fconst_ref[0, 3]
    seed = iconst_ref[0, 0].astype(jnp.uint32)
    # breakpoint days ride iconst lanes, so lockdown-day sweeps NEVER
    # recompile the kernel — only the schedule's shape is a compile key
    n_windows = sched.n_windows if sched is not None else 0
    breakpoints = tuple(iconst_ref[0, 1 + i] for i in range(n_windows))
    # summary/distance selectors + weights are runtime lanes too (one
    # compiled kernel serves every (summary, distance) pair): the kernel
    # body below is the traced-select twin of core.summaries.running_day
    mean_scale = fconst_ref[0, _MEAN_SCALE_LANE]
    n_chan = n_summary_channels(model, pool)
    weights = tuple(
        fconst_ref[0, _WEIGHT_LANE + m] for m in range(n_chan)
    )
    cumulative = iconst_ref[0, _SUM_ILANE + 0]
    use_log1p = iconst_ref[0, _SUM_ILANE + 1]
    power = iconst_ref[0, _SUM_ILANE + 2]
    root = iconst_ref[0, _SUM_ILANE + 3]
    bin_days = iconst_ref[0, _SUM_ILANE + 4]

    # region geometry: flat models are the R=1 degenerate case throughout
    R = model.n_regions
    C = model.n_state
    T = model.n_transitions
    slots = model.ctr_slots
    pop_r = population / R if R > 1 else population
    if model.is_regional:
        # mobility rides fconst lanes (row-major), like the breakpoints: a
        # mobility sweep reuses one compiled kernel
        ml = mobility_lane(model, pool)
        mob = tuple(
            tuple(fconst_ref[0, ml + r * R + q] for q in range(R))
            for r in range(R)
        )

    # global sample index of each lane in this tile
    tile_idx = pl.program_id(0)
    lane = jax.lax.broadcasted_iota(jnp.uint32, (1, tile), 1)
    idx = lane + jnp.uint32(tile) * tile_idx.astype(jnp.uint32)

    # theta rows, each (1, TB): base params plus any per-window scale rows
    pc = tuple(
        theta_ref[k : k + 1, :] for k in range(theta_width(model, sched))
    )

    # spec step 1: initial state rows + summary carries (cum/bin per summary
    # channel) + distance accumulator (base params only — interventions scale
    # hazards, never the day-0 seeding). Region `seed_region` receives the
    # dataset's day-0 counts; every other region starts fully susceptible.
    state0 = []
    for r in range(R):
        if R > 1:
            z = 1.0 if r == model.seed_region else 0.0
            rows = model.initial_rows(
                pc[: model.n_params], pop_r, a0 * z, r0 * z, d0 * z
            )
        else:
            rows = model.initial_rows(pc[: model.n_params], pop_r, a0, r0, d0)
        state0.extend(rows)
    acc0 = jnp.zeros_like(state0[0])
    chan0 = tuple(jnp.zeros_like(state0[0]) for _ in range(2 * n_chan))

    obs_idx = model.observed_idx
    n_obs_rows = obs_ref.shape[0]
    ns = R * C

    def day_step(day, carry):
        sc = list(carry[:ns])
        cum = list(carry[ns : ns + n_chan])
        binr = list(carry[ns + n_chan : ns + 2 * n_chan])
        acc = carry[ns + 2 * n_chan]
        # day-effective params: the window selects unroll into straight-line
        # VREG selects (shared row-level code with the XLA engine)
        pc_d = engine.effective_param_rows(model, sched, pc, day, breakpoints)
        new_sc = []
        for r in range(R):
            sc_r = sc[r * C : (r + 1) * C]
            # coupled rows: mobility-weighted compartment mass, appended
            # after the local compartments (spec contract) — unrolls into
            # R multiply-adds per coupled compartment
            extra = []
            for j in model.coupled_idx:
                row = mob[r][0] * sc[j]
                for q in range(1, R):
                    row = row + mob[r][q] * sc[q * C + j]
                extra.append(row)
            # spec step 2: hazards (rates cannot be negative)
            h = [
                jnp.maximum(row, 0.0)
                for row in model.hazard_rows(
                    tuple(sc_r) + tuple(extra), pc_d, pop_r
                )
            ]
            # spec step 3: Gaussian tau-leap counts, generated in-register;
            # counter slot r*T + k in the slots-per-day stream (slot stride
            # 8 and r=0 at R=1 — the legacy layout)
            raw = []
            for k in range(T):
                z = krng.normal(
                    seed, idx, krng.day_transition_ctr(day, r * T + k, slots)
                )
                raw.append(jnp.floor(h[k] + jnp.sqrt(h[k]) * z))
            # spec step 4: sequential source-draining clamp + stoichiometry —
            # shared row-level code with the XLA engine (unrolls at trace
            # time; per-region, the stoichiometry is block-diagonal)
            new_sc.extend(engine.drain_and_apply(model, sc_r, raw))
        sc = new_sc

        # running summary-distance accumulation (beyond-paper fusion,
        # DESIGN.md §2): the traced-select form of summaries.running_day.
        # Identity + euclidean (all-false selects, weights 1.0) is bit-
        # identical to the legacy per-channel squared accumulation.
        # Region-pooled summaries sum each channel across regions here,
        # collapsing the carry to n_obs rows.
        if pool > 1:
            xs = []
            for j in obs_idx:
                x = sc[j]
                for r in range(1, R):
                    x = x + sc[r * C + j]
                xs.append(x)
        else:
            xs = [sc[g] for g in model.total_observed_idx]
        obs_t = pl.load(obs_ref, (slice(0, n_obs_rows), pl.dslice(day, 1)))
        flush = jnp.logical_or(
            (day + 1) % bin_days == 0, day == num_days - 1
        ).astype(jnp.float32)
        for m in range(n_chan):
            x = xs[m]
            c = cum[m] + x
            v = jnp.where(cumulative == 1, c, x)
            # cumulative channels bin by their latest LEVEL, rates by the
            # running within-bin sum (see summaries module docstring)
            b = jnp.where(cumulative == 1, v, binr[m] + v)
            s = jnp.where(use_log1p == 1, jnp.log1p(jnp.maximum(b, 0.0)), b)
            diff = s - obs_t[m : m + 1]
            term = jnp.where(power == 1, jnp.abs(diff), diff * diff)
            acc = acc + flush * (weights[m] * term)
            cum[m] = c
            binr[m] = b * (1.0 - flush)
        return (*sc, *cum, *binr, acc)

    carry = jax.lax.fori_loop(0, num_days, day_step, (*state0, *chan0, acc0))
    acc = carry[ns + 2 * n_chan] * mean_scale
    dist_ref[...] = jnp.where(root == 1, jnp.sqrt(acc), acc)


def abc_sim_distance_kernel(
    theta_t: jax.Array,  # [Pp, B] f32 (transposed, sublane-padded, B % tile == 0)
    obs_pad: jax.Array,  # [Op, Tp] f32 (rows 0..n_obs-1 = observed channels)
    fconsts: jax.Array,  # [1, 128] f32
    iconsts: jax.Array,  # [1, 128] i32 (seed + breakpoint days)
    *,
    model: CompartmentalModel,
    num_days: int,
    tile: int,
    interpret: bool | None = None,
    sched: ScheduleShape | None = None,
    pool: int = 1,
) -> jax.Array:
    """Raw pallas_call wrapper; returns distances [1, B]. See ops.py for the
    user-facing API (padding, layout, backend selection).

    `interpret=None` dispatches by backend (`auto_interpret`): the Python
    interpreter only on CPU, a compiled kernel everywhere else.
    """
    if interpret is None:
        interpret = auto_interpret()
    p_pad, batch = theta_t.shape
    assert p_pad == sublane_pad(theta_width(model, sched)) and batch % tile == 0
    o_pad, t_pad = obs_pad.shape
    assert o_pad == sublane_pad(n_summary_channels(model, pool))
    grid = (batch // tile,)
    return pl.pallas_call(
        functools.partial(
            _kernel, model=model, num_days=num_days, tile=tile, sched=sched,
            pool=pool,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_pad, tile), lambda i: (0, i)),  # theta tile
            pl.BlockSpec((o_pad, t_pad), lambda i: (0, 0)),  # full obs each cell
            pl.BlockSpec((1, _CONST_LANES), lambda i: (0, 0)),
            pl.BlockSpec((1, _CONST_LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, batch), jnp.float32),
        interpret=interpret,
    )(theta_t, obs_pad, fconsts, iconsts)
