"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (dry-run contract).

Version compatibility: `jax.sharding.AxisType` only exists on newer jax
releases (>= 0.5.x); on older versions (e.g. the 0.4.37 in this container)
`jax.make_mesh` takes no `axis_types` and every axis is implicitly the
auto-sharded kind we request anyway. `make_compat_mesh` hides the difference
for every mesh built in this repo (and in tests).
"""

from __future__ import annotations

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """`{"axis_types": (AxisType.Auto,) * n}` where supported, else `{}`."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:  # jax < 0.5: no explicit axis types; Auto is implied
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_compat_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types where the jax version has them."""
    shape = tuple(shape)
    axes = tuple(axes)
    return jax.make_mesh(shape, axes, **axis_types_kwargs(len(axes)))


def set_mesh_compat(mesh):
    """Context manager: `jax.set_mesh(mesh)` where it exists, else the mesh.

    `jax.set_mesh` is the >= 0.5.x way to install an ambient mesh; on the
    0.4.x pin the Mesh object is itself the context manager with the same
    scoped semantics (it threads the physical mesh through thread_resources,
    which `models.moe._ambient_mesh` and pjit both read). Every `with
    jax.set_mesh(...)` in this repo goes through here.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; two pods for the multi-pod dry run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_compat_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over this host's devices (tests / CPU demos)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return make_compat_mesh((n // model, model), ("data", "model"))
