"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (dry-run contract)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; two pods for the multi-pod dry run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over this host's devices (tests / CPU demos)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
