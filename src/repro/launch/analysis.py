"""Compiled-HLO analysis: loop-aware FLOP/byte/collective accounting + roofline.

This is the "profiler" of the dry-run methodology: with no TPU attached, the
three roofline terms come from the compiled artifact —

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_wire_bytes_per_device / link_bw

`compiled.cost_analysis()` reports while-loop bodies ONCE (verified
empirically: a 5-step scan reports ~1x the matmul flops), which silently
drops ~n_layers x of the real work for scanned models. So we analyze
`compiled.as_text()` directly:

  * computations are parsed into symbol tables (every instruction's result
    type is inline; operand types resolve by name, incl. tuple params);
  * the call graph is walked from ENTRY; while bodies multiply downstream
    costs by the trip count recovered from the loop condition's comparison
    constant (the condition block contains exactly the bound constant);
  * dot ops contribute 2 * prod(result dims) * prod(contracted dims) FLOPs
    (matmul-only count — elementwise is <5% for LM archs and is reported
    separately as a fusion-byte-based bound);
  * every compute op contributes operand+result bytes (fusions are treated
    as single kernels: internal traffic hidden, matching XLA's own model);
  * collectives are credited with ring-algorithm wire bytes.

The raw cost_analysis() numbers are retained in the record for reference.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e constants (mandated by the brief)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \((.*)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+) = ((?:\([^)]*\)|\S+)) ([\w\-]+)(?:\(([^)]*)\))?"
)
_PARAM_RE = re.compile(r"([\w.\-]+): ((?:\([^)]*\)|[^,)]+))")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_ATTR_COMP_RE = re.compile(r"(condition|body|calls|to_apply|branch_computations)=")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _type_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES or dt == "token":
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _type_dims(type_str):
        if dt == "token":
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m and m.group(1):
        first = m.group(1).split("},{")[0]
        return max(1, len([x for x in re.split(r"[,{}]", first) if x.strip()]))
    return 1


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    symbols: Dict[str, str]  # name -> type string
    instrs: List[Instr]


def parse_hlo(txt: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in txt.splitlines():
        line = raw.rstrip()
        s = line.strip()
        hm = _HEADER_RE.match(s)
        if hm and line.endswith("{"):
            cur = Computation(hm.group(1), {}, [])
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            # parameters declared in the header carry their types
            for pname, ptype in _PARAM_RE.findall(hm.group(2)):
                cur.symbols[pname] = ptype
            continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, type_str, op, args = im.group(1), im.group(2), im.group(3), im.group(4)
            operands = re.findall(r"%([\w.\-]+)", args or "")
            cur.symbols[name] = type_str
            cur.instrs.append(Instr(name, type_str, op, operands, s))
    return comps, entry


def _trip_count(cond_name: str, comps: Dict[str, Computation]) -> int:
    """The condition block contains the loop bound as its only constant."""
    best = 1
    comp = comps.get(cond_name)
    if comp is None:
        return best
    consts = []
    for ins in comp.instrs:
        consts += [int(c) for c in _CONST_RE.findall(ins.line)]
        # one level of indirection through fused compares
        if ins.op == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", ins.line)
            if m and m.group(1) in comps:
                for ins2 in comps[m.group(1)].instrs:
                    consts += [int(c) for c in _CONST_RE.findall(ins2.line)]
    return max(consts) if consts else 1


def _dot_flops(ins: Instr, comp: Computation) -> float:
    dims = _type_dims(ins.type_str)
    if not dims:
        return 0.0
    out_n = 1
    for d in dims[0][1]:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    k = 1
    if m and ins.operands:
        lhs_type = comp.symbols.get(ins.operands[0], "")
        lhs_dims = _type_dims(lhs_type)
        if lhs_dims:
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs_dims[0][1]):
                    k *= lhs_dims[0][1][idx]
    return 2.0 * out_n * k


_LAYOUT_ONLY_OPS = {
    "parameter", "convert", "copy", "transpose", "bitcast", "reshape",
    "get-tuple-element", "tuple", "dynamic-slice", "slice",
}


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0  # matmul flops, loop-corrected, per device
    bytes_accessed: float = 0.0  # operand+result bytes, loop-corrected
    #: bytes of pure convert/copy/transpose fusions — mostly CPU-lowering
    #: artifacts (bf16 dot inputs promoted to f32); reported separately so
    #: the memory term reflects TPU-real traffic (see EXPERIMENTS.md §Method)
    layout_bytes: float = 0.0
    collective_wire: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_operand: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    while_trips: List[int] = dataclasses.field(default_factory=list)

    @property
    def total_wire(self) -> float:
        return sum(self.collective_wire.values())

    @property
    def total_operand(self) -> float:
        return sum(self.collective_operand.values())


def _coll_kind(op: str) -> Optional[str]:
    base = op[:-6] if op.endswith("-start") else op
    return base if base in COLLECTIVES else None


def analyze_hlo(txt: str) -> HloCosts:
    comps, entry = parse_hlo(txt)
    costs = HloCosts()

    def _called(ins: Instr) -> Optional[Computation]:
        m = re.search(r"calls=%?([\w.\-]+)", ins.line)
        return comps.get(m.group(1)) if m else None

    def _is_layout_fusion(ins: Instr) -> bool:
        sub = _called(ins)
        return sub is not None and all(i.op in _LAYOUT_ONLY_OPS for i in sub.instrs)

    def _is_inplace_update(ins: Instr) -> bool:
        if ins.op == "dynamic-update-slice":
            return True
        if ins.op != "fusion":
            return False
        sub = _called(ins)
        return sub is not None and any(
            i.op == "dynamic-update-slice" for i in sub.instrs
        )

    def _fusion_operand_bytes(ins: Instr, comp: Computation) -> List[float]:
        """Operand bytes for a fusion, substituting the SLICED size when the
        fusion consumes a whole stacked array but only dynamic-slices it
        internally (scan-over-layers weight/cache slicing — charging the full
        stacked operand overcounts by n_layers)."""
        full = [float(_shape_bytes(comp.symbols.get(o, ""))) for o in ins.operands]
        sub = _called(ins)
        if sub is None:
            return full
        # param index -> effective bytes, when every use is a dynamic-slice
        params = [n for n in sub.symbols if re.match(r"param_\d+", n)]
        sliced: Dict[int, float] = {}
        uses: Dict[str, List[Instr]] = {}
        for i2 in sub.instrs:
            for o in i2.operands:
                uses.setdefault(o, []).append(i2)
        for pname in params:
            m = re.match(r"param_(\d+)", pname)
            idx = int(m.group(1))
            us = uses.get(pname, [])
            if us and all(u.op == "dynamic-slice" for u in us):
                sliced[idx] = float(max(_shape_bytes(u.type_str) for u in us))
        return [sliced.get(i, b) for i, b in enumerate(full)]

    def walk(name: str, mult: float, depth: int = 0):
        comp = comps.get(name)
        if comp is None or depth > 10:
            return
        for ins in comp.instrs:
            kind = _coll_kind(ins.op)
            if kind:
                rb = _shape_bytes(ins.type_str)
                n = max(_group_size(ins.line), 1)
                if kind == "all-gather":
                    operand, wire = rb / n, rb * (n - 1) / n
                elif kind == "reduce-scatter":
                    operand, wire = rb * n, rb * (n - 1)
                elif kind == "all-reduce":
                    operand, wire = rb, 2 * rb * (n - 1) / n
                elif kind == "all-to-all":
                    operand, wire = rb, rb * (n - 1) / n
                else:
                    operand, wire = rb, rb
                costs.collective_wire[kind] = (
                    costs.collective_wire.get(kind, 0.0) + wire * mult
                )
                costs.collective_operand[kind] = (
                    costs.collective_operand.get(kind, 0.0) + operand * mult
                )
                costs.collective_counts[kind] = costs.collective_counts.get(kind, 0) + 1
            if ins.op == "while":
                m = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", ins.line)
                if m:
                    trips = _trip_count(m.group(1), comps)
                    costs.while_trips.append(trips)
                    walk(m.group(2), mult * trips, depth + 1)
                continue
            if ins.op in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if m:
                    walk(m.group(1), mult, depth + 1)
            if ins.op == "conditional":
                for cname in re.findall(r"%([\w.\-]+)", ins.line.split("branch_computations=")[-1])[:8]:
                    walk(cname, mult, depth + 1)
                continue
            if ins.op in ("dot", "dot-general"):
                costs.flops += _dot_flops(ins, comp) * mult
            if ins.op == "fusion":
                # fusions may wrap a single dot — count it
                m = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if m and m.group(1) in comps:
                    for sub in comps[m.group(1)].instrs:
                        if sub.op in ("dot", "dot-general"):
                            costs.flops += _dot_flops(sub, comps[m.group(1)]) * mult
            if ins.op not in _SKIP_BYTES_OPS and ins.op != "while":
                b = _shape_bytes(ins.type_str)
                if ins.op == "fusion":
                    op_bytes = _fusion_operand_bytes(ins, comp)
                else:
                    op_bytes = [
                        _shape_bytes(comp.symbols.get(o, "")) for o in ins.operands
                    ]
                b += sum(op_bytes)
                if _is_inplace_update(ins) and op_bytes:
                    # in-place dynamic-update-slice: the aliased buffer is
                    # neither fully read nor fully re-written — charge the
                    # slice, not the buffer (result ~= max operand).
                    big = max(op_bytes)
                    b = max(b - 2 * big, min(op_bytes))
                if ins.op == "fusion" and _is_layout_fusion(ins):
                    costs.layout_bytes += b * mult
                else:
                    costs.bytes_accessed += b * mult

    if entry:
        walk(entry, 1.0)
    return costs


# ----------------------------------------------------------------- roofline
@dataclasses.dataclass
class Roofline:
    flops: float  # per-device matmul flops (loop-corrected)
    bytes_accessed: float  # per-device bytes (loop-corrected)
    collective_wire: float
    collective_operand: float
    collective_detail: Dict[str, float]
    n_devices: int
    model_flops: float  # analytic global model flops for this step
    raw_cost_analysis: Dict[str, float]
    layout_bytes: float = 0.0  # CPU-lowering dtype/layout copies (reported, excluded)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_wire / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops x devices) — remat/redundancy waste."""
        total = self.flops * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-flop utilization if the step ran exactly at the dominant
        roofline term (the roofline-fraction score we hillclimb)."""
        denom = self.t_bound * self.n_devices * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_wire_bytes": self.collective_wire,
            "collective_operand_bytes": self.collective_operand,
            "collective_detail": self.collective_detail,
            "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "raw_cost_analysis": self.raw_cost_analysis,
            "layout_bytes": self.layout_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_step_flops(model, shape) -> float:
    """6*N*D (train) / 2*N*D (inference), N = active params."""
    n = model.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def roofline_from_compiled(compiled, model, shape, n_devices: int) -> Roofline:
    ca = compiled.cost_analysis() or {}
    costs = analyze_hlo(compiled.as_text())
    return Roofline(
        flops=costs.flops,
        bytes_accessed=costs.bytes_accessed,
        collective_wire=costs.total_wire,
        collective_operand=costs.total_operand,
        collective_detail=dict(costs.collective_wire),
        n_devices=n_devices,
        model_flops=model_step_flops(model, shape),
        raw_cost_analysis={
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        layout_bytes=costs.layout_bytes,
    )
