"""Builds the pjit'd train/serve steps with divisibility-safe shardings.

JAX rejects uneven shardings on jit arguments, so every (tensor dim, mesh
axes) assignment is validated against the actual dim size and dropped to
replicated when it does not divide (e.g. whisper's vocab 51866 on TP=16,
kv_heads=8 on TP=16, batch=1 on long_500k). For long_500k the KV cache is
sequence-sharded over the data axes instead (the batch of 1 cannot be) —
ring-style decode."""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.registry import ModelDef
from repro.models.sharding import dp_axes, rules_for_mesh
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def safe_sharding(mesh: Mesh, sds, logical, rules) -> NamedSharding:
    """Logical spec -> NamedSharding. Drops assignments that do not divide
    the dim, and (first-come) assignments whose mesh axis is already used by
    an earlier dim of the same tensor (e.g. decode caches map both seq and
    kv_heads to 'model'; seq wins, kv_heads falls back to replicated)."""
    parts = []
    used: set = set()
    for dim, name in zip(sds.shape, logical):
        axes = rules.get(name) if name is not None else None
        if axes is not None:
            ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
            ax_tuple = tuple(a for a in ax_tuple if a not in used)
            axes = ax_tuple if len(ax_tuple) > 1 else (ax_tuple[0] if ax_tuple else None)
        if axes is not None and dim > 0 and dim % _axes_size(mesh, axes) == 0:
            parts.append(axes)
            used.update((axes,) if isinstance(axes, str) else axes)
        else:
            parts.append(None)
    return NamedSharding(mesh, P(*parts))


def _is_logical_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_shardings(mesh, shape_tree, logical_tree, rules):
    return jax.tree.map(
        lambda s, l: safe_sharding(mesh, s, l, rules),
        shape_tree,
        logical_tree,
        is_leaf=lambda x: _is_logical_leaf(x),
    )


def _opt_logical(param_logical):
    return {
        "mu": param_logical,
        "nu": param_logical,
        "step": (),
    }


@dataclasses.dataclass
class BuiltStep:
    fn: Callable  # the jit'd function
    arg_shapes: Tuple[Any, ...]  # abstract inputs for .lower(*arg_shapes)
    in_shardings: Any
    out_shardings: Any
    description: str


def build_train_step(
    model: ModelDef,
    mesh: Mesh,
    shape,
    opt_cfg: Optional[AdamWConfig] = None,
    rules_overrides: Optional[dict] = None,
    donate: bool = True,
    microbatch: int = 1,
) -> BuiltStep:
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ZeRO-1: the f32 AdamW moments additionally shard their "embed" dim over
    the data axes (params keep TP-only sharding). AdamW is 10 bytes/param, so
    a 27B model's moments (216 GB f32) cannot live on 16 TP shards (13.5
    GB/chip); over 256 chips they are 0.84 GB. GSPMD reduce-scatters grads
    into the update and all-gathers fresh params — the ZeRO-1 schedule.
    (Full FSDP — sharding the params' embed dim too — was tried and REFUTED:
    without per-op activation constraints the partitioner chose a pathological
    schedule, 2.7x memory-term regression; see EXPERIMENTS.md §Perf.)
    """
    opt_cfg = opt_cfg or AdamWConfig()
    rules = rules_for_mesh(mesh, rules_overrides)
    opt_rules = rules_for_mesh(
        mesh, {**(rules_overrides or {}), "embed": ("pod", "data")}
    )

    params_shapes = model.param_shapes()
    opt_shapes = jax.eval_shape(adamw_init, params_shapes)
    batch_shapes, batch_logical = model.make_inputs(
        "train", shape.global_batch, shape.seq_len
    )

    p_sh = tree_shardings(mesh, params_shapes, model.param_logical(), rules)
    o_sh = tree_shardings(
        mesh, opt_shapes, _opt_logical(model.param_logical()), opt_rules
    )
    b_sh = tree_shardings(mesh, batch_shapes, batch_logical, rules)
    m_sh = {"loss": NamedSharding(mesh, P()), "grad_norm": NamedSharding(mesh, P()),
            "lr": NamedSharding(mesh, P())}

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            # gradient accumulation: activation working set scales 1/microbatch
            # (the gemma2-27b §Perf lever); grads accumulate in f32
            mbs = jax.tree.map(
                lambda x: jnp.reshape(
                    x, (microbatch, x.shape[0] // microbatch) + x.shape[1:]
                ),
                batch,
            )

            acc_dtype = (
                jnp.bfloat16 if os.environ.get("REPRO_GRAD_ACC_BF16") == "1"
                else jnp.float32
            )

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, grads = jax.value_and_grad(model.loss)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype), g_acc, grads
                )
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            )
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mbs
            )
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, **metrics}

    fn = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, m_sh),
        donate_argnums=(0, 1) if donate else (),
    )
    return BuiltStep(
        fn=fn,
        arg_shapes=(params_shapes, opt_shapes, batch_shapes),
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, m_sh),
        description=f"train_step[{model.name} x {shape.name}]",
    )


def build_prefill_step(
    model: ModelDef, mesh: Mesh, shape, rules_overrides: Optional[dict] = None
) -> BuiltStep:
    rules = rules_for_mesh(mesh, rules_overrides)
    params_shapes = model.param_shapes()
    batch_shapes, batch_logical = model.make_inputs(
        "prefill", shape.global_batch, shape.seq_len
    )
    p_sh = tree_shardings(mesh, params_shapes, model.param_logical(), rules)
    b_sh = tree_shardings(mesh, batch_shapes, batch_logical, rules)

    def prefill(params, batch):
        return model.prefill(params, batch)

    # logits [B, S, V]: batch over dp, vocab over model (avoid the gather)
    logits_shape = jax.eval_shape(prefill, params_shapes, batch_shapes)
    l_sh = safe_sharding(
        mesh, logits_shape, ("batch", None, "vocab"), rules
    )
    fn = jax.jit(prefill, in_shardings=(p_sh, b_sh), out_shardings=l_sh)
    return BuiltStep(
        fn=fn,
        arg_shapes=(params_shapes, batch_shapes),
        in_shardings=(p_sh, b_sh),
        out_shardings=l_sh,
        description=f"prefill[{model.name} x {shape.name}]",
    )


def build_decode_step(
    model: ModelDef, mesh: Mesh, shape, rules_overrides: Optional[dict] = None
) -> BuiltStep:
    """serve_step: one new token against a seq_len KV cache."""
    rules = rules_for_mesh(mesh, rules_overrides)
    dp = dp_axes(mesh)
    rules = dict(rules)
    if shape.global_batch % _axes_size(mesh, dp):
        # batch unshardable (long_500k, B=1): shard the cache SEQUENCE over
        # every axis — each chip holds a 1/512 slice of the 512k-token cache.
        rules["seq"] = dp + ("model",)
        rules["batch"] = None
    else:
        # decode caches are the HBM hog (e.g. internlm2 decode_32k: 412 GB
        # globally). kv_heads rarely divide TP=16 (8, 20...), so shard the
        # cache SEQ dim over the model axis instead; decode attention over a
        # seq-sharded cache is a partial-softmax + psum (GSPMD inserts it).
        rules["seq"] = ("model",)

    params_shapes = model.param_shapes()
    batch_shapes, batch_logical = model.make_inputs(
        "decode", shape.global_batch, shape.seq_len
    )
    cache_shapes = model.init_cache_shape(shape.global_batch, shape.seq_len)

    p_sh = tree_shardings(mesh, params_shapes, model.param_logical(), rules)
    b_sh = tree_shardings(mesh, batch_shapes, batch_logical, rules)
    c_sh = tree_shardings(mesh, cache_shapes, model.cache_logical(), rules)

    def decode(params, cache, batch):
        return model.decode_step(params, cache, batch)

    logits_shape, _ = jax.eval_shape(decode, params_shapes, cache_shapes, batch_shapes)
    l_sh = safe_sharding(mesh, logits_shape, ("batch", None, "vocab"), rules)
    fn = jax.jit(
        decode,
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(l_sh, c_sh),
        donate_argnums=(1,),
    )
    return BuiltStep(
        fn=fn,
        arg_shapes=(params_shapes, cache_shapes, batch_shapes),
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(l_sh, c_sh),
        description=f"decode[{model.name} x {shape.name}]",
    )


def build_step(model: ModelDef, mesh: Mesh, shape, **kw) -> BuiltStep:
    if shape.mode == "train":
        return build_train_step(model, mesh, shape, **kw)
    if shape.mode == "prefill":
        return build_prefill_step(model, mesh, shape, **kw)
    return build_decode_step(model, mesh, shape, **kw)
