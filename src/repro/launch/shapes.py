"""The assigned input-shape set (brief, LM-family block).

`decode_*` / `long_*` lower `serve_step` (one token against a KV cache of
seq_len), NOT `train_step`. `long_500k` requires sub-quadratic attention and
only runs for SSM/hybrid archs (DESIGN.md §Arch-applicability)."""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.models.registry import ModelDef


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    mode: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}

SHAPE_ORDER: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable(model: ModelDef, shape_name: str) -> bool:
    """long_500k only for sub-quadratic archs (skips documented in DESIGN.md)."""
    if shape_name == "long_500k":
        return model.sub_quadratic
    return True


def cells(archs, shapes=SHAPE_ORDER):
    from repro.models.registry import get_model

    for a in archs:
        m = get_model(a)
        for s in shapes:
            if applicable(m, s):
                yield a, s
