"""LM training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
        --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 10

Production pod usage is the same entry point with the full arch name and
`--mesh single|multi`; on this CPU container use --smoke (reduced config on
the host mesh). Features: ZeRO-1-sharded AdamW, async checkpointing with
resume, optional int8 error-feedback gradient compression, deterministic
(step, shard)-addressed data — so restart/elastic-rescale does not change
the sample stream.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.data import SyntheticTokenDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh, set_mesh_compat
from repro.launch.shapes import InputShape
from repro.launch.steps import build_train_step
from repro.models.registry import get_model
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config, host mesh")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    model = get_model(args.arch, smoke=args.smoke)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    shape = InputShape("cli", "train", args.seq, args.batch)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 5 + 1))

    vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab
    ds = SyntheticTokenDataset(vocab=vocab, seq_len=args.seq, seed=0)

    with set_mesh_compat(mesh):
        built = build_train_step(model, mesh, shape, opt_cfg=opt_cfg, donate=True)
        params = model.init_params(jax.random.PRNGKey(0))
        opt_state = adamw_init(params)

        start_step = 0
        ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
        if ck and args.resume and ck.steps():
            state, meta, start_step = ck.restore({"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

        if model.family not in ("decoder", "ssm", "hybrid"):
            raise SystemExit(
                "train.py drives token-LM training; use the benchmarks for "
                f"family={model.family}"
            )

        t0 = time.time()
        tokens_seen = 0
        for step in range(start_step, args.steps):
            raw = ds.batch(step, args.batch)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            params, opt_state, metrics = built.fn(params, opt_state, batch)
            tokens_seen += args.batch * args.seq
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.tree.map(float, metrics)
                print(
                    f"[train] step {step:5d} loss={m['loss']:.4f} "
                    f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                    f"tok/s={tokens_seen / (time.time() - t0):.0f}",
                    flush=True,
                )
            if ck and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ck.save_async(step + 1, {"params": params, "opt": opt_state},
                              metadata={"arch": args.arch})
        if ck:
            ck.wait()
            ck.save(args.steps, {"params": params, "opt": opt_state},
                    metadata={"arch": args.arch})
        print(f"[train] done in {time.time() - t0:.1f}s")
        return float(jax.tree.map(float, metrics)["loss"])


if __name__ == "__main__":
    main()
