"""The paper's workload driver: parallel ABC inference of the epidemiology
model, with multi-device sharding, checkpoint/resume and backend selection.

    PYTHONPATH=src python -m repro.launch.abc_run --dataset synthetic_small \
        --tolerance 1.6e4 --accept 100 --batch 8192 --days 20

    # paper §5 workflow (scaled): all three countries
    PYTHONPATH=src python -m repro.launch.abc_run --dataset italy --days 49 ...

    # any registered compartmental model (see repro.epi.models); synthetic
    # ground truth is generated from the chosen model's spec
    PYTHONPATH=src python -m repro.launch.abc_run --model seir \
        --dataset synthetic_small --auto-tolerance 1e-3 --batch 8192

    # campaign mode: fan a dataset x model x backend x seed grid across the
    # host's devices, one compiled wave loop per unique shape, per-scenario
    # checkpoint/resume and one aggregated report (see README)
    PYTHONPATH=src python -m repro.launch.abc_run --campaign \
        --datasets italy new_zealand usa --models siard seiard \
        --auto-tolerance 1e-3 --accept 100 --out experiments/campaigns/demo

    # amortized inference: train an NPE estimator instead of running waves
    # (backend=npe; --npe-* flags size the estimator, docs/ARCHITECTURE.md)
    PYTHONPATH=src python -m repro.launch.abc_run --backend npe \
        --model sir --dataset synthetic_small --days 20 --accept 256

    # strong/weak scaling study of the wave loop (bench-artifact/v1 JSON)
    PYTHONPATH=src python -m repro.launch.abc_run --scaling \
        --models siard --backends xla_fused --scaling-devices 1 2 4

    # posterior-predictive forecast bands (optionally counterfactual)
    PYTHONPATH=src python -m repro.launch.abc_run --dataset italy \
        --intervention "alpha@25=0.1:1" --forecast 14 \
        --forecast-schedule none --forecast-out bands.json

Flag families (full list: --help): single-run fitting (--dataset --model
--days --batch --accept --tolerance/--auto-tolerance --strategy --summary
--distance --intervention --seed), backend selection (--backend xla |
xla_fused | pallas | npe, --tile --scan-unroll --autotune --interpret,
--npe-steps --npe-batch --npe-hidden --npe-components), spatial
metapopulation (--regions --mobility), checkpoint/resume (--state),
multi-device (--multi-device --wave-loop), campaign grids (--campaign
--datasets --models --backends --seeds --interventions --summaries --out),
scaling studies (--scaling --scaling-devices --scaling-waves --scaling-reps
--scaling-out), and forecasting (--forecast --forecast-schedule
--forecast-out).
"""

from __future__ import annotations

import argparse
import json

from repro.core.abc import ABCConfig, ABCState, run_abc
from repro.core.distributed import make_runner, make_wave_runner
from repro.core.summaries import DISTANCE_KINDS, list_summaries
from repro.epi.data import get_dataset
from repro.epi.models import get_model, list_models
from repro.epi.spec import InterventionSchedule, regionalize
from repro.ioutils import atomic_write_text
from repro.launch.mesh import make_host_mesh


def parse_intervention(spec: str) -> InterventionSchedule | None:
    """Parse an intervention schedule from its CLI string form.

        PARAMS@WINDOW[,WINDOW...]
        PARAMS := name[+name...]            scaled (time-varying) parameters
        WINDOW := day[=SCALES]              new window starting at `day`
        SCALES := entry[+entry...]          one entry, or one per tv param
        entry  := x (pinned scale) | lo:hi (inferred under U(lo, hi))

    A bare `day` infers that window's scales under the default U(0, 2).
    Examples: "alpha@25=0.3" (contact rate pinned to 0.3x from day 25),
    "alpha@25=0.1:1,40" (inferred lockdown window, then a second inferred
    reopening window), "alpha+gamma@30=0.5+0.8".
    """
    spec = (spec or "").strip()
    if not spec or spec.lower() == "none":
        return None
    if "@" not in spec:
        raise ValueError(
            f"intervention {spec!r}: expected PARAMS@day[=scale][,day...]"
        )
    params_s, windows_s = spec.split("@", 1)
    tv_params = tuple(p.strip() for p in params_s.split("+") if p.strip())
    if not tv_params:
        raise ValueError(f"intervention {spec!r}: no parameter names before '@'")
    breakpoints, lows, highs = [], [], []
    for win in windows_s.split(","):
        win = win.strip()
        day_s, _, scales_s = win.partition("=")
        breakpoints.append(int(day_s))
        if not scales_s:
            entries = ["0:2"] * len(tv_params)
        else:
            entries = scales_s.split("+")
            if len(entries) == 1:
                entries = entries * len(tv_params)
        if len(entries) != len(tv_params):
            raise ValueError(
                f"intervention {spec!r}: window {win!r} has {len(entries)} "
                f"scales for {len(tv_params)} parameters"
            )
        lo_row, hi_row = [], []
        for e in entries:
            lo_s, _, hi_s = e.partition(":")
            lo_row.append(float(lo_s))
            hi_row.append(float(hi_s) if hi_s else float(lo_s))
        lows.append(tuple(lo_row))
        highs.append(tuple(hi_row))
    return InterventionSchedule(
        tv_params=tv_params,
        breakpoints=tuple(breakpoints),
        scale_lows=tuple(lows),
        scale_highs=tuple(highs),
    )


def posterior_forecast(
    theta,
    dataset,
    cfg: ABCConfig,
    horizon: int,
    schedule: InterventionSchedule | None = None,
    key=0,
    quantiles=(0.05, 0.25, 0.5, 0.75, 0.95),
    max_particles: int = 512,
) -> dict:
    """Posterior-predictive forecast: simulate accepted particles forward
    past the fitting horizon under a chosen schedule; returns credible bands.

    `theta` is the accepted sample set [N, p]; `schedule` defaults to the
    FIT schedule (cfg.schedule) — pass a different fixed-scale schedule for
    a counterfactual ("what if the lockdown lifts on day 60 instead"). The
    result is a strict-JSON-serializable dict: per observed channel, the
    mean and the requested quantiles over particles for every day of
    `cfg.num_days + horizon`.

    Sets larger than `max_particles` are subsampled with a seeded
    permutation (NOT truncated — topk accepted sets are distance-ordered,
    so taking the first rows would bias the bands toward the lowest-
    distance particles). Delegates to `repro.core.serving.forecast_bands`,
    the same compiled path the `serve --epi` batch server answers from.
    """
    from repro.core.serving import forecast_bands

    return forecast_bands(
        theta,
        dataset,
        model=cfg.model,
        fit_days=cfg.num_days,
        horizon=horizon,
        fit_schedule=cfg.schedule,
        schedule=schedule,
        key=key,
        quantiles=quantiles,
        max_particles=max_particles,
    )


def run_scaling_cli(args):
    """--scaling mode: the paper's multi-device experiment as one command.

    Sweeps the sharded device-resident wave loop over --scaling-devices on
    THIS process's device pool (force host devices on CPU with
    XLA_FLAGS=--xla_force_host_platform_device_count=N) and reports
    parallel_efficiency / scaling_overhead_pct per (model, backend) cell.
    """
    from repro.core.scaling import (
        ScalingConfig,
        format_report,
        run_scaling_study,
    )

    scfg = ScalingConfig(
        device_counts=tuple(args.scaling_devices),
        models=tuple(args.models),
        backends=tuple(args.backends),
        batch_per_device=args.batch,
        waves=args.scaling_waves,
        num_days=args.days,
        dataset=args.dataset,
        reps=args.scaling_reps,
        tile=args.tile,
        scan_unroll=args.scan_unroll,
        autotune=args.autotune,
    )
    report = run_scaling_study(scfg, verbose=True)
    print()
    print(format_report(report))
    if args.scaling_out:
        atomic_write_text(
            args.scaling_out, json.dumps(report, indent=1, allow_nan=False)
        )
        print(f"[scaling] report saved to {args.scaling_out}")
    return report


def run_campaign_cli(args, parser):
    from repro.core.campaign import CampaignConfig, run_campaign

    # the campaign grid reads ONLY the plural flags; refuse the singular ones
    # rather than silently running the wrong grid
    for flag, value in (("--dataset", args.dataset), ("--model", args.model),
                        ("--backend", args.backend), ("--seed", args.seed),
                        ("--intervention", args.intervention),
                        ("--summary", args.summary)):
        if value != parser.get_default(flag.lstrip("-").replace("-", "_")):
            parser.error(
                f"{flag} has no effect with --campaign; use the grid flag "
                f"{flag}s instead"
            )
    models = tuple(args.models)
    if args.regions > 1:
        # regionalize every grid model: the campaign's shape cache keys on
        # the resolved spec object, so spec-object cells behave like names
        models = tuple(
            regionalize(get_model(m), args.regions,
                        args.mobility or "identity")
            for m in models
        )
    cfg = CampaignConfig(
        datasets=tuple(args.datasets),
        models=models,
        backends=tuple(args.backends),
        seeds=tuple(args.seeds),
        interventions=tuple(
            parse_intervention(s) for s in args.interventions
        ),
        summaries=tuple(
            None if s == "identity" else s for s in args.summaries
        ),
        distance=args.distance,
        interpret=_interpret_flag(args.interpret),
        batch_size=args.batch,
        num_days=args.days,
        target_accepted=args.accept,
        max_runs=args.max_runs,
        tolerance=None if args.auto_tolerance else args.tolerance,
        auto_quantile=args.auto_tolerance or 1e-3,
        out_dir=args.out,
        checkpoint_every=args.checkpoint_every,
        devices_per_scenario=args.devices_per_scenario,
        tile=args.tile,
        scan_unroll=args.scan_unroll,
        autotune=args.autotune,
    )
    report = run_campaign(cfg, verbose=True)
    return report


def _interpret_flag(value: str):
    """'auto' -> None (backend-aware), 'on'/'off' -> forced mode."""
    return {"auto": None, "on": True, "off": False}[value]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic_small")
    ap.add_argument("--model", default="siard", choices=list_models(),
                    help="compartmental model to infer (registry name; the "
                         "paper's SIARD model is the default)")
    ap.add_argument("--regions", type=int, default=1,
                    help="regionalize --model into an N-region spatial "
                         "metapopulation (see repro.epi.spec.regionalize); "
                         "only metapop-aware models (e.g. metapop_seir) "
                         "exchange mass between regions — others become N "
                         "independent copies. 1 = the unchanged model")
    ap.add_argument("--mobility", default="",
                    help="mobility matrix for --regions > 1: 'identity' "
                         "(uncoupled), 'uniform:EPS' or 'ring:EPS' "
                         "(row-stochastic; see repro.epi.spec.make_mobility); "
                         "default identity")
    ap.add_argument("--tolerance", type=float, default=1.6e4,
                    help="absolute epsilon; use --auto-tolerance to calibrate")
    ap.add_argument("--auto-tolerance", type=float, default=0.0, metavar="Q",
                    help="pick epsilon as the Q-quantile of a pilot wave "
                         "(the paper hand-tunes epsilon per dataset)")
    ap.add_argument("--accept", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8192,
                    help="global batch per run (under --scaling: the "
                         "per-DEVICE batch — weak scaling multiplies it by "
                         "the device count)")
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--strategy", default="outfeed", choices=["outfeed", "topk"])
    ap.add_argument("--backend", default="xla_fused",
                    choices=["xla", "xla_fused", "pallas", "npe"])
    ap.add_argument("--npe-steps", type=int, default=None,
                    help="backend=npe: training steps (default NPEConfig)")
    ap.add_argument("--npe-batch", type=int, default=None,
                    help="backend=npe: fresh simulations per training step")
    ap.add_argument("--npe-hidden", type=int, default=None,
                    help="backend=npe: MDN trunk width")
    ap.add_argument("--npe-components", type=int, default=None,
                    help="backend=npe: mixture components")
    ap.add_argument("--summary", default="identity",
                    choices=list(list_summaries()),
                    help="summary statistic compared by --distance (every "
                         "backend lowers every pair; 'identity' is the "
                         "paper's raw daily trajectories)")
    ap.add_argument("--distance", default="euclidean",
                    choices=sorted(DISTANCE_KINDS),
                    help="distance kind over summary values: weighted L2 "
                         "(euclidean), weighted mean-L1 (mae) or observed-"
                         "scale-normalized L2 (normalized_euclidean)")
    ap.add_argument("--interpret", default="auto", choices=["auto", "on", "off"],
                    help="Pallas dispatch for backend=pallas: 'auto' runs the "
                         "interpreter only on CPU and compiled kernels on "
                         "accelerators; 'on'/'off' force a mode")
    ap.add_argument("--tile", type=int, default=None,
                    help="Pallas kernel tile (samples per grid cell); must be "
                         "a multiple of 128 dividing --batch. Default: auto "
                         "(1024-lane legacy default, or the tuning-cache "
                         "winner under --autotune). Pure scheduling — "
                         "accepted sets are identical across tiles")
    ap.add_argument("--scan-unroll", type=int, default=None,
                    help="unroll factor of the xla_fused day scan (pure "
                         "scheduling; default 1, or the tuning-cache winner "
                         "under --autotune)")
    ap.add_argument("--autotune", action="store_true",
                    help="resolve tile/scan-unroll from the measured tuning "
                         "cache under experiments/tuning/ at simulator-build "
                         "time (a cache miss runs the best-of-N search once "
                         "and persists the winners; see repro.core.tuning)")
    ap.add_argument("--intervention", default="",
                    help="piecewise-constant intervention schedule, e.g. "
                         "'alpha@25=0.3' (contact rate pinned to 0.3x from "
                         "day 25) or 'alpha@25=0.1:1' (scale inferred under "
                         "U(0.1, 1)); see parse_intervention for the grammar")
    ap.add_argument("--max-runs", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--state", default="", help="checkpoint path (resume if exists)")
    ap.add_argument("--save-posterior", default="")
    ap.add_argument("--multi-device", action="store_true",
                    help="shard_map over all host devices")
    ap.add_argument("--wave-loop", default="auto",
                    choices=["auto", "host", "device"],
                    help="per-wave host sync (host) vs one device-resident "
                         "lax.while_loop over all waves (device)")
    # campaign mode -------------------------------------------------------
    ap.add_argument("--campaign", action="store_true",
                    help="run a dataset x model x backend x seed grid with "
                         "per-scenario checkpoints and one aggregated report")
    ap.add_argument("--datasets", nargs="+",
                    default=["italy", "new_zealand", "usa"],
                    help="campaign dataset grid axis")
    ap.add_argument("--models", nargs="+", default=["siard"],
                    help="campaign model grid axis")
    ap.add_argument("--backends", nargs="+", default=["xla_fused"],
                    help="campaign backend grid axis")
    ap.add_argument("--seeds", nargs="+", type=int, default=[0],
                    help="campaign seed grid axis")
    ap.add_argument("--out", default="experiments/campaigns/default",
                    help="campaign output directory (checkpoints + report)")
    ap.add_argument("--checkpoint-every", type=int, default=32,
                    help="waves per device segment between campaign checkpoints")
    ap.add_argument("--devices-per-scenario", type=int, default=1,
                    help="carve jax.devices() into disjoint groups of this "
                         "size and shard each scenario's wave loop across "
                         "its group (1 = one scenario per device)")
    ap.add_argument("--interventions", nargs="+", default=["none"],
                    help="campaign intervention grid axis (schedule strings; "
                         "'none' is the constant-theta cell). Schedules "
                         "sharing a shape share one compiled wave loop, so "
                         "lockdown-day x scale sweeps never re-trace")
    ap.add_argument("--summaries", nargs="+", default=["identity"],
                    choices=list(list_summaries()),
                    help="campaign summary-statistic grid axis (registry "
                         "names; 'identity' is the raw-trajectory cell)")
    # scaling-study mode ---------------------------------------------------
    ap.add_argument("--scaling", action="store_true",
                    help="run the multi-device scaling study (the paper's "
                         "16-IPU experiment): sharded wave loop at every "
                         "--scaling-devices count, weak scaling with "
                         "--batch per device, efficiency/overhead per "
                         "(model, backend) cell from --models/--backends")
    ap.add_argument("--scaling-devices", nargs="+", type=int,
                    default=[1, 2, 4, 8],
                    help="device counts of the curve (prefix subsets of "
                         "this process's jax.devices())")
    ap.add_argument("--scaling-waves", type=int, default=4,
                    help="fixed wave budget per scaling cell")
    ap.add_argument("--scaling-reps", type=int, default=3,
                    help="timed repetitions per cell (best-of)")
    ap.add_argument("--scaling-out", default="",
                    help="path for the scaling report JSON (default: "
                         "stdout table only; the nightly artifact comes "
                         "from benchmarks/bench_scaling.py)")
    # forecast mode --------------------------------------------------------
    ap.add_argument("--forecast", type=int, default=0, metavar="DAYS",
                    help="after fitting, simulate the accepted particles "
                         "DAYS past the horizon and emit posterior-"
                         "predictive credible bands as strict JSON")
    ap.add_argument("--forecast-schedule", default="",
                    help="counterfactual schedule for the forecast (fixed "
                         "scales only); default: forecast under the FIT "
                         "schedule; 'none': forecast with interventions "
                         "lifted")
    ap.add_argument("--forecast-out", default="",
                    help="path for the forecast JSON (default: stdout)")
    args = ap.parse_args(argv)

    if args.regions < 1:
        ap.error("--regions must be >= 1")
    if args.mobility and args.regions == 1:
        ap.error("--mobility has no effect without --regions > 1")
    if args.scaling and (args.regions > 1 or args.mobility):
        ap.error("--regions/--mobility are not supported with --scaling; "
                 "regionalized specs go through single-run or --campaign")

    if "npe" in args.backends and (args.campaign or args.scaling):
        ap.error("backend 'npe' is not a campaign/scaling grid axis (it has "
                 "no wave loop to shard); use the single-run --backend npe")
    if args.backend == "npe":
        if args.multi_device:
            ap.error("--multi-device has no effect with --backend npe: "
                     "training is a single-device jitted loop")
        if args.auto_tolerance:
            ap.error("--auto-tolerance is wave-backend-only; backend npe "
                     "has no tolerance (posterior is a density estimator)")
        if args.state:
            ap.error("--state is wave-backend-only; NPE runs are not "
                     "checkpoint/resumable (re-train or fine-tune instead)")

    if args.campaign:
        return run_campaign_cli(args, ap)
    if args.scaling:
        return run_scaling_cli(args)

    # mirror of run_campaign_cli's guard: grid-only flags do nothing without
    # --campaign — refuse them rather than silently fitting the defaults
    for flag, singular, value in (("--datasets", "--dataset", args.datasets),
                                  ("--models", "--model", args.models),
                                  ("--backends", "--backend", args.backends),
                                  ("--seeds", "--seed", args.seeds),
                                  ("--interventions", "--intervention",
                                   args.interventions),
                                  ("--summaries", "--summary", args.summaries)):
        if value != ap.get_default(flag.lstrip("-").replace("-", "_")):
            ap.error(f"{flag} has no effect without --campaign; use the "
                     f"singular flag {singular} instead")
    for flag, value in (("--scaling-devices", args.scaling_devices),
                        ("--scaling-waves", args.scaling_waves),
                        ("--scaling-reps", args.scaling_reps),
                        ("--scaling-out", args.scaling_out)):
        if value != ap.get_default(flag.lstrip("-").replace("-", "_")):
            ap.error(f"{flag} has no effect without --scaling")

    model = args.model
    if args.regions > 1:
        model = regionalize(
            get_model(args.model), args.regions, args.mobility or "identity"
        )
    ds = get_dataset(args.dataset, num_days=args.days, model=model)
    schedule = parse_intervention(args.intervention)
    interpret = _interpret_flag(args.interpret)
    tolerance = args.tolerance
    if args.auto_tolerance:
        from repro.core.abc import calibrate_tolerance

        pilot_cfg = ABCConfig(batch_size=args.batch, tolerance=1.0,
                              num_days=args.days, backend=args.backend,
                              strategy="topk", top_k=1, model=model,
                              schedule=schedule, interpret=interpret,
                              summary=args.summary, distance=args.distance)
        tolerance = calibrate_tolerance(ds, pilot_cfg, key=args.seed,
                                        quantile=args.auto_tolerance)
        print(f"[abc] auto-calibrated tolerance = {tolerance:.4g} "
              f"(quantile {args.auto_tolerance:g})")
    npe_overrides = {
        k: v for k, v in (("train_steps", args.npe_steps),
                          ("train_batch", args.npe_batch),
                          ("hidden", args.npe_hidden),
                          ("n_components", args.npe_components))
        if v is not None
    }
    if npe_overrides and args.backend != "npe":
        ap.error("--npe-* flags have no effect without --backend npe")
    npe_cfg = None
    if npe_overrides:
        from repro.core.npe import NPEConfig

        npe_cfg = NPEConfig(**npe_overrides)
    cfg = ABCConfig(
        batch_size=args.batch,
        tolerance=tolerance,
        target_accepted=args.accept,
        strategy=args.strategy,
        chunk_size=args.chunk,
        num_days=args.days,
        backend=args.backend,
        max_runs=args.max_runs,
        model=model,
        wave_loop=args.wave_loop,
        schedule=schedule,
        interpret=interpret,
        summary=args.summary,
        distance=args.distance,
        tile=args.tile,
        scan_unroll=args.scan_unroll,
        autotune=args.autotune,
        npe=npe_cfg,
    )
    run_fn = None
    wave_runner = None
    if args.multi_device:
        mesh = make_host_mesh(model=1)
        if args.wave_loop == "device":
            wave_runner = make_wave_runner(mesh, ds, cfg)
        else:
            run_fn = make_runner(mesh, ds, cfg)

    state = None
    if args.state:
        import os

        if os.path.exists(args.state):
            state = ABCState.load(args.state)
            print(f"[abc] resuming from run {state.run_idx} "
                  f"({state.n_accepted} accepted)")

    post = run_abc(
        ds, cfg, key=args.seed, state=state, run_fn=run_fn,
        wave_runner=wave_runner,
        checkpoint_every=25 if args.state else 0,
        checkpoint_path=args.state or None, verbose=True,
    )
    print(post.summary_table())
    if args.save_posterior:
        post.save(args.save_posterior)
        print(f"[abc] posterior saved to {args.save_posterior}")
    if args.forecast:
        from repro.epi.spec import EMPTY_SCHEDULE

        if args.forecast_schedule:
            # an explicit counterfactual; "none" lifts every intervention
            fc_sched = parse_intervention(args.forecast_schedule) or EMPTY_SCHEDULE
        else:
            fc_sched = None  # forecast under the fit schedule
        bands = posterior_forecast(
            post.theta, ds, cfg, args.forecast, schedule=fc_sched,
            key=args.seed + 1,
        )
        text = json.dumps(bands, indent=1, allow_nan=False)
        if args.forecast_out:
            atomic_write_text(args.forecast_out, text)
            print(f"[abc] forecast bands saved to {args.forecast_out}")
        else:
            print(text)
    return post


if __name__ == "__main__":
    main()
