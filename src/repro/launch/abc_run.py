"""The paper's workload driver: parallel ABC inference of the epidemiology
model, with multi-device sharding, checkpoint/resume and backend selection.

    PYTHONPATH=src python -m repro.launch.abc_run --dataset synthetic_small \
        --tolerance 1.6e4 --accept 100 --batch 8192 --days 20

    # paper §5 workflow (scaled): all three countries
    PYTHONPATH=src python -m repro.launch.abc_run --dataset italy --days 49 ...

    # any registered compartmental model (see repro.epi.models); synthetic
    # ground truth is generated from the chosen model's spec
    PYTHONPATH=src python -m repro.launch.abc_run --model seir \
        --dataset synthetic_small --auto-tolerance 1e-3 --batch 8192

    # campaign mode: fan a dataset x model x backend x seed grid across the
    # host's devices, one compiled wave loop per unique shape, per-scenario
    # checkpoint/resume and one aggregated report (see README)
    PYTHONPATH=src python -m repro.launch.abc_run --campaign \
        --datasets italy new_zealand usa --models siard seiard \
        --auto-tolerance 1e-3 --accept 100 --out experiments/campaigns/demo
"""

from __future__ import annotations

import argparse

import jax

from repro.core.abc import ABCConfig, ABCState, run_abc
from repro.core.distributed import make_runner, make_wave_runner
from repro.epi.data import get_dataset
from repro.epi.models import list_models
from repro.launch.mesh import make_host_mesh


def run_campaign_cli(args, parser):
    from repro.core.campaign import CampaignConfig, run_campaign

    # the campaign grid reads ONLY the plural flags; refuse the singular ones
    # rather than silently running the wrong grid
    for flag, value in (("--dataset", args.dataset), ("--model", args.model),
                        ("--backend", args.backend), ("--seed", args.seed)):
        if value != parser.get_default(flag.lstrip("-")):
            parser.error(
                f"{flag} has no effect with --campaign; use the grid flag "
                f"{flag}s instead"
            )
    cfg = CampaignConfig(
        datasets=tuple(args.datasets),
        models=tuple(args.models),
        backends=tuple(args.backends),
        seeds=tuple(args.seeds),
        batch_size=args.batch,
        num_days=args.days,
        target_accepted=args.accept,
        max_runs=args.max_runs,
        tolerance=None if args.auto_tolerance else args.tolerance,
        auto_quantile=args.auto_tolerance or 1e-3,
        out_dir=args.out,
        checkpoint_every=args.checkpoint_every,
    )
    report = run_campaign(cfg, verbose=True)
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="synthetic_small")
    ap.add_argument("--model", default="siard", choices=list_models(),
                    help="compartmental model to infer (registry name; the "
                         "paper's SIARD model is the default)")
    ap.add_argument("--tolerance", type=float, default=1.6e4,
                    help="absolute epsilon; use --auto-tolerance to calibrate")
    ap.add_argument("--auto-tolerance", type=float, default=0.0, metavar="Q",
                    help="pick epsilon as the Q-quantile of a pilot wave "
                         "(the paper hand-tunes epsilon per dataset)")
    ap.add_argument("--accept", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8192, help="global batch per run")
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--strategy", default="outfeed", choices=["outfeed", "topk"])
    ap.add_argument("--backend", default="xla_fused",
                    choices=["xla", "xla_fused", "pallas"])
    ap.add_argument("--max-runs", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--state", default="", help="checkpoint path (resume if exists)")
    ap.add_argument("--save-posterior", default="")
    ap.add_argument("--multi-device", action="store_true",
                    help="shard_map over all host devices")
    ap.add_argument("--wave-loop", default="auto",
                    choices=["auto", "host", "device"],
                    help="per-wave host sync (host) vs one device-resident "
                         "lax.while_loop over all waves (device)")
    # campaign mode -------------------------------------------------------
    ap.add_argument("--campaign", action="store_true",
                    help="run a dataset x model x backend x seed grid with "
                         "per-scenario checkpoints and one aggregated report")
    ap.add_argument("--datasets", nargs="+",
                    default=["italy", "new_zealand", "usa"],
                    help="campaign dataset grid axis")
    ap.add_argument("--models", nargs="+", default=["siard"],
                    help="campaign model grid axis")
    ap.add_argument("--backends", nargs="+", default=["xla_fused"],
                    help="campaign backend grid axis")
    ap.add_argument("--seeds", nargs="+", type=int, default=[0],
                    help="campaign seed grid axis")
    ap.add_argument("--out", default="experiments/campaigns/default",
                    help="campaign output directory (checkpoints + report)")
    ap.add_argument("--checkpoint-every", type=int, default=32,
                    help="waves per device segment between campaign checkpoints")
    args = ap.parse_args(argv)

    if args.campaign:
        return run_campaign_cli(args, ap)

    ds = get_dataset(args.dataset, num_days=args.days, model=args.model)
    tolerance = args.tolerance
    if args.auto_tolerance:
        from repro.core.abc import calibrate_tolerance

        pilot_cfg = ABCConfig(batch_size=args.batch, tolerance=1.0,
                              num_days=args.days, backend=args.backend,
                              strategy="topk", top_k=1, model=args.model)
        tolerance = calibrate_tolerance(ds, pilot_cfg, key=args.seed,
                                        quantile=args.auto_tolerance)
        print(f"[abc] auto-calibrated tolerance = {tolerance:.4g} "
              f"(quantile {args.auto_tolerance:g})")
    cfg = ABCConfig(
        batch_size=args.batch,
        tolerance=tolerance,
        target_accepted=args.accept,
        strategy=args.strategy,
        chunk_size=args.chunk,
        num_days=args.days,
        backend=args.backend,
        max_runs=args.max_runs,
        model=args.model,
        wave_loop=args.wave_loop,
    )
    run_fn = None
    wave_runner = None
    if args.multi_device:
        mesh = make_host_mesh(model=1)
        if args.wave_loop == "device":
            wave_runner = make_wave_runner(mesh, ds, cfg)
        else:
            run_fn = make_runner(mesh, ds, cfg)

    state = None
    if args.state:
        import os

        if os.path.exists(args.state):
            state = ABCState.load(args.state)
            print(f"[abc] resuming from run {state.run_idx} "
                  f"({state.n_accepted} accepted)")

    post = run_abc(
        ds, cfg, key=args.seed, state=state, run_fn=run_fn,
        wave_runner=wave_runner,
        checkpoint_every=25 if args.state else 0,
        checkpoint_path=args.state or None, verbose=True,
    )
    print(post.summary_table())
    if args.save_posterior:
        post.save(args.save_posterior)
        print(f"[abc] posterior saved to {args.save_posterior}")
    return post


if __name__ == "__main__":
    main()
