import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input-shape x mesh)
cell on the production meshes; record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k

Results are cached as JSON under experiments/dryrun/ (one file per cell) and
aggregated by benchmarks/roofline.py into EXPERIMENTS.md tables. The 512
placeholder-device forcing above MUST precede any jax import (device count
locks on first init) and lives ONLY here, per the dry-run contract.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax  # noqa: F401  (locks the forced device count before other imports)

from repro.ioutils import atomic_write_text
from repro.launch.analysis import roofline_from_compiled
from repro.launch.mesh import make_production_mesh, set_mesh_compat
from repro.launch.shapes import SHAPES, SHAPE_ORDER, applicable
from repro.launch.steps import build_step
from repro.models.registry import get_model, list_archs

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, rules_overrides=None,
             tag: str = "baseline", **step_kwargs) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    model = get_model(arch)
    shape = SHAPES[shape_name]
    if shape.mode != "train":
        step_kwargs.pop("microbatch", None)
    t0 = time.time()
    with set_mesh_compat(mesh):
        built = build_step(model, mesh, shape, rules_overrides=rules_overrides,
                           **step_kwargs)
        lowered = built.fn.lower(*built.arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(f"  memory_analysis[{arch}/{shape_name}]: {mem}")  # proves it fits
        print(f"  cost_analysis[{arch}/{shape_name}]: "
              f"{ {k: v for k, v in (compiled.cost_analysis() or {}).items() if k in ('flops', 'bytes accessed')} }")
        roof = roofline_from_compiled(compiled, model, shape, n_dev)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "mode": shape.mode,
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_hbm_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "roofline": roof.to_dict(),
        "param_count": model.param_count(),
        "active_param_count": model.active_param_count(),
    }
    return rec


def cell_path(arch, shape_name, multi_pod, tag="baseline") -> Path:
    mesh = "multi" if multi_pod else "single"
    return OUT_DIR / f"{arch}__{shape_name}__{mesh}__{tag}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    archs = list(list_archs()) if args.arch == "all" else [args.arch]
    shapes = list(SHAPE_ORDER) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    OUT_DIR.mkdir(parents=True, exist_ok=True)

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        model = get_model(arch)
        for shape_name in shapes:
            if not applicable(model, shape_name):
                print(f"SKIP  {arch} x {shape_name} (long_500k needs sub-quadratic; "
                      f"see DESIGN.md §Arch-applicability)")
                n_skip += 1
                continue
            for multi_pod in meshes:
                path = cell_path(arch, shape_name, multi_pod, args.tag)
                if path.exists() and not args.force:
                    print(f"CACHED {path.name}")
                    n_ok += 1
                    continue
                label = f"{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
                try:
                    rec = run_cell(arch, shape_name, multi_pod, tag=args.tag,
                                   microbatch=args.microbatch)
                    atomic_write_text(path, json.dumps(rec, indent=1))
                    r = rec["roofline"]
                    print(
                        f"OK    {label}: compile={rec['compile_s']:.0f}s "
                        f"hbm/dev={rec['memory']['peak_hbm_bytes']/2**30:.2f}GiB "
                        f"t_comp={r['t_compute_s']:.2e} t_mem={r['t_memory_s']:.2e} "
                        f"t_coll={r['t_collective_s']:.2e} -> {r['bottleneck']}"
                        , flush=True,
                    )
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    err = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi_pod else "16x16",
                           "status": "fail", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                    atomic_write_text(
                        path.with_suffix(".fail.json"), json.dumps(err, indent=1)
                    )
                    print(f"FAIL  {label}: {type(e).__name__}: {str(e)[:300]}", flush=True)
    print(f"\ndry-run complete: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
