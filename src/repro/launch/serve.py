"""Serving driver: continuous-batching slot scheduler for LM decode and
for epidemiology posterior queries.

LM mode (decoder-family archs; batched prefill + decode loop):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 8 --prompt-len 16 --gen 8

Epidemiology mode (the paper workload's outward face): batched posterior
forecast / counterfactual queries answered from cached fits — queries
sharing a compiled forecast shape are microbatched into ONE compiled call
(see repro.core.serving). On-demand fits run SMC-ABC waves by default;
`--backend npe` swaps in the amortized estimator (repro.core.npe), making
every fit a forward pass after one training run:

    PYTHONPATH=src python -m repro.launch.serve --epi \
        --queries queries.json --data-dir data/ --store store/ --days 21

Both modes implement the paper-inspired fixed-shape service pattern: a
static batch of slots, requests slotted in/out of it (continuous
batching), per-slot state written in place — the serving analogue of the
ABC engine's fixed-shape outfeed.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ioutils import atomic_write_text
from repro.launch.mesh import make_host_mesh, set_mesh_compat
from repro.models.registry import get_model


# ----------------------------------------------------------------- LM mode
def _is_axes(x) -> bool:
    """Leaf predicate for cache_logical trees: a tuple of axis names."""
    return isinstance(x, tuple) and all(
        e is None or isinstance(e, str) for e in x
    )


def zero_slot(cache, logical, slot: int):
    """Zero one slot's lanes across every cache leaf (KV rows AND ssm/conv
    state). A freed slot's cache still holds the previous occupant's
    prefix; without this, the next request admitted into the slot attends
    over (or, for SSM state, integrates) stale context."""
    leaves, treedef = jax.tree.flatten(cache)
    axes = jax.tree.leaves(logical, is_leaf=_is_axes)
    assert len(leaves) == len(axes), (len(leaves), len(axes))
    out = []
    for arr, ax in zip(leaves, axes):
        b = ax.index("batch")
        out.append(arr.at[(slice(None),) * b + (slot,)].set(0))
    return jax.tree.unflatten(treedef, out)


def run_lm_server(model, prompts, gen: int, slots: int, cache_len: int):
    """Continuous-batching greedy decode; returns (outputs, steps).

    `outputs[i]` is the generated token list for `prompts[i]` (submission
    order), regardless of which slot served it or how many slot
    generations preceded it. Each slot advances at its OWN position — the
    decode step takes a [slots] pos vector, so a slot admitted mid-stream
    (or serving a shorter prompt) writes and attends its own cache prefix
    instead of the longest slot's. Admission zeroes the slot's cache
    lanes. Together these make batched outputs token-for-token identical
    to serving each request alone (pinned by tests/test_serve_slots.py).
    """
    logical = model.cache_logical()
    params = model.init_params(jax.random.PRNGKey(0))
    cache_shapes = model.init_cache_shape(slots, cache_len)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    queue = list(range(len(prompts)))
    outputs = [None] * len(prompts)
    slot_req = [None] * slots  # request index occupying each slot
    slot_pos = np.zeros(slots, np.int64)
    slot_out = [[] for _ in range(slots)]
    steps = 0
    while queue or any(r is not None for r in slot_req):
        for s in range(slots):
            if slot_req[s] is None and queue:
                slot_req[s] = queue.pop(0)
                slot_pos[s] = 0
                slot_out[s] = []
                cache = zero_slot(cache, logical, s)
        toks = np.zeros((slots, 1), np.int32)
        for s, ri in enumerate(slot_req):
            if ri is None:
                continue
            p = int(slot_pos[s])
            if p < len(prompts[ri]):
                toks[s, 0] = prompts[ri][p]  # still consuming the prompt
            elif slot_out[s]:
                toks[s, 0] = slot_out[s][-1]
        # per-slot positions: each slot writes ITS next cache row
        pos = jnp.asarray(slot_pos, jnp.int32)
        logits, cache = decode(
            params, cache, {"tokens": jnp.asarray(toks), "pos": pos}
        )
        steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, ri in enumerate(slot_req):
            if ri is None:
                continue
            slot_pos[s] += 1
            if slot_pos[s] >= len(prompts[ri]):
                slot_out[s].append(int(nxt[s]))
            if len(slot_out[s]) >= gen:
                outputs[ri] = slot_out[s]
                slot_req[s] = None
    return outputs, steps


def run_lm_cli(args):
    model = get_model(args.arch, smoke=args.smoke)
    if model.family == "encdec":
        raise SystemExit("serve.py LM mode drives decoder-family archs")
    mesh = make_host_mesh()
    vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab
    cache_len = args.prompt_len + args.gen

    with set_mesh_compat(mesh):
        rng = np.random.default_rng(0)
        prompts = [
            rng.integers(0, vocab, size=args.prompt_len).astype(np.int32).tolist()
            for _ in range(args.requests)
        ]
        t0 = time.time()
        outputs, steps = run_lm_server(
            model, prompts, args.gen, args.slots, cache_len
        )
        dt = time.time() - t0
        print(
            f"[serve] {len(outputs)} requests, {steps} decode steps, "
            f"{steps * args.slots / dt:.1f} tok/s (host mesh, CPU)"
        )
        for i, (req, out) in enumerate(zip(prompts, outputs)):
            if i >= 3:
                break
            print(f"  req{i}: prompt[:4]={req[:4]} -> gen={out}")
        return len(outputs)


# ---------------------------------------------------------------- epi mode
def _load_queries(path: str):
    from repro.core.serving import ForecastQuery

    with open(path) as f:
        raw = json.load(f)
    if isinstance(raw, dict):
        raw = raw["queries"]
    if not isinstance(raw, list) or not raw:
        raise SystemExit(f"--queries {path!r}: expected a non-empty list")
    return [ForecastQuery.from_json(q) for q in raw]


def run_epi_cli(args):
    from repro.core.serving import EpiServer, ServeConfig
    from repro.core.smc import SMCConfig

    if not args.queries:
        raise SystemExit("--epi requires --queries FILE.json")
    queries = _load_queries(args.queries)
    cfg = ServeConfig(
        slots=args.slots,
        forecast_particles=args.particles,
        fit=SMCConfig(
            n_particles=args.fit_particles,
            batch_size=args.fit_batch,
            n_rounds=args.fit_rounds,
            quantile=args.fit_quantile,
            num_days=args.days,
            backend=args.fit_backend,
        ),
        fit_seed=args.seed,
        data_dir=args.data_dir or None,
        store_dir=args.store or None,
        fit_backend=args.backend,
    )
    server = EpiServer(cfg)
    t0 = time.time()
    responses = server.answer(queries)
    stats = server.stats()
    stats["wall_time_s"] = time.time() - t0
    text = json.dumps(
        {"responses": responses, "stats": stats}, indent=1, allow_nan=False
    )
    if args.out:
        atomic_write_text(args.out, text)
        print(f"[serve] {len(responses)} responses saved to {args.out}",
              file=sys.stderr)
    else:
        print(text)
    print(
        f"[serve --epi] {len(responses)} queries, {stats['fits']} fits "
        f"({stats['warm_fits']} warm), {stats['npe_trains']} npe trains "
        f"({stats['npe_fine_tunes']} fine-tunes), "
        f"{stats['batched_calls']} batched "
        f"calls over {stats['compiled_shapes']} compiled shapes, "
        f"{stats['wall_time_s']:.2f}s",
        file=sys.stderr,
    )
    return len(responses)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM architecture to serve (LM mode; registry name)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode batch slots (LM) / query lanes per "
                         "compiled batch (--epi)")
    # epidemiology serving -------------------------------------------------
    ap.add_argument("--epi", action="store_true",
                    help="serve epidemiology posterior queries instead of "
                         "an LM: answer a batch of forecast/counterfactual "
                         "queries from cached SMC-ABC posteriors")
    ap.add_argument("--queries", default="",
                    help="JSON file: list of query objects (dataset, model, "
                         "horizon, schedule, quantiles, seed), or "
                         "{'queries': [...]}")
    ap.add_argument("--data-dir", default="",
                    help="directory of <name>.json dataset files (bundled "
                         "registry datasets resolve otherwise)")
    ap.add_argument("--store", default="",
                    help="posterior-store directory (persist fits across "
                         "invocations; the abc_serve daemon refreshes it)")
    ap.add_argument("--out", default="",
                    help="response JSON path (default: stdout)")
    ap.add_argument("--particles", type=int, default=128,
                    help="posterior particles per forecast")
    ap.add_argument("--days", type=int, default=21,
                    help="SMC fit window (days of observed data)")
    ap.add_argument("--fit-particles", type=int, default=128)
    ap.add_argument("--fit-batch", type=int, default=4096)
    ap.add_argument("--fit-rounds", type=int, default=3)
    ap.add_argument("--fit-quantile", type=float, default=0.5)
    ap.add_argument("--fit-backend", default="xla_fused",
                    choices=["xla", "xla_fused", "pallas"],
                    help="simulation backend of the SMC waves "
                         "(--backend smc only)")
    ap.add_argument("--backend", default="smc", choices=["smc", "npe"],
                    help="on-demand fit mechanism (--epi): SMC-ABC waves, "
                         "or an amortized NPE estimator (train once, "
                         "forward-pass per query; see core/npe.py)")
    ap.add_argument("--seed", type=int, default=0, help="fit seed (--epi)")
    args = ap.parse_args(argv)

    if args.epi:
        if args.arch:
            ap.error("--arch has no effect with --epi")
        return run_epi_cli(args)
    if not args.arch:
        ap.error("--arch is required (LM mode); or pass --epi")
    return run_lm_cli(args)


if __name__ == "__main__":
    main()
