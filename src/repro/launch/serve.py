"""LM serving driver: batched prefill + decode loop with a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --requests 8 --prompt-len 16 --gen 8

Implements the paper-inspired fixed-shape service pattern: a static decode
batch, requests slotted in/out of it (continuous batching), per-slot KV
caches written in place — the serving analogue of the ABC engine's
fixed-shape outfeed.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_host_mesh, set_mesh_compat
from repro.models.registry import get_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4, help="decode batch slots")
    args = ap.parse_args(argv)

    model = get_model(args.arch, smoke=args.smoke)
    if model.family == "encdec":
        raise SystemExit("serve.py demo drives decoder-family archs")
    mesh = make_host_mesh()
    vocab = model.cfg.vocab if hasattr(model.cfg, "vocab") else model.cfg.lm.vocab
    cache_len = args.prompt_len + args.gen

    with set_mesh_compat(mesh):
        params = model.init_params(jax.random.PRNGKey(0))
        cache_shapes = model.init_cache_shape(args.slots, cache_len)
        zero_cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cache_shapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )

        decode = jax.jit(model.decode_step, donate_argnums=(1,))

        rng = np.random.default_rng(0)
        queue = [
            rng.integers(0, vocab, size=args.prompt_len).astype(np.int32)
            for _ in range(args.requests)
        ]
        done = []
        t0 = time.time()
        # static decode batch: slots hold independent requests; prompts are
        # fed token-by-token (prefill-as-decode keeps the demo single-step;
        # the dry-run exercises the real batched prefill path)
        slot_req = [None] * args.slots
        slot_pos = np.zeros(args.slots, np.int64)
        slot_out = [[] for _ in range(args.slots)]
        cache = zero_cache
        steps = 0
        while queue or any(r is not None for r in slot_req):
            for s in range(args.slots):
                if slot_req[s] is None and queue:
                    slot_req[s] = queue.pop(0).tolist()
                    slot_pos[s] = 0
                    slot_out[s] = []
            toks = np.zeros((args.slots, 1), np.int32)
            for s, req in enumerate(slot_req):
                if req is None:
                    continue
                p = int(slot_pos[s])
                if p < len(req):
                    toks[s, 0] = req[p]  # still consuming the prompt
                elif slot_out[s]:
                    toks[s, 0] = slot_out[s][-1]
            pos = int(slot_pos.max())
            logits, cache = decode(
                params, cache, {"tokens": jnp.asarray(toks), "pos": jnp.asarray(pos, jnp.int32)}
            )
            steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for s, req in enumerate(slot_req):
                if req is None:
                    continue
                slot_pos[s] += 1
                if slot_pos[s] >= len(req):
                    slot_out[s].append(int(nxt[s]))
                if len(slot_out[s]) >= args.gen:
                    done.append((req, slot_out[s]))
                    slot_req[s] = None
        dt = time.time() - t0
        print(
            f"[serve] {len(done)} requests, {steps} decode steps, "
            f"{steps * args.slots / dt:.1f} tok/s (host mesh, CPU)"
        )
        for i, (req, out) in enumerate(done[:3]):
            print(f"  req{i}: prompt[:4]={req[:4]} -> gen={out}")
        return len(done)


if __name__ == "__main__":
    main()
