"""Posterior re-fit daemon: watch datasets, re-fit, atomically swap.

    # one sweep (CI / cron): re-fit anything whose data content changed
    PYTHONPATH=src python -m repro.launch.abc_serve --once \
        --data-dir data/ --store store/ --models siard --days 21

    # daemon: poll for dataset updates (e.g. new daily rows) forever
    PYTHONPATH=src python -m repro.launch.abc_serve \
        --data-dir data/ --store store/ --interval 300

The serving split (see repro.core.serving): `serve --epi` answers queries
from the posterior store; THIS process keeps the store fresh. Each sweep
hashes every `<name>.json` dataset's content and, for each (dataset,
model) pair whose version moved past the stored fit, runs an SMC re-fit
WARM-STARTED from the previous version's weighted population
(`SMCConfig.initial_particles`) — new daily rows barely move a posterior,
so round 0 costs n_particles simulations instead of a full prior wave —
then swaps the store entry atomically (tmp+rename on both the .npz and
the index). A query server crash-reading mid-swap is impossible; a daemon
crash mid-fit leaves the previous complete entry being served.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import time


def sweep(server, data_dir: str, models) -> dict:
    """One pass over every dataset file x model; returns status counts."""
    counts = {"cached": 0, "warm_refit": 0, "cold_fit": 0, "error": 0}
    paths = sorted(glob.glob(os.path.join(data_dir, "*.json")))
    for path in paths:
        name = os.path.splitext(os.path.basename(path))[0]
        if name == "index":
            continue
        for model in models:
            try:
                status = server.refresh(name, model)
            except (ValueError, FileNotFoundError) as e:
                print(f"[abc_serve] {name}/{model}: SKIP ({e})",
                      file=sys.stderr)
                counts["error"] += 1
                continue
            counts[status] += 1
            if status != "cached":
                print(f"[abc_serve] {name}/{model}: {status}",
                      file=sys.stderr)
    return counts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--data-dir", required=True,
                    help="directory of <name>.json dataset files to watch")
    ap.add_argument("--store", required=True,
                    help="posterior-store directory to keep fresh")
    ap.add_argument("--models", nargs="+", default=["siard"],
                    help="models to maintain a posterior for, per dataset")
    ap.add_argument("--once", action="store_true",
                    help="one sweep, then exit (exit code 0; prints counts)")
    ap.add_argument("--interval", type=float, default=300.0,
                    help="seconds between sweeps in daemon mode")
    ap.add_argument("--max-sweeps", type=int, default=0,
                    help="stop after N sweeps (0 = forever; testing hook)")
    ap.add_argument("--days", type=int, default=21,
                    help="SMC fit window (days of observed data)")
    ap.add_argument("--fit-particles", type=int, default=128)
    ap.add_argument("--fit-batch", type=int, default=4096)
    ap.add_argument("--fit-rounds", type=int, default=3)
    ap.add_argument("--fit-quantile", type=float, default=0.5)
    ap.add_argument("--fit-backend", default="xla_fused",
                    choices=["xla", "xla_fused", "pallas"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core.serving import EpiServer, ServeConfig
    from repro.core.smc import SMCConfig

    server = EpiServer(ServeConfig(
        fit=SMCConfig(
            n_particles=args.fit_particles,
            batch_size=args.fit_batch,
            n_rounds=args.fit_rounds,
            quantile=args.fit_quantile,
            num_days=args.days,
            backend=args.fit_backend,
        ),
        fit_seed=args.seed,
        data_dir=args.data_dir,
        store_dir=args.store,
    ))

    sweeps = 0
    while True:
        counts = sweep(server, args.data_dir, args.models)
        sweeps += 1
        refits = counts["warm_refit"] + counts["cold_fit"]
        print(f"[abc_serve] sweep {sweeps}: {counts}", file=sys.stderr)
        if args.once or (args.max_sweeps and sweeps >= args.max_sweeps):
            return refits
        time.sleep(args.interval)


if __name__ == "__main__":
    main()
