"""Posterior re-fit daemon: watch datasets, re-fit, atomically swap.

    # one sweep (CI / cron): re-fit anything whose data content changed
    PYTHONPATH=src python -m repro.launch.abc_serve --once \
        --data-dir data/ --store store/ --models siard --days 21

    # daemon: poll for dataset updates (e.g. new daily rows) forever
    PYTHONPATH=src python -m repro.launch.abc_serve \
        --data-dir data/ --store store/ --interval 300

    # amortized fast path: the re-fit is an NPE fine-tune, not a campaign
    PYTHONPATH=src python -m repro.launch.abc_serve --once --backend npe \
        --data-dir data/ --store store/ --models sir --days 21

The serving split (see repro.core.serving): `serve --epi` answers queries
from the posterior store; THIS process keeps the store fresh. Each sweep
hashes every `<name>.json` dataset's content and, for each (dataset,
model) pair whose version moved past the stored fit, refreshes the
posterior and swaps the store entry atomically (tmp+rename on both the
.npz and the index). A query server crash-reading mid-swap is impossible;
a daemon crash mid-fit leaves the previous complete entry being served.

Two refresh mechanisms (`--backend`):

  * smc (default) — an SMC re-fit WARM-STARTED from the previous version's
    weighted population (`SMCConfig.initial_particles`): new daily rows
    barely move a posterior, so round 0 costs n_particles simulations
    instead of a full prior wave.
  * npe — a `repro.core.npe` estimator is trained on the FIRST sweep, then
    every later version change costs only `--npe-fine-tune` gradient steps
    (0 = a pure forward pass, zero simulation waves) before re-sampling
    the store entry. The estimator itself persists under `<store>/npe/`.
"""

from __future__ import annotations

import argparse
import glob
import os
import sys
import time


def sweep(server, data_dir: str, models) -> dict:
    """One pass over every dataset file x model; returns status counts."""
    counts = {"cached": 0, "warm_refit": 0, "cold_fit": 0, "error": 0}
    paths = sorted(glob.glob(os.path.join(data_dir, "*.json")))
    for path in paths:
        name = os.path.splitext(os.path.basename(path))[0]
        if name == "index":
            continue
        for model in models:
            try:
                status = server.refresh(name, model)
            except (ValueError, FileNotFoundError) as e:
                print(f"[abc_serve] {name}/{model}: SKIP ({e})",
                      file=sys.stderr)
                counts["error"] += 1
                continue
            counts[status] += 1
            if status != "cached":
                print(f"[abc_serve] {name}/{model}: {status}",
                      file=sys.stderr)
    return counts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--data-dir", required=True,
                    help="directory of <name>.json dataset files to watch")
    ap.add_argument("--store", required=True,
                    help="posterior-store directory to keep fresh")
    ap.add_argument("--models", nargs="+", default=["siard"],
                    help="models to maintain a posterior for, per dataset")
    ap.add_argument("--once", action="store_true",
                    help="one sweep, then exit (exit code 0; prints counts)")
    ap.add_argument("--interval", type=float, default=300.0,
                    help="seconds between sweeps in daemon mode")
    ap.add_argument("--max-sweeps", type=int, default=0,
                    help="stop after N sweeps (0 = forever; testing hook)")
    ap.add_argument("--days", type=int, default=21,
                    help="SMC fit window (days of observed data)")
    ap.add_argument("--fit-particles", type=int, default=128)
    ap.add_argument("--fit-batch", type=int, default=4096)
    ap.add_argument("--fit-rounds", type=int, default=3)
    ap.add_argument("--fit-quantile", type=float, default=0.5)
    ap.add_argument("--fit-backend", default="xla_fused",
                    choices=["xla", "xla_fused", "pallas"],
                    help="simulation backend of the SMC waves "
                         "(--backend smc only)")
    ap.add_argument("--backend", default="smc", choices=["smc", "npe"],
                    help="refresh mechanism: SMC re-fit waves, or an "
                         "amortized NPE estimator fine-tuned per version")
    ap.add_argument("--npe-steps", type=int, default=None,
                    help="--backend npe: initial training steps "
                         "(default NPEConfig)")
    ap.add_argument("--npe-fine-tune", type=int, default=None,
                    help="--backend npe: gradient steps per version change "
                         "(0 = zero-cost refresh)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.core.serving import EpiServer, ServeConfig
    from repro.core.smc import SMCConfig

    if args.backend != "npe" and (
        args.npe_steps is not None or args.npe_fine_tune is not None
    ):
        ap.error("--npe-* flags have no effect without --backend npe")
    npe_cfg = None
    if args.backend == "npe":
        from repro.core.npe import NPEConfig

        overrides = {
            k: v for k, v in (("train_steps", args.npe_steps),
                              ("fine_tune_steps", args.npe_fine_tune))
            if v is not None
        }
        npe_cfg = NPEConfig(**overrides) if overrides else None

    server = EpiServer(ServeConfig(
        fit=SMCConfig(
            n_particles=args.fit_particles,
            batch_size=args.fit_batch,
            n_rounds=args.fit_rounds,
            quantile=args.fit_quantile,
            num_days=args.days,
            backend=args.fit_backend,
        ),
        fit_seed=args.seed,
        data_dir=args.data_dir,
        store_dir=args.store,
        fit_backend=args.backend,
        npe=npe_cfg,
    ))

    sweeps = 0
    while True:
        counts = sweep(server, args.data_dir, args.models)
        sweeps += 1
        refits = counts["warm_refit"] + counts["cold_fit"]
        print(f"[abc_serve] sweep {sweeps}: {counts}", file=sys.stderr)
        if args.once or (args.max_sweeps and sweeps >= args.max_sweeps):
            return refits
        time.sleep(args.interval)


if __name__ == "__main__":
    main()
