from repro.checkpoint.checkpointer import (
    Checkpointer,
    load_checkpoint,
    save_checkpoint,
)
