"""Lightweight orbax-free checkpointing for pytrees of jax/np arrays.

Design points for 1000+-node deployments (scaled down to this container):
  * atomic commit: write to `<step>.tmp/`, fsync, rename to `<step>/` — a
    crash mid-write never corrupts the latest checkpoint;
  * async save: the device->host copy happens on the caller thread (cheap),
    serialization happens on a writer thread so the train loop overlaps
    checkpoint I/O with the next steps;
  * keep-last-k GC;
  * elastic restore: arrays are saved UNSHARDED (per-leaf .npy); on load they
    are placed under whatever sharding the new mesh prescribes, so a job may
    restart on a different device count (reshard-on-load). On a real pod the
    same layout extends to per-shard files keyed by shard index — the
    manifest format already records shapes/dtypes for that;
  * manifest.json carries the tree structure + per-leaf metadata + a user
    metadata dict (step, rng state, dataset cursor ...).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import ml_dtypes  # noqa: F401 — registers bfloat16 etc. with np.dtype()
import numpy as np

from repro.ioutils import atomic_write


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _leaf_filename(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save_checkpoint(
    directory: str | Path,
    step: int,
    tree: Any,
    metadata: Optional[Dict] = None,
) -> Path:
    """Synchronous atomic save. Returns the committed path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = directory / f"step_{step:010d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves = _flatten_with_paths(tree)
    manifest = {
        "step": step,
        "time": time.time(),
        "metadata": metadata or {},
        "leaves": [],
    }
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        shape = list(arr.shape)  # before ascontiguousarray (it promotes 0-d)
        arr = np.ascontiguousarray(arr)
        # raw-bytes storage: np.save corrupts extension dtypes (bfloat16);
        # the manifest carries dtype/shape for reconstruction
        # analysis: allow(non-atomic-artifact-write) — writes land in the
        # uncommitted `<step>.tmp/` staging dir; the directory rename below
        # is the atomic commit, so per-leaf files never exist at a final path
        np.save(tmp / _leaf_filename(i), arr.reshape(-1).view(np.uint8))
        manifest["leaves"].append(
            {"path": path, "file": _leaf_filename(i), "shape": shape,
             "dtype": str(arr.dtype)}
        )
    with atomic_write(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def load_checkpoint(
    directory: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
):
    """Restore into the structure of `like`. If `shardings` is given, each
    leaf is device_put under the (possibly different) new mesh's sharding —
    the elastic-rescale path. Returns (tree, metadata, step)."""
    directory = Path(directory)
    steps = sorted(
        int(p.name.split("_")[1]) for p in directory.glob("step_*") if p.is_dir()
        and not p.name.endswith(".tmp")
    )
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = step if step is not None else steps[-1]
    path = directory / f"step_{step:010d}"
    with open(path / "manifest.json") as f:
        manifest = json.load(f)

    flat_like = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out_leaves = []
    flat_sh = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
        else [None] * len(flat_like)
    )
    for (keypath, leaf_like), sh in zip(flat_like, flat_sh):
        e = by_path.get(keypath)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {keypath}")
        raw = np.load(path / e["file"])
        arr = raw.view(np.dtype(e["dtype"])).reshape(e["shape"])
        expected = tuple(np.shape(leaf_like))
        if tuple(arr.shape) != expected:
            raise ValueError(
                f"shape mismatch for {keypath}: ckpt {arr.shape} vs {expected}"
            )
        if sh is not None:
            arr = jax.device_put(arr, sh)
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out_leaves
    )
    return tree, manifest["metadata"], step


class Checkpointer:
    """Async keep-k checkpoint manager."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        """Snapshot to host memory now; write on a background thread."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        save_checkpoint(self.directory, step, tree, metadata)
        self._gc()

    def restore(self, like: Any, step: Optional[int] = None, shardings: Any = None):
        self.wait()
        return load_checkpoint(self.directory, like, step, shardings)

    def steps(self):
        return sorted(
            int(p.name.split("_")[1])
            for p in self.directory.glob("step_*")
            if p.is_dir() and not p.name.endswith(".tmp")
        )

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:010d}", ignore_errors=True)
