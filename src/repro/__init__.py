"""repro: hardware-accelerated simulation-based inference (parallel ABC) at pod scale.

Reproduction + beyond-paper optimization of:
  Kulkarni, Krell, Nabarro, Moritz (2020),
  "Hardware-accelerated Simulation-based Inference of Stochastic
   Epidemiology Models for COVID-19" (DOI 10.1145/3471188).
"""

__version__ = "0.1.0"
