from repro.data.pipeline import SyntheticTokenDataset, make_batches
