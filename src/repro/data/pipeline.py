"""Deterministic synthetic token pipeline for LM training.

Generates a Zipf-ish Markov token stream per (seed, shard); every batch is
addressed by (epoch, step, shard) so any worker can regenerate any batch —
the same work-addressing idea the ABC engine uses for fault tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokenDataset:
    vocab: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, batch_size: int, shard: int = 0, n_shards: int = 1):
        """Returns dict(tokens [b, S], labels [b, S]) for this host's shard."""
        assert batch_size % n_shards == 0
        b = batch_size // n_shards
        rng = np.random.default_rng(
            np.uint64(self.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(131)
            + np.uint64(shard)
        )
        # cheap structured stream: mixture of a Zipf unigram draw and a
        # shifted copy (so there IS learnable next-token signal)
        z = rng.zipf(1.3, size=(b, self.seq_len + 1)).astype(np.int64)
        toks = np.minimum(z, self.vocab - 1)
        copy_mask = rng.random((b, self.seq_len + 1)) < 0.5
        toks[:, 1:] = np.where(copy_mask[:, 1:], toks[:, :-1], toks[:, 1:])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batches(
    ds: SyntheticTokenDataset, batch_size: int, steps: int, shard: int = 0, n_shards: int = 1
) -> Iterator[dict]:
    for step in range(steps):
        yield ds.batch(step, batch_size, shard, n_shards)
