"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128. SSD (state-space duality), chunked matmul form. Runs the
long_500k shape (O(1)-state decode). [arXiv:2405.21060]"""

from repro.models.registry import ModelDef, register
from repro.models.ssm import Mamba2Config


def full() -> ModelDef:
    return ModelDef(
        name="mamba2-130m",
        family="ssm",
        cfg=Mamba2Config(
            name="mamba2-130m",
            n_layers=24,
            d_model=768,
            d_state=128,
            vocab=50_280,
            head_dim=64,
            expand=2,
            chunk=128,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="mamba2-130m-smoke",
        family="ssm",
        cfg=Mamba2Config(
            name="mamba2-130m-smoke",
            n_layers=2,
            d_model=64,
            d_state=16,
            vocab=512,
            head_dim=16,
            expand=2,
            chunk=16,
            remat="none",
        ),
    )


register("mamba2-130m", full, smoke)
