"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (kv=16) d_ff=1408 (expert
width) vocab=102400, MoE 64 routed top-6 + 2 shared, fine-grained; first layer
dense. [arXiv:2401.06066; hf]"""

from repro.models.decoder import DecoderConfig
from repro.models.moe import MoEConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="deepseek-moe-16b",
        family="decoder",
        cfg=DecoderConfig(
            name="deepseek-moe-16b",
            n_layers=28,
            d_model=2048,
            n_heads=16,
            n_kv_heads=16,
            head_dim=128,
            d_ff=1408,
            vocab=102_400,
            act="silu",
            tie_embed=False,
            moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
            n_dense_prefix=1,
            dense_prefix_ff=10944,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="deepseek-moe-16b-smoke",
        family="decoder",
        cfg=DecoderConfig(
            name="deepseek-moe-16b-smoke",
            n_layers=3,
            d_model=64,
            n_heads=4,
            n_kv_heads=4,
            head_dim=16,
            d_ff=32,
            vocab=512,
            act="silu",
            tie_embed=False,
            moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2),
            n_dense_prefix=1,
            dense_prefix_ff=128,
            remat="none",
        ),
    )


register("deepseek-moe-16b", full, smoke)
