"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560 ssm_state=64 +
SHARED attention block (32H kv=32, d_ff=10240) applied every 6 layers with
concat(hidden, embedding) input. Runs long_500k (hybrid decode is O(S) in
memory, not quadratic). Simplifications vs HF noted in hybrid.py docstring.
[arXiv:2411.15242; hf]"""

from repro.models.hybrid import HybridConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="zamba2-2.7b",
        family="hybrid",
        cfg=HybridConfig(
            name="zamba2-2.7b",
            n_layers=54,
            d_model=2560,
            d_state=64,
            vocab=32_000,
            n_heads=32,
            n_kv_heads=32,
            head_dim=80,
            d_ff=10_240,
            shared_every=6,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        cfg=HybridConfig(
            name="zamba2-2.7b-smoke",
            n_layers=4,
            d_model=64,
            d_state=16,
            vocab=512,
            n_heads=4,
            n_kv_heads=4,
            head_dim=16,
            d_ff=128,
            shared_every=2,
            chunk=16,
            remat="none",
        ),
    )


register("zamba2-2.7b", full, smoke)
