"""internvl2-2b [vlm] — InternViT (stub) + InternLM2-1.8B backbone:
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553. Patch embeddings are
precomputed per brief; 2-layer MLP projector. [arXiv:2404.16821; hf]"""

from repro.models.decoder import DecoderConfig
from repro.models.registry import ModelDef, register
from repro.models.vlm import VLMConfig


def full() -> ModelDef:
    lm = DecoderConfig(
        name="internvl2-2b-lm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=92_553,
        act="silu",
        rope_theta=1_000_000.0,
        tie_embed=False,
    )
    return ModelDef(
        name="internvl2-2b",
        family="vlm",
        cfg=VLMConfig(name="internvl2-2b", lm=lm, vit_dim=1024, n_patches=256),
    )


def smoke() -> ModelDef:
    lm = DecoderConfig(
        name="internvl2-2b-lm-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        act="silu",
        tie_embed=False,
        remat="none",
    )
    return ModelDef(
        name="internvl2-2b-smoke",
        family="vlm",
        cfg=VLMConfig(name="internvl2-2b-smoke", lm=lm, vit_dim=32, n_patches=8),
    )


register("internvl2-2b", full, smoke)
