"""whisper-large-v3 [audio] — enc-dec backbone, 32+32L d_model=1280 20H (MHA
kv=20) d_ff=5120 vocab=51866. Conv frontend STUBBED per brief: inputs are
precomputed frame embeddings; a learned linear adapter stands in for the conv
stack. [arXiv:2212.04356]"""

from repro.models.encdec import EncDecConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="whisper-large-v3",
        family="encdec",
        cfg=EncDecConfig(
            name="whisper-large-v3",
            n_enc_layers=32,
            n_dec_layers=32,
            d_model=1280,
            n_heads=20,
            n_kv_heads=20,
            head_dim=64,
            d_ff=5120,
            vocab=51_866,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="whisper-large-v3-smoke",
        family="encdec",
        cfg=EncDecConfig(
            name="whisper-large-v3-smoke",
            n_enc_layers=2,
            n_dec_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=4,
            head_dim=16,
            d_ff=128,
            vocab=512,
            max_dec_len=64,
            remat="none",
        ),
    )


register("whisper-large-v3", full, smoke)
