"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768 (expert
width) vocab=151936, MoE 128 experts top-8, no shared experts.
[hf:Qwen/Qwen3-30B-A3B]"""

from repro.models.decoder import DecoderConfig
from repro.models.moe import MoEConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="qwen3-moe-30b-a3b",
        family="decoder",
        cfg=DecoderConfig(
            name="qwen3-moe-30b-a3b",
            n_layers=48,
            d_model=2048,
            n_heads=32,
            n_kv_heads=4,
            head_dim=128,
            d_ff=768,
            vocab=151_936,
            act="silu",
            rope_theta=1_000_000.0,
            tie_embed=False,
            moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, n_shared=0),
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="qwen3-moe-30b-a3b-smoke",
        family="decoder",
        cfg=DecoderConfig(
            name="qwen3-moe-30b-a3b-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=32,
            vocab=512,
            act="silu",
            tie_embed=False,
            moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=0),
            remat="none",
        ),
    )


register("qwen3-moe-30b-a3b", full, smoke)
