"""The paper's own workload config: parallel ABC over the stochastic
epidemiology models (DESIGN.md §1). Scales from this CPU container (reduced
batch) to the production pod meshes (launch/abc_run.py). Since the
stoichiometry-driven refactor a workload names its model via
`ABCConfig.model`; `cross_model_sweep()` yields one workload per registry
entry for model-comparison runs, `serving_demo()`/`npe_serving_demo()`
template the query server, and `npe_demo()` sizes the CI amortized-inference
estimator (backend="npe")."""

import dataclasses
from typing import Tuple

from repro.core.abc import ABCConfig
from repro.epi.models import list_models


@dataclasses.dataclass(frozen=True)
class ABCWorkload:
    name: str
    dataset: str
    abc: ABCConfig

    def load_dataset(self, num_days: int | None = None):
        """Materialize the dataset for this workload's model — callers must
        not re-derive it from the name alone, or the model gets lost."""
        from repro.epi.data import get_dataset

        return get_dataset(
            self.dataset,
            num_days=num_days or self.abc.num_days,
            model=self.abc.model,
        )


def paper_production() -> ABCWorkload:
    """Paper §4/§5 scale: 100k samples per device, outfeed chunks of 10k."""
    return ABCWorkload(
        name="epi-abc-production",
        dataset="italy",
        abc=ABCConfig(
            batch_size=100_000 * 512,  # 100k per device on the 512-chip mesh
            tolerance=5e4,
            target_accepted=1000,
            strategy="outfeed",
            chunk_size=10_000,
            num_days=49,
            backend="pallas",
            model="siard",
        ),
    )


def cpu_demo() -> ABCWorkload:
    return ABCWorkload(
        name="epi-abc-demo",
        dataset="synthetic_small",
        abc=ABCConfig(
            batch_size=8192,
            tolerance=1.6e4,
            target_accepted=100,
            strategy="outfeed",
            chunk_size=1024,
            num_days=20,
            backend="xla_fused",
            model="siard",
        ),
    )


def serving_demo(store_dir: str | None = None, data_dir: str | None = None):
    """Smoke-sized `serve --epi` config: fast SMC fits, small forecast
    batches. The shape of a production deployment (bigger fit budget, a
    persistent store refreshed by the abc_serve daemon) with CI-container
    costs. Returns a `repro.core.serving.ServeConfig`."""
    from repro.core.serving import ServeConfig
    from repro.core.smc import SMCConfig

    return ServeConfig(
        slots=4,
        forecast_particles=64,
        fit=SMCConfig(
            n_particles=64,
            batch_size=1024,
            n_rounds=2,
            quantile=0.5,
            num_days=15,
            backend="xla_fused",
            model="siard",
        ),
        data_dir=data_dir,
        store_dir=store_dir,
    )


def npe_demo(model: str = "sir", num_days: int = 15) -> ABCWorkload:
    """CI-sized amortized-inference workload: a tiny NPE estimator trained
    on ~1e5 simulator calls in seconds (the nightly trains exactly this via
    benchmarks/bench_npe.py). Production fits scale `train_steps`,
    `train_batch` and `hidden`; the workflow is identical."""
    from repro.core.npe import NPEConfig

    return ABCWorkload(
        name=f"epi-npe-demo-{model}",
        dataset="synthetic_small",
        abc=ABCConfig(
            target_accepted=256,
            num_days=num_days,
            backend="npe",
            model=model,
            npe=NPEConfig(
                train_steps=300,
                train_batch=256,
                hidden=64,
                n_components=4,
                n_pilot=512,
                fine_tune_steps=50,
            ),
        ),
    )


def npe_serving_demo(store_dir: str | None = None,
                     data_dir: str | None = None):
    """`serving_demo` with the amortized fit backend: the first query of a
    (model, summary, schedule) trains the estimator; every later dataset
    version is a fine-tune + forward pass, never a wave campaign."""
    from repro.core.npe import NPEConfig

    return dataclasses.replace(
        serving_demo(store_dir=store_dir, data_dir=data_dir),
        fit_backend="npe",
        npe=NPEConfig(train_steps=120, train_batch=128, n_pilot=256,
                      fine_tune_steps=20),
    )


def cross_model_sweep(
    batch_size: int = 8192,
    num_days: int = 20,
    backend: str = "xla_fused",
) -> Tuple[ABCWorkload, ...]:
    """One synthetic-recovery workload per registered model.

    Tolerances are left at infinity + topk so each workload self-selects its
    acceptance set; callers typically pair this with `calibrate_tolerance`.
    """
    out = []
    for name in list_models():
        out.append(
            ABCWorkload(
                name=f"epi-abc-{name}",
                dataset="synthetic_small",
                abc=ABCConfig(
                    batch_size=batch_size,
                    tolerance=float("inf"),
                    target_accepted=100,
                    strategy="topk",
                    top_k=100,
                    num_days=num_days,
                    backend=backend,
                    model=name,
                ),
            )
        )
    return tuple(out)
