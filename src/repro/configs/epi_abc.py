"""The paper's own workload config: parallel ABC over the stochastic
epidemiology model (DESIGN.md §1). Scales from this CPU container (reduced
batch) to the production pod meshes (launch/abc_run.py)."""

import dataclasses

from repro.core.abc import ABCConfig


@dataclasses.dataclass(frozen=True)
class ABCWorkload:
    name: str
    dataset: str
    abc: ABCConfig


def paper_production() -> ABCWorkload:
    """Paper §4/§5 scale: 100k samples per device, outfeed chunks of 10k."""
    return ABCWorkload(
        name="epi-abc-production",
        dataset="italy",
        abc=ABCConfig(
            batch_size=100_000 * 512,  # 100k per device on the 512-chip mesh
            tolerance=5e4,
            target_accepted=1000,
            strategy="outfeed",
            chunk_size=10_000,
            num_days=49,
            backend="pallas",
        ),
    )


def cpu_demo() -> ABCWorkload:
    return ABCWorkload(
        name="epi-abc-demo",
        dataset="synthetic_small",
        abc=ABCConfig(
            batch_size=8192,
            tolerance=1.6e4,
            target_accepted=100,
            strategy="outfeed",
            chunk_size=1024,
            num_days=20,
            backend="xla_fused",
        ),
    )
