"""Architecture configs — importing this package registers all archs."""

from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    gemma2_27b,
    gemma_2b,
    internlm2_20b,
    internvl2_2b,
    mamba2_130m,
    minitron_8b,
    qwen3_moe_30b_a3b,
    whisper_large_v3,
    zamba2_2_7b,
)

ALL_ARCHS = (
    "internlm2-20b",
    "gemma2-27b",
    "minitron-8b",
    "gemma-2b",
    "deepseek-moe-16b",
    "qwen3-moe-30b-a3b",
    "whisper-large-v3",
    "mamba2-130m",
    "internvl2-2b",
    "zamba2-2.7b",
)
