"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544. GQA. [arXiv:2403.17297; hf]"""

from repro.models.decoder import DecoderConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="internlm2-20b",
        family="decoder",
        cfg=DecoderConfig(
            name="internlm2-20b",
            n_layers=48,
            d_model=6144,
            n_heads=48,
            n_kv_heads=8,
            head_dim=128,
            d_ff=16384,
            vocab=92544,
            act="silu",
            rope_theta=1_000_000.0,
            tie_embed=False,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="internlm2-20b-smoke",
        family="decoder",
        cfg=DecoderConfig(
            name="internlm2-20b-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            act="silu",
            rope_theta=1_000_000.0,
            tie_embed=False,
            remat="none",
        ),
    )


register("internlm2-20b", full, smoke)
