"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000. Pruned nemotron: non-gated squared-ReLU MLP, untied embeddings.
[arXiv:2407.14679; hf]"""

from repro.models.decoder import DecoderConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="minitron-8b",
        family="decoder",
        cfg=DecoderConfig(
            name="minitron-8b",
            n_layers=32,
            d_model=4096,
            n_heads=32,
            n_kv_heads=8,
            head_dim=128,
            d_ff=16384,
            vocab=256_000,
            act="relu2",
            rope_theta=10_000.0,
            tie_embed=False,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="minitron-8b-smoke",
        family="decoder",
        cfg=DecoderConfig(
            name="minitron-8b-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=128,
            vocab=512,
            act="relu2",
            tie_embed=False,
            remat="none",
        ),
    )


register("minitron-8b", full, smoke)
