"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000. Local+global alternating, logit softcap. [arXiv:2408.00118; hf]

gemma2 specifics: GeGLU, (local 4096, global) alternation, attn softcap 50,
final logit softcap 30, post-attn/post-ffn RMSNorms, embeddings scaled by
sqrt(d_model), query scale 1/sqrt(query_pre_attn_scalar=128) ~ per-head-dim.
long_500k is SKIPPED: half the layers are global full attention
(DESIGN.md §Arch-applicability)."""

from repro.models.decoder import DecoderConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="gemma2-27b",
        family="decoder",
        cfg=DecoderConfig(
            name="gemma2-27b",
            n_layers=46,
            d_model=4608,
            n_heads=32,
            n_kv_heads=16,
            head_dim=128,
            d_ff=36864,
            vocab=256_000,
            act="gelu",
            attn_pattern=("local", "global"),
            window=4096,
            attn_softcap=50.0,
            final_softcap=30.0,
            query_scale=(4608 / 32) ** -0.5,  # query_pre_attn_scalar = d/heads
            embed_scale=True,
            post_norms=True,
            tie_embed=True,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="gemma2-27b-smoke",
        family="decoder",
        cfg=DecoderConfig(
            name="gemma2-27b-smoke",
            n_layers=4,
            d_model=64,
            n_heads=4,
            n_kv_heads=2,
            head_dim=16,
            d_ff=256,
            vocab=512,
            act="gelu",
            attn_pattern=("local", "global"),
            window=8,
            attn_softcap=50.0,
            final_softcap=30.0,
            query_scale=(64 / 4) ** -0.5,
            embed_scale=True,
            post_norms=True,
            tie_embed=True,
            remat="none",
        ),
    )


register("gemma2-27b", full, smoke)
