"""gemma-2b [dense] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
GeGLU, head_dim=256 (explicit, != d_model/n_heads), MQA. [arXiv:2403.08295; hf]"""

from repro.models.decoder import DecoderConfig
from repro.models.registry import ModelDef, register


def full() -> ModelDef:
    return ModelDef(
        name="gemma-2b",
        family="decoder",
        cfg=DecoderConfig(
            name="gemma-2b",
            n_layers=18,
            d_model=2048,
            n_heads=8,
            n_kv_heads=1,
            head_dim=256,
            d_ff=16384,
            vocab=256_000,
            act="gelu",
            embed_scale=True,
            tie_embed=True,
        ),
    )


def smoke() -> ModelDef:
    return ModelDef(
        name="gemma-2b-smoke",
        family="decoder",
        cfg=DecoderConfig(
            name="gemma-2b-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=1,
            head_dim=32,  # head_dim decoupled from d_model/heads, like gemma
            d_ff=128,
            vocab=512,
            act="gelu",
            embed_scale=True,
            tie_embed=True,
            remat="none",
        ),
    )


register("gemma-2b", full, smoke)
