"""Multi-device / multi-pod drivers for parallel ABC (paper §4.5, Table 7).

Two equivalent formulations are provided:

  * `make_pjit_runner`   — GSPMD: one logical batch, sharded over the data
    axes by the partitioner. Simplest; collectives chosen by XLA.
  * `make_shardmap_runner` — explicit per-device program (the faithful analogue
    of the paper's per-IPU replica): each device folds its axis index into the
    run key, simulates its own sub-batch, and the ONLY cross-device collective
    is a psum of the scalar accept count. This is why the paper sees <= 8%
    scaling overhead — we get the same property by construction.

Both return a callable with the RunOutput signature of `abc_run_batch`, so the
host driver (`run_abc`) is oblivious to the device topology. Work addressing
stays (base_key, run_idx, device_idx) => deterministic, resumable, elastic:
a restarted job with a different device count re-partitions runs without
changing the sample stream semantics (each (run, device) pair is a unique
fold_in, and acceptance is i.i.d. across all of them).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax >= 0.5 exposes shard_map at the top level; 0.4.x keeps it experimental
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover - exercised on jax 0.4.x containers
    from functools import wraps

    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    @wraps(_experimental_shard_map)
    def shard_map(*args, **kwargs):
        # 0.4.x has no replication rule for lax.while_loop (the device wave
        # loop); jax's documented workaround is to skip the static check.
        # Our P() outputs are psum-replicated by construction either way.
        kwargs.setdefault("check_rep", False)
        return _experimental_shard_map(*args, **kwargs)

from repro.core.abc import (
    ABCConfig,
    RunOutput,
    SimulatorFn,
    WaveLoopOutput,
    WaveRunner,
    abc_run_batch,
    build_wave_loop,
    make_simulator,
    wave_capacity,
)
from repro.core.priors import UniformBoxPrior, schedule_prior


def make_runner(mesh: Mesh, dataset, cfg: ABCConfig, style: str = "shard_map"):
    """Build a sharded runner from the config alone.

    Resolves the model spec named by `cfg.model` (prior bounds, parameter
    dimension, simulator) so callers never hardcode a particular model's
    shapes. `style` is "shard_map" (paper-faithful per-device replica) or
    "pjit" (GSPMD).
    """
    from repro.epi.models import get_model

    if style not in ("shard_map", "pjit"):
        raise ValueError(f"unknown runner style {style!r}")
    # schedule-aware: theta must carry the scale columns the simulator expects
    prior = schedule_prior(get_model(cfg.model), cfg.schedule)
    simulator = make_simulator(dataset, cfg)
    maker = make_shardmap_runner if style == "shard_map" else make_pjit_runner
    return maker(mesh, prior, simulator, cfg)


def make_wave_runner(mesh: Mesh, dataset, cfg: ABCConfig, style: str = "shard_map"):
    """Sharded DEVICE-RESIDENT wave loop (the multi-device analogue of
    `abc.make_wave_runner`): the whole accept/reject loop stays on the mesh,
    and the host is re-entered only at target/budget/checkpoint boundaries.
    """
    from repro.epi.models import get_model

    if style not in ("shard_map", "pjit"):
        raise ValueError(f"unknown runner style {style!r}")
    # schedule-aware: theta must carry the scale columns the simulator expects
    prior = schedule_prior(get_model(cfg.model), cfg.schedule)
    simulator = make_simulator(dataset, cfg)
    maker = (
        make_shardmap_wave_runner if style == "shard_map" else make_pjit_wave_runner
    )
    return maker(mesh, prior, simulator, cfg)


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All mesh axes used for ABC data parallelism (every axis: ABC is pure DP)."""
    return tuple(mesh.axis_names)


def make_pjit_runner(
    mesh: Mesh,
    prior: UniformBoxPrior,
    simulator: SimulatorFn,
    cfg: ABCConfig,
) -> Callable[[jax.Array], RunOutput]:
    """GSPMD path: shard the chunk dimension of the global batch."""
    axes = data_axes(mesh)
    run = abc_run_batch(prior, simulator, cfg)
    if cfg.strategy == "outfeed":
        out_shardings = RunOutput(
            NamedSharding(mesh, P(axes)),  # theta [nc, cs, p]
            NamedSharding(mesh, P(axes)),  # dist  [nc, cs]
            NamedSharding(mesh, P(axes)),  # flags [nc]
            NamedSharding(mesh, P()),  # count
        )
    else:
        out_shardings = RunOutput(
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
        )
    return jax.jit(run, out_shardings=out_shardings)


def make_shardmap_runner(
    mesh: Mesh,
    prior: UniformBoxPrior,
    simulator: SimulatorFn,
    cfg: ABCConfig,
) -> Callable[[jax.Array], RunOutput]:
    """Explicit per-device replica; `cfg.batch_size` is the GLOBAL batch.

    Mirrors the paper's setup where "2x100k" means 100k per IPU: the global
    batch is split evenly across every mesh axis.
    """
    axes = data_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    if cfg.batch_size % n_dev:
        raise ValueError(f"batch_size {cfg.batch_size} not divisible by {n_dev} devices")
    local_cfg = dataclasses.replace(
        cfg,
        batch_size=cfg.batch_size // n_dev,
        chunk_size=min(cfg.chunk_size, cfg.batch_size // n_dev),
    )
    local_run = abc_run_batch(prior, simulator, local_cfg)

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=P(),
        out_specs=RunOutput(P(axes), P(axes), P(axes), P()),
    )
    def run(key: jax.Array) -> RunOutput:
        dev = jax.lax.axis_index(axes)
        out = local_run(jax.random.fold_in(key, dev))
        # The ONLY steady-state collective: scalar accept-count reduction.
        count = jax.lax.psum(out.accept_count, axes)
        if cfg.strategy == "outfeed":
            return RunOutput(out.theta, out.dist, out.chunk_flags, count)
        # topk path: per-device top-k buffers are concatenated along the
        # leading axis by the out_spec; host filters dist <= eps as usual.
        return RunOutput(out.theta, out.dist, out.chunk_flags, count)

    return jax.jit(run)


def effective_chunk_flags(out: RunOutput) -> jax.Array:
    return out.chunk_flags


# --------------------------------------------------------------------------
# Device-resident wave loops, sharded
# --------------------------------------------------------------------------

def make_shardmap_wave_runner(
    mesh: Mesh,
    prior: UniformBoxPrior,
    simulator: SimulatorFn,
    cfg: ABCConfig,
) -> WaveRunner:
    """Per-device replica wave loop: each device runs its own while_loop over
    local waves with a local accept buffer; the ONLY steady-state collective
    is the per-wave psum of the scalar accept count that feeds the shared
    stop condition. Keying matches the legacy shard_map runner exactly:
    wave w on device d draws from fold_in(fold_in(key, run_idx0 + w), d).
    """
    axes = data_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    if cfg.batch_size % n_dev:
        raise ValueError(f"batch_size {cfg.batch_size} not divisible by {n_dev} devices")
    local_b = cfg.batch_size // n_dev
    # a device can soak up to (target - 1) of the global accepts plus its own
    # final wave, so the per-shard capacity mirrors the single-device bound
    cap = wave_capacity(cfg, local_b)

    loop = build_wave_loop(
        prior,
        lambda th, k, _data: simulator(th, k),
        cfg,
        batch_size=local_b,
        capacity=cap,
        fold_axis=lambda: jax.lax.axis_index(axes),
        count_all=lambda c: jax.lax.psum(c, axes),
    )

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(axes), P(axes), P(), P(axes), P(), P(), P()),
        out_specs=WaveLoopOutput(P(axes), P(axes), P(), P(), P(axes)),
    )
    def sharded(key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
                tolerance, data):
        out = loop(
            key, run_idx0, theta_buf, dist_buf, n0, fills[0], max_waves,
            tolerance, data,
        )
        return out

    def fn(key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
           tolerance, data):
        # `data` is always None here (the simulator baked the dataset in);
        # pass a dummy zero so every shard_map input is an array.
        # fills must be rank-1 to satisfy the P(axes) in_spec even on a
        # single-device mesh, where WaveRunner.init hands back a scalar.
        fills = jnp.atleast_1d(jnp.asarray(fills, jnp.int32))
        return sharded(
            key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
            tolerance, jnp.zeros((), jnp.int32),
        )

    return WaveRunner(
        fn=jax.jit(fn, donate_argnums=(2, 3)),
        capacity=cap,
        shards=n_dev,
        n_params=prior.dim,
        cfg=cfg,
    )


def make_shardmap_scenario_runner(
    mesh: Mesh,
    prior: UniformBoxPrior,
    sim_call,  # (theta [B_local, p], key, data: ScenarioData) -> dist
    cfg: ABCConfig,
) -> WaveRunner:
    """Per-device-replica wave loop over a PARAMETRIC simulator.

    The campaign's multi-device mode: like `make_shardmap_wave_runner`, but
    the traced `ScenarioData` tuple (observed series, population scalars,
    intervention breakpoints, prior box) rides REPLICATED into every shard
    instead of being baked into the simulator. One compiled loop per
    (scenario shape, device group) therefore still serves every dataset /
    seed / intervention cell of that shape — the compile-reuse property the
    serial campaign relies on, now on a mesh.
    """
    axes = data_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    if cfg.batch_size % n_dev:
        raise ValueError(f"batch_size {cfg.batch_size} not divisible by {n_dev} devices")
    local_b = cfg.batch_size // n_dev
    cap = wave_capacity(cfg, local_b)

    loop = build_wave_loop(
        prior,
        sim_call,
        cfg,
        batch_size=local_b,
        capacity=cap,
        fold_axis=lambda: jax.lax.axis_index(axes),
        count_all=lambda c: jax.lax.psum(c, axes),
    )

    @partial(
        shard_map,
        mesh=mesh,
        # the trailing P() is a pytree-prefix spec: every ScenarioData leaf
        # is replicated across the group
        in_specs=(P(), P(), P(axes), P(axes), P(), P(axes), P(), P(), P()),
        out_specs=WaveLoopOutput(P(axes), P(axes), P(), P(), P(axes)),
    )
    def sharded(key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
                tolerance, data):
        return loop(
            key, run_idx0, theta_buf, dist_buf, n0, fills[0], max_waves,
            tolerance, data,
        )

    def fn(key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
           tolerance, data):
        fills = jnp.atleast_1d(jnp.asarray(fills, jnp.int32))
        return sharded(
            key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
            tolerance, data,
        )

    return WaveRunner(
        fn=jax.jit(fn, donate_argnums=(2, 3)),
        capacity=cap,
        shards=n_dev,
        n_params=prior.dim,
        cfg=cfg,
    )


def make_pjit_wave_runner(
    mesh: Mesh,
    prior: UniformBoxPrior,
    simulator: SimulatorFn,
    cfg: ABCConfig,
) -> WaveRunner:
    """GSPMD wave loop: one logical batch per wave, sharded over the mesh by
    sharding hints on the per-wave batch arrays; the accept buffers stay
    replicated. Sample values are identical to the single-device wave loop
    (constraints never change values), so this style is stream-compatible
    with `run_abc`'s default device loop.
    """
    axes = data_axes(mesh)

    def shard_hint(x):
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    loop = build_wave_loop(
        prior,
        lambda th, k, _data: simulator(th, k),
        cfg,
        shard_hint=shard_hint,
    )
    return WaveRunner(
        fn=jax.jit(loop, donate_argnums=(2, 3)),
        capacity=wave_capacity(cfg),
        shards=1,
        n_params=prior.dim,
        cfg=cfg,
    )
