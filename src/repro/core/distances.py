"""Batched distance functions between simulated and observed data.

The paper uses the Euclidean distance over the flattened observed channels
— [3, T] = (A, R, D) for its SIARD model; every function here is generic
over the channel count, so the shapes below are [B, C, T] with C the
model's n_observed. We also provide normalized variants used in ablations.
"""

from __future__ import annotations

import jax.numpy as jnp


def euclidean_distance(simulated: jnp.ndarray, observed: jnp.ndarray) -> jnp.ndarray:
    """dist(D_s, D) = ||D_s - D||_2 over the trailing [C, T] axes.

    simulated: [B, C, T]; observed: [C, T]  ->  [B].
    """
    diff = simulated - observed[None]
    return jnp.sqrt(jnp.sum(diff * diff, axis=(-2, -1)))


def mean_absolute_distance(simulated: jnp.ndarray, observed: jnp.ndarray) -> jnp.ndarray:
    """Mean absolute error over channels x days. [B, C, T], [C, T] -> [B]."""
    diff = jnp.abs(simulated - observed[None])
    return jnp.mean(diff, axis=(-2, -1))


def normalized_euclidean_distance(
    simulated: jnp.ndarray, observed: jnp.ndarray, eps: float = 1.0
) -> jnp.ndarray:
    """Euclidean distance with per-channel normalization by the observed scale.

    Makes tolerances comparable across countries with very different case
    counts (an ablation the paper discusses when noting tolerances cannot be
    naively scaled by population).
    """
    scale = jnp.sqrt(jnp.mean(observed * observed, axis=-1, keepdims=True)) + eps
    diff = (simulated - observed[None]) / scale[None]
    return jnp.sqrt(jnp.sum(diff * diff, axis=(-2, -1)))


DISTANCES = {
    "euclidean": euclidean_distance,
    "mae": mean_absolute_distance,
    "normalized_euclidean": normalized_euclidean_distance,
}
