"""Massively parallel ABC rejection sampling (paper §3).

The paper's algorithm, verbatim in structure:

  repeat until `target_accepted` samples accepted:
    theta  ~ prior, vectorized          [B, p]
    D_s    ~ simulator(theta)           [B, 3, T]   (or fused distance)
    dist   = ||D_s - D||                [B]
    accept = dist <= tolerance
    return samples to host under a *fixed-shape* strategy (XLA constraint):
      - "outfeed" (paper's IPU path): split the batch into chunks; a chunk is
        transferred to host only if it contains >= 1 accepted sample.
      - "topk"    (paper's GPU path): return the k lowest-distance samples per
        run plus the global accept count; host filters dist <= eps.

Everything device-side is a single jitted function with static output shapes.
In JAX the "transfer only flagged chunks" semantics fall out naturally:
outputs are device arrays, and the host calls `jax.device_get` ONLY on the
flagged chunk rows, so D2H traffic matches the paper's outfeed behaviour.

The engine is resumable (ABCState) and backend-pluggable:
  backend="xla"        paper-faithful full-trajectory simulate + distance
  backend="xla_fused"  running-distance scan (no [B,3,T] materialization)
  backend="pallas"     fused VMEM-resident Pallas kernel (repro.kernels)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import DISTANCES
from repro.core.posterior import Posterior
from repro.core.priors import UniformBoxPrior
from repro.epi import engine
from repro.epi.data import CountryData
from repro.epi.models import get_model

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ABCConfig:
    """Configuration of a parallel ABC inference run."""

    batch_size: int = 100_000  # simulations per run (global)
    tolerance: float = 2e5
    target_accepted: int = 100
    strategy: str = "outfeed"  # "outfeed" | "topk"
    chunk_size: int = 10_000  # outfeed chunk granularity (paper default)
    top_k: int = 5  # samples returned per run under "topk"
    max_runs: int = 100_000
    distance: str = "euclidean"
    backend: str = "xla_fused"
    num_days: int = 49
    #: registry name of the compartmental model to infer (repro.epi.models)
    model: str = "siard"

    def __post_init__(self):
        if self.strategy not in ("outfeed", "topk"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.strategy == "outfeed" and self.batch_size % self.chunk_size:
            raise ValueError("batch_size must be a multiple of chunk_size")
        if self.backend not in ("xla", "xla_fused", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def num_chunks(self) -> int:
        return self.batch_size // self.chunk_size


class RunOutput(NamedTuple):
    """Fixed-shape per-run device outputs (XLA requirement, paper §3.2)."""

    theta: Array  # outfeed: [n_chunks, chunk, p]; topk: [k, p]
    dist: Array  # outfeed: [n_chunks, chunk];    topk: [k]
    chunk_flags: Array  # outfeed: [n_chunks] bool;      topk: [0]
    accept_count: Array  # [] int32 — global accepted this run


SimulatorFn = Callable[[Array, Array], Array]  # (theta [B,p], key) -> dist [B]


def make_simulator(dataset: CountryData, cfg: ABCConfig) -> SimulatorFn:
    """Build the batched theta -> distance function for the chosen backend.

    The model spec comes from `cfg.model`; the dataset must hold series for
    the same observed channels (checked here, not at run time).
    """
    spec = get_model(cfg.model)
    if not dataset.compatible_with(spec):
        raise ValueError(
            f"dataset {dataset.name!r} holds {dataset.model!r} series; model "
            f"{spec.name!r} observes different channels"
        )
    mcfg = dataset.model_config(cfg.num_days)
    observed = jnp.asarray(dataset.observed[:, : cfg.num_days], jnp.float32)
    dist_fn = DISTANCES[cfg.distance]

    if cfg.backend == "xla":

        def simulator(theta: Array, key: Array) -> Array:
            sim = engine.simulate_observed(spec, theta, key, mcfg)  # [B, n_obs, T]
            return dist_fn(sim, observed)

    elif cfg.backend == "xla_fused":
        if cfg.distance != "euclidean":
            raise ValueError("xla_fused backend implements euclidean only")

        def simulator(theta: Array, key: Array) -> Array:
            d, _ = engine.simulate_observed_lowmem(spec, theta, key, mcfg, observed)
            return d

    else:  # pallas
        if cfg.distance != "euclidean":
            raise ValueError("pallas backend implements euclidean only")
        from repro.kernels import ops as kernel_ops

        def simulator(theta: Array, key: Array) -> Array:
            # The kernel uses a counter-based hash RNG; derive a 32-bit seed
            # from the threefry key so runs stay deterministic & resumable.
            seed = jax.random.key_data(key).ravel()[-1].astype(jnp.uint32)
            return kernel_ops.abc_sim_distance(
                theta,
                seed,
                observed,
                population=mcfg.population,
                a0=mcfg.a0,
                r0=mcfg.r0,
                d0=mcfg.d0,
                model=spec,
            )

    return simulator


def abc_run_batch(
    prior: UniformBoxPrior, simulator: SimulatorFn, cfg: ABCConfig
) -> Callable[[Array], RunOutput]:
    """Build the device-side computation for ONE run (one batch).

    Returned callable takes the per-run PRNG key. Pure & jittable; sharding is
    applied by the caller (see core.distributed / launch.abc_run).
    """
    p = prior.dim

    def run(key: Array) -> RunOutput:
        k_prior, k_sim = jax.random.split(key)
        theta = prior.sample(k_prior, (cfg.batch_size,))  # [B, p]
        dist = simulator(theta, k_sim)  # [B]
        # Failed/NaN simulations never count as accepted.
        dist = jnp.where(jnp.isnan(dist), jnp.inf, dist)
        accept = dist <= cfg.tolerance
        count = jnp.sum(accept.astype(jnp.int32))

        if cfg.strategy == "outfeed":
            nc, cs = cfg.num_chunks, cfg.chunk_size
            theta_c = theta.reshape(nc, cs, p)
            dist_c = dist.reshape(nc, cs)
            flags = jnp.any(accept.reshape(nc, cs), axis=1)
            return RunOutput(theta_c, dist_c, flags, count)

        # top-k: k smallest distances (paper's GPU strategy)
        neg_top, idx = jax.lax.top_k(-dist, cfg.top_k)
        return RunOutput(
            theta[idx], -neg_top, jnp.zeros((0,), bool), count
        )

    return run


@dataclasses.dataclass
class ABCState:
    """Resumable sampler state — the fault-tolerance unit for inference.

    Work is addressed by (base seed, run index): any worker can recompute any
    run, so restart/elastic-rescale only needs this state (DESIGN.md §3).
    """

    run_idx: int = 0
    simulations: int = 0
    accepted_theta: list = dataclasses.field(default_factory=list)
    accepted_dist: list = dataclasses.field(default_factory=list)
    #: parameter dimension, set from the model/prior by run_abc (or on load);
    #: required only to give the empty-case arrays a concrete shape
    n_params: Optional[int] = None

    @property
    def n_accepted(self) -> int:
        return sum(int(t.shape[0]) for t in self.accepted_theta)

    def to_arrays(self):
        if not self.accepted_theta:
            # shape derives from the model/prior — NOT a hardcoded paper dim
            return (
                np.zeros((0, self.n_params or 0), np.float32),
                np.zeros((0,), np.float32),
            )
        return (
            np.concatenate(self.accepted_theta, axis=0),
            np.concatenate(self.accepted_dist, axis=0),
        )

    def save(self, path: str) -> None:
        th, d = self.to_arrays()
        np.savez(
            path, run_idx=self.run_idx, simulations=self.simulations, theta=th, dist=d
        )

    @staticmethod
    def load(path: str) -> "ABCState":
        z = np.load(path)
        st = ABCState(
            run_idx=int(z["run_idx"]),
            simulations=int(z["simulations"]),
            n_params=int(z["theta"].shape[1]),
        )
        if z["theta"].shape[0]:
            st.accepted_theta = [z["theta"]]
            st.accepted_dist = [z["dist"]]
        return st


def _harvest(out: RunOutput, cfg: ABCConfig, state: ABCState) -> int:
    """Host-side postprocessing of one run's outputs (paper §3.2 / Table 4).

    Pulls to host ONLY what the strategy marked for transfer, filters
    dist <= eps, and appends accepted samples to the state. Returns the
    number of accepted samples harvested.
    """
    n_new = 0
    if cfg.strategy == "outfeed":
        flags = np.asarray(out.chunk_flags)  # [n_chunks] — tiny transfer
        for ci in np.nonzero(flags)[0]:
            # per-chunk D2H transfer, mirroring the IPU outfeed
            d = np.asarray(out.dist[ci])
            th = np.asarray(out.theta[ci])
            m = d <= cfg.tolerance
            if m.any():
                state.accepted_theta.append(th[m])
                state.accepted_dist.append(d[m])
                n_new += int(m.sum())
    else:  # topk
        d = np.asarray(out.dist)
        th = np.asarray(out.theta)
        m = d <= cfg.tolerance
        if m.any():
            state.accepted_theta.append(th[m])
            state.accepted_dist.append(d[m])
            n_new += int(m.sum())
        # NOTE: if accept_count > k the paper accepts losing samples (their
        # Top-k caveat); we surface the same behaviour.
    return n_new


def run_abc(
    dataset: CountryData,
    cfg: ABCConfig,
    key: Array | int = 0,
    prior: Optional[UniformBoxPrior] = None,
    state: Optional[ABCState] = None,
    run_fn: Optional[Callable[[Array], RunOutput]] = None,
    checkpoint_every: int = 0,
    checkpoint_path: Optional[str] = None,
    verbose: bool = False,
) -> Posterior:
    """Host driver: iterate runs until `target_accepted` posterior samples.

    `run_fn` may be a pre-sharded/jitted runner (multi-device); by default a
    single-device jitted runner is built here.
    """
    spec = get_model(cfg.model)
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    prior = prior or spec.prior()
    state = state or ABCState()
    if state.n_params is None:
        state.n_params = prior.dim
    elif state.n_params != prior.dim:
        raise ValueError(
            f"resumed state holds {state.n_params}-parameter samples but model "
            f"{spec.name!r} has {prior.dim} parameters — wrong checkpoint?"
        )
    if run_fn is None:
        simulator = make_simulator(dataset, cfg)
        run_fn = jax.jit(abc_run_batch(prior, simulator, cfg))

    t0 = time.time()
    postproc_s = 0.0
    while state.n_accepted < cfg.target_accepted and state.run_idx < cfg.max_runs:
        run_key = jax.random.fold_in(key, state.run_idx)
        out = run_fn(run_key)
        out = jax.tree.map(jax.block_until_ready, out)
        tp = time.time()
        _harvest(out, cfg, state)
        postproc_s += time.time() - tp
        state.run_idx += 1
        state.simulations += cfg.batch_size
        if verbose and state.run_idx % 50 == 0:
            print(
                f"[abc] run {state.run_idx}: accepted {state.n_accepted}/"
                f"{cfg.target_accepted}"
            )
        if (
            checkpoint_every
            and checkpoint_path
            and state.run_idx % checkpoint_every == 0
        ):
            state.save(checkpoint_path)

    theta, dist = state.to_arrays()
    # every harvested sample is returned (a run may overshoot target_accepted;
    # the paper keeps the overshoot too — callers can slice with Posterior.top)
    post = Posterior(
        theta=theta,
        distances=dist,
        tolerance=cfg.tolerance,
        param_names=spec.param_names,
        runs=state.run_idx,
        simulations=state.simulations,
        wall_time_s=time.time() - t0,
    )
    post.postproc_time_s = postproc_s  # type: ignore[attr-defined]
    return post


def calibrate_tolerance(
    dataset: CountryData,
    cfg: ABCConfig,
    key: Array | int = 0,
    quantile: float = 1e-3,
    n_pilot: int = 65_536,
    prior: Optional[UniformBoxPrior] = None,
) -> float:
    """Auto-pick a tolerance as a quantile of the pilot distance distribution.

    The paper tunes epsilon per country by hand ("the tolerance had to be
    adjusted on an individual basis", §5); this calibrates it from a pilot
    wave of prior-predictive simulations so the expected acceptance rate —
    and therefore total runtime — is controlled a priori:
        expected runs ~= target_accepted / (quantile * batch_size).
    """
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    prior = prior or get_model(cfg.model).prior()
    simulator = jax.jit(make_simulator(dataset, cfg))
    per_wave = min(n_pilot, cfg.batch_size)
    dists = []
    for w in range(max(1, n_pilot // per_wave)):
        kw = jax.random.fold_in(key, w)
        theta = prior.sample(jax.random.fold_in(kw, 0), (per_wave,))
        d = np.asarray(simulator(theta, jax.random.fold_in(kw, 1)))
        dists.append(d[np.isfinite(d)])
    d = np.concatenate(dists)
    return float(np.quantile(d, quantile))
