"""Massively parallel ABC rejection sampling (paper §3).

The paper's algorithm, verbatim in structure:

  repeat until `target_accepted` samples accepted:
    theta  ~ prior, vectorized          [B, p]
    D_s    ~ simulator(theta)           [B, n_obs, T]  (or fused distance;
                                        n_obs = 3 for the paper's SIARD)
    dist   = ||D_s - D||                [B]
    accept = dist <= tolerance
    return samples to host under a *fixed-shape* strategy (XLA constraint):
      - "outfeed" (paper's IPU path): split the batch into chunks; a chunk is
        transferred to host only if it contains >= 1 accepted sample.
      - "topk"    (paper's GPU path): return the k lowest-distance samples per
        run plus the global accept count; host filters dist <= eps.

Everything device-side is a single jitted function with static output shapes.
In JAX the "transfer only flagged chunks" semantics fall out naturally:
outputs are device arrays, and the host calls `jax.device_get` ONLY on the
flagged chunk rows, so D2H traffic matches the paper's outfeed behaviour.

Two wave-loop drivers share the per-wave math:

  * host loop   — one jitted wave per call; the host harvests RunOutput after
    every wave (the original paper-faithful structure).
  * device loop — a single jitted `lax.while_loop` that runs simulate ->
    compare -> compact-into-buffer for as many waves as needed, with donated
    fixed-size accept buffers, and returns to the host only once the
    acceptance target is met or the wave budget is exhausted. Same-seed
    accepted-sample sets are identical to the host loop (pinned by
    tests/test_wave_loop.py); the per-wave host sync disappears.

The engine is resumable (ABCState) and backend-pluggable:
  backend="xla"        paper-faithful full-trajectory simulate + distance
  backend="xla_fused"  running-distance scan (no [B, n_obs, T] tensor)
  backend="pallas"     fused VMEM-resident Pallas kernel (repro.kernels)
  backend="npe"        amortized neural posterior estimation (repro.core.npe):
                       no waves at all — a mixture-density estimator trained
                       once on simulator output answers queries with a single
                       forward pass. `run_abc` delegates to `npe.run_npe`;
                       the wave machinery below never runs for this backend.

Every wave backend accepts every registered (summary, distance) pair
(ABCConfig.summary / ABCConfig.distance, see repro.core.summaries): the
"xla" path applies the summary post hoc, "xla_fused" folds it into the
running scan, and "pallas" lowers it into the kernel's per-day accumulator
with the weights/selectors riding scalar const lanes. The default
(identity, euclidean) is bit-identical to pre-summary releases.
"""

from __future__ import annotations

import dataclasses
import time
import zipfile
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distances import DISTANCES
from repro.core.posterior import Posterior
from repro.core.priors import UniformBoxPrior, schedule_prior
from repro.core.summaries import (
    SummarySpec,
    apply_summary,
    get_distance_kind,
    get_summary,
    lower_summary,
    pool_channels,
    pool_factor,
    summary_distance,
)
from repro.epi import engine
from repro.epi.data import CountryData
from repro.epi.models import get_model
from repro.epi.spec import InterventionSchedule
from repro.ioutils import atomic_write

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ABCConfig:
    """Configuration of a parallel ABC inference run."""

    batch_size: int = 100_000  # simulations per run (global)
    tolerance: float = 2e5
    target_accepted: int = 100
    strategy: str = "outfeed"  # "outfeed" | "topk"
    chunk_size: int = 10_000  # outfeed chunk granularity (paper default)
    top_k: int = 5  # samples returned per run under "topk"
    max_runs: int = 100_000
    distance: str = "euclidean"
    backend: str = "xla_fused"
    num_days: int = 49
    #: registry name of the compartmental model to infer (repro.epi.models)
    model: str = "siard"
    #: wave-loop driver: "host" (per-wave host sync, the original structure),
    #: "device" (one jitted lax.while_loop over waves with donated accept
    #: buffers), or "auto" (device for "outfeed" when the buffer fits, else
    #: host). The device loop yields the same same-seed accepted set as the
    #: host outfeed path (pinned by tests/test_wave_loop.py).
    wave_loop: str = "auto"
    #: optional piecewise-constant intervention schedule (repro.epi.spec):
    #: theta widens with per-window scale columns and the simulators apply
    #: the day-effective parameters; None keeps the constant-theta path
    #: bit-identical to previous releases
    schedule: Optional[InterventionSchedule] = None
    #: Pallas dispatch: True forces the interpreter (CPU correctness mode),
    #: False forces a compiled kernel, None auto-selects by backend
    #: (interpret only when jax runs on CPU)
    interpret: Optional[bool] = None
    #: summary statistic compared by `distance`: a registry name
    #: (core.summaries.SUMMARIES), a SummarySpec, or None for the paper's raw
    #: daily trajectories. Every backend lowers every (summary, distance)
    #: pair; the default (None, "euclidean") is bit-identical to pre-summary
    #: releases on all three backends (pinned by tests/test_summaries.py).
    summary: Optional[object] = None
    #: Pallas kernel tile (samples per grid cell). None auto-resolves via
    #: kernels.ops.resolve_tile (legacy 1024-lane default) or, with
    #: `autotune`, to the cached measured winner. An explicit tile must be a
    #: multiple of 128 dividing batch_size (validated loudly). Pure
    #: scheduling: accepted sets are bit-identical across tiles.
    tile: Optional[int] = None
    #: unroll factor of the xla_fused day scan (lax.scan unroll); None means
    #: 1 unless autotuning resolves a cached winner. Also pure scheduling.
    scan_unroll: Optional[int] = None
    #: consult (and on a miss, populate) the measured tuning cache under
    #: experiments/tuning/ at simulator-build time (repro.core.tuning);
    #: explicitly set tile/scan_unroll values always win over the cache
    autotune: bool = False
    #: metapop models only: a row-stochastic [R, R] mobility matrix (nested
    #: tuples) overriding the spec's static one — validated loudly here (rows
    #: must sum to 1); None keeps the model's own matrix. The matrix is a
    #: RUNTIME value on every backend (fconst lanes on pallas), so mobility
    #: sweeps share one compilation.
    mobility: Optional[Tuple[Tuple[float, ...], ...]] = None
    #: backend="npe" only: training hyperparameters (core.npe.NPEConfig);
    #: None uses the NPEConfig defaults. Ignored by the wave backends.
    npe: Optional[object] = None

    def __post_init__(self):
        if self.strategy not in ("outfeed", "topk"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.strategy == "outfeed" and self.batch_size % self.chunk_size:
            raise ValueError("batch_size must be a multiple of chunk_size")
        if self.backend not in ("xla", "xla_fused", "pallas", "npe"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.npe is not None:
            from repro.core.npe import resolve_npe_config

            resolve_npe_config(self.npe)  # raises loudly on wrong type
            if self.backend != "npe":
                raise ValueError(
                    f"cfg.npe is set but backend={self.backend!r}; NPE "
                    "hyperparameters only apply to backend='npe'"
                )
        get_distance_kind(self.distance)  # raises on unknown names
        get_summary(self.summary)
        if self.wave_loop not in ("auto", "host", "device"):
            raise ValueError(f"unknown wave_loop {self.wave_loop!r}")
        if self.tile is not None:
            from repro.kernels.ops import resolve_tile

            # validates multiple-of-128 and batch divisibility, loudly
            resolve_tile(self.batch_size, self.tile)
        if self.scan_unroll is not None and self.scan_unroll < 1:
            raise ValueError(f"scan_unroll must be >= 1, got {self.scan_unroll}")
        if self.mobility is not None:
            from repro.epi.spec import validate_mobility

            # normalizes to nested float tuples (keeps the frozen config
            # hashable) and raises loudly on non-row-stochastic rows; the
            # region count must match the model's (checked at simulator
            # build, where the spec is resolved)
            object.__setattr__(
                self, "mobility",
                validate_mobility(self.mobility, len(self.mobility)),
            )
        if self.wave_loop == "device" and self.strategy == "topk":
            # the device loop compacts EVERY sub-tolerance sample (outfeed
            # harvest semantics); it has no per-wave k cap, so pairing it
            # with topk would silently change the accepted set
            raise ValueError(
                "wave_loop='device' implements outfeed harvest semantics; "
                "use strategy='outfeed' (or wave_loop='host' to keep the "
                "top-k truncation caveat)"
            )

    @property
    def num_chunks(self) -> int:
        return self.batch_size // self.chunk_size

    @property
    def summary_spec(self) -> SummarySpec:
        """The resolved SummarySpec (None -> identity)."""
        return get_summary(self.summary)


class RunOutput(NamedTuple):
    """Fixed-shape per-run device outputs (XLA requirement, paper §3.2)."""

    theta: Array  # outfeed: [n_chunks, chunk, p]; topk: [k, p]
    dist: Array  # outfeed: [n_chunks, chunk];    topk: [k]
    chunk_flags: Array  # outfeed: [n_chunks] bool;      topk: [0]
    accept_count: Array  # [] int32 — global accepted this run


def run_param_names(cfg: ABCConfig, spec) -> Tuple[str, ...]:
    """Posterior column names: the model's params plus any window scales."""
    if cfg.schedule is not None and not cfg.schedule.is_empty:
        return cfg.schedule.param_names(spec)
    return spec.param_names


SimulatorFn = Callable[[Array, Array], Array]  # (theta [B,p], key) -> dist [B]


def resolved_mobility(cfg: ABCConfig, spec) -> Optional[Array]:
    """cfg.mobility as an [R, R] f32 array, checked against the spec's
    region count; None defers to the spec's own (validated) matrix."""
    if cfg.mobility is None:
        return None
    if not spec.is_regional:
        raise ValueError(
            f"cfg.mobility set but model {spec.name!r} has no region axis"
        )
    if len(cfg.mobility) != spec.n_regions:
        raise ValueError(
            f"cfg.mobility is {len(cfg.mobility)}x{len(cfg.mobility)} but "
            f"model {spec.name!r} has {spec.n_regions} regions"
        )
    return jnp.asarray(cfg.mobility, jnp.float32)


class ScenarioData(NamedTuple):
    """Traced per-scenario data threaded through a parametric simulator.

    Everything here is a runtime value, never a compile constant: the wave
    loop compiled for one scenario shape serves every (dataset, intervention
    timing, scale bounds, tolerance) combination of that shape. The
    intervention fields make lockdown-day x scale campaign grids share one
    compilation: breakpoint days are an i32 vector, and the (possibly
    pinned) per-window scale bounds ride in the prior box arrays.
    """

    observed: Array  # [n_obs, T] f32
    population: Array  # f32 scalar
    a0: Array  # f32 scalar
    r0: Array  # f32 scalar
    d0: Array  # f32 scalar
    breakpoints: Array  # [n_windows] i32 (length 0 without a schedule)
    prior_lows: Array  # [p_total] f32 — the (widened) sampling box
    prior_highs: Array  # [p_total] f32


def make_parametric_simulator(spec, cfg: ABCConfig):
    """theta -> distance with the *dataset as traced arguments*.

    Returns `sim(theta [B,p], key, data: ScenarioData) -> dist [B]`. Because
    the observed series, the (population, a0, r0, d0) scalars and any
    intervention breakpoint days are inputs rather than baked-in constants,
    one jitted computation serves every dataset/scenario of the same
    (model, num_days, batch, schedule-shape) — the campaign runner relies on
    this to compile once per shape and sweep countries/seeds/interventions.

    The "pallas" backend bakes its scalars as static kernel constants and
    therefore cannot be parameterized this way (use `make_simulator`).
    """
    from repro.epi.spec import EpiModelConfig

    if cfg.backend == "npe":
        raise ValueError(
            "backend='npe' has no theta -> distance simulator; it is an "
            "amortized estimator — use repro.core.npe.train_npe / run_npe"
        )
    if cfg.backend == "pallas":
        raise ValueError(
            "pallas bakes (population, a0, r0, d0) into the kernel as static "
            "constants; build a per-dataset simulator with make_simulator"
        )
    schedule = cfg.schedule
    summary = cfg.summary_spec
    mob = resolved_mobility(cfg, spec)
    pool = pool_factor(summary, spec.n_regions)
    # identity summaries keep the legacy full-trajectory distance functions
    # (bit-compat for all three registered distances); a real summary lowers
    # as a post-hoc transform on the paper-faithful path
    dist_fn = DISTANCES[cfg.distance] if summary.is_identity else None

    def simulator(theta: Array, key: Array, data: ScenarioData) -> Array:
        observed, population, a0, r0, d0 = data[:5]
        breakpoints = data.breakpoints if isinstance(data, ScenarioData) else None
        mcfg = EpiModelConfig(
            population=population, num_days=cfg.num_days, a0=a0, r0=r0, d0=d0
        )
        if cfg.backend == "xla":
            sim = engine.simulate_observed(
                spec, theta, key, mcfg, schedule, breakpoints, mobility=mob
            )
            if dist_fn is not None:
                return dist_fn(sim, observed)
            lowered = lower_summary(
                summary, cfg.distance, observed, n_regions=spec.n_regions
            )
            return summary_distance(
                cfg.distance, lowered,
                apply_summary(summary, pool_channels(sim, pool, axis=-2)),
            )
        d, _ = engine.simulate_observed_lowmem(
            spec, theta, key, mcfg, observed, schedule, breakpoints,
            summary=summary, distance=cfg.distance,
            unroll=cfg.scan_unroll or 1, mobility=mob,
        )
        return d

    return simulator


def scenario_data(
    dataset: CountryData, cfg: ABCConfig, prior: Optional[UniformBoxPrior] = None
) -> ScenarioData:
    """Pack a dataset into the traced-argument tuple of a parametric simulator."""
    prior = prior or schedule_prior(get_model(cfg.model), cfg.schedule)
    breakpoints = (
        cfg.schedule.breakpoints if cfg.schedule is not None else ()
    )
    return ScenarioData(
        observed=jnp.asarray(dataset.observed[:, : cfg.num_days], jnp.float32),
        population=jnp.float32(dataset.population),
        a0=jnp.float32(dataset.a0),
        r0=jnp.float32(dataset.r0),
        d0=jnp.float32(dataset.d0),
        breakpoints=jnp.asarray(breakpoints, jnp.int32),
        prior_lows=jnp.asarray(prior.lows, jnp.float32),
        prior_highs=jnp.asarray(prior.highs, jnp.float32),
    )


def make_simulator(dataset: CountryData, cfg: ABCConfig) -> SimulatorFn:
    """Build the batched theta -> distance function for the chosen backend.

    The model spec comes from `cfg.model`; the dataset must hold series for
    the same observed channels (checked here, not at run time). With
    `cfg.schedule`, theta must carry the widened scale columns
    (`schedule_prior(spec, cfg.schedule)` samples the right layout).
    """
    if cfg.backend == "npe":
        raise ValueError(
            "backend='npe' has no theta -> distance simulator; it is an "
            "amortized estimator — use repro.core.npe.train_npe / run_npe"
        )
    if cfg.autotune:
        # fill tile / scan_unroll from the measured tuning cache (a miss
        # runs the search once and persists it); returns autotune=False so
        # the tuner's own measurement probes land in this branch's else
        from repro.core import tuning

        cfg = tuning.resolve_tuned(dataset, cfg)
    spec = get_model(cfg.model)
    if not dataset.compatible_with(spec):
        raise ValueError(
            f"dataset {dataset.name!r} holds {dataset.model!r} series; model "
            f"{spec.name!r} observes different channels"
        )
    mcfg = dataset.model_config(cfg.num_days)
    observed = jnp.asarray(dataset.observed[:, : cfg.num_days], jnp.float32)

    if cfg.backend in ("xla", "xla_fused"):
        parametric = make_parametric_simulator(spec, cfg)
        data = scenario_data(dataset, cfg)

        def simulator(theta: Array, key: Array) -> Array:
            return parametric(theta, key, data)

    else:  # pallas
        from repro.kernels import ops as kernel_ops

        mob = resolved_mobility(cfg, spec)

        def simulator(theta: Array, key: Array) -> Array:
            # The kernel uses a counter-based hash RNG; derive a 32-bit seed
            # from the threefry key so runs stay deterministic & resumable.
            seed = jax.random.key_data(key).ravel()[-1].astype(jnp.uint32)
            return kernel_ops.abc_sim_distance(
                theta,
                seed,
                observed,
                population=mcfg.population,
                a0=mcfg.a0,
                r0=mcfg.r0,
                d0=mcfg.d0,
                model=spec,
                schedule=cfg.schedule,
                tile=cfg.tile,
                interpret=cfg.interpret,
                summary=cfg.summary_spec,
                distance=cfg.distance,
                mobility=mob,
            )

    return simulator


def abc_run_batch(
    prior: UniformBoxPrior, simulator: SimulatorFn, cfg: ABCConfig
) -> Callable[[Array], RunOutput]:
    """Build the device-side computation for ONE run (one batch).

    Returned callable takes the per-run PRNG key. Pure & jittable; sharding is
    applied by the caller (see core.distributed / launch.abc_run).
    """
    p = prior.dim

    def run(key: Array) -> RunOutput:
        k_prior, k_sim = jax.random.split(key)
        theta = prior.sample(k_prior, (cfg.batch_size,))  # [B, p]
        dist = simulator(theta, k_sim)  # [B]
        # Failed/NaN simulations never count as accepted.
        dist = jnp.where(jnp.isnan(dist), jnp.inf, dist)
        accept = dist <= cfg.tolerance
        count = jnp.sum(accept.astype(jnp.int32))

        if cfg.strategy == "outfeed":
            nc, cs = cfg.num_chunks, cfg.chunk_size
            theta_c = theta.reshape(nc, cs, p)
            dist_c = dist.reshape(nc, cs)
            flags = jnp.any(accept.reshape(nc, cs), axis=1)
            return RunOutput(theta_c, dist_c, flags, count)

        # top-k: k smallest distances (paper's GPU strategy)
        neg_top, idx = jax.lax.top_k(-dist, cfg.top_k)
        return RunOutput(
            theta[idx], -neg_top, jnp.zeros((0,), bool), count
        )

    return run


# --------------------------------------------------------------------------
# Device-resident wave loop
# --------------------------------------------------------------------------

class WaveLoopOutput(NamedTuple):
    """Outputs of one device-resident wave-loop invocation.

    The accept buffers are laid out as `shards` contiguous segments of
    `capacity` rows each; segment i holds `fill_counts[i]` valid rows. This
    layout is a cross-runner CONTRACT: the sharded runners
    (core.distributed) emit one segment per device, the lockstep reference
    (core.scaling.make_reference_wave_runner) emits the same segments on a
    single device, and tests/test_scaling.py pins the two bit-identical —
    so harvest/checkpoint code never cares which topology produced a buffer.
    """

    theta_buf: Array  # [shards * capacity, p]
    dist_buf: Array  # [shards * capacity]
    n_accepted: Array  # [] int32 — TOTAL accepted (may exceed buffer fill)
    waves_done: Array  # [] int32 — waves executed by THIS invocation
    fill_counts: Array  # [shards] int32 — valid rows per buffer segment


#: auto mode only picks the device loop when the accept buffer stays small
#: enough to live comfortably on one device (rows, not bytes)
_AUTO_DEVICE_MAX_ROWS = 4_000_000


def wave_capacity(cfg: ABCConfig, batch_size: Optional[int] = None) -> int:
    """Accept-buffer rows per shard: never overflows within one wave.

    The loop only enters a wave while accepted < target, and a wave adds at
    most one batch, so `target + batch - 1` bounds the fill — the final
    wave's overshoot is retained exactly like the host outfeed path.
    """
    return cfg.target_accepted + (batch_size or cfg.batch_size)


def compact_accepted(th_buf, d_buf, fill, theta, dist, accept, capacity: int):
    """Scatter accepted rows into the buffer's next free slots.

    Fixed shapes throughout: rejected rows get an out-of-bounds slot and are
    dropped by the scatter. Returns (th_buf, d_buf, new_fill). Shared by the
    ABC wave loop and the SMC device round — the capacity-edge semantics
    exist exactly once.
    """
    slot = fill + jnp.cumsum(accept.astype(jnp.int32)) - 1
    slot = jnp.where(accept, slot, capacity)
    th_buf = th_buf.at[slot].set(theta, mode="drop")
    d_buf = d_buf.at[slot].set(dist, mode="drop")
    return th_buf, d_buf, fill + jnp.sum(accept, dtype=jnp.int32)


def wave_loop_body(
    prior: UniformBoxPrior,
    sim_call,  # (theta, key, data) -> dist
    batch_size: int,
    capacity: int,
    *,
    fold_axis=None,  # device index to fold into the run key (shard_map path)
    count_all=None,  # per-wave local count -> global count (psum under shard_map)
):
    """One wave: sample -> simulate -> compare -> compact into the buffer.

    Returns a `body(carry)` for `lax.while_loop` with carry
    `(wave, n_global, fill, theta_buf, dist_buf)`; the extra run inputs
    (key, run_idx0, tolerance, data) are closed over by the caller via
    `functools.partial`-style nesting in `build_wave_loop`.
    """

    def body(carry, key, run_idx0, tolerance, data):
        w, n_global, fill, th_buf, d_buf = carry
        k = jax.random.fold_in(key, run_idx0 + w)
        if fold_axis is not None:
            k = jax.random.fold_in(k, fold_axis())
        k_prior, k_sim = jax.random.split(k)
        if isinstance(data, ScenarioData):
            # sample inside the scenario's traced box (bit-identical math to
            # the baked path) so one compiled loop serves every scenario of
            # this shape, including swept intervention-scale bounds
            theta = prior.sample(k_prior, (batch_size,),
                                 data.prior_lows, data.prior_highs)
        else:
            theta = prior.sample(k_prior, (batch_size,))
        dist = sim_call(theta, k_sim, data)
        dist = jnp.where(jnp.isnan(dist), jnp.inf, dist)
        accept = dist <= tolerance
        th_buf, d_buf, new_fill = compact_accepted(
            th_buf, d_buf, fill, theta, dist, accept, capacity
        )
        c_local = new_fill - fill
        c_global = count_all(c_local) if count_all is not None else c_local
        return (w + 1, n_global + c_global, new_fill, th_buf, d_buf)

    return body


def build_wave_loop(
    prior: UniformBoxPrior,
    sim_call,  # (theta, key, data) -> dist
    cfg: ABCConfig,
    *,
    batch_size: Optional[int] = None,
    capacity: Optional[int] = None,
    fold_axis=None,
    count_all=None,
    shard_hint=None,  # optional fn applied to per-wave batch arrays (pjit path)
):
    """Build the un-jitted device-resident wave loop.

    loop(key, run_idx0, theta_buf, dist_buf, n0, fill0, max_waves,
         tolerance, data) -> WaveLoopOutput

    A single `lax.while_loop` runs waves until the GLOBAL accepted count
    reaches `cfg.target_accepted` or `max_waves` waves have run. Sample
    streams are identical to the host loop: wave w uses
    `fold_in(key, run_idx0 + w)` (plus a device fold under shard_map),
    exactly as `run_abc`/`make_shardmap_runner` key their runs.
    """
    B = batch_size or cfg.batch_size
    cap = capacity or wave_capacity(cfg, B)
    target = cfg.target_accepted
    inner = sim_call
    if shard_hint is not None:
        def inner(theta, key, data):  # noqa: F811 — sharded wrapper
            return shard_hint(sim_call(shard_hint(theta), key, data))
    body_fn = wave_loop_body(
        prior, inner, B, cap, fold_axis=fold_axis, count_all=count_all
    )

    def loop(key, run_idx0, theta_buf, dist_buf, n0, fill0, max_waves,
             tolerance, data):
        run_idx0 = jnp.asarray(run_idx0, jnp.int32)
        max_waves = jnp.asarray(max_waves, jnp.int32)
        n0 = jnp.asarray(n0, jnp.int32)
        fill0 = jnp.asarray(fill0, jnp.int32)

        def cond(carry):
            w, n_global, *_ = carry
            return jnp.logical_and(n_global < target, w < max_waves)

        def body(carry):
            return body_fn(carry, key, run_idx0, tolerance, data)

        w, n, fill, th_buf, d_buf = jax.lax.while_loop(
            cond, body, (jnp.int32(0), n0, fill0, theta_buf, dist_buf)
        )
        return WaveLoopOutput(
            th_buf, d_buf, n, w, jnp.minimum(fill, cap)[None]
        )

    return loop


@dataclasses.dataclass
class WaveRunner:
    """A compiled device-resident wave loop plus its buffer layout.

    `fn(key, run_idx0, theta_buf, dist_buf, n0, fill0, max_waves, tolerance,
    data)` is jitted with the buffers donated; `data` is the traced
    per-scenario tuple (or None when the simulator baked the dataset in).
    `shards` > 1 means the buffers are laid out as per-device segments
    (distributed runners).
    """

    fn: Callable[..., WaveLoopOutput]
    capacity: int  # rows per shard segment
    shards: int
    n_params: int
    cfg: ABCConfig
    data: Optional[ScenarioData] = None

    def init(self, state: "ABCState"):
        """Device buffers seeded from (possibly resumed) host state.

        Returns the carry (theta_buf, dist_buf, n0, fill0). Existing accepted
        samples are split evenly across shard segments (exact order is
        preserved for shards == 1, the pinned single-device case).
        """
        theta, dist = state.to_arrays()
        n = theta.shape[0]
        th_buf = np.zeros((self.shards * self.capacity, self.n_params), np.float32)
        d_buf = np.full((self.shards * self.capacity,), np.inf, np.float32)
        fills = np.zeros((self.shards,), np.int32)
        splits = np.array_split(np.arange(n), self.shards)
        for s, idx in enumerate(splits):
            if idx.size > self.capacity:
                raise ValueError(
                    f"resumed state ({n} accepted) overflows the wave buffer "
                    f"({self.shards} x {self.capacity}); raise target/batch"
                )
            lo = s * self.capacity
            th_buf[lo : lo + idx.size] = theta[idx]
            d_buf[lo : lo + idx.size] = dist[idx]
            fills[s] = idx.size
        fill0 = fills if self.shards > 1 else np.int32(fills[0])
        return (jnp.asarray(th_buf), jnp.asarray(d_buf), np.int32(n), fill0)

    def __call__(self, key, run_idx0: int, carry, max_waves: int) -> WaveLoopOutput:
        th_buf, d_buf, n0, fill0 = carry
        return self.fn(
            key, np.int32(run_idx0), th_buf, d_buf, n0, fill0,
            np.int32(max_waves), np.float32(self.cfg.tolerance), self.data,
        )

    def carry_of(self, out: WaveLoopOutput):
        fill = out.fill_counts if self.shards > 1 else out.fill_counts[0]
        return (out.theta_buf, out.dist_buf, out.n_accepted, fill)

    def harvest(self, out: WaveLoopOutput, state: "ABCState") -> None:
        """Replace the state's accepted set with the buffers' contents.

        Unlike the host loop's incremental appends, the buffers are
        cumulative — they carry every accepted sample so far (including any
        resumed prefix), so this *replaces* rather than extends.
        """
        th = np.asarray(out.theta_buf)
        d = np.asarray(out.dist_buf)
        fills = np.asarray(out.fill_counts)
        state.accepted_theta = []
        state.accepted_dist = []
        for s, c in enumerate(fills):
            c = int(c)
            if c:
                lo = s * self.capacity
                state.accepted_theta.append(th[lo : lo + c])
                state.accepted_dist.append(d[lo : lo + c])


def make_wave_runner(
    prior: UniformBoxPrior, simulator: SimulatorFn, cfg: ABCConfig
) -> WaveRunner:
    """Single-device wave runner over a dataset-baked simulator."""
    loop = build_wave_loop(prior, lambda th, k, _data: simulator(th, k), cfg)
    fn = jax.jit(loop, donate_argnums=(2, 3))
    return WaveRunner(
        fn=fn, capacity=wave_capacity(cfg), shards=1, n_params=prior.dim, cfg=cfg
    )


def _auto_device_loop(cfg: ABCConfig) -> bool:
    """auto: device loop for outfeed runs whose accept buffer stays small."""
    if cfg.wave_loop == "device":
        return True
    if cfg.wave_loop == "host":
        return False
    return (
        cfg.strategy == "outfeed"
        and wave_capacity(cfg) <= _AUTO_DEVICE_MAX_ROWS
    )


@dataclasses.dataclass
class ABCState:
    """Resumable sampler state — the fault-tolerance unit for inference.

    Work is addressed by (base seed, run index): any worker can recompute any
    run, so restart/elastic-rescale only needs this state (DESIGN.md §3).
    """

    run_idx: int = 0
    simulations: int = 0
    accepted_theta: list = dataclasses.field(default_factory=list)
    accepted_dist: list = dataclasses.field(default_factory=list)
    #: parameter dimension, set from the model/prior by run_abc (or on load);
    #: required only to give the empty-case arrays a concrete shape
    n_params: Optional[int] = None

    @property
    def n_accepted(self) -> int:
        return sum(int(t.shape[0]) for t in self.accepted_theta)

    def to_arrays(self):
        if not self.accepted_theta:
            # shape derives from the model/prior — NOT a hardcoded paper dim
            return (
                np.zeros((0, self.n_params or 0), np.float32),
                np.zeros((0,), np.float32),
            )
        return (
            np.concatenate(self.accepted_theta, axis=0),
            np.concatenate(self.accepted_dist, axis=0),
        )

    def save(self, path: str) -> None:
        """Atomic save via the shared `repro.ioutils.atomic_write` helper:
        an interrupted save (crash, preemption mid-campaign) can never leave
        a truncated checkpoint at `path` — the previous complete file, if
        any, survives."""
        th, d = self.to_arrays()
        with atomic_write(path, "wb") as f:
            np.savez(
                f, run_idx=self.run_idx, simulations=self.simulations,
                theta=th, dist=d,
            )

    _REQUIRED_KEYS = ("run_idx", "simulations", "theta", "dist")

    @staticmethod
    def load(path: str) -> "ABCState":
        """Load a checkpoint, rejecting corrupt/partial files loudly.

        A truncated or otherwise unreadable file raises ValueError with a
        clear remediation message instead of surfacing a bare zipfile/KeyError
        deep inside a resumed campaign. A missing file is NOT corruption —
        FileNotFoundError propagates untouched."""
        try:
            z = np.load(path, allow_pickle=False)
            missing = [k for k in ABCState._REQUIRED_KEYS if k not in z.files]
            if missing:
                raise ValueError(f"missing arrays {missing}")
            theta = np.asarray(z["theta"], np.float32)
            dist = np.asarray(z["dist"], np.float32)
            if theta.ndim != 2 or dist.shape != (theta.shape[0],):
                raise ValueError(
                    f"inconsistent shapes theta={theta.shape} dist={dist.shape}"
                )
            st = ABCState(
                run_idx=int(z["run_idx"]),
                simulations=int(z["simulations"]),
                n_params=int(theta.shape[1]),
            )
        except FileNotFoundError:
            raise
        except (zipfile.BadZipFile, OSError, KeyError, ValueError) as e:
            raise ValueError(
                f"corrupt or incomplete ABC checkpoint {path!r} ({e}); it was "
                "probably truncated by an interrupted save — delete it to "
                "restart this scenario from scratch"
            ) from e
        if theta.shape[0]:
            st.accepted_theta = [theta]
            st.accepted_dist = [dist]
        return st


def _harvest(out: RunOutput, cfg: ABCConfig, state: ABCState) -> int:
    """Host-side postprocessing of one run's outputs (paper §3.2 / Table 4).

    Pulls to host ONLY what the strategy marked for transfer, filters
    dist <= eps, and appends accepted samples to the state. Returns the
    number of accepted samples harvested.
    """
    n_new = 0
    if cfg.strategy == "outfeed":
        flags = np.asarray(out.chunk_flags)  # [n_chunks] — tiny transfer
        for ci in np.nonzero(flags)[0]:
            # per-chunk D2H transfer, mirroring the IPU outfeed
            d = np.asarray(out.dist[ci])
            th = np.asarray(out.theta[ci])
            m = d <= cfg.tolerance
            if m.any():
                state.accepted_theta.append(th[m])
                state.accepted_dist.append(d[m])
                n_new += int(m.sum())
    else:  # topk
        d = np.asarray(out.dist)
        th = np.asarray(out.theta)
        m = d <= cfg.tolerance
        if m.any():
            state.accepted_theta.append(th[m])
            state.accepted_dist.append(d[m])
            n_new += int(m.sum())
        # NOTE: if accept_count > k the paper accepts losing samples (their
        # Top-k caveat); we surface the same behaviour.
    return n_new


def run_abc(
    dataset: CountryData,
    cfg: ABCConfig,
    key: Array | int = 0,
    prior: Optional[UniformBoxPrior] = None,
    state: Optional[ABCState] = None,
    run_fn: Optional[Callable[[Array], RunOutput]] = None,
    wave_runner: Optional[WaveRunner] = None,
    checkpoint_every: int = 0,
    checkpoint_path: Optional[str] = None,
    verbose: bool = False,
) -> Posterior:
    """Host driver: iterate runs until `target_accepted` posterior samples.

    Two drivers share the stream semantics (wave i == fold_in(key, i)):

      * host loop  — `run_fn` (a jitted `abc_run_batch`, possibly pre-sharded
        for multi-device) is invoked once per wave and harvested on the host.
      * device loop — `wave_runner` keeps the whole accept/reject loop in one
        jitted lax.while_loop with donated accept buffers; the host is only
        re-entered when the target is met, the budget is exhausted, or a
        checkpoint is due. Selected by `cfg.wave_loop` ("auto" picks it for
        outfeed-strategy runs) or by passing `wave_runner` explicitly
        (see core.distributed.make_wave_runner for the sharded styles).
    """
    if cfg.backend == "npe":
        # the amortized backend has no wave loop: train the estimator, then
        # one forward pass. The wave-driver knobs make no sense here.
        if run_fn is not None or wave_runner is not None or state is not None:
            raise ValueError(
                "backend='npe' does not run waves; run_fn / wave_runner / "
                "resumable state do not apply"
            )
        from repro.core import npe

        return npe.run_npe(dataset, cfg, key, prior=prior, verbose=verbose)
    spec = get_model(cfg.model)
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    prior = prior or schedule_prior(spec, cfg.schedule)
    state = state or ABCState()
    if state.n_params is None:
        state.n_params = prior.dim
    elif state.n_params != prior.dim:
        raise ValueError(
            f"resumed state holds {state.n_params}-parameter samples but model "
            f"{spec.name!r} has {prior.dim} parameters — wrong checkpoint?"
        )
    if run_fn is not None and wave_runner is None and cfg.wave_loop == "device":
        raise ValueError(
            "cfg.wave_loop='device' conflicts with an explicit host-loop "
            "run_fn; pass a wave_runner (see distributed.make_wave_runner) "
            "or drop one of the two"
        )
    if wave_runner is None and run_fn is None and _auto_device_loop(cfg):
        wave_runner = make_wave_runner(prior, make_simulator(dataset, cfg), cfg)
    if wave_runner is not None:
        return _run_abc_device(
            cfg, key, state, wave_runner, spec,
            checkpoint_every=checkpoint_every, checkpoint_path=checkpoint_path,
            verbose=verbose,
        )
    if run_fn is None:
        simulator = make_simulator(dataset, cfg)
        run_fn = jax.jit(abc_run_batch(prior, simulator, cfg))

    t0 = time.time()
    postproc_s = 0.0
    while state.n_accepted < cfg.target_accepted and state.run_idx < cfg.max_runs:
        run_key = jax.random.fold_in(key, state.run_idx)
        out = run_fn(run_key)
        out = jax.tree.map(jax.block_until_ready, out)
        tp = time.time()
        _harvest(out, cfg, state)
        postproc_s += time.time() - tp
        state.run_idx += 1
        state.simulations += cfg.batch_size
        if verbose and state.run_idx % 50 == 0:
            print(
                f"[abc] run {state.run_idx}: accepted {state.n_accepted}/"
                f"{cfg.target_accepted}"
            )
        if (
            checkpoint_every
            and checkpoint_path
            and state.run_idx % checkpoint_every == 0
        ):
            state.save(checkpoint_path)

    theta, dist = state.to_arrays()
    # every harvested sample is returned (a run may overshoot target_accepted;
    # the paper keeps the overshoot too — callers can slice with Posterior.top)
    post = Posterior(
        theta=theta,
        distances=dist,
        tolerance=cfg.tolerance,
        param_names=run_param_names(cfg, spec),
        runs=state.run_idx,
        simulations=state.simulations,
        wall_time_s=time.time() - t0,
    )
    post.postproc_time_s = postproc_s  # type: ignore[attr-defined]
    return post


def _run_abc_device(
    cfg: ABCConfig,
    key: Array,
    state: ABCState,
    wave_runner: WaveRunner,
    spec,
    checkpoint_every: int = 0,
    checkpoint_path: Optional[str] = None,
    verbose: bool = False,
) -> Posterior:
    """Device-loop driver: segments of waves between host syncs.

    Without checkpointing there is exactly ONE device invocation — the
    while_loop runs until the target is met or `max_runs` is exhausted, and
    the buffers come back once. With checkpointing, each segment is bounded
    by `checkpoint_every` waves so a crash loses at most one segment.
    """
    t0 = time.time()
    postproc_s = 0.0
    carry = wave_runner.init(state)
    while state.n_accepted < cfg.target_accepted and state.run_idx < cfg.max_runs:
        seg = cfg.max_runs - state.run_idx
        if checkpoint_every and checkpoint_path:
            seg = min(seg, checkpoint_every)
        out = wave_runner(key, state.run_idx, carry, seg)
        waves = int(out.waves_done)  # the segment's single host sync
        tp = time.time()
        wave_runner.harvest(out, state)
        postproc_s += time.time() - tp
        carry = wave_runner.carry_of(out)
        state.run_idx += waves
        state.simulations += waves * cfg.batch_size
        if verbose:
            print(
                f"[abc] run {state.run_idx}: accepted {state.n_accepted}/"
                f"{cfg.target_accepted} (device wave loop)"
            )
        if checkpoint_every and checkpoint_path:
            state.save(checkpoint_path)
        if waves == 0:  # budget/target already consumed; avoid a spin
            break

    theta, dist = state.to_arrays()
    post = Posterior(
        theta=theta,
        distances=dist,
        tolerance=cfg.tolerance,
        param_names=run_param_names(cfg, spec),
        runs=state.run_idx,
        simulations=state.simulations,
        wall_time_s=time.time() - t0,
    )
    post.postproc_time_s = postproc_s  # type: ignore[attr-defined]
    return post


def calibrate_tolerance(
    dataset: CountryData,
    cfg: ABCConfig,
    key: Array | int = 0,
    quantile: float = 1e-3,
    n_pilot: int = 65_536,
    prior: Optional[UniformBoxPrior] = None,
) -> float:
    """Auto-pick a tolerance as a quantile of the pilot distance distribution.

    The paper tunes epsilon per country by hand ("the tolerance had to be
    adjusted on an individual basis", §5); this calibrates it from a pilot
    wave of prior-predictive simulations so the expected acceptance rate —
    and therefore total runtime — is controlled a priori:
        expected runs ~= target_accepted / (quantile * batch_size).
    """
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    prior = prior or schedule_prior(get_model(cfg.model), cfg.schedule)
    simulator = jax.jit(make_simulator(dataset, cfg))
    per_wave = min(n_pilot, cfg.batch_size)
    dists = []
    for w in range(max(1, n_pilot // per_wave)):
        kw = jax.random.fold_in(key, w)
        theta = prior.sample(jax.random.fold_in(kw, 0), (per_wave,))
        d = np.asarray(simulator(theta, jax.random.fold_in(kw, 1)))
        dists.append(d[np.isfinite(d)])
    d = np.concatenate(dists)
    return float(np.quantile(d, quantile))
