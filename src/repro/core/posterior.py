"""Accepted-sample containers and posterior summaries (paper §5, Table 8)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Posterior:
    """A set of accepted ABC posterior samples."""

    theta: np.ndarray  # [N, p]
    distances: np.ndarray  # [N]
    tolerance: float
    param_names: Sequence[str]
    #: bookkeeping from the run
    runs: int = 0
    simulations: int = 0
    wall_time_s: float = 0.0

    def __post_init__(self):
        self.theta = np.asarray(self.theta, np.float32).reshape(
            -1, len(self.param_names)
        )
        self.distances = np.asarray(self.distances, np.float32).reshape(-1)
        assert self.theta.shape[0] == self.distances.shape[0]

    def __len__(self) -> int:
        return int(self.theta.shape[0])

    @property
    def acceptance_rate(self) -> float:
        return len(self) / max(self.simulations, 1)

    def mean(self) -> Dict[str, float]:
        return {
            name: float(m)
            for name, m in zip(self.param_names, self.theta.mean(axis=0))
        }

    def std(self) -> Dict[str, float]:
        return {
            name: float(s)
            for name, s in zip(self.param_names, self.theta.std(axis=0))
        }

    def quantiles(self, qs=(0.05, 0.5, 0.95)) -> Dict[str, Dict[float, float]]:
        out: Dict[str, Dict[float, float]] = {}
        for j, name in enumerate(self.param_names):
            out[name] = {
                float(q): float(np.quantile(self.theta[:, j], q)) for q in qs
            }
        return out

    def histogram(self, param: str, bins: int = 20):
        j = list(self.param_names).index(param)
        return np.histogram(self.theta[:, j], bins=bins)

    def top(self, k: int) -> "Posterior":
        """k lowest-distance samples."""
        idx = np.argsort(self.distances)[:k]
        return dataclasses.replace(
            self, theta=self.theta[idx], distances=self.distances[idx]
        )

    def summary_table(self) -> str:
        mu, sd = self.mean(), self.std()
        header = f"{'param':>8} | {'mean':>10} | {'std':>10}"
        rows = [header, "-" * len(header)]
        for name in self.param_names:
            rows.append(f"{name:>8} | {mu[name]:>10.4f} | {sd[name]:>10.4f}")
        rows.append(
            f"N={len(self)} eps={self.tolerance:g} runs={self.runs} "
            f"sims={self.simulations} accept_rate={self.acceptance_rate:.3e} "
            f"wall={self.wall_time_s:.2f}s"
        )
        return "\n".join(rows)

    def save(self, path: str) -> None:
        np.savez(
            path,
            theta=self.theta,
            distances=self.distances,
            tolerance=self.tolerance,
            param_names=np.asarray(self.param_names),
            runs=self.runs,
            simulations=self.simulations,
            wall_time_s=self.wall_time_s,
        )

    @staticmethod
    def load(path: str) -> "Posterior":
        z = np.load(path, allow_pickle=False)
        return Posterior(
            theta=z["theta"],
            distances=z["distances"],
            tolerance=float(z["tolerance"]),
            param_names=[str(s) for s in z["param_names"]],
            runs=int(z["runs"]),
            simulations=int(z["simulations"]),
            wall_time_s=float(z["wall_time_s"]),
        )
