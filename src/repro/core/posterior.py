"""Accepted-sample containers and posterior summaries (paper §5, Table 8)."""

from __future__ import annotations

import dataclasses
import zipfile
from typing import Dict, Optional, Sequence

import numpy as np

from repro.ioutils import atomic_write


@dataclasses.dataclass
class Posterior:
    """A set of accepted ABC posterior samples."""

    theta: np.ndarray  # [N, p]
    distances: np.ndarray  # [N]
    tolerance: float
    param_names: Sequence[str]
    #: bookkeeping from the run
    runs: int = 0
    simulations: int = 0
    wall_time_s: float = 0.0
    #: optional importance weights [N] (SMC populations); persisted so a
    #: stored posterior can warm-start a re-fit with its weighted population
    weights: Optional[np.ndarray] = None

    def __post_init__(self):
        self.theta = np.asarray(self.theta, np.float32).reshape(
            -1, len(self.param_names)
        )
        self.distances = np.asarray(self.distances, np.float32).reshape(-1)
        assert self.theta.shape[0] == self.distances.shape[0]
        if self.weights is not None:
            self.weights = np.asarray(self.weights, np.float32).reshape(-1)
            assert self.weights.shape[0] == self.theta.shape[0]

    def __len__(self) -> int:
        return int(self.theta.shape[0])

    @property
    def acceptance_rate(self) -> float:
        return len(self) / max(self.simulations, 1)

    def mean(self) -> Dict[str, float]:
        return {
            name: float(m)
            for name, m in zip(self.param_names, self.theta.mean(axis=0))
        }

    def std(self) -> Dict[str, float]:
        return {
            name: float(s)
            for name, s in zip(self.param_names, self.theta.std(axis=0))
        }

    def quantiles(self, qs=(0.05, 0.5, 0.95)) -> Dict[str, Dict[float, float]]:
        out: Dict[str, Dict[float, float]] = {}
        for j, name in enumerate(self.param_names):
            out[name] = {
                float(q): float(np.quantile(self.theta[:, j], q)) for q in qs
            }
        return out

    def histogram(self, param: str, bins: int = 20):
        j = list(self.param_names).index(param)
        return np.histogram(self.theta[:, j], bins=bins)

    def top(self, k: int) -> "Posterior":
        """k lowest-distance samples."""
        idx = np.argsort(self.distances)[:k]
        return dataclasses.replace(
            self, theta=self.theta[idx], distances=self.distances[idx],
            weights=None if self.weights is None else self.weights[idx],
        )

    def summary_table(self) -> str:
        mu, sd = self.mean(), self.std()
        header = f"{'param':>8} | {'mean':>10} | {'std':>10}"
        rows = [header, "-" * len(header)]
        for name in self.param_names:
            rows.append(f"{name:>8} | {mu[name]:>10.4f} | {sd[name]:>10.4f}")
        rows.append(
            f"N={len(self)} eps={self.tolerance:g} runs={self.runs} "
            f"sims={self.simulations} accept_rate={self.acceptance_rate:.3e} "
            f"wall={self.wall_time_s:.2f}s"
        )
        return "\n".join(rows)

    def save(self, path: str) -> None:
        """Atomic save through the shared `repro.ioutils.atomic_write`
        helper: a crash mid-write can never leave a truncated file at `path`
        — essential once posteriors back a serving cache — and writing
        through a file object keeps the EXACT path given (a bare np.savez
        silently appends ".npz" when the suffix is missing, so load(path)
        would miss save(path))."""
        arrays = dict(
            theta=self.theta,
            distances=self.distances,
            tolerance=self.tolerance,
            param_names=np.asarray(self.param_names),
            runs=self.runs,
            simulations=self.simulations,
            wall_time_s=self.wall_time_s,
        )
        if self.weights is not None:
            arrays["weights"] = self.weights
        with atomic_write(path, "wb") as f:
            np.savez(f, **arrays)

    _REQUIRED_KEYS = (
        "theta", "distances", "tolerance", "param_names", "runs",
        "simulations", "wall_time_s",
    )

    @staticmethod
    def load(path: str) -> "Posterior":
        """Load a saved posterior from the exact path given to save().

        Corrupt or truncated files raise ValueError with a remediation hint
        instead of a bare zipfile/KeyError deep inside a serving loop; a
        missing file is NOT corruption — FileNotFoundError propagates."""
        try:
            z = np.load(path, allow_pickle=False)
            missing = [k for k in Posterior._REQUIRED_KEYS if k not in z.files]
            if missing:
                raise ValueError(f"missing arrays {missing}")
            theta = np.asarray(z["theta"], np.float32)
            distances = np.asarray(z["distances"], np.float32)
            names = [str(s) for s in z["param_names"]]
            if theta.ndim != 2 or distances.shape != (theta.shape[0],):
                raise ValueError(
                    f"inconsistent shapes theta={theta.shape} "
                    f"distances={distances.shape}"
                )
            if len(names) != theta.shape[1]:
                raise ValueError(
                    f"{len(names)} param names for theta width {theta.shape[1]}"
                )
            return Posterior(
                theta=theta,
                distances=distances,
                tolerance=float(z["tolerance"]),
                param_names=names,
                runs=int(z["runs"]),
                simulations=int(z["simulations"]),
                wall_time_s=float(z["wall_time_s"]),
                weights=np.asarray(z["weights"], np.float32)
                if "weights" in z.files
                else None,
            )
        except FileNotFoundError:
            raise
        except (zipfile.BadZipFile, OSError, KeyError, ValueError) as e:
            raise ValueError(
                f"corrupt or incomplete posterior file {path!r} ({e}); it was "
                "probably truncated by an interrupted save — delete it and "
                "re-fit (or re-run the abc_serve daemon)"
            ) from e
