"""The paper's primary contribution: massively parallel ABC rejection inference.

Layers:
  priors       — vectorized priors with log-pdf (uniform box prior of the paper)
  distances    — batched distance functions (Euclidean of the paper + extras)
  abc          — batched rejection-ABC engine with the paper's two fixed-shape
                 sample-return strategies (chunked outfeed / top-k), resumable
  smc          — SMC-ABC (decreasing-tolerance sequential Monte Carlo)
  posterior    — accepted-sample containers + summaries
  distributed  — shard_map multi-device / multi-pod driver
"""

from repro.core.priors import UniformBoxPrior
from repro.core.distances import euclidean_distance
from repro.core.abc import ABCConfig, ABCState, run_abc, abc_run_batch
from repro.core.posterior import Posterior
