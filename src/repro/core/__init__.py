"""The paper's primary contribution: massively parallel ABC rejection inference.

Layers:
  priors       — vectorized priors with log-pdf (uniform box prior of the paper)
  distances    — batched distance functions (Euclidean of the paper + extras)
  abc          — batched rejection-ABC engine with the paper's two fixed-shape
                 sample-return strategies (chunked outfeed / top-k), resumable;
                 host and device-resident (single lax.while_loop) wave drivers
  smc          — SMC-ABC (decreasing-tolerance sequential Monte Carlo)
  posterior    — accepted-sample containers + summaries
  distributed  — shard_map multi-device / multi-pod driver (per-wave and
                 device-resident wave-loop styles)
  campaign     — multi-scenario grid runner (dataset x model x backend x seed)
                 with compile reuse, checkpoint/resume and aggregated report
"""

from repro.core.priors import UniformBoxPrior
from repro.core.distances import euclidean_distance
from repro.core.abc import (
    ABCConfig,
    ABCState,
    WaveRunner,
    abc_run_batch,
    build_wave_loop,
    make_wave_runner,
    run_abc,
    wave_capacity,
)
from repro.core.campaign import CampaignConfig, CampaignReport, Scenario, run_campaign
from repro.core.posterior import Posterior
