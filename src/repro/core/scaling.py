"""Multi-device scaling-study executor (the paper's 16-IPU experiment, §4.5).

The paper's headline systems claim is that the ABC framework "scales across
16 IPUs, with scaling overhead not exceeding 8%". This module reproduces
that experiment on ANY JAX device set — real accelerators or simulated host
devices (`XLA_FLAGS=--xla_force_host_platform_device_count=8`):

  * `device_mesh(n)` carves a 1-axis data mesh out of the first `n` devices,
    so one process measures every device count of the curve (disjoint
    subsets of the same device pool, exactly how the paper sweeps 1..16
    IPUs on one machine);
  * `run_scaling_cell` times the device-resident shard_map wave loop
    (`distributed.make_wave_runner`) over a fixed wave budget with an
    unreachable acceptance target, so every device count burns the same
    per-device work and the measured delta is pure scaling overhead
    (collective stop psum + host gather of the per-shard accept buffers);
  * `run_scaling_study` sweeps (model, backend) x device-count under WEAK
    scaling (global batch = n * batch_per_device, the paper's "2x100k means
    100k per IPU" convention) and derives the two headline metrics per cell:

        parallel_efficiency  = sims_per_s(n) / (n * sims_per_s(n_ref))
        scaling_overhead_pct = (1 - parallel_efficiency) * 100

    — the reproduction's analogue of the paper's Figure on 16-IPU scaling
    (the paper reports <= 8% overhead at n=16).

Correctness contract: `make_reference_wave_runner` executes the N-shard
wave-loop program LOCKSTEP ON ONE DEVICE — same per-(wave, shard) fold_in
keys, same per-shard accept buffers, same global stop condition — so the
sharded runner's accepted sets can be pinned bit-identical per shard against
a single-device run (tests/test_scaling.py). Scaling never changes the
statistics, only the wall clock.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.abc import (
    ABCConfig,
    WaveLoopOutput,
    WaveRunner,
    calibrate_tolerance,
    run_abc,
    wave_capacity,
    wave_loop_body,
)
from repro.core.priors import UniformBoxPrior
from repro.epi.data import get_dataset


def device_mesh(n: int, devices: Optional[Sequence] = None) -> Mesh:
    """A 1-axis data mesh over the FIRST `n` devices of the pool.

    Prefix subsets keep every device count of a study inside one process:
    the n=1 cell and the n=8 cell share device 0, exactly like the paper's
    1..16-IPU sweep on one machine.
    """
    devices = list(devices if devices is not None else jax.devices())
    if n > len(devices):
        raise ValueError(
            f"requested {n} devices but only {len(devices)} are visible; on "
            "CPU, simulate more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    return Mesh(np.asarray(devices[:n]), ("data",))


def make_reference_wave_runner(
    prior: UniformBoxPrior,
    simulator,
    cfg: ABCConfig,
    n_shards: int,
) -> WaveRunner:
    """The N-shard wave-loop program executed lockstep on ONE device.

    Each wave advances every shard's segment with that shard's own stream —
    `fold_in(fold_in(key, run_idx0 + w), shard)`, the exact keying of
    `distributed.make_shardmap_wave_runner` — and the global stop condition
    sums the per-shard accepts exactly like the sharded runner's psum. The
    per-shard accept buffers are therefore BIT-IDENTICAL to an N-device run
    with the same seed (pinned in tests/test_scaling.py): the reference that
    makes multi-device speedups trustworthy.
    """
    if cfg.batch_size % n_shards:
        raise ValueError(
            f"batch_size {cfg.batch_size} not divisible by {n_shards} shards"
        )
    local_b = cfg.batch_size // n_shards
    cap = wave_capacity(cfg, local_b)
    target = cfg.target_accepted
    sim_call = lambda th, k, _data: simulator(th, k)  # noqa: E731
    bodies = [
        wave_loop_body(
            prior, sim_call, local_b, cap,
            fold_axis=(lambda d=d: jnp.int32(d)),
        )
        for d in range(n_shards)
    ]

    def loop(key, run_idx0, theta_buf, dist_buf, n0, fills, max_waves,
             tolerance, data):
        run_idx0 = jnp.asarray(run_idx0, jnp.int32)
        max_waves = jnp.asarray(max_waves, jnp.int32)
        n0 = jnp.asarray(n0, jnp.int32)
        # rank-1 even for one shard, where WaveRunner.init hands back a scalar
        fills = jnp.atleast_1d(jnp.asarray(fills, jnp.int32))

        def cond(carry):
            w, n_global, *_ = carry
            return jnp.logical_and(n_global < target, w < max_waves)

        def body(carry):
            w, n_global, fills, th, d = carry
            n_run = n_global
            for s in range(n_shards):  # unrolled: one segment per shard
                lo = s * cap
                carry_s = (w, n_run, fills[s],
                           jax.lax.dynamic_slice_in_dim(th, lo, cap),
                           jax.lax.dynamic_slice_in_dim(d, lo, cap))
                _, n_run, fill_s, th_s, d_s = bodies[s](
                    carry_s, key, run_idx0, tolerance, data
                )
                th = jax.lax.dynamic_update_slice_in_dim(th, th_s, lo, 0)
                d = jax.lax.dynamic_update_slice_in_dim(d, d_s, lo, 0)
                fills = fills.at[s].set(fill_s)
            return (w + 1, n_run, fills, th, d)

        w, n, fills, th_buf, d_buf = jax.lax.while_loop(
            cond, body, (jnp.int32(0), n0, fills, theta_buf, dist_buf)
        )
        return WaveLoopOutput(th_buf, d_buf, n, w, jnp.minimum(fills, cap))

    return WaveRunner(
        fn=jax.jit(loop, donate_argnums=(2, 3)),
        capacity=cap,
        shards=n_shards,
        n_params=prior.dim,
        cfg=cfg,
    )


# --------------------------------------------------------------------------
# The study
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScalingConfig:
    """One scaling study: (model, backend) x device-count grid, weak scaling."""

    device_counts: Tuple[int, ...] = (1, 2, 4, 8)
    models: Tuple[str, ...] = ("siard",)
    backends: Tuple[str, ...] = ("xla_fused",)
    #: per-DEVICE batch; the global batch of the n-device cell is n * this
    #: (the paper's "2x100k" = 100k per IPU convention)
    batch_per_device: int = 4096
    #: fixed wave budget per measurement (the acceptance target is set
    #: unreachable so every cell burns exactly this many waves)
    waves: int = 8
    num_days: int = 20
    dataset: str = "synthetic_small"
    #: timed repetitions per cell, best-of (excludes the compile/warmup run)
    reps: int = 3
    #: pilot-quantile for the epsilon so the accept/compact path carries
    #: realistic traffic in every cell (an accept-nothing epsilon would hide
    #: the gather cost the paper's outfeed pays)
    tolerance_quantile: float = 0.01
    style: str = "shard_map"
    #: hot-path tuning knobs (repro.core.tuning), threaded into each cell's
    #: ABCConfig: explicit Pallas tile / xla_fused scan unroll, or
    #: autotune=True to resolve cached measured winners per cell shape
    tile: Optional[int] = None
    scan_unroll: Optional[int] = None
    autotune: bool = False

    def __post_init__(self):
        if not self.device_counts:
            raise ValueError("device_counts must be non-empty")
        if self.style not in ("shard_map", "pjit"):
            raise ValueError(f"unknown runner style {self.style!r}")


def cell_key(model: str, backend: str, batch_per_device: int, n: int) -> str:
    return f"{model}/{backend}/b{batch_per_device}/n{n}"


def _cell_abc_config(scfg: ScalingConfig, model: str, backend: str,
                     n: int, tolerance: float) -> ABCConfig:
    global_batch = n * scfg.batch_per_device
    return ABCConfig(
        batch_size=global_batch,
        tolerance=tolerance,
        # unreachable: every cell runs the full wave budget
        target_accepted=scfg.waves * global_batch + 1,
        strategy="outfeed",
        chunk_size=global_batch,
        max_runs=scfg.waves,
        num_days=scfg.num_days,
        backend=backend,
        model=model,
        wave_loop="device",
        tile=scfg.tile if backend == "pallas" else None,
        scan_unroll=scfg.scan_unroll if backend == "xla_fused" else None,
        autotune=scfg.autotune,
    )


def run_scaling_cell(
    dataset,
    cfg: ABCConfig,
    mesh: Mesh,
    reps: int = 3,
    style: str = "shard_map",
    key: int = 1,
) -> Dict[str, float]:
    """Time the sharded device-resident wave loop for one cell.

    Returns best-of-`reps` wall clock plus throughput; the warmup run (which
    pays trace + compile) is excluded. Accept statistics ride along so the
    caller can assert device-count invariance.
    """
    from repro.core import distributed

    runner = distributed.make_wave_runner(mesh, dataset, cfg, style=style)
    run_abc(dataset, cfg, key=0, wave_runner=runner)  # warmup: compile
    best, post = None, None
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        post = run_abc(dataset, cfg, key=key, wave_runner=runner)
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return {
        "wall_s": best,
        "simulations": int(post.simulations),
        "sims_per_s": post.simulations / best,
        "waves": int(post.runs),
        "n_accepted": int(len(post)),
        "accept_rate": len(post) / max(post.simulations, 1),
    }


def run_scaling_study(
    scfg: ScalingConfig,
    devices: Optional[Sequence] = None,
    verbose: bool = False,
) -> Dict:
    """Sweep the (model, backend) x device-count grid on this process's
    devices; returns the report dict (see benchmarks/bench_scaling.py for
    the artifact + regression-gate wrapping).

    Efficiency is relative to the SMALLEST device count in the sweep
    (normally 1): `parallel_efficiency = tp_n * n_ref / (tp_ref * n)` under
    weak scaling, and `scaling_overhead_pct = (1 - efficiency) * 100` — the
    number the paper bounds by 8% at 16 IPUs. On a single physical core the
    simulated-device curve measures dispatch/collective overhead only; on
    real accelerators it measures the paper's claim.
    """
    devices = list(devices if devices is not None else jax.devices())
    counts = sorted(set(scfg.device_counts))
    n_ref = counts[0]
    report: Dict = {
        "config": dataclasses.asdict(scfg),
        "n_visible_devices": len(devices),
        "device_kind": str(devices[0].platform) if devices else "none",
        "reference_device_count": n_ref,
        "cells": {},
    }
    for model in scfg.models:
        ds = get_dataset(scfg.dataset, num_days=scfg.num_days, model=model)
        for backend in scfg.backends:
            # one epsilon per (model, backend), calibrated at the per-device
            # batch so every device count accepts at the same expected rate
            cal_cfg = ABCConfig(
                batch_size=scfg.batch_per_device, tolerance=1.0,
                chunk_size=scfg.batch_per_device, num_days=scfg.num_days,
                backend=backend, model=model,
            )
            tol = calibrate_tolerance(
                ds, cal_cfg, key=42, quantile=scfg.tolerance_quantile,
                n_pilot=scfg.batch_per_device,
            )
            ref_tp = None
            for n in counts:
                mesh = device_mesh(n, devices)
                cfg = _cell_abc_config(scfg, model, backend, n, tol)
                cell = run_scaling_cell(
                    ds, cfg, mesh, reps=scfg.reps, style=scfg.style
                )
                if n == n_ref:
                    ref_tp = cell["sims_per_s"]
                eff = cell["sims_per_s"] * n_ref / (ref_tp * n)
                cell.update({
                    "model": model, "backend": backend, "devices": n,
                    "batch_per_device": scfg.batch_per_device,
                    "global_batch": n * scfg.batch_per_device,
                    "tolerance": tol,
                    "parallel_efficiency": eff,
                    "scaling_overhead_pct": (1.0 - eff) * 100.0,
                })
                report["cells"][cell_key(
                    model, backend, scfg.batch_per_device, n)] = cell
                if verbose:
                    print(f"[scaling] {model}/{backend} n={n}: "
                          f"{cell['sims_per_s']:,.0f} sims/s, "
                          f"eff={eff:.3f}, "
                          f"overhead={cell['scaling_overhead_pct']:.1f}%")
    return report


def format_report(report: Dict) -> str:
    """Render the throughput-vs-device-count curves as a table."""
    headers = ["model", "backend", "devices", "global_batch", "wall_ms",
               "sims/s", "efficiency", "overhead_%"]
    rows: List[List[str]] = []
    for cell in report["cells"].values():
        rows.append([
            cell["model"], cell["backend"], str(cell["devices"]),
            str(cell["global_batch"]), f"{cell['wall_s'] * 1e3:.1f}",
            f"{cell['sims_per_s']:,.0f}",
            f"{cell['parallel_efficiency']:.3f}",
            f"{cell['scaling_overhead_pct']:.1f}",
        ])
    widths = [max(len(h), max((len(r[i]) for r in rows), default=0))
              for i, h in enumerate(headers)]

    def fmt(row):
        return " | ".join(c.rjust(w) for c, w in zip(row, widths))

    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([fmt(headers), sep] + [fmt(r) for r in rows])
