"""Vectorized priors. The paper uses a uniform box prior U(0, highs) (eq. 2)."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UniformBoxPrior:
    """U(lows, highs) over R^p, independent per dimension."""

    highs: tuple
    lows: tuple | None = None

    def __post_init__(self):
        object.__setattr__(self, "highs", tuple(float(h) for h in self.highs))
        lows = self.lows or tuple(0.0 for _ in self.highs)
        object.__setattr__(self, "lows", tuple(float(l) for l in lows))
        assert len(self.lows) == len(self.highs)

    @property
    def dim(self) -> int:
        return len(self.highs)

    def _bounds(self):
        return (
            jnp.asarray(self.lows, jnp.float32),
            jnp.asarray(self.highs, jnp.float32),
        )

    def sample(self, key: jax.Array, batch_shape: Sequence[int] = ()) -> jax.Array:
        """Sample [*batch_shape, dim] parameter vectors."""
        lo, hi = self._bounds()
        u = jax.random.uniform(key, tuple(batch_shape) + (self.dim,), jnp.float32)
        return lo + u * (hi - lo)

    def sample_from_uniform(self, u: jax.Array) -> jax.Array:
        """Map externally-generated U[0,1) draws [..., dim] into the box.

        Used by the Pallas kernel path, which generates uniforms in-kernel.
        """
        lo, hi = self._bounds()
        return lo + u * (hi - lo)

    def log_pdf(self, theta: jax.Array) -> jax.Array:
        """log p(theta) per sample; -inf outside the box. theta [..., dim]."""
        lo, hi = self._bounds()
        inside = jnp.all((theta >= lo) & (theta <= hi), axis=-1)
        log_vol = jnp.sum(jnp.log(hi - lo))
        return jnp.where(inside, -log_vol, -jnp.inf)

    def clip(self, theta: jax.Array) -> jax.Array:
        lo, hi = self._bounds()
        return jnp.clip(theta, lo, hi)


def paper_prior() -> UniformBoxPrior:
    """The prior of eq. (2): U(0, [1, 100, 2, 1, 1, 1, 1, 2])."""
    from repro.epi.model import PRIOR_HIGHS

    return UniformBoxPrior(highs=PRIOR_HIGHS)
