"""Vectorized priors. The paper uses a uniform box prior U(0, highs) (eq. 2)."""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UniformBoxPrior:
    """U(lows, highs) over R^p, independent per dimension."""

    highs: tuple
    lows: tuple | None = None

    def __post_init__(self):
        object.__setattr__(self, "highs", tuple(float(h) for h in self.highs))
        lows = self.lows or tuple(0.0 for _ in self.highs)
        object.__setattr__(self, "lows", tuple(float(l) for l in lows))
        assert len(self.lows) == len(self.highs)

    @property
    def dim(self) -> int:
        return len(self.highs)

    def _bounds(self):
        return (
            jnp.asarray(self.lows, jnp.float32),
            jnp.asarray(self.highs, jnp.float32),
        )

    def sample(
        self,
        key: jax.Array,
        batch_shape: Sequence[int] = (),
        lows=None,
        highs=None,
    ) -> jax.Array:
        """Sample [*batch_shape, dim] parameter vectors.

        `lows`/`highs` optionally override the box bounds with TRACED arrays
        of the same dim — the campaign runner threads per-scenario bounds
        (e.g. pinned intervention scales) through one compiled wave loop this
        way. The arithmetic is identical to the baked path, so same-seed
        samples are bit-identical whichever way the bounds arrive.
        """
        lo, hi = self._bounds()
        if lows is not None:
            lo = jnp.asarray(lows, jnp.float32)
        if highs is not None:
            hi = jnp.asarray(highs, jnp.float32)
        u = jax.random.uniform(key, tuple(batch_shape) + (self.dim,), jnp.float32)
        return lo + u * (hi - lo)

    def sample_from_uniform(self, u: jax.Array) -> jax.Array:
        """Map externally-generated U[0,1) draws [..., dim] into the box.

        Used by the Pallas kernel path, which generates uniforms in-kernel.
        """
        lo, hi = self._bounds()
        return lo + u * (hi - lo)

    def log_pdf(self, theta: jax.Array) -> jax.Array:
        """log p(theta) per sample; -inf outside the box. theta [..., dim].

        Zero-width dimensions (low == high — pinned intervention scales)
        are treated as point masses: they contribute nothing to the box
        volume, and `inside` holds exactly at the pinned value.
        """
        lo, hi = self._bounds()
        inside = jnp.all((theta >= lo) & (theta <= hi), axis=-1)
        width = hi - lo
        log_vol = jnp.sum(
            jnp.where(width > 0, jnp.log(jnp.maximum(width, 1e-38)), 0.0)
        )
        return jnp.where(inside, -log_vol, -jnp.inf)

    def free_dims(self) -> tuple:
        """Boolean per dimension: True where the box has positive width
        (False marks pinned values, e.g. fixed counterfactual scales)."""
        return tuple(h > l for l, h in zip(self.lows, self.highs))

    def clip(self, theta: jax.Array) -> jax.Array:
        lo, hi = self._bounds()
        return jnp.clip(theta, lo, hi)


def paper_prior() -> UniformBoxPrior:
    """The prior of eq. (2): U(0, [1, 100, 2, 1, 1, 1, 1, 2])."""
    from repro.epi.model import PRIOR_HIGHS

    return UniformBoxPrior(highs=PRIOR_HIGHS)


def schedule_prior(model, schedule=None) -> UniformBoxPrior:
    """The widened box prior of a model under an intervention schedule.

    Columns are the model's own parameters followed by the schedule's
    window-major scale factors with their per-window bounds (pinned scales
    become zero-width dimensions). With schedule=None (or an empty schedule)
    this is exactly `model.prior()`.
    """
    base = model.prior()
    if schedule is None or schedule.is_empty:
        return base
    return UniformBoxPrior(
        highs=base.highs + tuple(h for row in schedule.scale_highs for h in row),
        lows=base.lows + tuple(l for row in schedule.scale_lows for l in row),
    )
