"""Roofline-instrumented autotuning of the fused ABC hot path.

The paper's headline claim is a roofline argument: the IPU's 30x over a Xeon
comes from where the simulation's working set sits relative to the memory
hierarchy. This module closes the loop between that analytic story and the
code we actually run, in three layers:

  1. **Analytic cost model** (`cost_model`) — FLOPs and HBM bytes per
     sample-day for ANY `(CompartmentalModel, schedule, summary, distance)`
     combination. Nothing is hardwired to the paper's SIARD constants: the
     per-day op count is obtained by tracing ONE day of the oracle dynamics
     (the spec's own `hazard_rows`, the shared counter RNG, the generic
     tau-leap clamp and the running summary accumulator) with
     `jax.make_jaxpr` and counting arithmetic primitives, so the number is
     derived from the spec itself and stays correct when a new model is
     registered (cross-checked against full `kernels/ref.py` traces in
     tests/test_tuning.py). The byte model is closed-form from the spec's
     shape: the fused kernel reads `theta_width` floats and writes one
     distance per sample (36 B for the unscheduled paper model — exactly the
     seed's `8*4+4`), while the naive path pays
     `(n_transitions + n_observed + 2*n_state) * 4` bytes per sample-DAY.

  2. **Roofline instrumentation** (`roofline_metrics`) — turns a measured
     (simulations, wall clock) cell into `achieved_flops`,
     `achieved_bytes_per_s`, `arithmetic_intensity` and
     `roofline_efficiency` (achieved vs the analytic ceiling
     `min(PEAK_FLOPS, HBM_BW * intensity)`). Every bench-artifact/v1 cell
     carries these fields and `tests/check_bench_regression.py` gates
     efficiency drift, not just wall clock.

  3. **Measured autotuner + persistent cache** (`autotune`, `TuningCache`) —
     a best-of-N search over the knobs that are pure scheduling (and
     therefore stream-invariant):

       * Pallas kernel tile size ({256, 512, 1024, 2048, 4096} filtered to
         divisors of the batch). The kernel's global sample index is
         `idx = lane + tile * tile_idx`, so the RNG stream — and with it the
         accepted particle set — is BIT-IDENTICAL across tiles (pinned by
         tests); the winner is auto-applied.
       * `xla_fused` scan chunking (`lax.scan(..., unroll=k)`), also
         stream-invariant; auto-applied.
       * wave batch size — measured and recorded as `best_batch` but
         ADVISORY ONLY: changing the batch changes the per-wave sample
         streams and hence the accepted set, so it is never applied behind
         the caller's back.

     Winners persist in a JSON cache under `experiments/tuning/` keyed by
     `(backend, model, days, batch, summary, distance, schedule-shape)`.
     `abc.make_simulator` consults the cache at simulator-build time when
     `ABCConfig.autotune` is set (a hit skips all measurement), so campaigns
     and scaling studies pick tuned sizes automatically.

CLI (the nightly cache-refresh job):

    PYTHONPATH=src python -m repro.core.tuning \
        --dataset synthetic_small --models siard sir \
        --backends pallas xla_fused --batch 8192 --days 20
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# accelerator ceilings (TPU v5e class) shared with benchmarks/roofline.py
from repro.ioutils import atomic_write
from repro.launch.analysis import HBM_BW, PEAK_FLOPS

_REPO = Path(__file__).resolve().parents[3]
#: where tuning winners persist (committed / uploaded by the nightly job)
TUNING_DIR = _REPO / "experiments" / "tuning"
DEFAULT_CACHE_PATH = TUNING_DIR / "cache.json"
CACHE_SCHEMA = "tuning-cache/v1"

#: kernel tile candidates of the measured search (filtered per batch)
TILE_CANDIDATES = (256, 512, 1024, 2048, 4096)
#: lax.scan unroll candidates for the xla_fused running-distance scan
UNROLL_CANDIDATES = (1, 2, 4, 8)
#: wave-batch candidates, as factors of the configured batch (advisory only)
BATCH_FACTORS = (0.5, 1.0, 2.0)


# --------------------------------------------------------------------------
# 1. Analytic cost model, derived from the model spec
# --------------------------------------------------------------------------

#: jaxpr primitives counted as one op per output element. Integer/bitwise ops
#: are included: the counter-based RNG is murmur-style integer mixing and
#: occupies the same VPU issue slots as float math on every target we model.
_OP_PRIMS = frozenset({
    "add", "sub", "mul", "div", "rem", "neg", "sign", "abs",
    "max", "min", "pow", "integer_pow", "sqrt", "rsqrt",
    "log", "log1p", "exp", "expm1", "tanh", "logistic", "erf", "erf_inv",
    "floor", "ceil", "round", "nextafter",
    "sin", "cos", "atan2",
    "eq", "ne", "lt", "le", "gt", "ge",
    "and", "or", "xor", "not",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "select_n", "clamp",
})

#: params keys under which higher-order primitives hide their inner jaxprs
_INNER_JAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def count_jaxpr_ops(jaxpr) -> float:
    """Arithmetic op count of a (closed) jaxpr, one op per output element.

    Recurses into scan (multiplied by the static trip count), while/cond
    bodies and inlined calls. This is an *operation* count, not an HLO FLOP
    estimate — it is the currency both sides of the cost-model cross-check
    use (tests/test_tuning.py), so only internal consistency matters.
    """
    closed = getattr(jaxpr, "jaxpr", None)
    if closed is not None:  # ClosedJaxpr -> Jaxpr
        jaxpr = closed
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            total += float(eqn.params["length"]) * count_jaxpr_ops(
                eqn.params["jaxpr"]
            )
        elif prim == "while":
            # one iteration of cond+body (trip count is data-dependent)
            total += count_jaxpr_ops(eqn.params["cond_jaxpr"])
            total += count_jaxpr_ops(eqn.params["body_jaxpr"])
        elif prim == "cond":
            total += max(
                (count_jaxpr_ops(b) for b in eqn.params["branches"]),
                default=0.0,
            )
        elif any(k in eqn.params and eqn.params[k] is not None
                 for k in _INNER_JAXPR_KEYS):
            for k in _INNER_JAXPR_KEYS:
                inner = eqn.params.get(k)
                if inner is not None:
                    total += count_jaxpr_ops(inner)
        elif prim in _OP_PRIMS:
            total += float(max(
                (int(np.prod(v.aval.shape)) for v in eqn.outvars), default=1
            ))
    return total


def count_fn_ops(fn, *args) -> float:
    """`count_jaxpr_ops` of `jax.make_jaxpr(fn)(*args)`."""
    return count_jaxpr_ops(jax.make_jaxpr(fn)(*args))


@functools.lru_cache(maxsize=None)
def _flops_per_sample_day(model, schedule, summary, distance: str) -> float:
    """Trace ONE day of the oracle dynamics and count ops per sample.

    All arguments are hashable statics (the model spec is frozen); the day
    index, seed and observed values are traced so nothing constant-folds.
    """
    from repro.core.summaries import (
        get_distance_kind,
        get_summary,
        pool_channels,
        pool_factor,
        running_day,
    )
    from repro.epi import engine
    from repro.kernels import ref

    spec = get_summary(summary)
    kind = get_distance_kind(distance)
    b = 256  # large enough to amortize the few per-day scalar ops
    pool = pool_factor(spec, model.n_regions)
    n_obs = model.total_observed // pool  # summary channels after pooling
    obs_idx = model.total_observed_idx
    width = model.n_params
    if schedule is not None and not schedule.is_empty:
        width += schedule.shape(model).n_scales

    def day(theta, state, cum, binv, acc, day_idx, obs_t, flush_t, seed, idx):
        z = ref.hash_normals(
            seed, idx, day_idx, model.total_transitions, model.ctr_slots
        )
        th_d = engine.effective_theta(model, schedule, theta, day_idx)
        nxt = engine.tau_leap_step(model, state, th_d, z, 1e6)
        cum, binv, acc = running_day(
            spec, kind, jnp.ones((n_obs,), jnp.float32),
            pool_channels(nxt[..., obs_idx], pool), obs_t, flush_t, cum,
            binv, acc,
        )
        return nxt, cum, binv, acc

    args = (
        jnp.zeros((b, width), jnp.float32),          # theta
        jnp.zeros((b, model.total_state), jnp.float32),  # state (all regions)
        jnp.zeros((b, n_obs), jnp.float32),          # cum carry
        jnp.zeros((b, n_obs), jnp.float32),          # bin carry
        jnp.zeros((b,), jnp.float32),                # distance accumulator
        jnp.uint32(0),                               # day index (traced)
        jnp.zeros((n_obs,), jnp.float32),            # observed summary at day
        jnp.float32(1.0),                            # flush flag
        jnp.uint32(0),                               # RNG seed
        jnp.arange(b, dtype=jnp.uint32),             # global sample indices
    )
    return count_fn_ops(day, *args) / b


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Analytic per-sample cost of the fused ABC hot path for one spec."""

    model: str
    days: int
    theta_width: int  # params + schedule scale columns
    #: region-major flattened totals (== the per-region counts at R=1)
    n_transitions: int
    n_state: int
    n_observed: int
    #: traced op count of one simulated day per sample (spec-derived)
    flops_per_sample_day: float
    #: fused-path HBM bytes per sample: theta row in + one distance out
    fused_bytes_per_sample: float
    #: naive-path bytes per sample-DAY: noise + trajectory + state round trip
    naive_bytes_per_sample_day: float
    n_regions: int = 1

    def flops(self, n_samples: float, days: Optional[int] = None) -> float:
        return n_samples * (days or self.days) * self.flops_per_sample_day

    def fused_bytes(self, n_samples: float) -> float:
        return n_samples * self.fused_bytes_per_sample

    def naive_bytes(self, n_samples: float, days: Optional[int] = None) -> float:
        return n_samples * (days or self.days) * self.naive_bytes_per_sample_day

    @property
    def arithmetic_intensity_fused(self) -> float:
        return self.days * self.flops_per_sample_day / self.fused_bytes_per_sample

    @property
    def arithmetic_intensity_naive(self) -> float:
        return self.flops_per_sample_day / self.naive_bytes_per_sample_day


def cost_model(
    model,
    days: int,
    schedule=None,
    summary=None,
    distance: str = "euclidean",
) -> CostModel:
    """Build the analytic cost model for any registered (or ad-hoc) spec.

    `model` is a registry name or a `CompartmentalModel`; `schedule` widens
    theta (more fused bytes) and adds the per-day window selects; `summary`
    and `distance` change the per-day accumulator ops.
    """
    from repro.epi.models import get_model

    spec = get_model(model)
    sched = None
    if schedule is not None and not schedule.is_empty:
        sched = schedule.shape(spec)
    width = spec.n_params + (sched.n_scales if sched is not None else 0)
    f = _flops_per_sample_day(spec, schedule, summary, distance)
    return CostModel(
        model=spec.name,
        days=int(days),
        theta_width=width,
        n_transitions=spec.total_transitions,
        n_state=spec.total_state,
        n_observed=spec.total_observed,
        n_regions=spec.n_regions,
        flops_per_sample_day=f,
        fused_bytes_per_sample=(width + 1) * 4.0,
        naive_bytes_per_sample_day=(
            (spec.total_transitions + spec.total_observed
             + 2 * spec.total_state) * 4.0
        ),
    )


# --------------------------------------------------------------------------
# 2. Roofline instrumentation of measured cells
# --------------------------------------------------------------------------

def roofline_from_totals(flops: float, hbm_bytes: float, wall_s: float) -> Dict:
    """achieved/intensity/efficiency fields from raw totals.

    `roofline_efficiency` is measured throughput over the analytic ceiling
    `min(PEAK_FLOPS, HBM_BW * intensity)` — the number the regression gate
    tracks for drift. On CPU hosts the absolute value is tiny (the ceiling
    models the accelerator); the gate only ever compares it to ITS baseline
    on the same machine class, so relative drift is still meaningful.
    """
    wall_s = max(float(wall_s), 1e-12)
    ai = flops / max(hbm_bytes, 1.0)
    achieved = flops / wall_s
    ceiling = min(PEAK_FLOPS, HBM_BW * ai)
    return {
        "achieved_flops": achieved,
        "achieved_bytes_per_s": hbm_bytes / wall_s,
        "arithmetic_intensity": ai,
        "roofline_efficiency": achieved / max(ceiling, 1e-12),
    }


def roofline_metrics(
    cm: CostModel, n_samples: float, wall_s: float, days: Optional[int] = None
) -> Dict:
    """Instrument one measured cell (simulations, wall clock) -> envelope
    fields. Uses the FUSED byte model — the hot path every backend aspires
    to; the naive/fused intensity comparison lives in benchmarks/roofline.py.
    """
    return roofline_from_totals(
        cm.flops(n_samples, days), cm.fused_bytes(n_samples), wall_s
    )


def bench_cell_metrics(
    model,
    days: int,
    simulations: float,
    wall_s: float,
    schedule=None,
    summary=None,
    distance: str = "euclidean",
) -> Dict:
    """One-call helper for benchmark scripts: cost model + roofline fields."""
    cm = cost_model(model, days, schedule=schedule, summary=summary,
                    distance=distance)
    return roofline_metrics(cm, simulations, wall_s)


# --------------------------------------------------------------------------
# 3. Persistent tuning cache
# --------------------------------------------------------------------------

def _schedule_shape_tag(model, schedule) -> str:
    if schedule is None or schedule.is_empty:
        return "nosched"
    from repro.epi.models import get_model

    shape = schedule.shape(get_model(model))
    return f"w{shape.n_windows}tv{len(shape.tv_indices)}"


def cache_key(
    *,
    backend: str,
    model: str,
    days: int,
    batch: int,
    summary: str = "identity",
    distance: str = "euclidean",
    schedule=None,
) -> str:
    """The tuning-cache key: everything that changes the tuned optimum."""
    sched = _schedule_shape_tag(model, schedule)
    return f"{backend}/{model}/d{days}/b{batch}/{summary}/{distance}/{sched}"


def cfg_cache_key(cfg) -> str:
    """Cache key of an `ABCConfig` (its summary resolved to a stable tag)."""
    return cache_key(
        backend=cfg.backend,
        model=cfg.model,
        days=cfg.num_days,
        batch=cfg.batch_size,
        summary=cfg.summary_spec.tag(),
        distance=cfg.distance,
        schedule=cfg.schedule,
    )


class TuningCache:
    """JSON-backed map of cache_key -> winning knob entry.

    Reads are lazy; writes are atomic (temp file + rename, like ABCState).
    A corrupt or schema-mismatched file raises ValueError LOUDLY instead of
    silently retuning from scratch — a half-written cache hiding a tuned
    winner would quietly cost every nightly run its measurement budget.
    """

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = Path(path) if path is not None else DEFAULT_CACHE_PATH
        self._entries: Optional[Dict[str, Dict]] = None

    def _load(self) -> None:
        if self._entries is not None:
            return
        if not self.path.exists():
            self._entries = {}
            return
        try:
            payload = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            raise ValueError(
                f"corrupt tuning cache {self.path} ({e}); delete it and "
                "re-run autotuning (python -m repro.core.tuning)"
            ) from e
        if (
            not isinstance(payload, dict)
            or payload.get("schema") != CACHE_SCHEMA
            or not isinstance(payload.get("entries"), dict)
        ):
            raise ValueError(
                f"tuning cache {self.path} is not a {CACHE_SCHEMA} payload; "
                "delete it and re-run autotuning (python -m repro.core.tuning)"
            )
        self._entries = payload["entries"]

    def get(self, key: str) -> Optional[Dict]:
        self._load()
        return self._entries.get(key)

    def entries(self) -> Dict[str, Dict]:
        self._load()
        return dict(self._entries)

    def put(self, key: str, entry: Dict) -> None:
        self._load()
        self._entries[key] = entry
        payload = {"schema": CACHE_SCHEMA, "entries": self._entries}
        with atomic_write(self.path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)


# --------------------------------------------------------------------------
# 4. Measured best-of-N search
# --------------------------------------------------------------------------

def measure_simulator(
    dataset,
    cfg,
    *,
    reps: int = 2,
    warmup: int = 1,
    key: int = 0,
    batch: Optional[int] = None,
) -> float:
    """Best-of-`reps` wall seconds of one simulator batch under `cfg`.

    Builds the backend simulator with autotuning OFF (so the search never
    recurses into itself) and times `simulator(theta, key)` end to end,
    compile/warmup excluded.
    """
    from repro.core.abc import make_simulator
    from repro.core.priors import schedule_prior
    from repro.epi.models import get_model

    b = int(batch or cfg.batch_size)
    cfg = dataclasses.replace(cfg, autotune=False)
    if batch is not None:
        # batch candidates only probe throughput; let the tile auto-resolve
        cfg = dataclasses.replace(cfg, batch_size=b, chunk_size=b, tile=None)
    sim = jax.jit(make_simulator(dataset, cfg))
    prior = schedule_prior(get_model(cfg.model), cfg.schedule)
    theta = prior.sample(jax.random.PRNGKey(key), (b,))
    k_sim = jax.random.PRNGKey(key + 1)
    for _ in range(max(0, warmup)):
        jax.block_until_ready(sim(theta, k_sim))
    best = None
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(sim(theta, k_sim))
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def tile_candidates(batch: int) -> Tuple[int, ...]:
    """Search space for the Pallas tile: the fixed candidate grid filtered to
    exact divisors of the batch, plus the legacy auto default."""
    from repro.kernels.ops import resolve_tile

    cands = {t for t in TILE_CANDIDATES if batch % t == 0 and t <= batch}
    auto = resolve_tile(batch, None)
    if batch % auto == 0:
        # the auto default only joins the EXPLICIT candidate set when it
        # divides the batch (explicit tiles never ghost-pad, by contract)
        cands.add(auto)
    return tuple(sorted(cands))


def autotune(
    dataset,
    cfg,
    *,
    cache: Optional[TuningCache] = None,
    reps: int = 2,
    measure: Optional[Callable] = None,
    measure_batches: bool = True,
    verbose: bool = False,
) -> Dict:
    """Measured best-of-N search for `cfg`'s backend; returns the cache entry.

    A cache HIT returns immediately without measuring anything (pinned by
    tests/test_tuning.py). On a miss the search measures, per backend:

      pallas    — every compatible kernel tile (`tile_candidates`); the
                  winner is auto-applied by `resolve_tuned` because tiling
                  is stream-invariant (bit-identical accepted sets).
      xla_fused — the day scan's unroll factor; also stream-invariant.
      (all)     — optionally, wave-batch candidates; `best_batch` is
                  recorded ADVISORY ONLY because the batch size changes the
                  per-wave RNG streams and therefore the accepted set.

    `measure(cfg, batch=None) -> seconds` can be injected for tests.
    """
    cache = cache if cache is not None else TuningCache()
    key = cfg_cache_key(cfg)
    hit = cache.get(key)
    if hit is not None:
        return hit
    if measure is None:
        def measure(c, batch=None):  # noqa: E731 — default measured probe
            return measure_simulator(dataset, c, reps=reps, batch=batch)

    entry: Dict = {
        "schema": CACHE_SCHEMA,
        "backend": cfg.backend,
        "model": cfg.model,
        "days": cfg.num_days,
        "batch": cfg.batch_size,
        "summary": cfg.summary_spec.tag(),
        "distance": cfg.distance,
        "schedule": _schedule_shape_tag(cfg.model, cfg.schedule),
    }
    measurements: Dict[str, float] = {}

    if cfg.backend == "pallas":
        cands = tile_candidates(cfg.batch_size)
        for t in cands:
            dt = measure(dataclasses.replace(cfg, tile=int(t)))
            measurements[f"tile{t}"] = dt
            if verbose:
                print(f"[tuning] {key}: tile={t} -> {dt * 1e3:.1f} ms")
        if cands:
            best = min(measurements, key=measurements.get)
            entry["tile"] = int(best[len("tile"):])
    elif cfg.backend == "xla_fused":
        for u in UNROLL_CANDIDATES:
            dt = measure(dataclasses.replace(cfg, scan_unroll=int(u)))
            measurements[f"unroll{u}"] = dt
            if verbose:
                print(f"[tuning] {key}: unroll={u} -> {dt * 1e3:.1f} ms")
        best = min(measurements, key=measurements.get)
        entry["scan_unroll"] = int(best[len("unroll"):])

    if measure_batches:
        best_batch, best_tp = None, -1.0
        for f in BATCH_FACTORS:
            b = int(cfg.batch_size * f)
            if b < 256:
                continue
            dt = measure(cfg, batch=b)
            measurements[f"batch{b}"] = dt
            if b / dt > best_tp:
                best_batch, best_tp = b, b / dt
            if verbose:
                print(f"[tuning] {key}: batch={b} -> {b / dt:,.0f} sims/s")
        # advisory: applying it would change the per-wave sample streams
        entry["best_batch"] = best_batch

    entry["measurements"] = measurements
    cache.put(key, entry)
    return entry


def resolve_tuned(dataset, cfg, cache: Optional[TuningCache] = None):
    """An `ABCConfig` with tuned knobs filled in from the cache.

    No-op unless `cfg.autotune` is set. Explicit user settings always win
    over cached winners; `best_batch` is never applied (advisory only). The
    returned config has `autotune=False` so downstream builders — including
    the search's own measurement probes — never re-enter the tuner.
    """
    if not getattr(cfg, "autotune", False):
        return cfg
    entry = autotune(dataset, cfg, cache=cache)
    repl: Dict = {"autotune": False}
    if cfg.tile is None and entry.get("tile"):
        repl["tile"] = int(entry["tile"])
    if cfg.scan_unroll is None and entry.get("scan_unroll"):
        repl["scan_unroll"] = int(entry["scan_unroll"])
    return dataclasses.replace(cfg, **repl)


# --------------------------------------------------------------------------
# CLI: build/refresh the tuning cache (the nightly job's entry point)
# --------------------------------------------------------------------------

def main(argv=None) -> int:
    import argparse

    from repro.core.abc import ABCConfig

    ap = argparse.ArgumentParser(
        description="Measure and persist ABC hot-path tuning winners."
    )
    ap.add_argument("--dataset", default="synthetic_small")
    ap.add_argument("--models", nargs="+", default=["siard", "sir"])
    ap.add_argument("--backends", nargs="+",
                    default=["pallas", "xla_fused"])
    ap.add_argument("--batch", type=int, default=8192)
    ap.add_argument("--days", type=int, default=20)
    ap.add_argument("--summary", default="identity")
    ap.add_argument("--distance", default="euclidean")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--cache", default=str(DEFAULT_CACHE_PATH))
    ap.add_argument("--no-batch-search", action="store_true",
                    help="skip the (advisory) wave-batch measurements")
    args = ap.parse_args(argv)

    from repro.epi.data import get_dataset

    cache = TuningCache(args.cache)
    for model in args.models:
        ds = get_dataset(args.dataset, num_days=args.days, model=model)
        for backend in args.backends:
            cfg = ABCConfig(
                batch_size=args.batch, chunk_size=args.batch,
                num_days=args.days, backend=backend, model=model,
                summary=None if args.summary == "identity" else args.summary,
                distance=args.distance, autotune=True,
            )
            entry = autotune(ds, cfg, cache=cache, reps=args.reps,
                             measure_batches=not args.no_batch_search,
                             verbose=True)
            knobs = {k: entry.get(k) for k in ("tile", "scan_unroll",
                                               "best_batch")
                     if entry.get(k) is not None}
            print(f"[tuning] {cfg_cache_key(cfg)} -> {knobs}")
            cm = cost_model(model, args.days, summary=cfg.summary,
                            distance=args.distance)
            print(f"[tuning]   cost model: {cm.flops_per_sample_day:.0f} "
                  f"ops/sample-day, {cm.fused_bytes_per_sample:.0f} B/sample "
                  f"fused (AI {cm.arithmetic_intensity_fused:.0f}), "
                  f"{cm.naive_bytes_per_sample_day:.0f} B/sample-day naive")
    print(f"[tuning] cache: {cache.path} ({len(cache.entries())} entries)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
