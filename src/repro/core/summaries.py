"""Declarative summary statistics + weighted distances for ABC calibration.

The paper compares raw (A, R, D) trajectories with a plain Euclidean
distance; the SBI-assessment literature (PAPERS.md) shows the choice of
summary statistic and distance weighting dominates posterior quality for
stochastic epidemic models. This module makes both first-class calibration
components, expressed so that every simulation backend can lower them:

  * `SummarySpec` — a composable transform of the observed-channel series:
    optional cumulative channels, optional log1p, optional `bin_days`-day
    binning (weekly = 7), optional per-channel weights. Transforms compose in
    the order cumulative -> binning -> log1p (log of weekly totals).
  * `DISTANCE_KINDS` — the distance family over summary values: weighted L2
    ("euclidean"), weighted mean-L1 ("mae") and observed-scale-normalized L2
    ("normalized_euclidean"); the names deliberately mirror the legacy
    `repro.core.distances.DISTANCES` registry so `ABCConfig.distance` values
    are unchanged.

Every (summary, distance) pair reduces to ONE running-accumulator shape that
all three backends share (the generalization of the fused running squared
distance, DESIGN.md §2). Per day t, with per-channel carries `cum` and `bin`:

    cum  += x_t                        # running cumulative
    v     = cum  if cumulative else x_t
    bin   = v if cumulative else bin + v   # cumulative: END-OF-BIN level;
                                           # rates: running within-bin SUM
    flush = ((t+1) % bin_days == 0) or (t == T-1)   # partial final bin counts
    s     = log1p(max(bin, 0)) if log1p else bin
    acc  += flush * sum_c w_c * |s_c - obs_summary_c[t]| ** power
    bin  *= 1 - flush
    dist  = sqrt(acc) | acc / n_terms                # by distance kind

(Binning a cumulative channel takes the latest cumulative value — "weekly
cumulative deaths" means the level at the end of each week — rather than
summing levels within the bin, which would scale each term by its bin
length and silently down-weight a partial final bin.)

The observed side is precomputed once (`lower_summary`) in the SAME running
layout, so the comparison at flush days is exact and the values at non-flush
days are ignored. The identity spec with the "euclidean" kind degenerates to
exactly the legacy accumulation (flush == 1 and w == 1 every day; every
extra op is a multiply-by-1.0 or a constant-false select, both bit-exact),
which is how the default path stays bit-identical to pre-summary releases —
pinned by tests/test_summaries.py.

Lowerings (consumers):
  * `apply_summary` + `summary_distance` — vectorized post-hoc transform for
    the paper-faithful "xla" backend (full [B, n_obs, T] trajectories).
  * `running_day` / `running_finalize`  — per-day fold for the "xla_fused"
    scan (repro.epi.engine.simulate_observed_lowmem) and the kernel oracle
    (repro.kernels.ref).
  * the Pallas kernel (repro.kernels.abc_sim) re-expresses `running_day`
    with traced selects: the lowered weights/flags ride scalar const lanes
    like the intervention breakpoints, so a summary/distance sweep reuses
    one compiled kernel (pinned by a jit-cache test).

This module imports nothing from the rest of the repo, so every layer can
depend on it without cycles.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SummarySpec:
    """A composable summary transform of the observed-channel series.

    Applied identically to the simulated and the observed side; the
    transforms compose as cumulative -> `bin_days`-binning -> log1p.
    """

    name: str = "identity"
    #: per-channel cumulative sums over time (e.g. cumulative deaths)
    cumulative: bool = False
    #: log1p of the (clamped non-negative) values — tames heavy-tailed counts
    log1p: bool = False
    #: bin length in days; 1 = daily (no binning), 7 = weekly totals. The
    #: final bin may be partial (it flushes on the last day regardless).
    bin_days: int = 1
    #: optional per-channel weights (length n_observed); None = all 1.0.
    #: For metapop models, either the flattened total channel count or the
    #: per-region count (then tiled identically across regions).
    channel_weights: Optional[Tuple[float, ...]] = None
    #: metapop models only: sum each observed channel across regions BEFORE
    #: the transform chain, comparing national aggregates instead of
    #: per-region series (the region axis of the summary accumulator
    #: collapses; requires `n_regions` at lowering time). No-op at R=1.
    region_pool: bool = False

    def __post_init__(self):
        if self.bin_days < 1:
            raise ValueError(f"bin_days must be >= 1, got {self.bin_days}")
        if self.channel_weights is not None:
            object.__setattr__(
                self, "channel_weights",
                tuple(float(w) for w in self.channel_weights),
            )
            if any(w < 0 for w in self.channel_weights):
                raise ValueError("channel weights must be non-negative")

    @property
    def is_identity(self) -> bool:
        """True when the transform is a no-op (the paper's raw statistic)."""
        return (
            not self.cumulative
            and not self.log1p
            and self.bin_days == 1
            and self.channel_weights is None
            and not self.region_pool
        )

    def tag(self) -> str:
        """Compact filesystem-safe label for scenario/checkpoint names.

        The bare name is only trusted when this spec IS the registry entry
        of that name; any other spec gets a parameter-derived tag, so two
        different statistics can never share a scenario name (and therefore
        a campaign checkpoint directory)."""
        if SUMMARIES.get(self.name) == self:
            return self.name
        if self.is_identity:
            return "identity"
        parts = []
        if self.cumulative:
            parts.append("cum")
        if self.bin_days > 1:
            parts.append(f"bin{self.bin_days}")
        if self.log1p:
            parts.append("log1p")
        if self.channel_weights is not None:
            parts.append("w" + "-".join(f"{w:g}" for w in self.channel_weights))
        if self.region_pool:
            parts.append("rpool")
        return "_".join(parts)


#: registry of named summary statistics (ABCConfig.summary / --summary / the
#: campaign's --summaries axis accept these names or SummarySpec instances)
SUMMARIES = {
    "identity": SummarySpec(),
    "weekly": SummarySpec("weekly", bin_days=7),
    "cumulative": SummarySpec("cumulative", cumulative=True),
    "log_daily": SummarySpec("log_daily", log1p=True),
    "log_weekly": SummarySpec("log_weekly", bin_days=7, log1p=True),
    # metapop: per-channel national aggregates; identical to "identity" at R=1
    "region_pooled": SummarySpec("region_pooled", region_pool=True),
}


def list_summaries() -> Tuple[str, ...]:
    return tuple(sorted(SUMMARIES))


def get_summary(s) -> SummarySpec:
    """Resolve None (identity) / registry name / SummarySpec instance."""
    if s is None:
        return SUMMARIES["identity"]
    if isinstance(s, SummarySpec):
        return s
    if isinstance(s, str):
        try:
            return SUMMARIES[s]
        except KeyError:
            raise ValueError(
                f"unknown summary {s!r}; registered: {list_summaries()}"
            ) from None
    raise TypeError(f"summary must be None, a name or a SummarySpec; got {s!r}")


class DistanceKind(NamedTuple):
    """How the weighted per-term residuals reduce to one distance."""

    power: int  # 1 (absolute) | 2 (squared) residuals
    root: bool  # sqrt the accumulator at the end (L2 family)
    mean: bool  # divide by the number of summary terms (mean-L1 family)
    normalize: bool  # fold 1/observed-scale^2 into the channel weights


#: same keys as repro.core.distances.DISTANCES, so ABCConfig.distance values
#: carry over unchanged; here they act on SUMMARY values instead of raw days
DISTANCE_KINDS = {
    "euclidean": DistanceKind(power=2, root=True, mean=False, normalize=False),
    "mae": DistanceKind(power=1, root=False, mean=True, normalize=False),
    "normalized_euclidean": DistanceKind(
        power=2, root=True, mean=False, normalize=True
    ),
}


def get_distance_kind(name: str) -> DistanceKind:
    try:
        return DISTANCE_KINDS[name]
    except KeyError:
        raise ValueError(
            f"unknown distance {name!r}; registered: {tuple(sorted(DISTANCE_KINDS))}"
        ) from None


# indices into LoweredSummary.flags — the i32 selector vector the Pallas
# kernel reads off its const lanes (traced, so specs share one compile)
FLAG_CUMULATIVE, FLAG_LOG1P, FLAG_POWER, FLAG_ROOT, FLAG_BIN_DAYS = range(5)
N_FLAGS = 5


class LoweredSummary(NamedTuple):
    """Runtime (traced-compatible) values a backend needs for one
    (summary, distance) pair against one observed series."""

    obs_summary: Array  # [n_obs, T] — observed side in the running-bin layout
    flush: Array  # [T] f32 — 1.0 on days whose bin closes
    weights: Array  # [n_obs] f32 — channel weights incl. normalization
    mean_scale: Array  # [] f32 — 1/n_terms for mean-kind distances else 1.0
    flags: Array  # [N_FLAGS] i32 — selector vector (see FLAG_*)


def num_bins(num_days: int, bin_days: int) -> int:
    """Summary terms per channel (the final partial bin counts)."""
    return -(-num_days // bin_days)


def flush_mask(num_days: int, bin_days: int) -> Array:
    """[T] f32: 1.0 on the last day of each bin (incl. a partial final bin)."""
    t = np.arange(num_days)
    m = ((t + 1) % bin_days == 0) | (t == num_days - 1)
    return jnp.asarray(m, jnp.float32)


def pool_factor(spec: SummarySpec, n_regions: int) -> int:
    """Static region-pooling factor: `n_regions` when this spec pools the
    region axis of a metapop series, else 1 (identity). Backends branch on
    this at trace time, so R=1 and non-pooling paths stay bit-exact."""
    return n_regions if (spec.region_pool and n_regions > 1) else 1


def pool_channels(x: Array, pool: int, axis: int = -1) -> Array:
    """Sum a region-major flattened channel axis across regions.

    `axis` (-1 for per-day vectors [..., R*n], -2 for series [..., R*n, T])
    has length pool*n laid out region-major (channel r*n+c, matching
    `CompartmentalModel.total_observed_idx`); the result drops the region
    factor, length n. `pool <= 1` returns the input unchanged (bit-exact)."""
    if pool <= 1:
        return x
    axis = axis % x.ndim
    n_chan = x.shape[axis]
    if n_chan % pool:
        raise ValueError(
            f"cannot pool axis of length {n_chan} by region factor {pool}"
        )
    shape = x.shape[:axis] + (pool, n_chan // pool) + x.shape[axis + 1:]
    return jnp.sum(x.reshape(shape), axis=axis)


def apply_summary(spec: SummarySpec, series: Array) -> Array:
    """Vectorized summary transform, running-bin layout: [..., n_obs, T] ->
    [..., n_obs, T] where entry t holds the within-bin running value at day t
    (== the bin's summary value on flush days). Binning SUMS rate channels
    within each bin; a cumulative channel's bin value is its latest running
    level (module docstring), which for the cumulative series is just the
    series itself. With the identity spec the input is returned unchanged
    (bit-exact)."""
    x = jnp.asarray(series, jnp.float32)
    num_days = x.shape[-1]
    v = jnp.cumsum(x, axis=-1) if spec.cumulative else x
    if spec.bin_days > 1 and not spec.cumulative:
        cv = jnp.cumsum(v, axis=-1)
        t = np.arange(num_days)
        start = (t // spec.bin_days) * spec.bin_days  # first day of t's bin
        prev = jnp.where(
            jnp.asarray(start > 0), cv[..., np.maximum(start - 1, 0)], 0.0
        )
        v = cv - prev  # running within-bin sum at day t
    if spec.log1p:
        v = jnp.log1p(jnp.maximum(v, 0.0))
    return v


def lower_summary(
    spec: SummarySpec, distance: str, observed: Array, n_regions: int = 1
) -> LoweredSummary:
    """Precompute the observed-side summary + weights for one pair.

    `observed` [n_obs, T] may be a traced value (the campaign threads
    datasets through compiled wave loops as arguments); every output is then
    traced too. The flags vector is always concrete here — the Pallas path
    re-feeds it as a runtime argument so sweeps share one compiled kernel.

    For metapop models `observed` carries the region-major flattened channel
    axis ([R*n, T], `CompartmentalModel.total_observed_idx` order) and
    `n_regions` must be passed; a region-pooling spec then sums the observed
    side across regions here, so the lowered layout matches the pooled
    simulated series the backends feed to the accumulator. Per-region
    `channel_weights` (length n_obs / R) are tiled identically across
    regions.
    """
    kind = get_distance_kind(distance)
    obs = jnp.asarray(observed, jnp.float32)
    pool = pool_factor(spec, n_regions)
    obs = pool_channels(obs, pool, axis=-2)
    n_obs, num_days = obs.shape
    s = apply_summary(spec, obs)
    fl = flush_mask(num_days, spec.bin_days)
    nb = num_bins(num_days, spec.bin_days)
    if spec.channel_weights is not None:
        cw = spec.channel_weights
        if len(cw) != n_obs and n_regions > 1 and len(cw) * n_regions == n_obs:
            cw = cw * n_regions  # per-region weights, tiled region-major
        if len(cw) != n_obs:
            raise ValueError(
                f"summary {spec.tag()!r} has {len(spec.channel_weights)} channel "
                f"weights for {n_obs} observed channels"
            )
        w = jnp.asarray(cw, jnp.float32)
    else:
        w = jnp.ones((n_obs,), jnp.float32)
    if kind.normalize:
        # per-channel RMS of the observed summary over its flush days — the
        # cross-country comparability weighting (legacy normalized_euclidean
        # generalized to any summary); eps=1.0 matches the legacy distance
        msq = jnp.sum(fl * s * s, axis=-1) / nb
        scale = jnp.sqrt(msq) + 1.0
        w = w / (scale * scale)
    mean_scale = jnp.float32(1.0 / (n_obs * nb) if kind.mean else 1.0)
    flags = jnp.asarray(
        [int(spec.cumulative), int(spec.log1p), kind.power, int(kind.root),
         spec.bin_days],
        jnp.int32,
    )
    return LoweredSummary(s, fl, w, mean_scale, flags)


def summary_distance(
    distance: str, lowered: LoweredSummary, sim_summary: Array
) -> Array:
    """Post-hoc weighted distance over summary values: [..., n_obs, T] -> [...].

    The "xla" backend's lowering: `sim_summary` is `apply_summary` of the
    full simulated trajectories."""
    kind = get_distance_kind(distance)
    diff = sim_summary - lowered.obs_summary
    term = jnp.abs(diff) if kind.power == 1 else diff * diff
    acc = jnp.sum(lowered.flush * (lowered.weights[..., None] * term),
                  axis=(-2, -1))
    acc = acc * lowered.mean_scale
    return jnp.sqrt(acc) if kind.root else acc


def running_day(
    spec: SummarySpec,
    kind: DistanceKind,
    weights: Array,
    x: Array,  # [..., n_obs] — this day's observed-channel values
    obs_t: Array,  # [n_obs] (or broadcastable) — observed summary at day t
    flush_t: Array,  # [] f32 — 1.0 if day t closes a bin
    cum: Array,  # [..., n_obs] carry
    binv: Array,  # [..., n_obs] carry
    acc: Array,  # [...] carry
):
    """One day of the generalized running-distance accumulator (module
    docstring recurrence), tensor layout. Shared by the fused XLA scan and
    the kernel oracle; the Pallas kernel body is the traced-select twin
    (kernels/abc_sim.py) validated against this via ref.py parity tests."""
    # spec is always a concrete SummarySpec here (only the Pallas kernel
    # needs traced selects), so non-cumulative specs skip the cum update
    # entirely — the carry passes through untouched. A cumulative channel's
    # bin value is its latest level (end-of-bin on flush days); a rate
    # channel's is the running within-bin sum.
    if spec.cumulative:
        cum = cum + x
        v = cum
        binv = v
    else:
        v = x
        binv = binv + v
    s = jnp.log1p(jnp.maximum(binv, 0.0)) if spec.log1p else binv
    diff = s - obs_t
    term = jnp.abs(diff) if kind.power == 1 else diff * diff
    acc = acc + flush_t * jnp.sum(weights * term, axis=-1)
    binv = binv * (1.0 - flush_t)
    return cum, binv, acc


def running_finalize(kind: DistanceKind, mean_scale: Array, acc: Array) -> Array:
    acc = acc * mean_scale
    return jnp.sqrt(acc) if kind.root else acc


def flush_columns(num_days: int, bin_days: int) -> np.ndarray:
    """Static day indices of the flush (bin-closing) columns, [n_bins] i64.

    These are the columns of the running-bin layout that hold actual summary
    values; everything else is an in-progress partial bin. bin_days == 1
    degenerates to every day."""
    t = np.arange(num_days)
    return t[((t + 1) % bin_days == 0) | (t == num_days - 1)]


def summary_features(
    spec: SummarySpec, series: Array, n_regions: int = 1
) -> Array:
    """Flatten a series to its summary FEATURE vector: [..., n_obs, T] ->
    [..., n_chan * n_bins].

    The conditioning-feature lowering used by the NPE backend
    (repro.core.npe): region-pool, apply the summary transform, then keep
    only the flush-day columns — exactly the values the running accumulator
    compares, so the features carry the same information the ABC distance
    sees. Applied identically to simulated batches ([B, n_obs, T]) and the
    observed side ([n_obs, T]); the flush-column gather is static (shape
    depends only on num_days/bin_days), so it traces under jit/vmap.
    """
    x = pool_channels(jnp.asarray(series, jnp.float32),
                      pool_factor(spec, n_regions), axis=-2)
    s = apply_summary(spec, x)
    cols = flush_columns(x.shape[-1], spec.bin_days)
    feats = s[..., cols]  # [..., n_chan, n_bins]
    return feats.reshape(feats.shape[:-2] + (-1,))


def summary_pairs() -> Tuple[Tuple[str, str], ...]:
    """Every registered (summary, distance) combination — the parity-test
    and benchmark sweep space."""
    return tuple(
        (s, d) for s in list_summaries() for d in sorted(DISTANCE_KINDS)
    )
