"""SMC-ABC: sequential Monte Carlo ABC with a decreasing tolerance schedule.

The paper (§2.2) notes that instead of a fixed threshold, SMC can transform an
initial sample set into a high-quality set with a decreasing sequence of
tolerances [Drovandi & Pettitt 2011; Warne et al. 2020]. This is the batched
ABC-PMC variant (Beaumont-style): every proposal wave is a full vectorized
batch, so the engine reuses the paper's parallel simulate->distance machinery.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abc import ABCConfig, compact_accepted, make_simulator, run_param_names
from repro.core.posterior import Posterior
from repro.core.priors import UniformBoxPrior, schedule_prior
from repro.epi.data import CountryData
from repro.epi.models import get_model
from repro.epi.spec import InterventionSchedule


@dataclasses.dataclass(frozen=True)
class SMCConfig:
    n_particles: int = 256
    batch_size: int = 4096  # proposals per wave
    n_rounds: int = 4
    quantile: float = 0.5  # eps_{t+1} = this quantile of current distances
    kernel_scale: float = 2.0  # Beaumont: perturbation var = scale * weighted var
    num_days: int = 49
    backend: str = "xla_fused"
    max_waves_per_round: int = 200
    min_tolerance: float = 0.0
    #: compartmental model to infer: a registry name or a CompartmentalModel
    #: spec object (ad-hoc regionalized metapop specs work unregistered)
    model: object = "siard"
    #: metapop models only: row-stochastic [R, R] mobility override (nested
    #: tuples), forwarded to the simulator (see ABCConfig.mobility)
    mobility: Optional[Tuple[Tuple[float, ...], ...]] = None
    #: distance kind over summary values (core.summaries.DISTANCE_KINDS)
    distance: str = "euclidean"
    #: summary statistic (SummarySpec / registry name / None = raw daily);
    #: lowered by every backend exactly as in rejection ABC
    summary: Optional[object] = None
    #: optional intervention schedule; particles widen with per-window scale
    #: columns (pinned zero-width scale dims are never perturbed)
    schedule: Optional[InterventionSchedule] = None
    #: Pallas dispatch override for backend="pallas" (see ABCConfig.interpret)
    interpret: Optional[bool] = None
    #: "host": numpy proposal loop with one device sync per wave (original
    #: structure). "device": each round's propose -> simulate -> accept loop
    #: is a single jitted lax.while_loop that fills the particle buffer
    #: on-device and syncs once per round. Streams differ (jax vs numpy RNG)
    #: but both are seeded and deterministic; statistical behaviour is pinned
    #: by tests/test_posterior_recovery.py.
    wave_loop: str = "host"
    #: optional warm start: seed round 0 from this particle set [N, p]
    #: (e.g. yesterday's cached posterior) instead of a fresh prior wave.
    #: The set is resampled by `initial_weights` (uniform when None) to
    #: exactly n_particles and re-simulated against the CURRENT dataset,
    #: so round 0 costs n_particles simulations instead of batch_size —
    #: the serving layer's daily re-fit path (repro.core.serving).
    initial_particles: Optional[object] = None
    #: importance weights of `initial_particles` (None = uniform)
    initial_weights: Optional[object] = None

    def __post_init__(self):
        if self.wave_loop not in ("host", "device"):
            raise ValueError(f"unknown wave_loop {self.wave_loop!r}")
        if self.initial_weights is not None and self.initial_particles is None:
            raise ValueError("initial_weights given without initial_particles")
        if self.initial_particles is not None:
            init = np.asarray(self.initial_particles, np.float32)
            if init.ndim != 2 or init.shape[0] == 0:
                raise ValueError(
                    f"initial_particles must be a non-empty [N, p] array, "
                    f"got shape {init.shape}"
                )
            if self.initial_weights is not None:
                w = np.asarray(self.initial_weights, np.float64)
                if w.shape != (init.shape[0],):
                    raise ValueError(
                        f"initial_weights shape {w.shape} does not match "
                        f"{init.shape[0]} initial particles"
                    )
                if (w < 0).any() or not np.isfinite(w).all() or w.sum() <= 0:
                    raise ValueError(
                        "initial_weights must be finite, non-negative and "
                        "sum to a positive value"
                    )


def _weighted_var(theta: np.ndarray, w: np.ndarray) -> np.ndarray:
    mu = np.average(theta, axis=0, weights=w)
    return np.average((theta - mu) ** 2, axis=0, weights=w) + 1e-12


def make_smc_round_fn(simulator, prior: UniformBoxPrior, cfg: SMCConfig):
    """Device-resident SMC proposal round (the SMC face of the ABC device
    wave loop): a jitted lax.while_loop that resamples parents by weight,
    perturbs, simulates and compacts acceptances into a fixed particle
    buffer until `n_particles` proposals are accepted or the wave budget is
    spent. Proposal semantics match the host loop (first-accepted-first, out
    of bounds / NaN rejected); only the RNG stream differs (threefry here).

    round_fn(key, particles [n,p], log_weights [n], sigma [p], eps,
             max_waves) -> (theta_buf, dist_buf, n_accepted, waves_done)
    """
    B, n_p = cfg.batch_size, cfg.n_particles
    lo = jnp.asarray(prior.lows, jnp.float32)
    hi = jnp.asarray(prior.highs, jnp.float32)
    cap = n_p + B  # a final wave's overshoot always fits

    def round_fn(key, particles, log_weights, sigma, eps, max_waves):
        p = particles.shape[1]

        def cond(carry):
            w, n, *_ = carry
            return jnp.logical_and(n < n_p, w < max_waves)

        def body(carry):
            w, n, th_buf, d_buf = carry
            k = jax.random.fold_in(key, w)
            k_par, k_pert, k_sim = jax.random.split(k, 3)
            parents = jax.random.categorical(k_par, log_weights, shape=(B,))
            prop = particles[parents] + sigma * jax.random.normal(
                k_pert, (B, p), jnp.float32
            )
            inside = jnp.all((prop >= lo) & (prop <= hi), axis=-1)
            d = simulator(prop, k_sim)
            d = jnp.where(jnp.isnan(d) | ~inside, jnp.inf, d)
            th_buf, d_buf, n = compact_accepted(
                th_buf, d_buf, n, prop, d, d <= eps, cap
            )
            return (w + 1, n, th_buf, d_buf)

        th0 = jnp.zeros((cap, p), jnp.float32)
        d0 = jnp.full((cap,), jnp.inf, jnp.float32)
        w, n, th_buf, d_buf = jax.lax.while_loop(
            cond, body, (jnp.int32(0), jnp.int32(0), th0, d0)
        )
        return th_buf, d_buf, n, w

    return jax.jit(round_fn)


def make_sharded_smc_round_fn(mesh, simulator, prior: UniformBoxPrior,
                              cfg: SMCConfig):
    """Multi-device SMC proposal round under the scaling study's sharding.

    Each device of the mesh proposes `batch_size / n_dev` particles per wave
    from the REPLICATED parent population (resampling and perturbation stay
    device-resident between waves, keyed by `fold_in(fold_in(key, w), dev)`),
    simulates its own sub-batch and compacts acceptances into its own buffer
    segment; the only steady-state collective is the per-wave psum of the
    scalar accept count feeding the shared stop condition — the exact
    property that bounds the ABC wave loop's scaling overhead.

    round_fn(key, particles [n,p], log_weights [n], sigma [p], eps,
             max_waves) -> (theta_buf [n_dev*cap, p], dist_buf, n_accepted,
                            waves_done, fills [n_dev])

    The sample stream differs from the single-device round (per-device key
    folds), but is deterministic in (key, mesh shape); statistical behaviour
    matches the host/device rounds (tests/test_scaling.py).
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.abc import compact_accepted as _compact
    from repro.core.distributed import data_axes, shard_map

    axes = data_axes(mesh)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    if cfg.batch_size % n_dev:
        raise ValueError(
            f"batch_size {cfg.batch_size} not divisible by {n_dev} devices"
        )
    B, n_p = cfg.batch_size // n_dev, cfg.n_particles
    lo = jnp.asarray(prior.lows, jnp.float32)
    hi = jnp.asarray(prior.highs, jnp.float32)
    cap = n_p + B  # a final wave's overshoot always fits per shard

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),
        out_specs=(P(axes), P(axes), P(), P(), P(axes)),
    )
    def round_fn(key, particles, log_weights, sigma, eps, max_waves):
        dev = jax.lax.axis_index(axes)
        p = particles.shape[1]

        def cond(carry):
            w, n, *_ = carry
            return jnp.logical_and(n < n_p, w < max_waves)

        def body(carry):
            w, n, fill, th_buf, d_buf = carry
            k = jax.random.fold_in(jax.random.fold_in(key, w), dev)
            k_par, k_pert, k_sim = jax.random.split(k, 3)
            parents = jax.random.categorical(k_par, log_weights, shape=(B,))
            prop = particles[parents] + sigma * jax.random.normal(
                k_pert, (B, p), jnp.float32
            )
            inside = jnp.all((prop >= lo) & (prop <= hi), axis=-1)
            d = simulator(prop, k_sim)
            d = jnp.where(jnp.isnan(d) | ~inside, jnp.inf, d)
            th_buf, d_buf, new_fill = _compact(
                th_buf, d_buf, fill, prop, d, d <= eps, cap
            )
            n = n + jax.lax.psum(new_fill - fill, axes)
            return (w + 1, n, new_fill, th_buf, d_buf)

        th0 = jnp.zeros((cap, p), jnp.float32)
        d0 = jnp.full((cap,), jnp.inf, jnp.float32)
        w, n, fill, th_buf, d_buf = jax.lax.while_loop(
            cond, body,
            (jnp.int32(0), jnp.int32(0), jnp.int32(0), th0, d0),
        )
        return th_buf, d_buf, n, w, jnp.minimum(fill, cap)[None]

    return jax.jit(round_fn)


def run_smc_abc(
    dataset: CountryData,
    cfg: SMCConfig,
    key: jax.Array | int = 0,
    prior: Optional[UniformBoxPrior] = None,
    verbose: bool = False,
    mesh=None,
) -> Posterior:
    """Returns the final particle population as a Posterior.

    With `mesh` (and `cfg.wave_loop == "device"`), each round's
    propose/simulate/accept loop is sharded across the mesh's devices with
    per-shard buffers and a psum'd stop condition
    (`make_sharded_smc_round_fn`) — the SMC face of the scaling study."""
    spec = get_model(cfg.model)
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    prior = prior or schedule_prior(spec, cfg.schedule)
    abc_cfg = ABCConfig(
        batch_size=cfg.batch_size,
        tolerance=np.inf,
        target_accepted=cfg.n_particles,
        strategy="topk",
        top_k=cfg.batch_size,
        num_days=cfg.num_days,
        backend=cfg.backend,
        model=cfg.model,
        schedule=cfg.schedule,
        interpret=cfg.interpret,
        distance=cfg.distance,
        summary=cfg.summary,
        mobility=cfg.mobility,
    )
    simulator = make_simulator(dataset, abc_cfg)
    sim_jit = jax.jit(simulator)
    round_fn = None
    sharded = mesh is not None
    if sharded and cfg.wave_loop != "device":
        raise ValueError("sharded SMC requires wave_loop='device'")
    if cfg.wave_loop == "device":
        round_fn = (
            make_sharded_smc_round_fn(mesh, simulator, prior, cfg)
            if sharded
            else make_smc_round_fn(simulator, prior, cfg)
        )
    lo = np.asarray(prior.lows, np.float32)
    hi = np.asarray(prior.highs, np.float32)
    # zero-width prior dims are point masses (pinned intervention scales):
    # they get no perturbation noise and stay out of the kernel density
    free = np.asarray(prior.free_dims(), bool)
    t0 = time.time()

    # --- round 0 -----------------------------------------------------------
    k0, key = jax.random.split(key)
    if cfg.initial_particles is not None:
        # warm start: resample the provided population by weight to exactly
        # n_particles and re-simulate it against the CURRENT dataset (the
        # data may have changed since the population was fitted) — round 0
        # costs n_particles simulations instead of a full prior wave
        init = np.asarray(cfg.initial_particles, np.float32)
        if init.shape[1] != lo.shape[0]:
            raise ValueError(
                f"initial_particles have width {init.shape[1]}; model "
                f"{cfg.model!r} with this schedule expects {lo.shape[0]}"
            )
        w0 = (
            np.asarray(cfg.initial_weights, np.float64)
            if cfg.initial_weights is not None
            else np.full(init.shape[0], 1.0)
        )
        w0 = w0 / w0.sum()
        # particles from a stale fit can sit marginally outside a changed
        # prior box; clip so their prior density (and kernel weights) stay
        # finite rather than silently zeroing the whole population
        init = np.clip(init, lo, hi)
        idx = np.asarray(
            jax.random.choice(
                k0, init.shape[0], shape=(cfg.n_particles,), replace=True,
                p=jnp.asarray(w0, jnp.float32),
            )
        )
        particles = init[idx]
        d0 = np.asarray(
            sim_jit(jnp.asarray(particles), jax.random.fold_in(key, 0))
        )
        dists = np.where(np.isnan(d0), np.inf, d0)
        sims = cfg.n_particles
    else:
        # cold start: prior wave, keep the best n_particles
        theta0 = prior.sample(k0, (cfg.batch_size,))
        d0 = np.asarray(sim_jit(theta0, jax.random.fold_in(key, 0)))
        d0 = np.where(np.isnan(d0), np.inf, d0)
        order = np.argsort(d0)[: cfg.n_particles]
        particles = np.asarray(theta0)[order]
        dists = d0[order]
        sims = cfg.batch_size
    weights = np.full(cfg.n_particles, 1.0 / cfg.n_particles)
    finite = dists[np.isfinite(dists)]
    eps = float(np.max(finite)) if finite.size else float("inf")

    rng = np.random.default_rng(np.asarray(jax.random.key_data(key))[-1])
    for rnd in range(1, cfg.n_rounds + 1):
        eps = max(float(np.quantile(dists, cfg.quantile)), cfg.min_tolerance)
        sigma = np.sqrt(cfg.kernel_scale * _weighted_var(particles, weights))
        sigma = np.where(free, sigma, 0.0).astype(np.float32)
        new_theta = np.zeros_like(particles)
        new_dist = np.full(cfg.n_particles, np.inf, np.float32)
        n_done = 0
        if round_fn is not None:
            # device-resident round: the whole propose/simulate/accept loop
            # runs in one jitted while_loop; a single host sync per round
            key, k_round = jax.random.split(key)
            logw = np.log(np.maximum(weights, 1e-38)).astype(np.float32)
            out = round_fn(
                k_round,
                jnp.asarray(particles),
                jnp.asarray(logw),
                jnp.asarray(sigma, jnp.float32),
                np.float32(eps),
                np.int32(cfg.max_waves_per_round),
            )
            if sharded:
                # gather the per-shard buffer segments in shard order (the
                # host re-entry of the sharded round); the global accept
                # count can exceed the kept population, like any overshoot
                th_buf, d_buf, n_acc, waves, fills = out
                th, d = np.asarray(th_buf), np.asarray(d_buf)
                fills = np.asarray(fills)
                cap = th.shape[0] // fills.shape[0]
                seg_th = [th[s * cap: s * cap + int(c)]
                          for s, c in enumerate(fills)]
                seg_d = [d[s * cap: s * cap + int(c)]
                         for s, c in enumerate(fills)]
                acc_th = np.concatenate(seg_th, axis=0)
                acc_d = np.concatenate(seg_d, axis=0)
                n_done = min(acc_th.shape[0], cfg.n_particles)
                sims += int(waves) * cfg.batch_size
                new_theta[:n_done] = acc_th[:n_done]
                new_dist[:n_done] = acc_d[:n_done]
            else:
                th_buf, d_buf, n_acc, waves = out
                n_done = min(int(n_acc), cfg.n_particles)
                sims += int(waves) * cfg.batch_size
                new_theta[:n_done] = np.asarray(th_buf)[:n_done]
                new_dist[:n_done] = np.asarray(d_buf)[:n_done]
        else:
            for wave in range(cfg.max_waves_per_round):
                # propose a full batch: resample parents by weight, perturb
                parents = rng.choice(cfg.n_particles, size=cfg.batch_size, p=weights)
                prop = particles[parents] + rng.normal(
                    0.0, sigma, size=(cfg.batch_size, particles.shape[1])
                ).astype(np.float32)
                inside = np.all((prop >= lo) & (prop <= hi), axis=1)
                key, kw = jax.random.split(key)
                d = np.asarray(sim_jit(jnp.asarray(prop), kw))
                d = np.where(np.isnan(d) | ~inside, np.inf, d)
                sims += cfg.batch_size
                ok = np.nonzero(d <= eps)[0]
                take = ok[: cfg.n_particles - n_done]
                if take.size:
                    sl = slice(n_done, n_done + take.size)
                    new_theta[sl] = prop[take]
                    new_dist[sl] = d[take]
                    n_done += take.size
                if n_done >= cfg.n_particles:
                    break
        if n_done < cfg.n_particles:
            # could not refresh the full population at this tolerance; keep
            # the best of old+new to stay robust (documented fallback)
            n_keep = cfg.n_particles - n_done
            keep = np.argsort(dists)[:n_keep]
            new_theta[n_done:] = particles[keep]
            new_dist[n_done:] = dists[keep]
        # weight update: w_i ∝ prior(theta_i) / sum_j w_j K(theta_i | theta_j)
        # (pinned dims divide by 1 — their diffs are exactly 0 — and are
        # excluded from the kernel normalization)
        denom_sig = np.where(free, sigma, 1.0)
        diff = (new_theta[:, None, :] - particles[None, :, :]) / denom_sig[None, None, :]
        log_k = -0.5 * np.sum(diff * diff, axis=-1)  # [new, old], up to const
        log_k -= np.sum(np.log(sigma[free]))  # kernel normalization (shared const)
        mx = log_k.max(axis=1, keepdims=True)
        denom = (weights[None, :] * np.exp(log_k - mx)).sum(axis=1)
        log_prior = np.asarray(prior.log_pdf(jnp.asarray(new_theta)))
        w = np.exp(log_prior - (np.log(denom) + mx[:, 0]))
        w = np.where(np.isfinite(w), w, 0.0)
        weights = w / w.sum() if w.sum() > 0 else np.full_like(w, 1.0 / len(w))
        particles, dists = new_theta, new_dist
        if verbose:
            print(
                f"[smc] round {rnd}: eps={eps:.4g} mean_dist={dists.mean():.4g} "
                f"ess={1.0 / np.sum(weights ** 2):.1f}"
            )

    return Posterior(
        theta=particles,
        distances=dists,
        tolerance=eps,
        param_names=run_param_names(abc_cfg, spec),
        runs=cfg.n_rounds,
        simulations=sims,
        wall_time_s=time.time() - t0,
        weights=weights,
    )
