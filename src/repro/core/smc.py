"""SMC-ABC: sequential Monte Carlo ABC with a decreasing tolerance schedule.

The paper (§2.2) notes that instead of a fixed threshold, SMC can transform an
initial sample set into a high-quality set with a decreasing sequence of
tolerances [Drovandi & Pettitt 2011; Warne et al. 2020]. This is the batched
ABC-PMC variant (Beaumont-style): every proposal wave is a full vectorized
batch, so the engine reuses the paper's parallel simulate->distance machinery.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.abc import ABCConfig, make_simulator
from repro.core.posterior import Posterior
from repro.core.priors import UniformBoxPrior
from repro.epi.data import CountryData
from repro.epi.models import get_model


@dataclasses.dataclass(frozen=True)
class SMCConfig:
    n_particles: int = 256
    batch_size: int = 4096  # proposals per wave
    n_rounds: int = 4
    quantile: float = 0.5  # eps_{t+1} = this quantile of current distances
    kernel_scale: float = 2.0  # Beaumont: perturbation var = scale * weighted var
    num_days: int = 49
    backend: str = "xla_fused"
    max_waves_per_round: int = 200
    min_tolerance: float = 0.0
    #: registry name of the compartmental model to infer (repro.epi.models)
    model: str = "siard"


def _weighted_var(theta: np.ndarray, w: np.ndarray) -> np.ndarray:
    mu = np.average(theta, axis=0, weights=w)
    return np.average((theta - mu) ** 2, axis=0, weights=w) + 1e-12


def run_smc_abc(
    dataset: CountryData,
    cfg: SMCConfig,
    key: jax.Array | int = 0,
    prior: Optional[UniformBoxPrior] = None,
    verbose: bool = False,
) -> Posterior:
    """Returns the final particle population as a Posterior."""
    spec = get_model(cfg.model)
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    prior = prior or spec.prior()
    abc_cfg = ABCConfig(
        batch_size=cfg.batch_size,
        tolerance=np.inf,
        target_accepted=cfg.n_particles,
        strategy="topk",
        top_k=cfg.batch_size,
        num_days=cfg.num_days,
        backend=cfg.backend,
        model=cfg.model,
    )
    simulator = make_simulator(dataset, abc_cfg)
    sim_jit = jax.jit(simulator)
    lo = np.asarray(prior.lows, np.float32)
    hi = np.asarray(prior.highs, np.float32)
    t0 = time.time()

    # --- round 0: prior wave, keep the best n_particles --------------------
    k0, key = jax.random.split(key)
    theta0 = prior.sample(k0, (cfg.batch_size,))
    d0 = np.asarray(sim_jit(theta0, jax.random.fold_in(key, 0)))
    d0 = np.where(np.isnan(d0), np.inf, d0)
    order = np.argsort(d0)[: cfg.n_particles]
    particles = np.asarray(theta0)[order]
    dists = d0[order]
    weights = np.full(cfg.n_particles, 1.0 / cfg.n_particles)
    eps = float(np.max(dists))
    sims = cfg.batch_size

    rng = np.random.default_rng(np.asarray(jax.random.key_data(key))[-1])
    for rnd in range(1, cfg.n_rounds + 1):
        eps = max(float(np.quantile(dists, cfg.quantile)), cfg.min_tolerance)
        sigma = np.sqrt(cfg.kernel_scale * _weighted_var(particles, weights))
        new_theta = np.zeros_like(particles)
        new_dist = np.full(cfg.n_particles, np.inf, np.float32)
        new_parent_logk = np.zeros(cfg.n_particles, np.float32)
        n_done = 0
        for wave in range(cfg.max_waves_per_round):
            # propose a full batch: resample parents by weight, gaussian perturb
            parents = rng.choice(cfg.n_particles, size=cfg.batch_size, p=weights)
            prop = particles[parents] + rng.normal(
                0.0, sigma, size=(cfg.batch_size, particles.shape[1])
            ).astype(np.float32)
            inside = np.all((prop >= lo) & (prop <= hi), axis=1)
            key, kw = jax.random.split(key)
            d = np.asarray(sim_jit(jnp.asarray(prop), kw))
            d = np.where(np.isnan(d) | ~inside, np.inf, d)
            sims += cfg.batch_size
            ok = np.nonzero(d <= eps)[0]
            take = ok[: cfg.n_particles - n_done]
            if take.size:
                sl = slice(n_done, n_done + take.size)
                new_theta[sl] = prop[take]
                new_dist[sl] = d[take]
                n_done += take.size
            if n_done >= cfg.n_particles:
                break
        if n_done < cfg.n_particles:
            # could not refresh the full population at this tolerance; keep
            # the best of old+new to stay robust (documented fallback)
            n_keep = cfg.n_particles - n_done
            keep = np.argsort(dists)[:n_keep]
            new_theta[n_done:] = particles[keep]
            new_dist[n_done:] = dists[keep]
        # weight update: w_i ∝ prior(theta_i) / sum_j w_j K(theta_i | theta_j)
        diff = (new_theta[:, None, :] - particles[None, :, :]) / sigma[None, None, :]
        log_k = -0.5 * np.sum(diff * diff, axis=-1)  # [new, old], up to const
        log_k -= np.sum(np.log(sigma))  # kernel normalization (shared const)
        mx = log_k.max(axis=1, keepdims=True)
        denom = (weights[None, :] * np.exp(log_k - mx)).sum(axis=1)
        log_prior = np.asarray(prior.log_pdf(jnp.asarray(new_theta)))
        w = np.exp(log_prior - (np.log(denom) + mx[:, 0]))
        w = np.where(np.isfinite(w), w, 0.0)
        weights = w / w.sum() if w.sum() > 0 else np.full_like(w, 1.0 / len(w))
        particles, dists = new_theta, new_dist
        if verbose:
            print(
                f"[smc] round {rnd}: eps={eps:.4g} mean_dist={dists.mean():.4g} "
                f"ess={1.0 / np.sum(weights ** 2):.1f}"
            )

    post = Posterior(
        theta=particles,
        distances=dists,
        tolerance=eps,
        param_names=spec.param_names,
        runs=cfg.n_rounds,
        simulations=sims,
        wall_time_s=time.time() - t0,
    )
    post.weights = weights  # type: ignore[attr-defined]
    return post
