"""Amortized inference: neural posterior estimation over the tau-leap engine.

The ABC/SMC backends pay ~1e6 simulations PER POSTERIOR FIT; the NPE line of
work (PAPERS.md: the SBI-vs-MCMC comparison, NPE for stochastic epidemic
models, the SBI-methods assessment) converges on the amortized alternative:
train a conditional density estimator q(theta | x) ONCE on simulator output,
then posterior inference for any new observed series is a single forward
pass — no waves, no tolerance schedule. This repo already owned every
ingredient; this module only wires them together:

  * the tau-leap engine is an infinite training-set generator —
    `epi.engine.simulate_features` yields device-resident batches of
    `(theta ~ prior, x = summary(simulate(theta)))` pairs, one jitted call
    per training step, so no dataset is ever materialized on disk;
  * `core.summaries` provides the conditioning features: the SAME flush-day
    summary values the ABC running accumulator compares
    (`summary_features`), so the estimator conditions on exactly the
    statistic the ABC distance sees;
  * the estimator is a small mixture-density network built from
    `models.common` blocks (layer_norm + GELU MLP residual blocks) with a
    K-component diagonal-Gaussian head over box-standardized theta,
    optimized with the repo's own AdamW (`optim.adamw`).

Entry points:

  * `train_npe(dataset, cfg, key)`   — train an `NPEstimator` for an
    `ABCConfig(backend="npe")`; the dataset contributes its scalars
    (population, a0, r0, d0) to the simulator, NOT its observed series —
    the estimator amortizes over observation content.
  * `NPEstimator.sample_posterior(observed, n)` — one forward pass + n
    mixture draws; returns the same `Posterior` object ABC produces
    (`distances` holds the negative log-density of each draw, so
    `Posterior.top(k)` selects the highest-density samples; `tolerance` is
    0.0 — there is no epsilon), so `PosteriorStore`, `serve --epi`,
    forecasting and the campaign consumers work unchanged.
  * `fine_tune(est, dataset, key)`   — a short continuation of training on
    fresh simulations: the serving layer's re-fit path
    (`abc_serve --backend npe`), replacing a full wave campaign with
    `NPEConfig.fine_tune_steps` gradient steps (0 = pure forward pass).
  * `run_npe(dataset, cfg, key)`     — the `run_abc` face: train + sample
    `cfg.target_accepted` draws conditioned on the dataset's observed
    series. `core.abc.run_abc` dispatches here for `backend="npe"`.

Accuracy is validated against the ABC posterior as oracle: on the
tests/test_posterior_recovery.py fixtures the NPE credible intervals must
overlap the ABC intervals and the posterior means must agree within
prior-width bounds. Determinism: training and sampling are threefry-keyed
jitted programs, so a fixed seed reproduces the estimator and its samples
exactly (pinned by tests).
"""

from __future__ import annotations

import dataclasses
import json
import time
import zipfile
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.posterior import Posterior
from repro.core.priors import UniformBoxPrior, schedule_prior
from repro.core.summaries import SummarySpec, get_summary, summary_features
from repro.epi import engine
from repro.epi.data import CountryData
from repro.epi.models import get_model
from repro.epi.spec import InterventionSchedule
from repro.ioutils import atomic_write
from repro.models.common import layer_norm, ninit, vanilla_mlp
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

Array = jax.Array

#: fold_in salts separating the training / pilot / sampling key streams
_PILOT_SALT = 0x9112
_SAMPLE_SALT = 0x5A3D

#: softplus offset putting the initial component sigma near 0.45 — wide
#: enough to cover the unit box before training shapes it
_SIGMA0 = -0.4328


@dataclasses.dataclass(frozen=True)
class NPEConfig:
    """Training hyperparameters of the NPE backend (`ABCConfig.npe`).

    Defaults are sized for the CI container: a tiny MDN trained on ~1e5
    simulated pairs in seconds. Production fits raise `train_steps` /
    `train_batch` / `hidden`; everything stays device-resident either way.
    """

    #: gradient steps; each step simulates a FRESH `train_batch` of pairs
    train_steps: int = 400
    #: simulations per step (the infinite-training-set generator batch)
    train_batch: int = 256
    #: MLP width of the conditioning trunk
    hidden: int = 64
    #: residual (layer_norm -> GELU MLP) blocks after the input projection
    n_layers: int = 2
    #: mixture components of the diagonal-Gaussian head
    n_components: int = 4
    lr: float = 3e-3
    weight_decay: float = 1e-4
    #: floor on component sigmas (box-standardized units)
    sigma_min: float = 1e-3
    #: prior-predictive simulations used to standardize the features once
    n_pilot: int = 512
    #: gradient steps of a serving re-fit (`fine_tune`); 0 makes a dataset
    #: refresh a pure forward pass
    fine_tune_steps: int = 100
    fine_tune_lr: float = 1e-3

    def __post_init__(self):
        if self.train_steps < 1:
            raise ValueError(f"train_steps must be >= 1, got {self.train_steps}")
        if self.train_batch < 2:
            raise ValueError(f"train_batch must be >= 2, got {self.train_batch}")
        if self.hidden < 1 or self.n_layers < 0 or self.n_components < 1:
            raise ValueError(
                f"invalid MDN shape: hidden={self.hidden} "
                f"n_layers={self.n_layers} n_components={self.n_components}"
            )
        if self.fine_tune_steps < 0:
            raise ValueError(
                f"fine_tune_steps must be >= 0, got {self.fine_tune_steps}"
            )
        if self.sigma_min <= 0:
            raise ValueError(f"sigma_min must be > 0, got {self.sigma_min}")


def resolve_npe_config(npe) -> NPEConfig:
    """None -> defaults; validates the type loudly."""
    if npe is None:
        return NPEConfig()
    if not isinstance(npe, NPEConfig):
        raise TypeError(
            f"cfg.npe must be an NPEConfig or None, got {type(npe).__name__}"
        )
    return npe


# ----------------------------------------------------------------- MDN core
def mdn_init(key, n_features: int, n_params: int, cfg: NPEConfig) -> dict:
    """Initialize the mixture-density network parameters (f32 pytree).

    Trunk: input projection -> `n_layers` residual blocks (layer_norm +
    GELU MLP, `models.common` building blocks). Head: one linear layer to
    K * (1 + 2p) raw outputs (logits, means, sigma pre-activations). The
    head bias spreads the K component means across the unit box so the
    mixture starts diverse instead of collapsed.
    """
    K, p, H = cfg.n_components, n_params, cfg.hidden
    ks = jax.random.split(key, 2 + cfg.n_layers)
    f32 = jnp.float32
    blocks = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[2 + i])
        blocks.append({
            "ln_s": jnp.ones((H,), f32),
            "ln_b": jnp.zeros((H,), f32),
            "w1": ninit(k1, (H, 2 * H), dtype=f32),
            "b1": jnp.zeros((2 * H,), f32),
            "w2": ninit(k2, (2 * H, H), fan_in=2 * H, dtype=f32),
            "b2": jnp.zeros((H,), f32),
        })
    head_b = np.zeros((K * (1 + 2 * p),), np.float32)
    # component k's mean starts at (k + 0.5) / K on every standardized dim
    head_b[K : K + K * p] = np.repeat(
        (np.arange(K) + 0.5) / K - 0.5, p
    ).astype(np.float32)
    return {
        "in_w": ninit(ks[0], (n_features, H), dtype=f32),
        "in_b": jnp.zeros((H,), f32),
        "blocks": tuple(blocks),
        "head_w": ninit(ks[1], (H, K * (1 + 2 * p)), fan_in=H, dtype=f32),
        "head_b": jnp.asarray(head_b),
    }


def mdn_forward(
    params: dict, x: Array, cfg: NPEConfig, n_params: int
) -> Tuple[Array, Array, Array]:
    """x [..., F] -> (log_pi [..., K], mu [..., K, p], sigma [..., K, p]).

    mu is offset to the box center (0.5) and sigma floors at
    `cfg.sigma_min`, so an untrained net already emits a proper density
    over the standardized box.
    """
    K, p = cfg.n_components, n_params
    h = jax.nn.gelu((x @ params["in_w"] + params["in_b"]).astype(jnp.float32))
    for blk in params["blocks"]:
        h = h + vanilla_mlp(
            layer_norm(h, blk["ln_s"], blk["ln_b"]),
            blk["w1"], blk["b1"], blk["w2"], blk["b2"],
        )
    out = h @ params["head_w"] + params["head_b"]
    log_pi = jax.nn.log_softmax(out[..., :K], axis=-1)
    mu = 0.5 + out[..., K : K + K * p].reshape(out.shape[:-1] + (K, p))
    raw = out[..., K + K * p :].reshape(out.shape[:-1] + (K, p))
    sigma = cfg.sigma_min + jax.nn.softplus(raw + _SIGMA0)
    return log_pi, mu, sigma


def mdn_log_prob(
    params: dict, x: Array, theta_std: Array, cfg: NPEConfig, n_params: int
) -> Array:
    """Mixture log-density of box-standardized theta given features x.

    x [..., F], theta_std [..., p] -> [...]; the mixture is over K diagonal
    Gaussians, reduced with a logsumexp over components.
    """
    log_pi, mu, sigma = mdn_forward(params, x, cfg, n_params)
    t = theta_std[..., None, :]  # [..., 1, p]
    z = (t - mu) / sigma
    comp = -0.5 * jnp.sum(z * z, axis=-1) - jnp.sum(
        jnp.log(sigma), axis=-1
    ) - 0.5 * n_params * jnp.log(2.0 * jnp.pi)
    return jax.nn.logsumexp(log_pi + comp, axis=-1)


def mdn_sample(
    params: dict, x: Array, key: Array, n: int, cfg: NPEConfig, n_params: int
) -> Array:
    """Draw n standardized samples from q(theta | x) for ONE feature vector.

    x [F] -> theta_std [n, p]: categorical over components, then the
    component's diagonal Gaussian.
    """
    log_pi, mu, sigma = mdn_forward(params, x, cfg, n_params)
    k_c, k_n = jax.random.split(key)
    comp = jax.random.categorical(k_c, log_pi, shape=(n,))  # [n]
    eps = jax.random.normal(k_n, (n, n_params), jnp.float32)
    return mu[comp] + sigma[comp] * eps


# ------------------------------------------------------------- the estimator
@dataclasses.dataclass
class NPEstimator:
    """A trained amortized posterior q(theta | summary features).

    Tied to (model, num_days, summary, schedule, dataset scalars) — NOT to
    the observed series content: any new observation of the same shape is a
    forward pass. `sample_posterior` returns the standard `Posterior`
    container, so every downstream consumer (store, server, forecasts,
    campaign reports) is oblivious to how the samples were produced.
    """

    model: str
    num_days: int
    summary: SummarySpec
    schedule: Optional[InterventionSchedule]
    npe: NPEConfig
    param_names: Tuple[str, ...]
    lows: np.ndarray  # [p] prior box (widened for the schedule)
    highs: np.ndarray  # [p]
    feat_mean: np.ndarray  # [F] pilot standardization
    feat_std: np.ndarray  # [F]
    params: dict  # MDN pytree
    train_steps_done: int = 0
    train_sims: int = 0
    train_wall_s: float = 0.0
    final_loss: float = float("nan")

    @property
    def n_params(self) -> int:
        return int(self.lows.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.feat_mean.shape[0])

    def _widths(self) -> np.ndarray:
        # zero-width (pinned) dims train/sample at a constant 0 in
        # standardized space; the clamp only guards the division
        return np.maximum(self.highs - self.lows, 1e-6)

    def features_of(self, observed) -> np.ndarray:
        """Observed series [n_obs, T>=num_days] -> standardized features [F]."""
        obs = np.asarray(observed, np.float32)[:, : self.num_days]
        if obs.shape[-1] < self.num_days:
            raise ValueError(
                f"observed series has {obs.shape[-1]} days; this estimator "
                f"conditions on {self.num_days}"
            )
        spec = get_model(self.model)
        x = np.asarray(summary_features(self.summary, obs, spec.n_regions))
        if x.shape != self.feat_mean.shape:
            raise ValueError(
                f"observed summary has {x.shape[0]} features; estimator was "
                f"trained on {self.n_features} (wrong channels or summary?)"
            )
        return (x - self.feat_mean) / self.feat_std

    def sample_posterior(self, observed, n: int, key: Array | int = 0) -> Posterior:
        """n posterior draws conditioned on an observed series — one forward
        pass, zero simulations.

        Returns a `Posterior` whose `distances` hold each draw's NEGATIVE
        log-density under the estimator (so `top(k)` picks the densest
        samples), `tolerance` 0.0, and `simulations` the cumulative TRAINING
        cost — the amortized denominator, unchanged by queries.
        """
        t0 = time.time()
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        key = jax.random.fold_in(key, _SAMPLE_SALT)
        x = jnp.asarray(self.features_of(observed))
        t_std = mdn_sample(self.params, x, key, int(n), self.npe, self.n_params)
        t_std = jnp.clip(t_std, 0.0, 1.0)
        nlq = -mdn_log_prob(self.params, x, t_std, self.npe, self.n_params)
        theta = np.asarray(t_std) * self._widths() + self.lows
        theta = np.clip(theta, self.lows, self.highs)
        return Posterior(
            theta=theta,
            distances=np.asarray(nlq, np.float32),
            tolerance=0.0,
            param_names=self.param_names,
            runs=0,
            simulations=self.train_sims,
            wall_time_s=time.time() - t0,
        )

    def log_prob(self, observed, theta) -> np.ndarray:
        """Standardized-space log q(theta | observed) per row of theta [N, p]."""
        x = jnp.asarray(self.features_of(observed))
        t_std = (np.asarray(theta, np.float32) - self.lows) / self._widths()
        return np.asarray(
            mdn_log_prob(self.params, x, jnp.asarray(t_std), self.npe,
                         self.n_params)
        )

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        """Atomic .npz save (shared `repro.ioutils.atomic_write` semantics:
        a crash mid-write never leaves a truncated estimator where the
        serving layer reads). Params are stored as canonically-flattened
        leaves; the structure is rebuilt from the config at load."""
        meta = {
            "model": self.model,
            "num_days": self.num_days,
            "summary": dataclasses.asdict(self.summary),
            "schedule": None if self.schedule is None
            else dataclasses.asdict(self.schedule),
            "npe": dataclasses.asdict(self.npe),
            "param_names": list(self.param_names),
            "train_steps_done": int(self.train_steps_done),
            "train_sims": int(self.train_sims),
            "train_wall_s": float(self.train_wall_s),
            "final_loss": float(self.final_loss)
            if np.isfinite(self.final_loss) else None,
        }
        leaves = jax.tree.leaves(self.params)
        arrays = {
            "meta": np.asarray(json.dumps(meta)),
            "lows": self.lows, "highs": self.highs,
            "feat_mean": self.feat_mean, "feat_std": self.feat_std,
        }
        for i, leaf in enumerate(leaves):
            arrays[f"leaf_{i:03d}"] = np.asarray(leaf, np.float32)
        with atomic_write(path, "wb") as f:
            np.savez(f, **arrays)

    @staticmethod
    def load(path: str) -> "NPEstimator":
        """Load a saved estimator; corrupt/truncated files raise ValueError
        with a remediation hint (the Posterior.load contract); a missing
        file propagates FileNotFoundError untouched."""
        try:
            z = np.load(path, allow_pickle=False)
            meta = json.loads(str(z["meta"]))
            npe_cfg = NPEConfig(**meta["npe"])
            summary = SummarySpec(**{
                k: tuple(v) if isinstance(v, list) else v
                for k, v in meta["summary"].items()
            })
            sched = meta["schedule"]
            if sched is not None:
                sched = InterventionSchedule(
                    tv_params=tuple(sched["tv_params"]),
                    breakpoints=tuple(sched["breakpoints"]),
                    scale_lows=tuple(map(tuple, sched["scale_lows"])),
                    scale_highs=tuple(map(tuple, sched["scale_highs"])),
                )
            lows = np.asarray(z["lows"], np.float32)
            feat_mean = np.asarray(z["feat_mean"], np.float32)
            template = mdn_init(
                jax.random.PRNGKey(0), feat_mean.shape[0], lows.shape[0],
                npe_cfg,
            )
            treedef = jax.tree.structure(template)
            n_leaves = treedef.num_leaves
            leaves = [
                jnp.asarray(z[f"leaf_{i:03d}"], jnp.float32)
                for i in range(n_leaves)
            ]
            t_leaves = jax.tree.leaves(template)
            for got, want in zip(leaves, t_leaves):
                if got.shape != want.shape:
                    raise ValueError(
                        f"leaf shape {got.shape} != expected {want.shape}"
                    )
            est = NPEstimator(
                model=str(meta["model"]),
                num_days=int(meta["num_days"]),
                summary=summary,
                schedule=sched,
                npe=npe_cfg,
                param_names=tuple(meta["param_names"]),
                lows=lows,
                highs=np.asarray(z["highs"], np.float32),
                feat_mean=feat_mean,
                feat_std=np.asarray(z["feat_std"], np.float32),
                params=jax.tree.unflatten(treedef, leaves),
                train_steps_done=int(meta["train_steps_done"]),
                train_sims=int(meta["train_sims"]),
                train_wall_s=float(meta["train_wall_s"]),
                final_loss=float("nan") if meta["final_loss"] is None
                else float(meta["final_loss"]),
            )
        except FileNotFoundError:
            raise
        except (zipfile.BadZipFile, OSError, KeyError, ValueError,
                TypeError, json.JSONDecodeError) as e:
            raise ValueError(
                f"corrupt or incomplete NPE estimator file {path!r} ({e}); "
                "it was probably truncated by an interrupted save — delete "
                "it to re-train from scratch"
            ) from e
        return est


# ------------------------------------------------------------------ training
def _train_setup(dataset: CountryData, cfg, prior: Optional[UniformBoxPrior]):
    """Shared resolution for train_npe / fine_tune: (spec, prior, mcfg,
    mobility, summary, npe_cfg). Validates dataset/model compatibility the
    way make_simulator does."""
    from repro.core.abc import resolved_mobility

    spec = get_model(cfg.model)
    if not dataset.compatible_with(spec):
        raise ValueError(
            f"dataset {dataset.name!r} holds {dataset.model!r} series; model "
            f"{spec.name!r} observes different channels"
        )
    prior = prior or schedule_prior(spec, cfg.schedule)
    mcfg = dataset.model_config(cfg.num_days)
    mob = resolved_mobility(cfg, spec)
    return spec, prior, mcfg, mob, cfg.summary_spec, resolve_npe_config(cfg.npe)


def _make_train_step(spec, prior, mcfg, schedule, summary, mobility,
                     npe_cfg: NPEConfig, opt_cfg: AdamWConfig,
                     lows, highs, feat_mean, feat_std):
    """One jitted training step: simulate a fresh batch of pairs, take one
    AdamW step on the MDN negative log-likelihood."""
    # analysis: allow(scalar-closure-capture) — n_params sizes the MDN head
    # reshape (shape-determining, MUST be a compile constant), and the step
    # is built once per estimator whose parameter count never changes
    n_params = int(lows.shape[0])
    lo = jnp.asarray(lows, jnp.float32)
    width = jnp.asarray(np.maximum(highs - lows, 1e-6), jnp.float32)
    mu_x = jnp.asarray(feat_mean, jnp.float32)
    sd_x = jnp.asarray(feat_std, jnp.float32)

    def loss_fn(params, theta, feats):
        x = (feats - mu_x) / sd_x
        t_std = (theta - lo) / width
        return -jnp.mean(
            mdn_log_prob(params, x, t_std, npe_cfg, n_params)
        )

    @jax.jit
    def step(params, opt_state, key):
        k_prior, k_sim = jax.random.split(key)
        theta = prior.sample(k_prior, (npe_cfg.train_batch,))
        feats = engine.simulate_features(
            spec, theta, k_sim, mcfg, schedule, None, summary, mobility
        )
        loss, grads = jax.value_and_grad(loss_fn)(params, theta, feats)
        params, opt_state, _ = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, loss

    return step


def _pilot_stats(spec, prior, mcfg, schedule, summary, mobility,
                 npe_cfg: NPEConfig, key):
    """Feature standardization from one prior-predictive pilot batch.

    Computed ONCE at training time and frozen into the estimator —
    fine-tuning continues under the same normalization, so the trained
    trunk weights stay valid."""
    k1, k2 = jax.random.split(jax.random.fold_in(key, _PILOT_SALT))
    theta = prior.sample(k1, (npe_cfg.n_pilot,))
    feats = np.asarray(engine.simulate_features(
        spec, theta, k2, mcfg, schedule, None, summary, mobility
    ))
    mean = feats.mean(axis=0).astype(np.float32)
    std = np.maximum(feats.std(axis=0), 1e-3).astype(np.float32)
    return mean, std


def train_npe(
    dataset: CountryData,
    cfg,
    key: Array | int = 0,
    prior: Optional[UniformBoxPrior] = None,
    verbose: bool = False,
) -> NPEstimator:
    """Train an amortized posterior for `ABCConfig(backend="npe")`.

    Every training step simulates a FRESH `npe.train_batch` of
    (theta, features) pairs inside the jitted step — the engine as an
    infinite training-set generator. Total simulation cost:
    `n_pilot + train_steps * train_batch`, paid once; afterwards every
    posterior query is a forward pass.
    """
    t0 = time.time()
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    spec, prior, mcfg, mob, summary, npe_cfg = _train_setup(
        dataset, cfg, prior
    )
    schedule = cfg.schedule
    lows = np.asarray(prior.lows, np.float32)
    highs = np.asarray(prior.highs, np.float32)
    feat_mean, feat_std = _pilot_stats(
        spec, prior, mcfg, schedule, summary, mob, npe_cfg, key
    )
    params = mdn_init(
        jax.random.fold_in(key, 0), feat_mean.shape[0], lows.shape[0], npe_cfg
    )
    opt_cfg = AdamWConfig(
        lr=npe_cfg.lr, weight_decay=npe_cfg.weight_decay,
        warmup_steps=max(1, npe_cfg.train_steps // 20),
        total_steps=npe_cfg.train_steps,
    )
    step = _make_train_step(
        spec, prior, mcfg, schedule, summary, mob, npe_cfg, opt_cfg,
        lows, highs, feat_mean, feat_std,
    )
    opt_state = adamw_init(params)
    loss = None
    for i in range(npe_cfg.train_steps):
        params, opt_state, loss = step(
            params, opt_state, jax.random.fold_in(key, i + 1)
        )
        if verbose and (i + 1) % 100 == 0:
            print(f"[npe] step {i + 1}/{npe_cfg.train_steps}: "
                  f"nll {float(loss):.3f}")
    from repro.core.abc import run_param_names

    return NPEstimator(
        model=spec.name,
        num_days=cfg.num_days,
        summary=summary,
        schedule=schedule,
        npe=npe_cfg,
        param_names=tuple(run_param_names(cfg, spec)),
        lows=lows,
        highs=highs,
        feat_mean=feat_mean,
        feat_std=feat_std,
        params=params,
        train_steps_done=npe_cfg.train_steps,
        train_sims=npe_cfg.n_pilot
        + npe_cfg.train_steps * npe_cfg.train_batch,
        train_wall_s=time.time() - t0,
        final_loss=float(loss) if loss is not None else float("nan"),
    )


def fine_tune(
    est: NPEstimator,
    dataset: CountryData,
    key: Array | int = 0,
    steps: Optional[int] = None,
    verbose: bool = False,
) -> NPEstimator:
    """Continue training an estimator for a few steps on fresh simulations.

    The serving re-fit path: when a dataset's content version moves, the
    stored estimator needs no full re-train — the posterior conditions on
    the NEW observed features at query time — but a short fine-tune keeps
    the density head sharp against simulator drift (e.g. updated dataset
    scalars). `steps` defaults to `est.npe.fine_tune_steps`; 0 returns the
    estimator unchanged (a pure forward-pass refresh). Feature
    standardization and the prior box are frozen from the original
    training.
    """
    steps = est.npe.fine_tune_steps if steps is None else int(steps)
    if steps == 0:
        return est
    t0 = time.time()
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    spec = get_model(est.model)
    if not dataset.compatible_with(spec):
        raise ValueError(
            f"dataset {dataset.name!r} holds {dataset.model!r} series; "
            f"estimator was trained for {est.model!r}"
        )
    prior = UniformBoxPrior(highs=tuple(est.highs), lows=tuple(est.lows))
    mcfg = dataset.model_config(est.num_days)
    opt_cfg = AdamWConfig(
        lr=est.npe.fine_tune_lr, weight_decay=est.npe.weight_decay,
        warmup_steps=1, total_steps=max(steps, 1),
    )
    step_fn = _make_train_step(
        spec, prior, mcfg, est.schedule, est.summary, None, est.npe, opt_cfg,
        est.lows, est.highs, est.feat_mean, est.feat_std,
    )
    params, opt_state, loss = est.params, adamw_init(est.params), None
    for i in range(steps):
        params, opt_state, loss = step_fn(
            params, opt_state, jax.random.fold_in(key, i + 1)
        )
    if verbose:
        print(f"[npe] fine-tuned {steps} steps: nll {float(loss):.3f}")
    return dataclasses.replace(
        est,
        params=params,
        train_steps_done=est.train_steps_done + steps,
        train_sims=est.train_sims + steps * est.npe.train_batch,
        train_wall_s=est.train_wall_s + (time.time() - t0),
        final_loss=float(loss) if loss is not None else est.final_loss,
    )


def run_npe(
    dataset: CountryData,
    cfg,
    key: Array | int = 0,
    prior: Optional[UniformBoxPrior] = None,
    verbose: bool = False,
) -> Posterior:
    """The `run_abc` face of the NPE backend: train, then sample
    `cfg.target_accepted` posterior draws conditioned on the dataset's
    observed series. `core.abc.run_abc` dispatches here for
    `ABCConfig(backend="npe")`; the returned `Posterior` carries the
    training simulations in `simulations` (the amortized cost) and the
    total wall time including training."""
    t0 = time.time()
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    est = train_npe(dataset, cfg, key, prior=prior, verbose=verbose)
    post = est.sample_posterior(
        dataset.observed[:, : cfg.num_days], cfg.target_accepted, key=key
    )
    post.wall_time_s = time.time() - t0
    return post
