"""Multi-scenario ABC campaign runner (the paper's §5 study, industrialized).

The paper demonstrates its throughput claims by running inference for three
countries; doing that by hand means one process per (country, model) pair.
A *campaign* fans a grid of scenarios — dataset x model x backend x seed —
across the host's devices in one process:

  * one compiled device-resident wave loop is REUSED for every scenario of
    the same (model, num_days, batch_size, backend) shape: the observed
    series and the (population, a0, r0, d0) scalars are traced arguments of
    a parametric simulator (`abc.make_parametric_simulator`), so sweeping
    countries and seeds never re-traces ("pallas" bakes its scalars into the
    kernel and is the documented exception — it compiles per dataset);
  * scenarios are placed round-robin over `jax.devices()` and advanced in
    interleaved segments, so independent scenarios overlap on a multi-device
    host while the per-scenario stream semantics stay identical to a solo
    `run_abc` call with the same seed;
  * every scenario checkpoints through the existing checkpointer
    (`repro.checkpoint`) — fixed-shape accept buffers plus a metadata dict —
    and resumes transparently: a finished scenario replays its recorded
    summary instead of re-running;
  * the aggregated report (JSON + table) carries per-scenario epsilon
    schedules, acceptance rates, wall clock and posterior summaries.

    from repro.core.campaign import CampaignConfig, run_campaign
    report = run_campaign(CampaignConfig(
        datasets=("italy", "new_zealand", "usa"),
        models=("siard", "seiard"),
    ))

CLI: `python -m repro.launch.abc_run --campaign ...` (see README).
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.abc import (
    ABCConfig,
    ABCState,
    ScenarioData,
    WaveRunner,
    build_wave_loop,
    make_parametric_simulator,
    make_simulator,
    run_param_names,
    scenario_data,
    wave_capacity,
)
from repro.core.priors import schedule_prior
from repro.core.summaries import get_summary
from repro.epi.data import get_dataset
from repro.epi.models import get_model
from repro.epi.spec import InterventionSchedule
from repro.ioutils import atomic_write_text


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One cell of the campaign grid.

    `model` is a registry name or a `CompartmentalModel` spec object — spec
    objects let a campaign sweep ad-hoc regionalized models (e.g.
    `regionalize(get_model("seir"), 100, "ring:0.1")`) without registering
    them; the spec's name tags the scenario and its checkpoint directory."""

    dataset: str
    model: object  # registry name (str) or CompartmentalModel spec
    backend: str = "xla_fused"
    seed: int = 0
    #: optional intervention schedule (lockdown-day x scale sweeps); cells
    #: whose schedules share a SHAPE share one compiled wave loop
    schedule: Optional[InterventionSchedule] = None
    #: summary statistic compared by `distance` (SummarySpec / registry
    #: name / None = the paper's raw daily trajectories)
    summary: Optional[object] = None
    #: distance kind (core.summaries.DISTANCE_KINDS); part of the scenario's
    #: identity so campaigns differing only in distance can never share a
    #: checkpoint directory
    distance: str = "euclidean"

    @property
    def model_tag(self) -> str:
        """Filesystem/JSON-safe model label (spec objects tag by name)."""
        return self.model if isinstance(self.model, str) else self.model.name

    @property
    def name(self) -> str:
        base = f"{self.dataset}__{self.model_tag}__{self.backend}__s{self.seed}"
        if self.schedule is not None and not self.schedule.is_empty:
            base += f"__{self.schedule.tag()}"
        spec = get_summary(self.summary)
        if not spec.is_identity:
            base += f"__{spec.tag()}"
        if self.distance != "euclidean":
            base += f"__{self.distance}"
        return base


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    """Grid spec + per-scenario ABC settings + campaign-level policy."""

    datasets: Tuple[str, ...]
    #: registry names and/or CompartmentalModel spec objects (ad-hoc
    #: regionalized models sweep without registration; see Scenario.model)
    models: Tuple[object, ...] = ("siard",)
    backends: Tuple[str, ...] = ("xla_fused",)
    seeds: Tuple[int, ...] = (0,)
    #: intervention-scenario grid axis: each entry is an InterventionSchedule
    #: or None (the constant-theta cell). Schedules sharing a shape — same
    #: window count and scaled params — share ONE compiled wave loop, because
    #: breakpoint days and scale bounds are traced scenario data; sweeping
    #: lockdown-day x post-lockdown-scale grids never re-traces.
    interventions: Tuple[Optional[InterventionSchedule], ...] = (None,)
    #: summary-statistic grid axis: SummarySpec instances or registry names
    #: (core.summaries.SUMMARIES); None is the raw-trajectory cell. The
    #: Pallas kernel itself compiles once across summary cells (weights and
    #: selectors are runtime lanes); the surrounding wave loop bakes the
    #: static spec into its closure, so each distinct summary gets its own
    #: (cheap) wave-loop trace — one shape-cache entry per summary cell.
    summaries: Tuple[Optional[object], ...] = (None,)
    #: distance kind shared by every cell (core.summaries.DISTANCE_KINDS)
    distance: str = "euclidean"
    #: Pallas dispatch override for backend="pallas" cells (ABCConfig.interpret)
    interpret: Optional[bool] = None
    # per-scenario ABC shape (shared across the grid so compilations are
    # reusable; the tolerance is per-scenario)
    batch_size: int = 8192
    num_days: int = 49
    target_accepted: int = 100
    max_runs: int = 10_000
    #: fixed epsilon for every scenario; None auto-calibrates per scenario
    tolerance: Optional[float] = None
    #: pilot-quantile for auto-calibration (expected acceptance rate)
    auto_quantile: float = 1e-3
    pilot_size: int = 8192
    # campaign policy
    out_dir: str = "experiments/campaigns/default"
    #: waves per device segment between checkpoints (0 = single segment,
    #: i.e. checkpoint only on completion)
    checkpoint_every: int = 32
    keep_checkpoints: int = 2
    #: grid cells whose model cannot fit the dataset's observed channels are
    #: recorded as "skipped" instead of failing the whole campaign
    skip_incompatible: bool = True
    #: devices per scenario: 1 (default) places one scenario per device and
    #: interleaves; k > 1 carves jax.devices() into DISJOINT groups of k and
    #: runs each scenario's wave loop sharded across its group
    #: (distributed.make_shardmap_scenario_runner) — independent scenarios
    #: still advance concurrently, now each at multi-device throughput. The
    #: per-scenario sample stream matches a solo sharded run of the same
    #: seed/mesh shape (per-shard key folds), not the 1-device stream.
    devices_per_scenario: int = 1
    #: hot-path tuning knobs, threaded into every scenario's ABCConfig
    #: (repro.core.tuning): explicit Pallas tile / xla_fused scan unroll, or
    #: autotune=True to pull the measured winners from the tuning cache at
    #: simulator-build time. All are pure scheduling — accepted sets are
    #: unchanged — so scenario checkpoints stay compatible across settings.
    tile: Optional[int] = None
    scan_unroll: Optional[int] = None
    autotune: bool = False

    def __post_init__(self):
        if self.devices_per_scenario < 1:
            raise ValueError("devices_per_scenario must be >= 1")
        if self.devices_per_scenario > 1 and "pallas" in self.backends:
            # the pallas simulator bakes its scalars into the kernel and is
            # not lowered under shard_map here
            raise ValueError(
                "devices_per_scenario > 1 does not support the pallas "
                "backend; drop it from the grid or run serially"
            )

    def scenarios(self) -> List[Scenario]:
        return [
            Scenario(dataset=d, model=m, backend=b, seed=s, schedule=iv,
                     summary=su, distance=self.distance)
            for d in self.datasets
            for m in self.models
            for b in self.backends
            for s in self.seeds
            for iv in self.interventions
            for su in self.summaries
        ]

    def abc_config(self, sc: Scenario, tolerance: float) -> ABCConfig:
        return ABCConfig(
            batch_size=self.batch_size,
            tolerance=tolerance,
            target_accepted=self.target_accepted,
            strategy="outfeed",
            chunk_size=self.batch_size,
            max_runs=self.max_runs,
            num_days=self.num_days,
            backend=sc.backend,
            model=sc.model,
            wave_loop="device",
            schedule=sc.schedule,
            interpret=self.interpret,
            summary=sc.summary,
            distance=sc.distance,
            # tuning knobs apply only where they are meaningful: the tile to
            # pallas cells, the scan unroll to xla_fused cells
            tile=self.tile if sc.backend == "pallas" else None,
            scan_unroll=self.scan_unroll if sc.backend == "xla_fused" else None,
            autotune=self.autotune,
        )


@dataclasses.dataclass
class ScenarioResult:
    name: str
    dataset: str
    model: str
    backend: str
    seed: int
    status: str  # "ok" | "budget_exhausted" | "skipped" | "resumed_complete"
    tolerance: Optional[float] = None  # None until calibrated (skipped cells)
    eps_schedule: Tuple[float, ...] = ()
    n_accepted: int = 0
    runs: int = 0
    simulations: int = 0
    acceptance_rate: float = 0.0
    wall_time_s: float = 0.0
    posterior_mean: Dict[str, float] = dataclasses.field(default_factory=dict)
    posterior_std: Dict[str, float] = dataclasses.field(default_factory=dict)
    checkpoint_dir: str = ""
    device: str = ""
    detail: str = ""


def _jsonable(obj):
    """Strict-JSON sanitizer: numpy scalars -> python, NaN/inf -> None."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        return f if np.isfinite(f) else None
    if isinstance(obj, np.integer):
        return int(obj)
    return obj


def schedule_shape_key(schedule: Optional[InterventionSchedule]) -> tuple:
    """The compile-relevant identity of an intervention schedule.

    () for None/empty, else (n_windows, tv_params): breakpoint DAYS and
    scale VALUES are runtime data (traced scalars / theta columns), so two
    schedules sharing this key share one compiled simulator. Used by both
    the campaign _ShapeCache and the serving layer's forecast kernel cache
    (repro.core.serving) so their reuse semantics can never drift apart.
    """
    if schedule is None or schedule.is_empty:
        return ()
    return (schedule.n_windows, schedule.tv_params)


@dataclasses.dataclass
class CampaignReport:
    """Aggregated campaign outcome; serialized to one JSON artifact."""

    config: Dict
    scenarios: List[ScenarioResult]
    wall_time_s: float = 0.0
    compiled_shapes: int = 0

    def save(self, path: str | Path) -> Path:
        payload = {
            "config": self.config,
            "wall_time_s": self.wall_time_s,
            "compiled_shapes": self.compiled_shapes,
            "scenarios": [dataclasses.asdict(r) for r in self.scenarios],
        }
        # allow_nan=False keeps the artifact strict JSON (a stray NaN/inf
        # would otherwise serialize as a non-JSON literal and break every
        # downstream consumer of the nightly artifact)
        return atomic_write_text(
            path, json.dumps(_jsonable(payload), indent=1, allow_nan=False)
        )

    def summary_table(self) -> str:
        headers = [
            "scenario", "status", "eps", "accepted", "runs", "acc_rate", "wall_s"
        ]
        rows = []
        for r in self.scenarios:
            rows.append([
                r.name, r.status,
                "-" if r.tolerance is None else f"{r.tolerance:.3g}",
                str(r.n_accepted), str(r.runs),
                f"{r.acceptance_rate:.2e}", f"{r.wall_time_s:.1f}",
            ])
        widths = [
            max(len(h), max((len(row[i]) for row in rows), default=0))
            for i, h in enumerate(headers)
        ]

        def fmt(row):
            return " | ".join(c.ljust(w) for c, w in zip(row, widths))

        lines = [fmt(headers), "-+-".join("-" * w for w in widths)]
        lines += [fmt(r) for r in rows]
        ok = sum(1 for r in self.scenarios if r.status in ("ok", "resumed_complete"))
        lines.append(
            f"{ok}/{len(self.scenarios)} scenarios complete, "
            f"{self.compiled_shapes} compiled shapes, "
            f"wall {self.wall_time_s:.1f}s"
        )
        return "\n".join(lines)


class _ShapeCache:
    """One compiled (wave loop, pilot) pair per unique scenario shape.

    Parametric backends (xla / xla_fused) key on (model, num_days,
    batch_size, backend) and take the dataset as traced arguments; pallas
    bakes the dataset scalars into the kernel, so its cache key includes the
    dataset and the entry closes over a per-dataset simulator.
    """

    def __init__(self, cfg: CampaignConfig):
        self.cfg = cfg
        self._entries: Dict[tuple, tuple] = {}

    @property
    def n_compiled(self) -> int:
        return len(self._entries)

    def key_of(self, sc: Scenario, group=None) -> tuple:
        # key on the RESOLVED spec (hashable by design), not the name: the
        # spec carries the region axis (n_regions, mobility, coupled), so a
        # 100-region scenario can never alias its single-region namesake's
        # compiled loop, while registered names still dedupe to one entry
        spec = get_model(sc.model)
        key = (spec, self.cfg.num_days, self.cfg.batch_size, sc.backend)
        if group is not None and len(group) > 1:
            # a sharded loop is compiled against its device group's mesh;
            # scenarios on the same group still share one compilation
            key += (tuple(d.id for d in group),)
        # only the schedule's SHAPE is compile-relevant: breakpoint days and
        # scale bounds are traced, so a lockdown-day x scale sweep maps to
        # one cache entry
        key += schedule_shape_key(sc.schedule)
        # the summary spec is baked (static) into the simulator closure, so
        # each summary cell owns a wave-loop entry; inside the pallas entry
        # the kernel itself still compiles once across summary cells because
        # weights/selectors ride runtime lanes
        key += (get_summary(sc.summary), sc.distance)
        if sc.backend == "pallas":
            # pallas bakes the dataset scalars (and schedule constants) into
            # the kernel — the documented per-dataset compile exception
            key += (sc.dataset, sc.schedule)
        return key

    def get(self, sc: Scenario, dataset, group=None) -> tuple:
        key = self.key_of(sc, group)
        if key in self._entries:
            return self._entries[key]
        spec = get_model(sc.model)
        prior = schedule_prior(spec, sc.schedule)
        # the loop's shape (batch, capacity, target) is tolerance-independent;
        # epsilon is a traced argument, so one compile serves every scenario
        shape_cfg = self.cfg.abc_config(sc, tolerance=1.0)
        if sc.backend == "pallas":
            # make_simulator resolves autotune internally (per dataset)
            sim = make_simulator(dataset, shape_cfg)
            sim_call = lambda th, k, _data: sim(th, k)  # noqa: E731
        else:
            if shape_cfg.autotune:
                from repro.core import tuning

                # tune against the FIRST dataset reaching this shape: the
                # knobs are shape-determined (the dataset is traced data)
                shape_cfg = tuning.resolve_tuned(dataset, shape_cfg)
            parametric = make_parametric_simulator(spec, shape_cfg)
            sim_call = parametric
        if group is not None and len(group) > 1:
            from jax.sharding import Mesh
            from repro.core.distributed import make_shardmap_scenario_runner

            mesh = Mesh(np.asarray(list(group)), ("data",))
            tmpl = make_shardmap_scenario_runner(mesh, prior, sim_call,
                                                 shape_cfg)
            fn, shards, capacity = tmpl.fn, tmpl.shards, tmpl.capacity
        else:
            loop = build_wave_loop(prior, sim_call, shape_cfg)
            fn = jax.jit(loop, donate_argnums=(2, 3))
            shards, capacity = 1, wave_capacity(shape_cfg)

        def pilot(key, data):
            # sample within the scenario's traced box (scale bounds may be
            # swept across cells sharing this cache entry)
            bounds = (
                (data.prior_lows, data.prior_highs)
                if isinstance(data, ScenarioData)
                else (None, None)
            )
            theta = prior.sample(jax.random.fold_in(key, 0),
                                 (self.cfg.pilot_size,), *bounds)
            return sim_call(theta, jax.random.fold_in(key, 1), data)

        entry = (fn, jax.jit(pilot), prior, spec, shards, capacity)
        self._entries[key] = entry
        return entry


class _ScenarioRun:
    """Driver state for one scenario: carry buffers, checkpointing, report."""

    def __init__(self, sc: Scenario, cfg: CampaignConfig, cache: _ShapeCache,
                 group, verbose: bool = False):
        self.sc = sc
        self.cfg = cfg
        self.verbose = verbose
        self.group = list(group)
        self.sharded = len(self.group) > 1
        self.device = self.group[0]
        device_label = (
            str(self.device) if not self.sharded
            else "+".join(str(d.id) for d in self.group)
        )
        self.result = ScenarioResult(
            name=sc.name, dataset=sc.dataset, model=sc.model_tag,
            backend=sc.backend, seed=sc.seed, status="pending",
            device=device_label,
        )
        self.done = False
        self._out = None
        self._t0 = time.time()

        try:
            self.dataset = get_dataset(sc.dataset, num_days=cfg.num_days,
                                       model=sc.model)
        except (ValueError, KeyError) as e:
            if not (cfg.skip_incompatible and isinstance(e, ValueError)):
                raise
            self.result.status = "skipped"
            self.result.detail = str(e)
            self.done = True
            return
        fn, pilot, prior, _, shards, capacity = cache.get(
            sc, self.dataset, self.group
        )
        self._pilot = pilot
        self._shards, self._capacity = shards, capacity
        ckpt_dir = Path(cfg.out_dir) / "checkpoints" / sc.name
        self.ckpt = Checkpointer(ckpt_dir, keep=cfg.keep_checkpoints)
        self.result.checkpoint_dir = str(ckpt_dir)
        self.key = jax.random.PRNGKey(sc.seed)

        shape_cfg = cfg.abc_config(sc, tolerance=1.0)
        # every backend gets the traced scenario tuple: the pallas simulator
        # ignores the dataset fields (they are baked into its kernel) but the
        # wave loop still samples theta from the traced prior box
        data = scenario_data(self.dataset, shape_cfg)
        self.state = ABCState(n_params=prior.dim)
        self.eps_schedule: List[float] = []
        restored_eps = self._try_restore(prior.dim, shape_cfg)
        if self.done:
            return  # finished scenario replayed from its checkpoint
        if restored_eps is not None:
            eps = restored_eps  # eps_schedule restored alongside
        elif cfg.tolerance is not None:
            eps = float(cfg.tolerance)
        else:
            eps = self._calibrate(data)
        if not self.eps_schedule:
            self.eps_schedule = [eps]
        self.abc_cfg = cfg.abc_config(sc, tolerance=eps)
        self.result.tolerance = eps
        self.result.eps_schedule = tuple(self.eps_schedule)
        self.runner = WaveRunner(
            fn=fn, capacity=capacity, shards=shards,
            n_params=prior.dim, cfg=self.abc_cfg, data=data,
        )
        if self.sharded:
            # shard_map + jit place the replicated inputs on the group's
            # mesh; committing them to one device would fight the placement
            self.carry = self.runner.init(self.state)
        else:
            self.carry = jax.device_put(self.runner.init(self.state),
                                        self.device)
            self.key = jax.device_put(self.key, self.device)

    # ------------------------------------------------------------- restore
    def _like_tree(self, n_params: int, shape_cfg: ABCConfig):
        rows = self._shards * self._capacity
        return {
            "theta_buf": np.zeros((rows, n_params), np.float32),
            "dist_buf": np.zeros((rows,), np.float32),
        }

    def _try_restore(self, n_params: int, shape_cfg: ABCConfig):
        """Load the newest checkpoint, if any. Returns the stored epsilon
        (resume) or None (fresh start); sets self.done for finished runs."""
        if not self.ckpt.steps():
            return None
        try:
            tree, meta, _ = self.ckpt.restore(
                self._like_tree(n_params, shape_cfg)
            )
        except ValueError as e:
            if "shape mismatch" not in str(e):
                raise  # corrupt checkpoints still fail loudly
            # buffer layout changed since the checkpoint was written (e.g. a
            # different devices_per_scenario): start fresh instead of dying
            if self.verbose:
                print(f"[campaign] {self.sc.name}: checkpoint layout "
                      f"incompatible with current device group, restarting "
                      f"({e})")
            return None
        self.state.run_idx = int(meta["run_idx"])
        self.state.simulations = int(meta["simulations"])
        # per-shard segment fills (pre-group checkpoints stored one total)
        fills = meta.get("fills", [meta["fill"]])
        for s, c in enumerate(int(c) for c in fills):
            if c:
                lo = s * self._capacity
                self.state.accepted_theta.append(tree["theta_buf"][lo:lo + c])
                self.state.accepted_dist.append(tree["dist_buf"][lo:lo + c])
        self.eps_schedule = list(meta.get("eps_schedule", []))
        if meta.get("done"):
            self.result = ScenarioResult(**{
                **dataclasses.asdict(self.result), **meta["result"],
                "status": "resumed_complete", "device": self.result.device,
            })
            self.done = True
        return float(meta["tolerance"])

    def _calibrate(self, data) -> float:
        """Pilot wave -> epsilon at the configured quantile (the campaign's
        answer to the paper's hand-tuned per-country tolerances)."""
        pk = jax.random.fold_in(self.key, 0x7FFFFFFF)  # never a wave index
        d = np.asarray(self._pilot(pk, data))
        d = d[np.isfinite(d)]
        if d.size == 0:
            raise ValueError(f"{self.sc.name}: pilot produced no finite distances")
        return float(np.quantile(d, self.cfg.auto_quantile))

    # ------------------------------------------------------------- driving
    def launch(self):
        """Dispatch one segment (async); syncs happen in complete_segment."""
        seg = self.abc_cfg.max_runs - self.state.run_idx
        if self.cfg.checkpoint_every:
            seg = min(seg, self.cfg.checkpoint_every)
        self._out = self.runner(self.key, self.state.run_idx, self.carry, seg)

    def complete_segment(self):
        out, self._out = self._out, None
        waves = int(out.waves_done)
        self.state.run_idx += waves
        self.state.simulations += waves * self.cfg.batch_size
        self.carry = self.runner.carry_of(out)
        n_acc = int(out.n_accepted)
        hit_target = n_acc >= self.cfg.target_accepted
        exhausted = self.state.run_idx >= self.abc_cfg.max_runs
        if hit_target or exhausted:
            self.done = True
            self.runner.harvest(out, self.state)
            self._finalize(hit_target)
        self._checkpoint(out, done=self.done)
        if self.verbose:
            print(f"[campaign] {self.sc.name}: run {self.state.run_idx}, "
                  f"accepted {n_acc}/{self.cfg.target_accepted}")

    def _finalize(self, hit_target: bool):
        theta, dist = self.state.to_arrays()
        spec = get_model(self.sc.model)
        names = run_param_names(self.abc_cfg, spec)
        r = self.result
        r.status = "ok" if hit_target else "budget_exhausted"
        r.n_accepted = int(theta.shape[0])
        r.runs = self.state.run_idx
        r.simulations = self.state.simulations
        r.acceptance_rate = r.n_accepted / max(r.simulations, 1)
        r.wall_time_s = time.time() - self._t0
        if theta.shape[0]:
            r.posterior_mean = {
                n: float(m) for n, m in zip(names, theta.mean(axis=0))
            }
            r.posterior_std = {
                n: float(s) for n, s in zip(names, theta.std(axis=0))
            }

    def _checkpoint(self, out, done: bool):
        fills = np.asarray(out.fill_counts)
        # spec-object models serialize by tag (a spec holds functions, which
        # are not checkpoint-meta material); everything else as-is
        sc_meta = dataclasses.asdict(
            dataclasses.replace(self.sc, model=self.sc.model_tag)
        )
        meta = {
            "scenario": sc_meta,
            "run_idx": self.state.run_idx,
            "simulations": self.state.simulations,
            "n_accepted": int(out.n_accepted),
            "fill": int(fills.sum()),
            "fills": [int(c) for c in fills],
            "tolerance": self.result.tolerance,
            "eps_schedule": list(self.eps_schedule),
            "done": done,
        }
        if done:
            meta["result"] = dataclasses.asdict(self.result)
        tree = {"theta_buf": out.theta_buf, "dist_buf": out.dist_buf}
        # async: the D2H snapshot happens here, serialization + fsync on the
        # checkpointer's writer thread — devices keep simulating the next
        # segment while the previous one commits (run_campaign waits at the
        # end so completion reports only cover durable checkpoints)
        self.ckpt.save_async(self.state.run_idx, tree, meta)


def run_campaign(cfg: CampaignConfig, verbose: bool = False) -> CampaignReport:
    """Run (or resume) every scenario in the grid; returns the report and
    writes it to `<out_dir>/campaign_report.json`."""
    t0 = time.time()
    devices = jax.devices()
    dps = cfg.devices_per_scenario
    if dps > len(devices):
        raise ValueError(
            f"devices_per_scenario={dps} exceeds the {len(devices)} visible "
            "devices; on CPU, simulate more with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N"
        )
    # disjoint device groups: scenarios placed round-robin over the groups
    # advance concurrently, each sharded across its own group (any remainder
    # devices are left idle rather than sharing a device between groups)
    groups = [devices[g * dps:(g + 1) * dps] for g in range(len(devices) // dps)]
    cache = _ShapeCache(cfg)
    runs = [
        _ScenarioRun(sc, cfg, cache, groups[i % len(groups)], verbose=verbose)
        for i, sc in enumerate(cfg.scenarios())
    ]
    active = [r for r in runs if not r.done]
    while active:
        for r in active:  # dispatch one segment each — overlaps across devices
            r.launch()
        for r in active:  # then sync in order
            r.complete_segment()
        active = [r for r in active if not r.done]
    for r in runs:  # drain in-flight checkpoint writes (surfaces I/O errors)
        if getattr(r, "ckpt", None) is not None:
            r.ckpt.wait()

    report = CampaignReport(
        # spec-object models serialize by name tag (specs hold functions)
        config=dataclasses.asdict(dataclasses.replace(
            cfg,
            models=tuple(
                m if isinstance(m, str) else m.name for m in cfg.models
            ),
        )),
        scenarios=[r.result for r in runs],
        wall_time_s=time.time() - t0,
        compiled_shapes=cache.n_compiled,
    )
    path = report.save(Path(cfg.out_dir) / "campaign_report.json")
    if verbose:
        print(report.summary_table())
        print(f"[campaign] report saved to {path}")
    return report
