"""Epidemiology forecast serving: amortized posterior queries over cached fits.

The paper's framework makes ABC fitting hardware-fast, but every query still
pays the full inference cost. This layer implements the split both SBI
comparison studies motivate (PAPERS.md): posterior estimation is the
expensive OFFLINE phase; forecasts and counterfactuals are cheap forward
simulations that a server can batch. Three pieces:

  * `ForecastKernelCache` — one compiled posterior-predictive simulator per
    forecast SHAPE (model, horizon, particle count, schedule shape). The
    campaign runner's `_ShapeCache` contract (traced ScenarioData): dataset
    scalars and breakpoint days are runtime arguments, so every (country,
    intervention timing, scale) of a shape shares one compilation. A
    `batched` vmapped variant drives one fixed-width microbatch of query
    lanes — the epidemiology face of `launch/serve.py`'s continuous-batching
    slot scheduler.
  * `PosteriorStore` — filesystem posterior cache keyed by (dataset version,
    model, summary, distance, schedule-shape), with atomic swap semantics
    (tmp+rename for both the .npz payload and the index), so a crashed
    re-fit can never corrupt what the server reads.
  * `EpiServer` — answers `ForecastQuery` batches: groups compatible queries
    by compiled shape, pads each group to a fixed lane count, answers the
    whole group with ONE `batched` call, and (re-)fits posteriors on demand.
    Two fit backends (`ServeConfig.fit_backend`):
      - "smc" (default): SMC-ABC per dataset version, warm-started from the
        previous version's population when the content changes
        (`SMCConfig.initial_particles`);
      - "npe": a `repro.core.npe` estimator trained ONCE per
        (model, summary, schedule) is the amortized fast path — a posterior
        for any dataset version is a forward pass + mixture draws, ZERO
        simulation waves (pinned by tests), and a version change costs at
        most `NPEConfig.fine_tune_steps` gradient steps instead of a wave
        campaign. Estimators persist next to the PosteriorStore.

Batched responses are BIT-IDENTICAL to sequential `posterior_forecast`
calls for the same (query, seed): both paths subsample/widen theta with the
same seeded helpers and run the same traced core (vmap of threefry draws
per-lane keys exactly as the sequential call does) — pinned by
tests/test_serving.py.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.campaign import _jsonable, schedule_shape_key
from repro.core.posterior import Posterior
from repro.core.smc import SMCConfig, run_smc_abc
from repro.core.summaries import get_summary
from repro.epi import engine
from repro.epi.data import CountryData, get_dataset
from repro.epi.models import get_model
from repro.ioutils import atomic_write_text as _atomic_write_text
from repro.epi.spec import EpiModelConfig, InterventionSchedule

# --------------------------------------------------------------- particles

#: fold_in salt deriving the subsample permutation key from the forecast
#: key — sequential and batched paths MUST pick identical subsets
_SUBSAMPLE_SALT = 0x5EED


def subsample_particles(theta, key, max_particles: int) -> np.ndarray:
    """Seeded-permutation subsample of an accepted set.

    topk accepted sets are distance-ordered, so `theta[:k]` is biased toward
    the lowest-distance particles and narrows the credible bands; a seeded
    permutation keeps the subset an unbiased draw from the full set
    (tests/test_serving.py pins the statistical match). Deterministic in
    (key, N): the same query seed always selects the same particles.
    """
    theta = np.asarray(theta, np.float32)
    n = theta.shape[0]
    if n <= max_particles:
        return theta
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    perm = np.asarray(
        jax.random.permutation(jax.random.fold_in(key, _SUBSAMPLE_SALT), n)
    )
    return theta[perm[:max_particles]]


def _widen_for_schedule(spec, theta, counterfactual, fc_sched):
    """theta columns for the forecast schedule.

    Forecast under the FIT schedule: theta already carries the fitted scale
    columns — pass through. Counterfactual: keep the base parameters, append
    the counterfactual's pinned scales (broadcast to every particle)."""
    if not counterfactual:
        return theta
    base = theta[:, : spec.n_params]
    if fc_sched is None or fc_sched.is_empty:
        return base
    scales = np.asarray(
        [s for row in fc_sched.fixed_scales() for s in row], np.float32
    )
    return np.concatenate(
        [base, np.broadcast_to(scales, (base.shape[0], scales.size))], axis=1
    )


def _breakpoint_arg(fc_sched) -> jnp.ndarray:
    if fc_sched is None or fc_sched.is_empty:
        return jnp.zeros((0,), jnp.int32)
    return jnp.asarray(fc_sched.breakpoints, jnp.int32)


# ----------------------------------------------------------- kernel cache
class ForecastKernelCache:
    """One compiled posterior-predictive simulator per forecast shape.

    Key: (model, total_days, n_particles, theta width) + schedule shape.
    Dataset scalars (population, a0, r0, d0) and breakpoint days are TRACED
    arguments, so one compile serves every country / intervention timing of
    a shape; counterfactual scale values ride theta columns. `get` returns
    (single, batched): `single` answers one query, `batched` is
    jit(vmap(single)) over stacked query lanes — its jit cache keys on the
    lane count, so a fixed slot width compiles exactly once (pinned by a
    jit-cache-size test).
    """

    def __init__(self):
        self._fns: Dict[tuple, tuple] = {}

    @property
    def n_compiled(self) -> int:
        return len(self._fns)

    def key_of(self, model_name, total_days, n_particles, width, fc_sched):
        return (
            model_name, int(total_days), int(n_particles), int(width),
        ) + schedule_shape_key(fc_sched)

    def get(self, spec, total_days, n_particles, width, fc_sched):
        key = self.key_of(spec.name, total_days, n_particles, width, fc_sched)
        if key in self._fns:
            return self._fns[key]
        # only the schedule's SHAPE is baked; same-shape schedules reuse the
        # closure with their own traced breakpoints + theta scale columns
        sched = None if fc_sched is None or fc_sched.is_empty else fc_sched
        n_windows = 0 if sched is None else sched.n_windows
        # analysis: allow(scalar-closure-capture) — total_days is part of
        # key_of(), so baking it is the cache design: one compile per
        # forecast length, keyed, never a silent recompile
        days = int(total_days)

        def core(theta, key_, population, a0, r0, d0, breakpoints):
            mcfg = EpiModelConfig(
                population=population, num_days=days, a0=a0, r0=r0, d0=d0
            )
            bp = breakpoints if n_windows else None
            return engine.simulate_observed(spec, theta, key_, mcfg, sched, bp)

        entry = (jax.jit(core), jax.jit(jax.vmap(core)))
        self._fns[key] = entry
        return entry


#: process-default cache backing sequential `posterior_forecast` calls
DEFAULT_KERNELS = ForecastKernelCache()


# ------------------------------------------------------------------ bands
def bands_payload(
    traj: np.ndarray,  # [N, n_obs, T]
    spec,
    dataset: CountryData,
    fit_days: int,
    horizon: int,
    fc_sched: Optional[InterventionSchedule],
    quantiles: Sequence[float],
) -> dict:
    """Credible-band payload from a posterior-predictive trajectory stack.

    Strict-JSON (no NaN/inf); identical field layout for the sequential
    `posterior_forecast` path and the batched server path — bit-identity of
    the two is a pinned serving invariant."""
    channels = {}
    for m, name in enumerate(spec.observed):
        ch = traj[:, m, :]  # [N, T]
        bands = {"mean": ch.mean(axis=0).tolist()}
        for q in quantiles:
            bands[f"q{int(round(q * 100)):02d}"] = np.quantile(
                ch, q, axis=0
            ).tolist()
        channels[name] = bands
    payload = {
        "model": spec.name,
        "dataset": dataset.name,
        "fit_days": int(fit_days),
        "horizon_days": int(horizon),
        "total_days": int(fit_days) + int(horizon),
        "n_particles": int(traj.shape[0]),
        "schedule": None
        if fc_sched is None or fc_sched.is_empty
        else dataclasses.asdict(fc_sched),
        "quantiles": list(quantiles),
        "channels": channels,
        "observed": {
            name: dataset.observed[m, : int(fit_days)].tolist()
            for m, name in enumerate(spec.observed)
        },
    }
    return _jsonable(payload)


def forecast_bands(
    theta,
    dataset: CountryData,
    *,
    model: str,
    fit_days: int,
    horizon: int,
    fit_schedule: Optional[InterventionSchedule] = None,
    schedule: Optional[InterventionSchedule] = None,
    key=0,
    quantiles: Sequence[float] = (0.05, 0.25, 0.5, 0.75, 0.95),
    max_particles: int = 512,
    kernels: Optional[ForecastKernelCache] = None,
) -> dict:
    """Sequential posterior-predictive forecast (one query, one compiled call).

    The single-query face of the serving layer: `posterior_forecast` in
    launch/abc_run.py delegates here, so the CLI path and the batched server
    share every step (seeded subsample, schedule widening, traced core,
    payload assembly)."""
    spec = get_model(model)
    counterfactual = schedule is not None
    fc_sched = schedule if counterfactual else fit_schedule
    theta = np.asarray(theta, np.float32)
    if theta.shape[0] == 0:
        raise ValueError("no accepted samples to forecast from")
    if isinstance(key, int):
        key = jax.random.PRNGKey(key)
    theta = subsample_particles(theta, key, max_particles)
    theta = _widen_for_schedule(spec, theta, counterfactual, fc_sched)
    total_days = int(fit_days) + int(horizon)
    kernels = kernels or DEFAULT_KERNELS
    single, _ = kernels.get(
        spec, total_days, theta.shape[0], theta.shape[1], fc_sched
    )
    traj = np.asarray(
        single(
            jnp.asarray(theta),
            key,
            jnp.float32(dataset.population),
            jnp.float32(dataset.a0),
            jnp.float32(dataset.r0),
            jnp.float32(dataset.d0),
            _breakpoint_arg(fc_sched),
        )
    )
    return bands_payload(
        traj, spec, dataset, fit_days, horizon, fc_sched, quantiles
    )


# ---------------------------------------------------------------- queries
@dataclasses.dataclass(frozen=True)
class ForecastQuery:
    """One serving request: forecast or counterfactual credible bands.

    `schedule=None` forecasts under the FIT schedule; an
    InterventionSchedule with fixed scales is a counterfactual ("what if
    alpha drops to 0.5x on day 20"). In the JSON form, `schedule` is the
    CLI grammar string (`PARAMS@day[=scale][,day...]`, see
    `parse_intervention`); the string "none" lifts every intervention
    (counterfactual under the empty schedule)."""

    dataset: str
    model: str = "siard"
    horizon: int = 14
    schedule: Optional[InterventionSchedule] = None
    quantiles: Tuple[float, ...] = (0.05, 0.25, 0.5, 0.75, 0.95)
    seed: int = 0

    @property
    def kind(self) -> str:
        return "counterfactual" if self.schedule is not None else "forecast"

    @staticmethod
    def from_json(d: dict) -> "ForecastQuery":
        from repro.epi.spec import EMPTY_SCHEDULE
        from repro.launch.abc_run import parse_intervention

        sched = d.get("schedule")
        if isinstance(sched, str):
            s = sched.strip()
            sched = (
                EMPTY_SCHEDULE if not s or s.lower() == "none"
                else parse_intervention(s)
            )
        elif sched is not None:
            raise ValueError(
                f"query schedule must be a grammar string or null, got "
                f"{type(sched).__name__}"
            )
        return ForecastQuery(
            dataset=d["dataset"],
            model=d.get("model", "siard"),
            horizon=int(d.get("horizon", 14)),
            schedule=sched,
            quantiles=tuple(d.get("quantiles", (0.05, 0.25, 0.5, 0.75, 0.95))),
            seed=int(d.get("seed", 0)),
        )


# ----------------------------------------------------------- dataset files
def dataset_version(ds: CountryData) -> str:
    """Content hash of a dataset — the freshness axis of the posterior cache
    key. Re-fits trigger on CONTENT change (new daily rows), never on file
    mtime churn."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(ds.observed, np.float32).tobytes())
    h.update(
        f"{ds.name}|{ds.population}|{ds.a0}|{ds.r0}|{ds.d0}|{ds.model}".encode()
    )
    return h.hexdigest()[:12]


def save_dataset_file(path: str, ds: CountryData) -> None:
    """Serialize a CountryData to the serving JSON schema (atomic write)."""
    payload = {
        "name": ds.name,
        "population": float(ds.population),
        "a0": float(ds.a0),
        "r0": float(ds.r0),
        "d0": float(ds.d0),
        "model": ds.model,
        "observed_channels": list(ds.observed_channels),
        "observed": np.asarray(ds.observed, np.float32).tolist(),
    }
    _atomic_write_text(path, json.dumps(payload, indent=1, allow_nan=False))


def load_dataset_file(path: str, model=None) -> CountryData:
    """Load a dataset from the serving JSON schema (see save_dataset_file).

    `model` optionally re-tags the series for a different registry spec with
    matching observed channels (the get_dataset compatibility rule)."""
    with open(path) as f:
        raw = json.load(f)
    try:
        ds = CountryData(
            name=str(raw["name"]),
            population=float(raw["population"]),
            a0=float(raw.get("a0", 100.0)),
            r0=float(raw.get("r0", 0.0)),
            d0=float(raw.get("d0", 0.0)),
            observed=np.asarray(raw["observed"], np.float32),
            model=str(raw.get("model", "siard")),
            observed_channels=tuple(raw.get("observed_channels", ("A", "R", "D"))),
            synthetic=True,
        )
    except (KeyError, TypeError, ValueError) as e:
        raise ValueError(f"malformed dataset file {path!r}: {e}") from e
    if ds.observed.ndim != 2:
        raise ValueError(
            f"dataset file {path!r}: observed must be [n_channels, T], got "
            f"shape {ds.observed.shape}"
        )
    if model is not None and model != ds.model:
        spec = get_model(model)
        if not ds.compatible_with(spec):
            raise ValueError(
                f"dataset {ds.name!r} holds {ds.observed_channels} series; "
                f"model {spec.name!r} observes {spec.observed}"
            )
        ds = dataclasses.replace(ds, model=spec.name)
    return ds


# ------------------------------------------------------------------ store
class PosteriorStore:
    """Filesystem posterior cache with atomic entry swap.

    One versioned .npz per cache key (written by Posterior.save — itself
    atomic) plus an index.json routing key -> current version, rewritten
    tmp+rename. Readers resolve through the index, so a re-fit becomes
    visible only at the single atomic index swap: a crash mid-refit leaves
    the previous complete entry being served. Stale versions are pruned
    after the swap."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._index_path = os.path.join(root, "index.json")

    # -- index ------------------------------------------------------------
    def _read_index(self) -> dict:
        try:
            with open(self._index_path) as f:
                return json.load(f)
        except FileNotFoundError:
            return {}
        except (json.JSONDecodeError, OSError) as e:
            raise ValueError(
                f"corrupt posterior-store index {self._index_path!r} ({e}); "
                "delete it to rebuild the store from scratch"
            ) from e

    def keys(self) -> Tuple[str, ...]:
        return tuple(sorted(self._read_index()))

    def version_of(self, key: str) -> Optional[str]:
        entry = self._read_index().get(key)
        return None if entry is None else entry["version"]

    # -- entries ----------------------------------------------------------
    @staticmethod
    def _slug(key: str) -> str:
        return "".join(c if c.isalnum() or c in "._-" else "_" for c in key)

    def _file_of(self, key: str, version: str) -> str:
        return os.path.join(self.root, f"{self._slug(key)}-{version}.npz")

    def put(self, key: str, version: str, posterior: Posterior) -> None:
        """Atomic swap: persist the new version's payload, then flip the
        index entry in one rename; prune the superseded payload after."""
        path = self._file_of(key, version)
        posterior.save(path)
        index = self._read_index()
        old = index.get(key)
        index[key] = {
            "version": version,
            "file": os.path.basename(path),
            "n": len(posterior),
            "simulations": int(posterior.simulations),
            "tolerance": float(posterior.tolerance),
            "updated_at": time.time(),
        }
        _atomic_write_text(
            self._index_path, json.dumps(index, indent=1, allow_nan=False)
        )
        if old and old["file"] != os.path.basename(path):
            stale = os.path.join(self.root, old["file"])
            if os.path.exists(stale):
                os.unlink(stale)

    def get(self, key: str, version: str) -> Optional[Posterior]:
        """The posterior for (key, version), or None on miss/stale."""
        entry = self._read_index().get(key)
        if entry is None or entry["version"] != version:
            return None
        return Posterior.load(os.path.join(self.root, entry["file"]))

    def latest(self, key: str) -> Optional[Tuple[str, Posterior]]:
        """Newest stored (version, posterior) for a key — the warm-start
        source when the dataset content has moved past it."""
        entry = self._read_index().get(key)
        if entry is None:
            return None
        return entry["version"], Posterior.load(
            os.path.join(self.root, entry["file"])
        )


# ----------------------------------------------------------------- server
def _default_fit() -> SMCConfig:
    return SMCConfig(
        n_particles=128, batch_size=4096, n_rounds=3, quantile=0.5,
        num_days=21, backend="xla_fused", model="siard",
    )


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """EpiServer policy: microbatch width, forecast particles, fit template.

    `fit` is the SMC template applied to every dataset the server must fit
    on demand (its `model` field is overridden per query); `fit.num_days`
    is the fitting window every forecast extends past."""

    slots: int = 8
    forecast_particles: int = 128
    fit: SMCConfig = dataclasses.field(default_factory=_default_fit)
    fit_seed: int = 0
    #: directory of <name>.json dataset files; bundled registry datasets
    #: (italy / new_zealand / usa / synthetic_small) resolve when no file
    #: of that name exists
    data_dir: Optional[str] = None
    #: PosteriorStore directory (None = in-memory cache only)
    store_dir: Optional[str] = None
    #: "smc" fits per dataset version via SMC-ABC waves; "npe" trains one
    #: amortized estimator per (model, summary, schedule) and answers every
    #: version with a forward pass (+ optional fine-tune on version change)
    fit_backend: str = "smc"
    #: fit_backend="npe" only: training hyperparameters (core.npe.NPEConfig);
    #: None uses the NPEConfig defaults
    npe: Optional[object] = None

    def __post_init__(self):
        if self.fit_backend not in ("smc", "npe"):
            raise ValueError(
                f"unknown fit_backend {self.fit_backend!r} "
                "(expected 'smc' or 'npe')"
            )
        if self.npe is not None:
            from repro.core.npe import resolve_npe_config

            resolve_npe_config(self.npe)
            if self.fit_backend != "npe":
                raise ValueError(
                    "cfg.npe is set but fit_backend is not 'npe'"
                )


class EpiServer:
    """Batched posterior-query server over a posterior cache.

    `answer(queries)` groups compatible queries by compiled forecast shape
    and drives each group through ONE vmapped compiled call on a fixed
    `slots`-lane microbatch (padding lanes repeat lane 0 and are
    discarded) — the continuous-batching pattern of launch/serve.py with
    forecast queries in the slots. Posteriors come from the in-memory
    cache, then the PosteriorStore, then an on-demand SMC fit
    (warm-started from the previous dataset version when one is cached).
    """

    def __init__(self, cfg: ServeConfig):
        if cfg.slots < 1:
            raise ValueError("slots must be >= 1")
        self.cfg = cfg
        self.kernels = ForecastKernelCache()
        self.store = (
            PosteriorStore(cfg.store_dir) if cfg.store_dir else None
        )
        #: base cache key -> (dataset version, posterior)
        self._posteriors: Dict[str, Tuple[str, Posterior]] = {}
        #: fit_backend="npe": base cache key -> trained NPEstimator
        self._estimators: Dict[str, object] = {}
        self.fits = 0
        self.warm_fits = 0
        self.batched_calls = 0
        self.npe_trains = 0
        self.npe_fine_tunes = 0

    # -- cache keys --------------------------------------------------------
    def posterior_key(self, dataset_name: str, model: str) -> str:
        """Everything the fit depends on except the data content: (model,
        summary, distance, schedule-shape); the dataset VERSION rides next
        to the key so a content change invalidates without renaming."""
        f = self.cfg.fit
        shape = schedule_shape_key(f.schedule)
        shape_tag = (
            "none" if not shape else f"w{shape[0]}_" + "+".join(shape[1])
        )
        return (
            f"{dataset_name}__{model}__{get_summary(f.summary).tag()}"
            f"__{f.distance}__{shape_tag}"
        )

    # -- datasets ----------------------------------------------------------
    def dataset(self, name: str, model: str) -> Tuple[CountryData, str]:
        """Resolve a dataset to exactly the fit window and version it.

        File-backed (`data_dir/<name>.json`) series win over bundled
        registry names; files longer than the fit window are truncated to
        it (the daily-update flow appends rows, moving the version)."""
        fit_days = self.cfg.fit.num_days
        if self.cfg.data_dir:
            path = os.path.join(self.cfg.data_dir, f"{name}.json")
            if os.path.exists(path):
                ds = load_dataset_file(path, model=model)
                if ds.num_days < fit_days:
                    raise ValueError(
                        f"dataset {name!r} has {ds.num_days} days; the fit "
                        f"window needs {fit_days}"
                    )
                if ds.num_days > fit_days:
                    ds = dataclasses.replace(
                        ds, observed=ds.observed[:, :fit_days]
                    )
                return ds, dataset_version(ds)
        ds = get_dataset(name, num_days=fit_days, model=model)
        return ds, dataset_version(ds)

    # -- posteriors --------------------------------------------------------
    def preload(self, name: str, model: str, posterior: Posterior) -> None:
        """Install a posterior for the dataset's CURRENT version (tests /
        external fits); the server will answer from it without fitting."""
        _, version = self.dataset(name, model)
        self._posteriors[self.posterior_key(name, model)] = (version, posterior)

    def refresh(self, name: str, model: str) -> str:
        """Ensure the cached posterior matches the dataset content.

        Returns "cached" (fresh already), "warm_refit" (re-fit seeded from
        the previous version's population) or "cold_fit"."""
        _, _, status = self._ensure(name, model)
        return status

    def get_posterior(self, name: str, model: str):
        post, ds, _ = self._ensure(name, model)
        return post, ds

    def _ensure(self, name: str, model: str):
        ds, version = self.dataset(name, model)
        bk = self.posterior_key(name, model)
        hit = self._posteriors.get(bk)
        if hit is not None and hit[0] == version:
            return hit[1], ds, "cached"
        if self.cfg.fit_backend == "npe":
            return self._ensure_npe(bk, ds, version)
        if self.store is not None:
            stored = self.store.get(bk, version)
            if stored is not None:
                self._posteriors[bk] = (version, stored)
                return stored, ds, "cached"
        # stale or missing: fit, warm-started from the newest prior version
        warm = hit[1] if hit is not None else None
        if warm is None and self.store is not None:
            latest = self.store.latest(bk)
            warm = latest[1] if latest is not None else None
        post = self._fit(ds, model, warm)
        self._posteriors[bk] = (version, post)
        if self.store is not None:
            self.store.put(bk, version, post)
        return post, ds, "warm_refit" if warm is not None else "cold_fit"

    def _estimator_path(self, bk: str) -> Optional[str]:
        """On-disk home of a trained estimator (beside the PosteriorStore)."""
        if self.cfg.store_dir is None:
            return None
        return os.path.join(
            self.cfg.store_dir, "npe", f"{PosteriorStore._slug(bk)}.npz"
        )

    def _npe_train_cfg(self, model: str):
        """The backend='npe' ABCConfig mirroring the SMC fit template: same
        model / window / summary / schedule, so NPE and SMC posteriors for a
        dataset share the cache key and only the fit mechanism differs."""
        from repro.core.abc import ABCConfig

        f = self.cfg.fit
        return ABCConfig(
            model=model, num_days=f.num_days, backend="npe",
            summary=f.summary, distance=f.distance, schedule=f.schedule,
            mobility=f.mobility, target_accepted=f.n_particles,
            npe=self.cfg.npe,
        )

    def _ensure_npe(self, bk: str, ds: CountryData, version: str):
        """Amortized posterior path: the estimator is trained at most once
        per cache key; every dataset version is answered with a forward
        pass. A version change while an estimator exists costs only
        `NPEConfig.fine_tune_steps` gradient steps (0 = free refresh) —
        never a simulation-wave campaign (`self.fits` stays untouched)."""
        from repro.core import npe as npe_mod

        if self.store is not None:
            stored = self.store.get(bk, version)
            if stored is not None:
                self._posteriors[bk] = (version, stored)
                return stored, ds, "cached"
        cfg = self._npe_train_cfg(ds.model)
        est = self._estimators.get(bk)
        path = self._estimator_path(bk)
        if est is None and path is not None and os.path.exists(path):
            est = npe_mod.NPEstimator.load(path)
        if est is None:
            est = npe_mod.train_npe(ds, cfg, key=self.cfg.fit_seed)
            self.npe_trains += 1
            status = "cold_fit"
        else:
            # the estimator amortizes over content, but the posterior cache
            # missed: the dataset version moved (or the cache is cold) —
            # refresh with a short fine-tune against the current scalars
            est = npe_mod.fine_tune(est, ds, key=self.cfg.fit_seed)
            self.npe_fine_tunes += 1
            status = "warm_refit"
        self._estimators[bk] = est
        if path is not None:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            est.save(path)
        post = est.sample_posterior(
            ds.observed, self.cfg.fit.n_particles, key=self.cfg.fit_seed
        )
        self._posteriors[bk] = (version, post)
        if self.store is not None:
            self.store.put(bk, version, post)
        return post, ds, status

    def _fit(self, ds: CountryData, model: str, warm: Optional[Posterior]):
        fit = dataclasses.replace(self.cfg.fit, model=model)
        if warm is not None:
            expected = len(
                fit.schedule.param_names(get_model(model))
                if fit.schedule is not None and not fit.schedule.is_empty
                else get_model(model).param_names
            )
            if warm.theta.shape[1] == expected:
                fit = dataclasses.replace(
                    fit,
                    initial_particles=warm.theta,
                    initial_weights=warm.weights,
                )
                self.warm_fits += 1
            else:
                warm = None  # incompatible width (model/schedule changed)
        self.fits += 1
        return run_smc_abc(ds, fit, key=self.cfg.fit_seed)

    # -- answering ---------------------------------------------------------
    def answer(self, queries: Sequence[ForecastQuery]) -> List[dict]:
        """Answer a batch of queries; responses align with query order.

        Queries sharing a forecast shape share one compiled kernel and are
        answered `slots` lanes at a time through its vmapped variant; a
        mixed batch across S shapes costs ceil(group/slots) calls per
        shape — >= 8 queries over 2 schedules resolve in <= 2 compiled
        calls (acceptance-pinned)."""
        results: List[Optional[dict]] = [None] * len(queries)
        groups: Dict[tuple, List[int]] = {}
        prep: List[tuple] = []
        for i, q in enumerate(queries):
            post, ds = self.get_posterior(q.dataset, q.model)
            spec = get_model(q.model)
            counterfactual = q.schedule is not None
            fc_sched = q.schedule if counterfactual else self.cfg.fit.schedule
            key = jax.random.PRNGKey(q.seed)
            th = subsample_particles(
                post.theta, key, self.cfg.forecast_particles
            )
            th = _widen_for_schedule(spec, th, counterfactual, fc_sched)
            total_days = self.cfg.fit.num_days + int(q.horizon)
            gkey = self.kernels.key_of(
                spec.name, total_days, th.shape[0], th.shape[1], fc_sched
            )
            groups.setdefault(gkey, []).append(i)
            prep.append((th, key, ds, fc_sched, spec, total_days, q))
        for idxs in groups.values():
            for start in range(0, len(idxs), self.cfg.slots):
                chunk = idxs[start: start + self.cfg.slots]
                self._answer_chunk(chunk, prep, results)
        return results  # every entry filled: each query joined one chunk

    def _answer_chunk(self, chunk, prep, results) -> None:
        """One microbatched compiled call over <= slots same-shape lanes."""
        lanes = chunk + [chunk[0]] * (self.cfg.slots - len(chunk))
        th0, _, _, fc_sched, spec, total_days, _ = prep[chunk[0]]
        theta = jnp.asarray(
            np.stack([prep[i][0] for i in lanes]), jnp.float32
        )
        keys = jnp.stack([prep[i][1] for i in lanes])
        pop = jnp.asarray(
            [prep[i][2].population for i in lanes], jnp.float32
        )
        a0 = jnp.asarray([prep[i][2].a0 for i in lanes], jnp.float32)
        r0 = jnp.asarray([prep[i][2].r0 for i in lanes], jnp.float32)
        d0 = jnp.asarray([prep[i][2].d0 for i in lanes], jnp.float32)
        bp = jnp.stack([_breakpoint_arg(prep[i][3]) for i in lanes])
        _, batched = self.kernels.get(
            spec, total_days, th0.shape[0], th0.shape[1], fc_sched
        )
        traj = np.asarray(batched(theta, keys, pop, a0, r0, d0, bp))
        self.batched_calls += 1
        for lane, i in enumerate(chunk):
            _, _, ds_i, sched_i, spec_i, _, q = prep[i]
            results[i] = bands_payload(
                traj[lane], spec_i, ds_i, self.cfg.fit.num_days, q.horizon,
                sched_i, q.quantiles,
            )

    def stats(self) -> dict:
        return {
            "fits": self.fits,
            "warm_fits": self.warm_fits,
            "batched_calls": self.batched_calls,
            "compiled_shapes": self.kernels.n_compiled,
            "npe_trains": self.npe_trains,
            "npe_fine_tunes": self.npe_fine_tunes,
        }
