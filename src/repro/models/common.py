"""Shared neural layers for the architecture zoo (pure JAX, explicit pytrees).

Conventions:
  * params are nested dicts of jnp arrays; layer stacks are STACKED on a
    leading "layers" axis and consumed with jax.lax.scan (compile-time
    containment for 48-layer models; see DESIGN.md §5).
  * activations/params bf16, norms/softmax/router f32 (standard practice).
  * attention: q [B,S,H,D], k/v [B,T,K,D] with H = K*G (GQA groups).
    `dense` path for short sequences, `blockwise` online-softmax path for
    32k+ (no [S,T] materialization), `decode` path for single-token steps.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_DTYPE = jnp.bfloat16

NEG_INF = -1e30


# ----------------------------------------------------------------- init utils
def ninit(key, shape, fan_in=None, dtype=DEFAULT_DTYPE):
    """Truncated-normal-ish init scaled by 1/sqrt(fan_in)."""
    fan_in = fan_in or shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ----------------------------------------------------------------- norms etc.
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) + bias.astype(
        jnp.float32
    )
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ----------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: [B, S, H, D] (D even), positions: [B, S] or [S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]  # [B, S, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ attention
def _score_mod(s, cap):
    return softcap(s, cap) if cap is not None else s


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference attention, materializes [.., S, T]. For short sequences/tests."""
    b, sq, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qq = (q * scale).reshape(b, sq, kh, g, d)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qq, k).astype(jnp.float32)
    s = _score_mod(s, attn_softcap)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(t)
    ok = jnp.ones((sq, t), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Online-softmax (flash-style) attention in pure JAX.

    Never materializes [S, T]; lax.scan over KV blocks with running
    (max, denom, acc) carried per q block. Memory O(S*D + blocks).
    """
    b, sq, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    q_block = min(q_block, sq)
    kv_block = min(kv_block, t)
    assert sq % q_block == 0 and t % kv_block == 0, (sq, q_block, t, kv_block)
    nq, nk = sq // q_block, t // kv_block

    qr = (q * scale).reshape(b, nq, q_block, kh, g, d)
    kr = k.reshape(b, nk, kv_block, kh, d)
    vr = v.reshape(b, nk, kv_block, kh, d)

    q_ids = jnp.arange(q_block)
    k_ids = jnp.arange(kv_block)

    def one_q_block(qi, qblk):
        # qblk: [b, q_block, kh, g, d]
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kblk, vblk = inp
            s = jnp.einsum("bqkgd,bckd->bkgqc", qblk, kblk).astype(jnp.float32)
            s = _score_mod(s, attn_softcap)
            qpos = qi * q_block + q_ids
            kpos = ki * kv_block + k_ids
            ok = jnp.ones((q_block, kv_block), bool)
            if causal:
                ok &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                ok &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_block, d), v.dtype)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return out  # [b, kh, g, q_block, d]

    # checkpoint per q-block: the backward pass recomputes the online-softmax
    # statistics instead of storing every [q_block, kv_block] score matrix —
    # without this, training at 4k+ context saves O(S^2) f32 residuals per
    # layer (measured 330+ GB/device traffic on gemma-2b train_4k).
    outs = jax.vmap(jax.checkpoint(one_q_block), in_axes=(0, 1), out_axes=1)(
        jnp.arange(nq), qr
    )  # [b, nq, kh, g, q_block, d]
    out = jnp.moveaxis(outs, (2, 3), (3, 4))  # [b, nq, q_block, kh, g, d]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # [B, 1, H, D]
    k_cache: jax.Array,  # [B, T, K, D]
    v_cache: jax.Array,
    *,
    valid_len: Optional[jax.Array] = None,  # [B] or None = full cache valid
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token attention against a KV cache (memory-bound serve step)."""
    b, _, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qq = (q * scale).reshape(b, kh, g, d)
    s = jnp.einsum("bkgd,btkd->bkgt", qq, k_cache).astype(jnp.float32)
    s = _score_mod(s, attn_softcap)
    kpos = jnp.arange(t)
    if valid_len is not None:
        ok = kpos[None, :] < valid_len[:, None]  # [B, T]
        if window is not None:
            ok &= kpos[None, :] >= valid_len[:, None] - window
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    elif window is not None:
        s = jnp.where((kpos >= t - window)[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)


def attention(q, k, v, *, impl: str = "auto", **kw):
    if impl == "auto":
        impl = "blockwise" if q.shape[1] * k.shape[1] > 2048 * 2048 else "dense"
    if impl == "dense":
        return dense_attention(q, k, v, **kw)
    if impl == "blockwise":
        return blockwise_attention(q, k, v, **kw)
    if impl == "flash_pallas":
        # TPU-hardware path (kernels/flash_attention.py); kept off the default
        # route because Pallas custom-calls are opaque to the dry-run HLO
        # analyzer (EXPERIMENTS.md §Method / §Perf gemma2 log)
        from repro.kernels import ops as kernel_ops

        return kernel_ops.flash_attention(
            q, k, v,
            causal=kw.get("causal", True),
            window=kw.get("window"),
            softcap=kw.get("attn_softcap"),
            scale=kw.get("scale"),
        )
    raise ValueError(impl)


# ------------------------------------------------------------------------ MLP
def gated_mlp(x, wg, wu, wd, act: str = "silu"):
    """SwiGLU/GeGLU feed-forward: act(x@wg) * (x@wu) @ wd."""
    a = x @ wg
    if act == "silu":
        a = jax.nn.silu(a.astype(jnp.float32)).astype(x.dtype)
    elif act == "gelu":
        a = jax.nn.gelu(a.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        raise ValueError(act)
    return (a * (x @ wu)) @ wd


def vanilla_mlp(x, w1, b1, w2, b2):
    """Plain GELU MLP (whisper/ViT style)."""
    a = jax.nn.gelu((x @ w1 + b1).astype(jnp.float32), approximate=True)
    return (a.astype(x.dtype) @ w2 + b2.astype(x.dtype)).astype(x.dtype)


# ----------------------------------------------------------- KV quantization
def kv_quantize(x: jax.Array):
    """Per-(token, head) symmetric int8 quantization of K/V tiles.

    x [B, S, K, D] -> (q int8 [B,S,K,D], scale f32 [B,S,K,1]). Halves decode
    HBM bytes/token — the §Perf lever for memory-bound decode cells."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype=DEFAULT_DTYPE) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------ embedding
def embed(tokens: jax.Array, table: jax.Array, scale_by_dim: bool = False):
    x = jnp.take(table, tokens, axis=0)
    if scale_by_dim:
        x = x * np.sqrt(table.shape[1])
    return x.astype(DEFAULT_DTYPE)


def unembed(x: jax.Array, table: jax.Array, logit_cap: Optional[float] = None):
    logits = jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
    if logit_cap is not None:
        logits = logit_cap * jnp.tanh(logits / logit_cap)
    return logits


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token-level cross entropy. logits [B,S,V] f32, labels [B,S] int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def _pick_chunk(s: int, target: int = 1024) -> int:
    for c in (target, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if c <= s and s % c == 0:
            return c
    return s


def cross_entropy_chunked(
    x: jax.Array,  # [B, S, d] final features
    table: jax.Array,  # [V, d] unembedding
    labels: jax.Array,  # [B, S]
    logit_cap: Optional[float] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Cross entropy WITHOUT materializing the [B, S, V] f32 logits tensor.

    The unembed matmul + logsumexp run per sequence-chunk inside a rematted
    scan, so peak HBM holds one [B, chunk, V] slab instead of the full tensor
    (at 256k vocab x 1M tokens the full tensor is ~4 TB/device — the dominant
    memory-roofline term of the naive baseline; see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    c = _pick_chunk(s, chunk)
    nc = s // c
    xs = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)  # [nc, B, c, d]
    ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)  # [nc, B, c]

    @jax.checkpoint
    def body(acc, inp):
        xc, lc = inp
        logits = unembed(xc, table, logit_cap)  # [B, c, V] f32 (one chunk)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return total / (b * s)


def last_token_logits(x: jax.Array, table: jax.Array, logit_cap=None) -> jax.Array:
    """Serving prefill output: next-token logits [B, 1, V] only."""
    return unembed(x[:, -1:], table, logit_cap)
