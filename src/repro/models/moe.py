"""Mixture-of-Experts FFN: fine-grained routed experts + optional shared experts.

Covers deepseek-moe-16b (2 shared + 64 routed, top-6) and qwen3-moe-30b-a3b
(128 routed, top-8). Dispatch is capacity-based scatter/gather (no [N,E,C]
one-hot tensor): tokens are placed into per-expert buffers [E, C, d] whose
expert axis shards over the "model" mesh axis (expert parallelism) — the SPMD
partitioner emits the all-to-all traffic that the roofline collective term
measures. Router math in f32; a switch-style load-balancing aux loss is
returned for training.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN width (fine-grained)
    n_shared: int = 0  # shared (always-on) experts of the same width
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    #: steer GSPMD: pin tokens to the data axes and expert buffers to the
    #: model axis around the dispatch scatter/gather (EXPERIMENTS.md §Perf —
    #: without these the partitioner replicates the dispatch; toggle via env
    #: REPRO_MOE_CONSTRAIN=0 to reproduce the baseline)
    shard_constraints: bool = os.environ.get("REPRO_MOE_CONSTRAIN", "1") == "1"


def _ambient_mesh():
    """The mesh in scope, or None — version-guarded.

    Newer jax exposes `jax.sharding.get_abstract_mesh()` (set by
    `jax.set_mesh` / `use_mesh`); jax < 0.5 has neither, but the physical
    mesh installed by a `with Mesh(...):` context is available through the
    thread-resources environment. Either way an empty/absent mesh returns
    None so constraints are skipped (single-process smoke tests).
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
    else:  # jax < 0.5: the mesh threaded by `with Mesh(...):`
        try:
            from jax._src.mesh import thread_resources

            mesh = thread_resources.env.physical_mesh
        except Exception:  # very old/new private layout — no ambient mesh
            return None
    if mesh is None or not mesh.axis_names:
        return None
    return mesh


def _constrain(x, *logical):
    """Best-effort sharding constraint using the ambient (abstract) mesh.

    logical entries: 'tokens' -> data axes, 'experts' -> model axis, None.
    Skipped entirely when no mesh is set (smoke tests) or dims don't divide.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)

    def axes_for(l):
        if l == "tokens" and dp:
            return dp
        if l == "experts" and "model" in names:
            return "model"
        return None

    parts = []
    for dim, l in zip(x.shape, logical):
        a = axes_for(l)
        if a is not None:
            sz = 1
            for ax in ((a,) if isinstance(a, str) else a):
                sz *= mesh.shape[ax]
            if dim % sz != 0:
                a = None
        parts.append(a)
    return jax.lax.with_sharding_constraint(x, P(*parts))


def init_moe(key, d_model: int, cfg: MoEConfig):
    ks = cm.keygen(key)
    e, f = cfg.n_experts, cfg.d_expert
    p = {
        "router": cm.ninit(next(ks), (d_model, e), d_model, jnp.float32),
        "wg": cm.ninit(next(ks), (e, d_model, f), d_model),
        "wu": cm.ninit(next(ks), (e, d_model, f), d_model),
        "wd": cm.ninit(next(ks), (e, f, d_model), f),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["shared_wg"] = cm.ninit(next(ks), (d_model, fs), d_model)
        p["shared_wu"] = cm.ninit(next(ks), (d_model, fs), d_model)
        p["shared_wd"] = cm.ninit(next(ks), (fs, d_model), fs)
    return p


def moe_logical(cfg: MoEConfig):
    spec = {
        "router": ("embed", "experts"),
        "wg": ("experts", "embed", "expert_ffn"),
        "wu": ("experts", "embed", "expert_ffn"),
        "wd": ("experts", "expert_ffn", "embed"),
    }
    if cfg.n_shared:
        spec["shared_wg"] = ("embed", "ffn")
        spec["shared_wu"] = ("embed", "ffn")
        spec["shared_wd"] = ("ffn", "embed")
    return spec


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cf = float(os.environ.get("REPRO_MOE_CF", cfg.capacity_factor))
    c = int(np.ceil(n_tokens * cfg.top_k * cf / cfg.n_experts))
    return max(8, int(np.ceil(c / 8) * 8))


def _dp_group_count(n_tokens: int) -> int:
    """Number of data shards (dispatch groups) from the ambient mesh."""
    mesh = _ambient_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            g *= mesh.shape[a]
    return g if g > 1 and n_tokens % g == 0 else 1


def moe_ffn(
    x: jax.Array, p: dict, cfg: MoEConfig, act: str = "silu"
) -> Tuple[jax.Array, jax.Array]:
    """Grouped expert-parallel dispatch (EXPERIMENTS.md §Perf, qwen3 cell).

    Tokens are dispatched into PER-DATA-SHARD capacity buffers
    [G, E, C_local, d] (scatter stays shard-local), then a single transpose
    G <-> E moves tokens to their expert shards — the canonical EP
    all-to-all. The naive global-buffer formulation (moe_ffn_global) forced
    GSPMD to ALL-REDUCE the full [E, C, d] buffer across the data axis every
    layer (~3.3 TB/device wire on qwen3 train_4k); grouped dispatch replaces
    that with the all-to-all, which is smaller by ~G x.

    x: [B, S, d] -> (y [B, S, d], aux_loss scalar f32).
    """
    if os.environ.get("REPRO_MOE_GROUPED", "1") != "1":
        return moe_ffn_global(x, p, cfg, act)
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = _dp_group_count(n)
    m = n // g  # tokens per group
    c = capacity(m, cfg)  # LOCAL capacity
    cons = _constrain if cfg.shard_constraints else (lambda t, *a: t)
    xg = cons(x.reshape(g, m, d), "tokens", None, None)

    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [G,M,E]
    # keep logits replicated over "model": top_k needs every expert column,
    # so an E-sharded layout forces a [G,M,E] f32 all-gather per layer; with
    # this constraint GSPMD gathers the 1 MB router param instead.
    logits = cons(logits, "tokens", None, None)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)  # [G, M, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    f_e = jnp.zeros(e, jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (n * k)
    p_e = probs.mean(axis=(0, 1))
    aux = cfg.router_aux_weight * e * jnp.sum(f_e * p_e)

    # ---- per-group dispatch (shard-local scatter) ----
    ids_g = top_ids.reshape(g, m * k)  # [G, M*k]
    w_g = top_w.reshape(g, m * k)
    oh = jax.nn.one_hot(ids_g, e, dtype=jnp.int32)  # [G, M*k, E]
    pos = jnp.take_along_axis(
        jnp.cumsum(oh, axis=1) - 1, ids_g[..., None], axis=2
    )[..., 0]  # [G, M*k]
    keep = pos < c
    slot = jnp.clip(ids_g * c + pos, 0, e * c - 1)
    tok_idx = jnp.repeat(jnp.arange(m), k)  # [M*k]
    src = jnp.where(keep[..., None], xg[:, tok_idx, :], 0).astype(x.dtype)
    src = cons(src, "tokens", None, None)
    buf_g = jax.vmap(lambda sl, u: jnp.zeros((e * c, d), x.dtype).at[sl].add(u))(
        slot, src
    )  # [G, E*C, d], G on data axes
    buf_g = cons(buf_g.reshape(g, e, c, d), "tokens", "experts", None, None)

    # ---- the EP all-to-all: groups -> expert shards ----
    buf_e = cons(
        jnp.swapaxes(buf_g, 0, 1).reshape(e, g * c, d), "experts", None, None
    )
    h = jnp.einsum("ecd,edf->ecf", buf_e, p["wg"])
    hu = jnp.einsum("ecd,edf->ecf", buf_e, p["wu"])
    if act == "silu":
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h * hu, p["wd"])  # [E, G*C, d]
    out_e = cons(out_e, "experts", None, None)

    # ---- all-to-all back + per-group combine ----
    out_g = jnp.swapaxes(out_e.reshape(e, g, c, d), 0, 1)  # [G, E, C, d]
    out_g = cons(out_g, "tokens", "experts", None, None).reshape(g, e * c, d)
    gathered = jnp.take_along_axis(out_g, slot[..., None], axis=1)  # [G, M*k, d]
    gathered = jnp.where(keep[..., None], gathered, 0)
    # combine in bf16: f32 here doubled every dispatch collective AND flipped
    # the backward buffers to f32 (measured 2x wire on qwen3; §Perf iter 2)
    y = (
        (gathered * w_g[..., None].astype(x.dtype))
        .reshape(g, m, k, d)
        .sum(axis=2)
        .reshape(b, s, d)
    )

    if cfg.n_shared:
        y = y + cm.gated_mlp(x, p["shared_wg"], p["shared_wu"], p["shared_wd"], act)
    return y, aux


def moe_ffn_global(
    x: jax.Array, p: dict, cfg: MoEConfig, act: str = "silu"
) -> Tuple[jax.Array, jax.Array]:
    """Baseline global-capacity dispatch (kept for the §Perf record).

    x: [B, S, d] -> (y [B, S, d], aux_loss scalar f32)."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(n, cfg)
    xf = x.reshape(n, d)
    cons = _constrain if cfg.shard_constraints else (lambda t, *a: t)
    xf = cons(xf, "tokens", None)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, k)  # [N, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # switch-style load-balance aux: E * sum_e f_e * p_e
    f_e = jnp.zeros(e, jnp.float32).at[top_ids.reshape(-1)].add(1.0) / (n * k)
    p_e = probs.mean(axis=0)
    aux = cfg.router_aux_weight * e * jnp.sum(f_e * p_e)

    # ---- dispatch: position-in-expert via cumsum, scatter into [E*C, d] ----
    flat_ids = top_ids.reshape(-1)  # [N*k]
    flat_w = top_w.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(n), k)
    oh = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)  # [N*k, E]
    pos = jnp.take_along_axis(
        jnp.cumsum(oh, axis=0) - 1, flat_ids[:, None], axis=1
    )[:, 0]
    keep = pos < c
    slot = jnp.clip(flat_ids * c + pos, 0, e * c - 1)
    src = jnp.where(keep[:, None], xf[tok_idx], 0).astype(x.dtype)
    src = cons(src, "tokens", None)
    buf = jnp.zeros((e * c, d), x.dtype).at[slot].add(src).reshape(e, c, d)
    buf = cons(buf, "experts", None, None)

    # ---- expert FFN (einsum over the expert-sharded buffers) ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    hu = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    if act == "silu":
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    h = cons(h, "experts", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h * hu, p["wd"])
    out_buf = cons(out_buf, "experts", None, None).reshape(e * c, d)

    # ---- combine: gather back, weight, sum over the k slots ----
    gathered = jnp.where(keep[:, None], out_buf[slot], 0)
    gathered = cons(gathered, "tokens", None)
    y = (
        (gathered.astype(jnp.float32) * flat_w[:, None])
        .reshape(n, k, d)
        .sum(axis=1)
        .astype(x.dtype)
        .reshape(b, s, d)
    )

    if cfg.n_shared:
        y = y + cm.gated_mlp(x, p["shared_wg"], p["shared_wu"], p["shared_wd"], act)
    return y, aux
