"""InternVL2-style VLM: ViT frontend stubbed (precomputed patch embeddings per
brief), 2-layer MLP projector, InternLM2-family decoder backbone."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import decoder as dec_lib


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    name: str
    lm: dec_lib.DecoderConfig
    vit_dim: int = 1024
    n_patches: int = 256
    sub_quadratic: bool = False

    def param_count(self) -> int:
        proj = self.vit_dim * self.lm.d_model + self.lm.d_model * self.lm.d_model
        return int(self.lm.param_count() + proj)

    def active_param_count(self) -> int:
        return self.param_count()


def init_params(key, cfg: VLMConfig):
    ks = cm.keygen(key)
    return {
        "projector": {
            "w1": cm.ninit(next(ks), (cfg.vit_dim, cfg.lm.d_model), cfg.vit_dim),
            "w2": cm.ninit(next(ks), (cfg.lm.d_model, cfg.lm.d_model), cfg.lm.d_model),
        },
        "lm": dec_lib.init_params(next(ks), cfg.lm),
    }


def param_logical(cfg: VLMConfig):
    return {
        "projector": {"w1": ("embed", "ffn"), "w2": ("ffn", "embed")},
        "lm": dec_lib.param_logical(cfg.lm),
    }


def _project(patches, p):
    h = jax.nn.gelu((patches.astype(cm.DEFAULT_DTYPE) @ p["w1"]).astype(jnp.float32),
                    approximate=True).astype(cm.DEFAULT_DTYPE)
    return h @ p["w2"]


def _embeds(params, batch, cfg: VLMConfig):
    img = _project(batch["patch_embeds"], params["projector"])  # [B, P, d]
    txt = cm.embed(batch["tokens"], params["lm"]["embed"])
    return jnp.concatenate([img, txt], axis=1)


def forward(params, batch, cfg: VLMConfig):
    """batch: patch_embeds [B, P, vit_dim], tokens [B, S-P] -> features."""
    return dec_lib.forward(params["lm"], None, cfg.lm, embeds=_embeds(params, batch, cfg))


def loss_fn(params, batch, cfg: VLMConfig):
    return dec_lib.loss_fn(
        params["lm"], batch, cfg.lm, embeds=_embeds(params, batch, cfg)
    )


def prefill_logits(params, batch, cfg: VLMConfig):
    return dec_lib.prefill_logits(
        params["lm"], batch, cfg.lm, embeds=_embeds(params, batch, cfg)
    )


def init_cache_shape(cfg: VLMConfig, batch: int, cache_len: int):
    return dec_lib.init_cache_shape(cfg.lm, batch, cache_len)


def cache_logical(cfg: VLMConfig):
    return dec_lib.cache_logical(cfg.lm)


def decode_step(params, cache, tokens, pos, cfg: VLMConfig):
    """Text decode against a cache whose prefix covers the image tokens."""
    return dec_lib.decode_step(params["lm"], cache, tokens, pos, cfg.lm)
