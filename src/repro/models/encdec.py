"""Whisper-style encoder-decoder backbone (audio frontend stubbed per brief:
inputs are precomputed frame embeddings at d_model; the conv frontend is
represented by a learned linear adapter). LayerNorm+bias, GELU MLP,
sinusoidal encoder positions, learned decoder positions, MHA (kv == heads).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common as cm


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    max_dec_len: int = 32_768
    dec_ratio: int = 8  # train/prefill: dec_len = enc_len // dec_ratio
    norm_eps: float = 1e-5
    remat: str = "full"
    attn_impl: str = "auto"
    sub_quadratic: bool = False

    def param_count(self) -> int:
        d, h, hd, ff = self.d_model, self.n_heads, self.head_dim, self.d_ff
        attn = d * (h + 2 * self.n_kv_heads) * hd + h * hd * d
        mlp = 2 * d * ff + ff + d
        enc = self.n_enc_layers * (attn + mlp + 4 * d)
        dec = self.n_dec_layers * (2 * attn + mlp + 6 * d)
        return int(
            enc + dec + self.vocab * d + self.max_dec_len * d + d * d + 4 * d
        )

    def active_param_count(self) -> int:
        return self.param_count()


def _ln():
    return lambda key, d: {
        "scale": jnp.ones((d,), jnp.float32),
        "bias": jnp.zeros((d,), jnp.float32),
    }


def _init_attn(ks, cfg):
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": cm.ninit(next(ks), (d, h * hd), d),
        "wk": cm.ninit(next(ks), (d, k * hd), d),
        "wv": cm.ninit(next(ks), (d, k * hd), d),
        "wo": cm.ninit(next(ks), (h * hd, d), h * hd),
    }


def _init_enc_layer(key, cfg: EncDecConfig):
    ks = cm.keygen(key)
    d = cfg.d_model
    return {
        "ln1": _ln()(next(ks), d),
        "attn": _init_attn(ks, cfg),
        "ln2": _ln()(next(ks), d),
        "w1": cm.ninit(next(ks), (d, cfg.d_ff), d),
        "b1": jnp.zeros((cfg.d_ff,), jnp.float32),
        "w2": cm.ninit(next(ks), (cfg.d_ff, d), cfg.d_ff),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def _init_dec_layer(key, cfg: EncDecConfig):
    ks = cm.keygen(key)
    p = _init_enc_layer(key, cfg)
    p["ln_cross"] = _ln()(next(ks), cfg.d_model)
    p["cross"] = _init_attn(ks, cfg)
    return p


_ATTN_SPEC = {
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),
}
_LN_SPEC = {"scale": ("embed",), "bias": ("embed",)}


def _enc_layer_logical():
    return {
        "ln1": _LN_SPEC,
        "attn": dict(_ATTN_SPEC),
        "ln2": _LN_SPEC,
        "w1": ("embed", "ffn"),
        "b1": ("ffn",),
        "w2": ("ffn", "embed"),
        "b2": ("embed",),
    }


def _dec_layer_logical():
    s = _enc_layer_logical()
    s["ln_cross"] = _LN_SPEC
    s["cross"] = dict(_ATTN_SPEC)
    return s


def init_params(key, cfg: EncDecConfig):
    ks = cm.keygen(key)

    def stack(fn, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *(fn(next(ks)) for _ in range(n)))

    return {
        "frontend": cm.ninit(next(ks), (cfg.d_model, cfg.d_model), cfg.d_model),
        "enc_layers": stack(lambda k: _init_enc_layer(k, cfg), cfg.n_enc_layers),
        "enc_norm": _ln()(next(ks), cfg.d_model),
        "embed": cm.ninit(next(ks), (cfg.vocab, cfg.d_model), cfg.d_model),
        "dec_pos": cm.ninit(next(ks), (cfg.max_dec_len, cfg.d_model), cfg.d_model),
        "dec_layers": stack(lambda k: _init_dec_layer(k, cfg), cfg.n_dec_layers),
        "dec_norm": _ln()(next(ks), cfg.d_model),
    }


def param_logical(cfg: EncDecConfig):
    def with_layers(spec):
        return jax.tree.map(
            lambda t: ("layers",) + t, spec, is_leaf=lambda x: isinstance(x, tuple)
        )

    return {
        "frontend": ("embed", "ffn"),
        "enc_layers": with_layers(_enc_layer_logical()),
        "enc_norm": _LN_SPEC,
        "embed": ("vocab", "embed"),
        "dec_pos": ("seq", "embed"),
        "dec_layers": with_layers(_dec_layer_logical()),
        "dec_norm": _LN_SPEC,
    }


def _sinusoid(s: int, d: int):
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), cm.DEFAULT_DTYPE
    )


def _mha(hx, p, cfg, *, kv_input=None, causal, impl, cache=None, pos=None):
    b, s, _ = hx.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_src = hx if kv_input is None else kv_input
    q = (hx @ p["wq"]).reshape(b, s, h, hd)
    new_cache = None
    if cache is not None and kv_input is None:  # self-attn decode
        kc, vc = cache
        k = (kv_src @ p["wk"]).reshape(b, s, kh, hd)
        v = (kv_src @ p["wv"]).reshape(b, s, kh, hd)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
        out = cm.decode_attention(
            q, kc, vc, valid_len=jnp.full((b,), pos + 1, jnp.int32)
        )
        new_cache = (kc, vc)
    elif cache is not None:  # cross-attn decode: cache holds projected enc K/V
        kc, vc = cache
        out = cm.decode_attention(q, kc, vc)
        new_cache = cache
    else:
        k = (kv_src @ p["wk"]).reshape(b, kv_src.shape[1], kh, hd)
        v = (kv_src @ p["wv"]).reshape(b, kv_src.shape[1], kh, hd)
        out = cm.attention(q, k, v, impl=impl, causal=causal)
    return out.reshape(b, s, h * hd) @ p["wo"], new_cache


def encode(params, frames: jax.Array, cfg: EncDecConfig):
    """frames: [B, S_enc, d_model] precomputed embeddings (frontend stub)."""
    x = frames.astype(cm.DEFAULT_DTYPE) @ params["frontend"]
    x = x + _sinusoid(x.shape[1], cfg.d_model)[None]

    def body(x, lp):
        hx = cm.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        a, _ = _mha(hx, lp["attn"], cfg, causal=False, impl=cfg.attn_impl)
        x = x + a
        hx = cm.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        x = x + cm.vanilla_mlp(hx, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return x, None

    body = (
        body
        if cfg.remat == "none"
        else (
            jax.checkpoint(body)
            if cfg.remat == "full"
            else jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        )
    )
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.layer_norm(x, params["enc_norm"]["scale"], params["enc_norm"]["bias"], cfg.norm_eps)


def decode_train(params, enc_out, tokens, cfg: EncDecConfig):
    x = cm.embed(tokens, params["embed"]) + params["dec_pos"][None, : tokens.shape[1]].astype(
        cm.DEFAULT_DTYPE
    )

    def body(x, lp):
        hx = cm.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        a, _ = _mha(hx, lp["attn"], cfg, causal=True, impl=cfg.attn_impl)
        x = x + a
        hx = cm.layer_norm(x, lp["ln_cross"]["scale"], lp["ln_cross"]["bias"], cfg.norm_eps)
        a, _ = _mha(hx, lp["cross"], cfg, kv_input=enc_out, causal=False, impl=cfg.attn_impl)
        x = x + a
        hx = cm.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        x = x + cm.vanilla_mlp(hx, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return x, None

    body = (
        body
        if cfg.remat == "none"
        else (
            jax.checkpoint(body)
            if cfg.remat == "full"
            else jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        )
    )
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return cm.layer_norm(x, params["dec_norm"]["scale"], params["dec_norm"]["bias"], cfg.norm_eps)


def forward(params, batch, cfg: EncDecConfig):
    """Returns (decoder FEATURES [B, dec_len, d], aux)."""
    enc_out = encode(params, batch["frames"], cfg)
    return decode_train(params, enc_out, batch["tokens"], cfg), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: EncDecConfig):
    feats, aux = forward(params, batch, cfg)
    return cm.cross_entropy_chunked(feats, params["embed"], batch["labels"]) + aux


def prefill_logits(params, batch, cfg: EncDecConfig):
    feats, _ = forward(params, batch, cfg)
    return cm.last_token_logits(feats, params["embed"])


def init_cache_shape(cfg: EncDecConfig, batch: int, cache_len: int):
    kv = jax.ShapeDtypeStruct(
        (cfg.n_dec_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim),
        cm.DEFAULT_DTYPE,
    )
    return {"self": (kv, kv), "cross": (kv, kv)}


def cache_logical(cfg: EncDecConfig):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"self": (kv, kv), "cross": (kv, kv)}


def decode_step(params, cache, tokens, pos, cfg: EncDecConfig):
    """One decoder token; cross K/V cache precomputed from the encoder."""
    b = tokens.shape[0]
    x = cm.embed(tokens, params["embed"]) + jnp.take(
        params["dec_pos"], jnp.full((1,), pos), axis=0
    )[None].astype(cm.DEFAULT_DTYPE)

    def body(x, inp):
        lp, (sk, sv), (ck, cv) = inp
        hx = cm.layer_norm(x, lp["ln1"]["scale"], lp["ln1"]["bias"], cfg.norm_eps)
        a, new_self = _mha(hx, lp["attn"], cfg, causal=True, impl="dense",
                           cache=(sk, sv), pos=pos)
        x = x + a
        hx = cm.layer_norm(x, lp["ln_cross"]["scale"], lp["ln_cross"]["bias"], cfg.norm_eps)
        a, _ = _mha(hx, lp["cross"], cfg, kv_input=x, causal=False, impl="dense",
                    cache=(ck, cv), pos=pos)
        x = x + a
        hx = cm.layer_norm(x, lp["ln2"]["scale"], lp["ln2"]["bias"], cfg.norm_eps)
        x = x + cm.vanilla_mlp(hx, lp["w1"], lp["b1"], lp["w2"], lp["b2"])
        return x, new_self

    x, new_self = jax.lax.scan(body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    x = cm.layer_norm(x, params["dec_norm"]["scale"], params["dec_norm"]["bias"], cfg.norm_eps)
    logits = cm.unembed(x, params["embed"])
    return logits, {"self": new_self, "cross": cache["cross"]}
