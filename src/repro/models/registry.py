"""Uniform model API over the architecture zoo.

Every assigned architecture is exposed as a ModelDef with the same surface:
  init_params / param_logical          — parameters + sharding
  loss(params, batch)                  — train objective (CE + aux)
  prefill(params, batch)               — full forward -> logits
  decode_step(params, cache, batch)    — one-token serve step
  init_cache_shape / cache_logical     — decode state
  make_inputs(mode, batch, seq)        — ShapeDtypeStruct stand-ins + logical specs
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import decoder as dec_lib
from repro.models import encdec as encdec_lib
from repro.models import hybrid as hybrid_lib
from repro.models import ssm as ssm_lib
from repro.models import vlm as vlm_lib

I32 = jnp.int32
BF16 = cm.DEFAULT_DTYPE


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    family: str
    cfg: Any

    def module(self):
        return {
            "decoder": dec_lib,
            "ssm": ssm_lib,
            "hybrid": hybrid_lib,
            "encdec": encdec_lib,
            "vlm": vlm_lib,
        }[self.family]

    # ----- params
    def init_params(self, key):
        return self.module().init_params(key, self.cfg)

    def param_shapes(self):
        return jax.eval_shape(lambda k: self.init_params(k), jax.random.PRNGKey(0))

    def param_logical(self):
        return self.module().param_logical(self.cfg)

    # ----- train / serve entry points
    def loss(self, params, batch):
        return self.module().loss_fn(params, batch, self.cfg)

    def prefill(self, params, batch):
        """Serving prefill: next-token logits [B, 1, V] (the [B,S,V] tensor is
        never materialized; see common.last_token_logits)."""
        return self.module().prefill_logits(params, batch, self.cfg)

    def decode_step(self, params, cache, batch):
        return self.module().decode_step(
            params, cache, batch["tokens"], batch["pos"], self.cfg
        )

    def init_cache_shape(self, batch: int, cache_len: int):
        return self.module().init_cache_shape(self.cfg, batch, cache_len)

    def cache_logical(self):
        return self.module().cache_logical(self.cfg)

    # ----- stats
    def param_count(self) -> int:
        return self.cfg.param_count()

    def active_param_count(self) -> int:
        return self.cfg.active_param_count()

    @property
    def sub_quadratic(self) -> bool:
        return bool(getattr(self.cfg, "sub_quadratic", False))

    # ----- abstract inputs (the dry-run contract: no allocation, shardable)
    def make_inputs(self, mode: str, batch: int, seq: int) -> Tuple[dict, dict]:
        """Returns (tree of ShapeDtypeStruct, tree of logical axis tuples)."""
        if self.family == "vlm":
            npatch = self.cfg.n_patches
            if mode in ("train", "prefill"):
                spec = {
                    "patch_embeds": _sds((batch, npatch, self.cfg.vit_dim), BF16),
                    "tokens": _sds((batch, seq - npatch), I32),
                }
                logical = {
                    "patch_embeds": ("batch", "seq", None),
                    "tokens": ("batch", "seq"),
                }
                if mode == "train":
                    spec["labels"] = _sds((batch, seq), I32)
                    logical["labels"] = ("batch", "seq")
                return spec, logical
        elif self.family == "encdec":
            if mode in ("train", "prefill"):
                dec_len = max(seq // self.cfg.dec_ratio, 8)
                spec = {
                    "frames": _sds((batch, seq, self.cfg.d_model), BF16),
                    "tokens": _sds((batch, dec_len), I32),
                }
                logical = {
                    "frames": ("batch", "seq", None),
                    "tokens": ("batch", "seq"),
                }
                if mode == "train":
                    spec["labels"] = _sds((batch, dec_len), I32)
                    logical["labels"] = ("batch", "seq")
                return spec, logical
        else:
            if mode in ("train", "prefill"):
                spec = {"tokens": _sds((batch, seq), I32)}
                logical = {"tokens": ("batch", "seq")}
                if mode == "train":
                    spec["labels"] = _sds((batch, seq), I32)
                    logical["labels"] = ("batch", "seq")
                return spec, logical
        # decode for every family: one token + write position
        spec = {"tokens": _sds((batch, 1), I32), "pos": _sds((), I32)}
        logical = {"tokens": ("batch", None), "pos": ()}
        return spec, logical


# --------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelDef]] = {}
_SMOKE: Dict[str, Callable[[], ModelDef]] = {}


def register(name: str, full: Callable[[], ModelDef], smoke: Callable[[], ModelDef]):
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_model(name: str, smoke: bool = False) -> ModelDef:
    _ensure_configs_loaded()
    table = _SMOKE if smoke else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> Tuple[str, ...]:
    _ensure_configs_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_configs_loaded():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import ALL_ARCHS  # noqa: F401  (import side effect)

    _LOADED = True
