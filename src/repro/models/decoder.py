"""Dense / MoE decoder-only LM family.

Covers: internlm2-20b, gemma2-27b (local+global alternation, softcaps,
post-norms), minitron-8b, gemma-2b (MQA, GeGLU, head_dim 256),
deepseek-moe-16b (dense prefix layer + 2 shared + 64 routed top-6),
qwen3-moe-30b-a3b (128 routed top-8), and the LM backbone of internvl2-2b.

Layers are stacked per attention-pattern position and consumed with
lax.scan over layer groups; remat policy applies per group.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import moe as moe_lib


@dataclasses.dataclass(frozen=True)
class DecoderConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "silu"  # "silu" | "gelu" (gated) | "relu2" (non-gated, nemotron)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    attn_pattern: Tuple[str, ...] = ("global",)  # cycled over layers
    window: int = 4096  # local-attention window
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # None -> 1/sqrt(head_dim)
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    tie_embed: bool = True
    post_norms: bool = False  # gemma2: post-attn/post-ffn RMSNorms
    moe: Optional[moe_lib.MoEConfig] = None
    n_dense_prefix: int = 0  # deepseek: leading dense-FFN layers
    dense_prefix_ff: int = 0  # their width
    remat: str = "full"  # "none" | "dots" | "full" — full: peak-HBM-safe default at 1M-token batches
    attn_impl: str = "auto"  # "auto" | "dense" | "blockwise"
    sub_quadratic: bool = False  # True only for SSM/hybrid (long_500k gate)
    kv_quant: bool = False  # int8 KV cache (decode §Perf lever; env REPRO_KV_QUANT=1)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.attn_pattern) == 0, (
            self.n_layers,
            self.attn_pattern,
        )
        return (self.n_layers - self.n_dense_prefix) // len(self.attn_pattern)

    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * d
        if self.moe:
            m = self.moe
            ffn = d * m.n_experts + 3 * m.n_experts * d * m.d_expert
            ffn += 3 * d * m.d_expert * m.n_shared
        else:
            ffn = (2 if self.act == "relu2" else 3) * d * self.d_ff
        n = self.n_layers * (attn + ffn + 2 * d)
        n += self.n_dense_prefix * (3 * d * self.dense_prefix_ff - ffn)
        n += self.vocab * d * (1 if self.tie_embed else 2) + d
        return int(n)

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        routed_all = 3 * m.n_experts * self.d_model * m.d_expert
        routed_act = 3 * (m.top_k) * self.d_model * m.d_expert
        return int(self.param_count() - self.n_layers * (routed_all - routed_act))


# ----------------------------------------------------------------- params
def _init_layer(key, cfg: DecoderConfig, kind: str):
    """kind: 'attn_global' | 'attn_local' have identical params."""
    ks = cm.keygen(key)
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "wq": cm.ninit(next(ks), (d, h * hd), d),
        "wk": cm.ninit(next(ks), (d, k * hd), d),
        "wv": cm.ninit(next(ks), (d, k * hd), d),
        "wo": cm.ninit(next(ks), (h * hd, d), h * hd),
        "ln2": jnp.zeros((d,), jnp.float32),
    }
    if cfg.post_norms:
        p["post_attn"] = jnp.zeros((d,), jnp.float32)
        p["post_ffn"] = jnp.zeros((d,), jnp.float32)
    if kind == "moe":
        p["moe"] = moe_lib.init_moe(next(ks), d, cfg.moe)
    else:
        ff = cfg.dense_prefix_ff if kind == "dense_prefix" else cfg.d_ff
        p["wg"] = cm.ninit(next(ks), (d, ff), d)
        if cfg.act != "relu2":  # relu2 MLP is non-gated (no up-projection)
            p["wu"] = cm.ninit(next(ks), (d, ff), d)
        p["wd"] = cm.ninit(next(ks), (ff, d), ff)
    return p


def _layer_logical(cfg: DecoderConfig, kind: str):
    spec = {
        "ln1": ("embed",),
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
        "ln2": ("embed",),
    }
    if cfg.post_norms:
        spec["post_attn"] = ("embed",)
        spec["post_ffn"] = ("embed",)
    if kind == "moe":
        spec["moe"] = moe_lib.moe_logical(cfg.moe)
    else:
        spec["wg"] = ("embed", "ffn")
        if cfg.act != "relu2":
            spec["wu"] = ("embed", "ffn")
        spec["wd"] = ("ffn", "embed")
    return spec


def _ffn_kind(cfg: DecoderConfig) -> str:
    return "moe" if cfg.moe else "dense"


def init_params(key, cfg: DecoderConfig):
    ks = cm.keygen(key)
    npos = len(cfg.attn_pattern)

    def stack(fn, n):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *(fn(next(ks)) for _ in range(n)))

    params = {
        "embed": cm.ninit(next(ks), (cfg.vocab, cfg.d_model), cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": tuple(
            stack(lambda kk: _init_layer(kk, cfg, _ffn_kind(cfg)), cfg.n_groups)
            for _ in range(npos)
        ),
    }
    if cfg.n_dense_prefix:
        params["prefix"] = stack(
            lambda kk: _init_layer(kk, cfg, "dense_prefix"), cfg.n_dense_prefix
        )
    if not cfg.tie_embed:
        params["unembed"] = cm.ninit(next(ks), (cfg.vocab, cfg.d_model), cfg.d_model)
    return params


def param_logical(cfg: DecoderConfig):
    def with_layers(spec):
        return jax.tree.map(lambda t: ("layers",) + t, spec, is_leaf=lambda x: isinstance(x, tuple))

    spec = {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "layers": tuple(
            with_layers(_layer_logical(cfg, _ffn_kind(cfg)))
            for _ in range(len(cfg.attn_pattern))
        ),
    }
    if cfg.n_dense_prefix:
        spec["prefix"] = with_layers(_layer_logical(cfg, "dense_prefix"))
    if not cfg.tie_embed:
        spec["unembed"] = ("vocab", "embed")
    return spec


def _kv_quant_on(cfg: DecoderConfig) -> bool:
    return cfg.kv_quant or os.environ.get("REPRO_KV_QUANT", "0") == "1"


def _write_token(entry: jax.Array, new: jax.Array, pos_idx) -> jax.Array:
    """Write one decode token [B, 1, ...] into a cache array [B, T, ...] at
    `pos_idx` — a scalar (all rows at one position) or a [B] vector
    (per-slot positions: each batch row writes its OWN cache lane at its
    own position; a continuous-batching scheduler admits requests
    mid-stream, so slots are never in lockstep)."""
    new = new.astype(entry.dtype)
    if pos_idx.ndim == 1:
        b = entry.shape[0]
        return entry.at[jnp.arange(b), pos_idx].set(new[:, 0])
    return jax.lax.dynamic_update_slice(
        entry, new, (0, pos_idx) + (0,) * (entry.ndim - 2)
    )


def _cache_write_read(entry, new: jax.Array, pos_idx):
    """Write one token into a cache entry (raw bf16 array OR int8+scale dict)
    and return (updated entry, dequantized full view for attention)."""
    if isinstance(entry, dict):  # quantized: {"q": int8, "s": f32}
        q, s = cm.kv_quantize(new)
        eq = _write_token(entry["q"], q, pos_idx)
        es = _write_token(entry["s"], s, pos_idx)
        return {"q": eq, "s": es}, cm.kv_dequantize(eq, es)
    e = _write_token(entry, new, pos_idx)
    return e, e


# ----------------------------------------------------------------- forward
def _attn(x, p, cfg: DecoderConfig, kind: str, positions, impl, cache=None, pos=None):
    b, s, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hx = cm.rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (hx @ p["wq"]).reshape(b, s, h, hd)
    k = (hx @ p["wk"]).reshape(b, s, kh, hd)
    v = (hx @ p["wv"]).reshape(b, s, kh, hd)
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    window = cfg.window if kind == "local" else None
    new_cache = None
    if cache is not None:
        kc, vc = cache  # [B, T, K, D] (raw) or {"q","s"} (int8 + scale)
        # scalar pos: all slots write one position; [B] pos: per-slot writes
        pos_idx = jnp.asarray(
            pos if pos is not None else positions[..., 0], jnp.int32
        )
        kc, k_view = _cache_write_read(kc, k, pos_idx)
        vc, v_view = _cache_write_read(vc, v, pos_idx)
        out = cm.decode_attention(
            q,
            k_view,
            v_view,
            valid_len=jnp.broadcast_to(pos_idx + 1, (b,)).astype(jnp.int32),
            window=window,
            attn_softcap=cfg.attn_softcap,
            scale=cfg.query_scale,
        )
        new_cache = (kc, vc)
    else:
        out = cm.attention(
            q,
            k,
            v,
            impl=impl,
            causal=True,
            window=window,
            attn_softcap=cfg.attn_softcap,
            scale=cfg.query_scale,
        )
    out = out.reshape(b, s, h * hd) @ p["wo"]
    if cfg.post_norms:
        out = cm.rms_norm(out, p["post_attn"], cfg.norm_eps)
    return out, new_cache


def _ffn(x, p, cfg: DecoderConfig, kind: str):
    hx = cm.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_lib.moe_ffn(hx, p["moe"], cfg.moe, cfg.act)
    elif cfg.act == "relu2":
        a = jnp.square(jax.nn.relu((hx @ p["wg"]).astype(jnp.float32))).astype(hx.dtype)
        y = a @ p["wd"]
        aux = jnp.zeros((), jnp.float32)
    else:
        y = cm.gated_mlp(hx, p["wg"], p["wu"], p["wd"], cfg.act)
        aux = jnp.zeros((), jnp.float32)
    if cfg.post_norms:
        y = cm.rms_norm(y, p["post_ffn"], cfg.norm_eps)
    return y, aux


def _block(x, p, cfg, attn_kind, ffn_kind, positions, impl, cache=None, pos=None):
    a, new_cache = _attn(x, p, cfg, attn_kind, positions, impl, cache, pos)
    x = x + a
    f, aux = _ffn(x, p, cfg, ffn_kind)
    return x + f, aux, new_cache


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def unembed_table(params, cfg: DecoderConfig):
    return params["embed"] if cfg.tie_embed else params["unembed"]


def forward(params, tokens: jax.Array, cfg: DecoderConfig, *, embeds=None):
    """Training/prefill trunk. tokens [B, S] (or embeds [B, S, d]).

    Returns (final FEATURES [B, S, d], aux_loss) — logits are produced
    downstream (chunked CE for train, last-token unembed for prefill) so the
    [B, S, V] f32 tensor is never materialized.
    """
    x = (
        cm.embed(tokens, params["embed"], cfg.embed_scale)
        if embeds is None
        else embeds.astype(cm.DEFAULT_DTYPE)
    )
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    ffn_kind = _ffn_kind(cfg)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.n_dense_prefix:

        def prefix_body(carry, lp):
            x, aux = carry
            x, a, _ = _block(x, lp, cfg, "global", "dense", positions, cfg.attn_impl)
            return (x, aux + a), None

        (x, aux0), _ = jax.lax.scan(
            _remat_wrap(prefix_body, cfg.remat), (x, aux0), params["prefix"]
        )

    def group_body(carry, group_params):
        x, aux = carry
        for pi, kind in enumerate(cfg.attn_pattern):
            x, a, _ = _block(
                x, jax.tree.map(lambda t: t, group_params[pi]), cfg, kind, ffn_kind,
                positions, cfg.attn_impl,
            )
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        _remat_wrap(group_body, cfg.remat), (x, aux0), params["layers"]
    )
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(params, batch, cfg: DecoderConfig, *, embeds=None):
    feats, aux = forward(params, batch.get("tokens"), cfg, embeds=embeds)
    return (
        cm.cross_entropy_chunked(
            feats, unembed_table(params, cfg), batch["labels"], cfg.final_softcap
        )
        + aux
    )


def prefill_logits(params, batch, cfg: DecoderConfig, *, embeds=None):
    feats, _ = forward(params, batch.get("tokens"), cfg, embeds=embeds)
    return cm.last_token_logits(feats, unembed_table(params, cfg), cfg.final_softcap)


# ------------------------------------------------------------------- decode
def _kv_entry_shape(cfg: DecoderConfig, n_stack: int, batch: int, cache_len: int):
    shape = (n_stack, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    if _kv_quant_on(cfg):
        return {
            "q": jax.ShapeDtypeStruct(shape, jnp.int8),
            "s": jax.ShapeDtypeStruct(shape[:-1] + (1,), jnp.float32),
        }
    return jax.ShapeDtypeStruct(shape, cm.DEFAULT_DTYPE)


def init_cache_shape(cfg: DecoderConfig, batch: int, cache_len: int):
    """ShapeDtypeStructs of the KV cache (per pattern position, stacked
    groups); int8+scale entries when KV quantization is on."""
    kv = _kv_entry_shape(cfg, cfg.n_groups, batch, cache_len)
    caches = tuple((kv, kv) for _ in cfg.attn_pattern)
    if cfg.n_dense_prefix:
        pkv = _kv_entry_shape(cfg, cfg.n_dense_prefix, batch, cache_len)
        return {"layers": caches, "prefix": (pkv, pkv)}
    return {"layers": caches}


def cache_logical(cfg: DecoderConfig):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    if _kv_quant_on(cfg):
        kv = {"q": kv, "s": ("layers", "batch", "seq", "kv_heads", None)}
    caches = tuple((kv, kv) for _ in cfg.attn_pattern)
    if cfg.n_dense_prefix:
        return {"layers": caches, "prefix": (kv, kv)}
    return {"layers": caches}


def decode_step(params, cache, tokens: jax.Array, pos: jax.Array, cfg: DecoderConfig,
                *, embeds=None):
    """One-token decode. tokens [B, 1]; pos [] int32 (lockstep write
    position) or [B] int32 (per-slot positions for continuous batching —
    each slot writes/attends its own cache prefix).

    Returns (logits [B, 1, V], new_cache).
    """
    x = (
        cm.embed(tokens, params["embed"], cfg.embed_scale)
        if embeds is None
        else embeds.astype(cm.DEFAULT_DTYPE)
    )
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(
        pos.reshape(-1, 1) if pos.ndim else pos, (x.shape[0], 1)
    )
    ffn_kind = _ffn_kind(cfg)
    new_cache = {}

    if cfg.n_dense_prefix:

        def prefix_body(x, inp):
            lp, (kc, vc) = inp
            x, _, nc = _block(x, lp, cfg, "global", "dense", positions, "dense",
                              cache=(kc, vc), pos=pos)
            return x, nc

        x, pc = jax.lax.scan(prefix_body, x, (params["prefix"], cache["prefix"]))
        new_cache["prefix"] = pc

    def group_body(x, inp):
        gp, gc = inp
        ncs = []
        for pi, kind in enumerate(cfg.attn_pattern):
            x, _, nc = _block(x, gp[pi], cfg, kind, ffn_kind, positions, "dense",
                              cache=gc[pi], pos=pos)
            ncs.append(nc)
        return x, tuple(ncs)

    x, lc = jax.lax.scan(group_body, x, (params["layers"], cache["layers"]))
    new_cache["layers"] = lc
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.unembed(x, unembed_table(params, cfg), cfg.final_softcap)
    return logits, new_cache
