"""Zamba2-style hybrid: Mamba-2 backbone + a SHARED attention block applied
every k layers (weight re-use across applications, separate KV per site).

Simplifications vs the HF release (recorded in DESIGN.md §4): one shared
transformer block instead of two alternating ones, and no per-application
LoRA deltas. The concat(hidden, embedding) input projection — the signature
feature of the Zamba family — is kept.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models import ssm as ssm_lib


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int  # mamba layers (54)
    d_model: int
    d_state: int
    vocab: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    shared_every: int = 6
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    chunk: int = 128
    remat: str = "full"
    attn_impl: str = "auto"
    sub_quadratic: bool = True
    tie_embed: bool = True

    @property
    def n_super(self) -> int:
        assert self.n_layers % self.shared_every == 0
        return self.n_layers // self.shared_every

    @property
    def mamba(self) -> ssm_lib.Mamba2Config:
        return ssm_lib.Mamba2Config(
            name=self.name + "-mamba",
            n_layers=self.n_layers,
            d_model=self.d_model,
            d_state=self.d_state,
            vocab=self.vocab,
            chunk=self.chunk,
        )

    def param_count(self) -> int:
        m = self.mamba.param_count() - self.vocab * self.d_model - self.d_model
        d, h, k, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        shared = (
            2 * d * d  # w_in (2d->d), w_out
            + d * d
            + d * (h + 2 * k) * hd
            + h * hd * d
            + 3 * d * self.d_ff
            + 2 * d
        )
        return int(m + shared + self.vocab * d + d)

    def active_param_count(self) -> int:
        return self.param_count()


def _init_shared(key, cfg: HybridConfig):
    ks = cm.keygen(key)
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "w_in": cm.ninit(next(ks), (2 * d, d), 2 * d),
        "ln1": jnp.zeros((d,), jnp.float32),
        "wq": cm.ninit(next(ks), (d, h * hd), d),
        "wk": cm.ninit(next(ks), (d, k * hd), d),
        "wv": cm.ninit(next(ks), (d, k * hd), d),
        "wo": cm.ninit(next(ks), (h * hd, d), h * hd),
        "ln2": jnp.zeros((d,), jnp.float32),
        "wg": cm.ninit(next(ks), (d, cfg.d_ff), d),
        "wu": cm.ninit(next(ks), (d, cfg.d_ff), d),
        "wd": cm.ninit(next(ks), (cfg.d_ff, d), cfg.d_ff),
        "w_out": cm.ninit(next(ks), (d, d), d),
    }


def _shared_logical():
    return {
        "w_in": ("embed", "ffn"),
        "ln1": ("embed",),
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
        "ln2": ("embed",),
        "wg": ("embed", "ffn"),
        "wu": ("embed", "ffn"),
        "wd": ("ffn", "embed"),
        "w_out": ("embed", "ffn"),
    }


def init_params(key, cfg: HybridConfig):
    ks = cm.keygen(key)
    mcfg = cfg.mamba
    layers = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *(ssm_lib.init_mamba_layer(next(ks), mcfg) for _ in range(cfg.n_layers)),
    )
    # reshape to [n_super, shared_every, ...]
    layers = jax.tree.map(
        lambda a: a.reshape((cfg.n_super, cfg.shared_every) + a.shape[1:]), layers
    )
    return {
        "embed": cm.ninit(next(ks), (cfg.vocab, cfg.d_model), cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": layers,
        "shared": _init_shared(next(ks), cfg),
    }


def param_logical(cfg: HybridConfig):
    mspec = jax.tree.map(
        lambda t: ("layers", None) + t,
        ssm_lib.mamba_layer_logical(cfg.mamba),
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return {
        "embed": ("vocab", "embed"),
        "final_norm": ("embed",),
        "layers": mspec,
        "shared": _shared_logical(),
    }


def _shared_block(x, x0, p, cfg: HybridConfig, positions, impl, cache=None, pos=None):
    h = jnp.concatenate([x, x0], axis=-1) @ p["w_in"]
    hx = cm.rms_norm(h, p["ln1"], cfg.norm_eps)
    b, s, _ = h.shape
    q = (hx @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (hx @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (hx @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = cm.rope(q, positions, cfg.rope_theta)
    k = cm.rope(k, positions, cfg.rope_theta)
    new_cache = None
    if cache is not None:
        from repro.models.decoder import _write_token

        kc, vc = cache
        # scalar pos: all slots write one position; [B] pos: per-slot writes
        pos_idx = jnp.asarray(
            pos if pos is not None else positions[..., 0], jnp.int32
        )
        kc = _write_token(kc, k, pos_idx)
        vc = _write_token(vc, v, pos_idx)
        a = cm.decode_attention(
            q, kc, vc,
            valid_len=jnp.broadcast_to(pos_idx + 1, (b,)).astype(jnp.int32),
        )
        new_cache = (kc, vc)
    else:
        a = cm.attention(q, k, v, impl=impl, causal=True)
    h = h + a.reshape(b, s, -1) @ p["wo"]
    h = h + cm.gated_mlp(cm.rms_norm(h, p["ln2"], cfg.norm_eps), p["wg"], p["wu"], p["wd"])
    return x + h @ p["w_out"], new_cache


def forward(params, tokens, cfg: HybridConfig):
    x0 = cm.embed(tokens, params["embed"])
    x = x0
    positions = jnp.arange(x.shape[1])[None, :]
    mcfg = cfg.mamba

    def super_body(x, lp):
        def inner(x, mp):
            return ssm_lib.mamba_block(x, mp, mcfg), None

        x, _ = jax.lax.scan(inner, x, lp)
        x, _ = _shared_block(x, x0, params["shared"], cfg, positions, cfg.attn_impl)
        return x, None

    body = (
        super_body
        if cfg.remat == "none"
        else (
            jax.checkpoint(super_body)
            if cfg.remat == "full"
            else jax.checkpoint(
                super_body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
        )
    )
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg: HybridConfig):
    feats, aux = forward(params, batch["tokens"], cfg)
    return cm.cross_entropy_chunked(feats, params["embed"], batch["labels"]) + aux


def prefill_logits(params, batch, cfg: HybridConfig):
    feats, _ = forward(params, batch["tokens"], cfg)
    return cm.last_token_logits(feats, params["embed"])


def init_cache_shape(cfg: HybridConfig, batch: int, cache_len: int):
    m = cfg.mamba
    kv = jax.ShapeDtypeStruct(
        (cfg.n_super, batch, cache_len, cfg.n_kv_heads, cfg.head_dim), cm.DEFAULT_DTYPE
    )
    return {
        "ssm": jax.ShapeDtypeStruct(
            (cfg.n_super, cfg.shared_every, batch, m.n_heads, m.d_state, m.head_dim),
            jnp.float32,
        ),
        "conv": jax.ShapeDtypeStruct(
            (cfg.n_super, cfg.shared_every, batch, m.conv_width - 1, m.conv_channels),
            cm.DEFAULT_DTYPE,
        ),
        "attn": (kv, kv),
    }


def cache_logical(cfg: HybridConfig):
    kv = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {
        "ssm": ("layers", None, "batch", "ssm_heads", "ssm_state", "head_dim"),
        "conv": ("layers", None, "batch", "conv", "ssm_heads"),
        "attn": (kv, kv),
    }


def decode_step(params, cache, tokens, pos, cfg: HybridConfig):
    x0 = cm.embed(tokens, params["embed"])
    x = x0
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(
        pos.reshape(-1, 1) if pos.ndim else pos, (x.shape[0], 1)
    )
    mcfg = cfg.mamba

    def super_body(x, inp):
        lp, ssm, conv, kv = inp

        def inner(x, minp):
            mp, s, c = minp
            x, s, c = ssm_lib.mamba_decode_block(x, mp, mcfg, s, c)
            return x, (s, c)

        x, (ssm, conv) = jax.lax.scan(inner, x, (lp, ssm, conv))
        x, new_kv = _shared_block(
            x, x0, params["shared"], cfg, positions, "dense", cache=kv, pos=pos
        )
        return x, (ssm, conv, new_kv)

    x, (ssm, conv, kv) = jax.lax.scan(
        super_body, x, (params["layers"], cache["ssm"], cache["conv"], cache["attn"])
    )
    x = cm.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return cm.unembed(x, params["embed"]), {"ssm": ssm, "conv": conv, "attn": kv}
